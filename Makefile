# BiSwift reproduction — common entry points.
#
# `test` is the tier-1 gate (real 1-device platform; multi-device coverage
# runs in subprocesses spawned by tests/test_stream_sharding.py).
# `test-multidevice` runs the WHOLE suite on a forced 4-device CPU
# platform: BISWIFT_FORCED_MULTIDEVICE activates the sharded-parity tests
# in-process instead of via the subprocess driver (which skips itself).

PY ?= python
MD_ENV = XLA_FLAGS=--xla_force_host_platform_device_count=4 \
         JAX_PLATFORMS=cpu BISWIFT_FORCED_MULTIDEVICE=4

.PHONY: lint test test-codec test-chaos test-multidevice bench \
	bench-smoke bench-chaos bench-async bench-async-smoke \
	bench-multidevice bench-kernels kernel-trajectory check-bench-errors

# first CI gate (the CI lint job runs exactly this target).  Both checks
# block: ruff check AND ruff format --check (baseline established — any
# unformatted file fails the job).  Config in ruff.toml.
lint:
	ruff check src tests benchmarks
	ruff format --check src tests benchmarks

# PYTEST_FLAGS hooks extra options in without forking the command line —
# CI's latest-jax leg passes --cov=repro --cov-report=xml here (pytest-cov
# is NOT a local requirement; the container runs this target bare)
test:
	PYTHONPATH=src $(PY) -m pytest -x -q $(PYTEST_FLAGS)

# codec/encoder regression net: golden vectors + property tests + kernels
# + the ROI gate (its bit-exactness contract rides the codec statistics)
test-codec:
	PYTHONPATH=src $(PY) -m pytest -x -q tests/test_codec.py \
		tests/test_codec_golden.py tests/test_fused_encoder.py \
		tests/test_fused_pipeline.py tests/test_kernels.py \
		tests/test_roi.py

# chaos/robustness net: fault-schedule semantics + closed-loop soak
test-chaos:
	PYTHONPATH=src $(PY) -m pytest -x -q tests/test_faults.py \
		tests/test_chaos.py tests/test_serving.py

test-multidevice:
	$(MD_ENV) PYTHONPATH=src $(PY) -m pytest -x -q

bench:
	PYTHONPATH=src $(PY) -m benchmarks.run

# tiny shapes, 1 rep: catches import/trace breakage in bench code without
# timing noise (the CI bench-smoke job)
bench-smoke:
	PYTHONPATH=src $(PY) -m benchmarks.run --smoke

# seeded chaos soak over every preset fault schedule; exits non-zero on
# any accounting leak, queue leak, or missed fps recovery (the CI
# chaos-smoke job runs this and uploads BENCH_chaos.json)
bench-chaos:
	PYTHONPATH=src $(PY) -m benchmarks.chaos --smoke

bench-multidevice:
	PYTHONPATH=src $(PY) -m benchmarks.run --multidevice

# continuous-batching throughput rows + the 64-stream churn soak; exits
# non-zero on any frame-accounting violation or queue leak (the CI
# async-soak job runs the smoke variant and uploads BENCH_async.json).
# Full mode also merges runtime_async_* rows into BENCH_pipeline.json.
bench-async:
	PYTHONPATH=src $(PY) -m benchmarks.async_serving

bench-async-smoke:
	PYTHONPATH=src $(PY) -m benchmarks.async_serving --smoke

# kernel/encoder micro-benches only (kernel_* / encoder_block_sad_* rows),
# fresh timings vs the committed BENCH_pipeline.json baseline — the fast
# way to see whether a kernel change won or regressed without the full
# `make bench` harness
bench-kernels:
	PYTHONPATH=src $(PY) -m benchmarks.kernel_trajectory --run

# compare the working-tree BENCH_pipeline.json against the committed one
# (no bench execution; the CI bench-smoke job runs this after the smoke
# harness rewrites the working-tree file).  Non-blocking on slowdowns,
# blocking on ERROR rows.
kernel-trajectory:
	PYTHONPATH=src $(PY) -m benchmarks.kernel_trajectory

# scan bench artifacts (BENCH_pipeline/chaos/async.json) for failure
# evidence — ERROR rows, soak error lists, bad chaos presets — and exit
# non-zero with a listing.  CI runs it in every bench job with the job's
# artifacts as ARGS (explicitly-named files must exist).
check-bench-errors:
	PYTHONPATH=src $(PY) -m benchmarks.check_bench_errors $(ARGS)
