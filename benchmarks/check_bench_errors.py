"""Bench-artifact ERROR gate: ``python -m benchmarks.check_bench_errors
[artifact.json ...]``.

The bench jobs each write a machine-readable artifact
(``BENCH_pipeline.json`` / ``BENCH_chaos.json`` / ``BENCH_async.json``),
and each harness already exits non-zero on ITS OWN failures — but a row
that errored in a non-smoke run, or an artifact written by a harness
that was later killed, used to land in the repo as data that nothing
re-read.  This gate closes that hole: it scans every given artifact for
failure evidence and exits non-zero with a listing, so CI fails on ERROR
rows from ALL bench artifacts rather than only on the harness's own
exit code.

Understands both payload schemas:

  * ``biswift-bench-v2`` (pipeline + async): a row whose ``derived`` or
    ``params`` starts with ``ERROR`` is a bench that stopped executing;
    a non-empty top-level ``errors`` list (the async soak's invariant
    violations) blocks too.
  * ``biswift-chaos-v1``: a non-empty ``errors`` list blocks, and each
    preset report is re-checked (``accounting_ok``/``recovery_ok`` false
    or ``queue_leaks > 0``) so a stale errors list can't mask a bad
    preset.

Files passed explicitly must exist; with no arguments the three default
artifacts are scanned and missing ones are skipped (a local tree usually
has only the committed BENCH_pipeline.json).
"""
from __future__ import annotations

import json
import os
import sys

DEFAULT_ARTIFACTS = ("BENCH_pipeline.json", "BENCH_chaos.json",
                     "BENCH_async.json")


def _check_rows(payload: dict, path: str) -> list[str]:
    problems = []
    for r in payload.get("rows", []):
        name = str(r.get("name", "?"))
        for field in ("derived", "params"):
            v = r.get(field)
            if isinstance(v, str) and v.startswith("ERROR"):
                problems.append(f"{path}: row {name}: {v[:120]}")
                break
    return problems


def _check_chaos(payload: dict, path: str) -> list[str]:
    problems = []
    for p in payload.get("presets", []):
        name = str(p.get("preset", "?"))
        if not p.get("accounting_ok", True):
            problems.append(f"{path}: preset {name}: accounting leak")
        if not p.get("recovery_ok", True):
            problems.append(f"{path}: preset {name}: fps did not recover")
        if p.get("queue_leaks", 0):
            problems.append(
                f"{path}: preset {name}: {p['queue_leaks']} queue leaks")
    return problems


def check_artifact(path: str) -> list[str]:
    """Failure evidence found in one artifact (empty list = clean)."""
    try:
        with open(path) as f:
            payload = json.load(f)
    except json.JSONDecodeError as e:
        return [f"{path}: unparseable JSON ({e})"]
    if not isinstance(payload, dict):
        return [f"{path}: unexpected payload type {type(payload).__name__}"]
    problems = _check_rows(payload, path)
    problems += _check_chaos(payload, path)
    for err in payload.get("errors", []):
        problems.append(f"{path}: {err}")
    return problems


def main() -> int:
    args = [a for a in sys.argv[1:] if not a.startswith("-")]
    explicit = bool(args)
    paths = args or list(DEFAULT_ARTIFACTS)

    problems, scanned = [], []
    for path in paths:
        if not os.path.exists(path):
            if explicit:
                problems.append(f"{path}: artifact missing")
            else:
                print(f"# {path} not present — skipped")
            continue
        scanned.append(path)
        problems.extend(check_artifact(path))

    for p in problems:
        print(f"BLOCKING: {p}")
    if problems:
        print(f"# bench-error gate FAILED: {len(problems)} problem(s) "
              f"across {len(scanned)} artifact(s)")
        return 1
    print(f"# bench-error gate clean: {len(scanned)} artifact(s) scanned")
    return 0


if __name__ == "__main__":
    sys.exit(main())
