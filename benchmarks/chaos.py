"""Chaos soak benchmark: ``PYTHONPATH=src python -m benchmarks.chaos``.

Runs the closed-loop chaos soak (``repro.serving.faults.run_soak``) under
every preset fault schedule and writes ``BENCH_chaos.json`` — the
machine-readable robustness trajectory alongside ``BENCH_pipeline.json``:
per-preset wall time, accounting verdicts, recovery verdicts (did
steady-state fps come back within K chunks of each fault clearing), and
the aggregated degradation-ladder counters (retries, demotions, forced
reuse, frame skips, evictions, hedges).

``--smoke`` / ``BISWIFT_BENCH_SMOKE=1`` (CI chaos-smoke job) shrinks the
soak to the minimum preset horizon — every fault kind still fires, every
invariant is still checked, and a violated invariant exits non-zero so
the job gates.
"""
from __future__ import annotations

import json
import os
import sys
import time

import numpy as np

BENCH_JSON = os.environ.get("BENCH_CHAOS_JSON", "BENCH_chaos.json")
SMOKE = os.environ.get("BISWIFT_BENCH_SMOKE") == "1"


def _preset_report(name: str, n_chunks: int, seed: int,
                   check_batch_equivalence: bool = False,
                   forecast: bool = False) -> dict:
    from repro.serving.faults import SoakConfig, preset_schedule, run_soak
    n_shards = 2 if name == "shard-chaos" else 1
    cfg = SoakConfig(n_chunks=n_chunks, n_streams=3, chunk_frames=3,
                     n_shards=n_shards, seed=seed)
    sched = preset_schedule(name, n_chunks=n_chunks, n_streams=3,
                            n_shards=n_shards, seed=seed)
    fc = None
    if forecast:
        from repro.core.forecast import ForecastConfig
        fc = ForecastConfig()
    # the continuous-batching path is the serving mode under test; one
    # preset re-runs chunk-sequentially to prove control-equivalence
    rep = run_soak(cfg, sched, batch_submit=True, forecast=fc)
    if check_batch_equivalence:
        sync = run_soak(cfg, sched, batch_submit=False)
        if rep["stream_stats"] != sync["stream_stats"] or \
                not np.array_equal(rep["fps_norm"], sync["fps_norm"]):
            raise AssertionError(
                "batch_submit soak diverged from chunk-sequential soak")
    recovery = rep["recovery"] + rep["recovery_infer"]
    checked = [r for r in recovery if r["ok"] is not None]
    ladder = {k: int(sum(s[k] for s in rep["stream_stats"].values()))
              for k in ("retries", "deadline_misses", "demote_events",
                        "promote_events", "reuse_fallback_chunks",
                        "frames_skipped", "chunks_lost", "chunks_corrupt",
                        "chunks_stalled")}
    return {
        "preset": name + ("-forecast" if forecast else ""),
        "forecast": forecast,
        "forecast_holds": int(rep["forecast_holds"]),
        "batch_submit": True,
        "n_chunks": n_chunks,
        "n_shards": n_shards,
        "wall_s": round(rep["wall_s"], 3),
        "accounting_ok": bool(rep["accounting_ok"]),
        "queue_leaks": len(rep["queue_leaks"]),
        "recovery_checked": len(checked),
        "recovery_ok": all(r["ok"] for r in checked),
        "mean_fps_norm": round(float(np.mean(rep["fps_norm"])), 2),
        "mean_infer_norm": round(float(np.mean(rep["infer_norm"])), 2),
        "evictions": sum(a == "evict" for _, a, _ in rep["fault_log"]),
        "recoveries": sum(a == "recover" for _, a, _ in rep["fault_log"]),
        "hedged_dispatches": int(rep["hedged_dispatches"]),
        "ladder": ladder,
    }


def main() -> None:
    global SMOKE
    if "--smoke" in sys.argv:
        SMOKE = True
        os.environ["BISWIFT_BENCH_SMOKE"] = "1"
    from repro.serving.faults import PRESETS
    n_chunks = 12 if SMOKE else 24
    t0 = time.time()
    reports, errors = [], []
    print("preset,wall_s,accounting_ok,recovery_ok,evictions,hedges")
    for name in PRESETS:
        try:
            rep = _preset_report(
                name, n_chunks, seed=7,
                check_batch_equivalence=(name == "stream-churn"))
        except Exception as e:  # keep the harness robust, gate on smoke
            errors.append(f"{name}: {type(e).__name__}: {e}")
            print(f"{name},-1,ERROR,ERROR,0,0")
            continue
        reports.append(rep)
        print(f"{rep['preset']},{rep['wall_s']},{rep['accounting_ok']},"
              f"{rep['recovery_ok']},{rep['evictions']},"
              f"{rep['hedged_dispatches']}")
        if not rep["accounting_ok"]:
            errors.append(f"{name}: accounting leak")
        if rep["queue_leaks"]:
            errors.append(f"{name}: {rep['queue_leaks']} queue leaks")
        if not rep["recovery_ok"]:
            errors.append(f"{name}: fps did not recover within K chunks")
    # bench-adaptive: predictive admission vs the reactive ladder under
    # bandwidth collapse — the forecast gate must strictly lower deadline
    # misses (both runs share the seeded schedule, so this is a
    # deterministic comparison, not a flaky race)
    try:
        fc_rep = _preset_report("bw-collapse", n_chunks, seed=7,
                                forecast=True)
        reactive = next((r for r in reports if r["preset"] == "bw-collapse"),
                        None)
        miss_r = reactive["ladder"]["deadline_misses"] if reactive else None
        miss_f = fc_rep["ladder"]["deadline_misses"]
        fc_rep["deadline_misses_vs_reactive"] = f"{miss_f}/{miss_r}"
        reports.append(fc_rep)
        print(f"{fc_rep['preset']},{fc_rep['wall_s']},"
              f"{fc_rep['accounting_ok']},{fc_rep['recovery_ok']},"
              f"misses:{miss_f}/{miss_r},holds:{fc_rep['forecast_holds']}")
        if not fc_rep["accounting_ok"]:
            errors.append("bw-collapse-forecast: accounting leak")
        if fc_rep["queue_leaks"]:
            errors.append(
                f"bw-collapse-forecast: {fc_rep['queue_leaks']} queue leaks")
        if not fc_rep["recovery_ok"]:
            errors.append("bw-collapse-forecast: no recovery within K chunks")
        if miss_r is not None and miss_r > 0 and miss_f >= miss_r:
            errors.append(
                f"bw-collapse-forecast: forecast did not lower deadline "
                f"misses ({miss_f} vs reactive {miss_r})")
    except Exception as e:
        errors.append(f"bw-collapse-forecast: {type(e).__name__}: {e}")
    payload = {
        "schema": "biswift-chaos-v1",
        "smoke": SMOKE,
        "wall_s": round(time.time() - t0, 2),
        "presets": reports,
        "errors": errors,
    }
    with open(BENCH_JSON, "w") as f:
        json.dump(payload, f, indent=1)
    print(f"# wrote {BENCH_JSON} ({len(reports)} presets, "
          f"{time.time() - t0:.1f}s)")
    if errors:
        sys.exit("# chaos soak FAILED: " + "; ".join(errors))


if __name__ == "__main__":
    main()
