"""Sharded-vs-single-device stream throughput rows.

Run directly under a forced multi-device CPU platform:

    XLA_FLAGS=--xla_force_host_platform_device_count=4 \
        PYTHONPATH=src python -m benchmarks.stream_shard

Prints a single JSON payload (list of [name, us_per_call, derived] rows)
as the LAST stdout line.  ``benchmarks/run.py`` invokes this module as a
subprocess — its own process has already committed jax to the real
1-device platform, and XLA only honours the device-count flag before the
first jax import.
"""
from __future__ import annotations

import json

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.run import _timeit

N_STREAMS = 8


def build_inputs(n_streams):
    from repro.core.hybrid_encoder import encode_hybrid
    from repro.models import detection as D
    from repro.sim.video_source import StreamConfig, generate_chunk

    packs = []
    for s in range(n_streams):
        frames, _, _ = generate_chunk(
            jax.random.PRNGKey(s),
            StreamConfig(height=64, width=96, n_objects=3), 0, 4)
        packs.append(encode_hybrid(np.asarray(frames), 8000.0, 0.05, 0.1))
    det_cfg = D.TinyDetectorConfig()
    params = D.init(jax.random.PRNGKey(1), det_cfg)
    T = packs[0].types.shape[0]
    n_cells_gt = 8
    args = dict(
        enc=jax.tree.map(lambda *xs: jnp.stack(xs),
                         *[p.video for p in packs]),
        types=jnp.stack([jnp.asarray(p.types) for p in packs]),
        anchor_hd=jnp.stack([jnp.asarray(p.anchor_hd) for p in packs]),
        gt_boxes=jnp.zeros((n_streams, T, n_cells_gt, 4), jnp.float32),
        gt_valid=jnp.zeros((n_streams, T, n_cells_gt), jnp.bool_),
        bw_kbps=jnp.full((n_streams,), 8000.0, jnp.float32),
        queue_delay=jnp.zeros((n_streams,), jnp.float32),
        total_bits=jnp.asarray([p.total_bits for p in packs], jnp.float32),
    )
    return args, params, det_cfg, T


def main():
    from repro.core.hybrid_decoder import decode_execute_batched
    from repro.distributed.sharding import SINGLE_POD_RULES
    from repro.distributed.stream_sharding import (shard_streams,
                                                   stream_shard_count)

    n_dev = len(jax.devices())
    args, params, det_cfg, T = build_inputs(N_STREAMS)
    a = args

    def single():
        return decode_execute_batched(
            a["enc"], a["types"], a["anchor_hd"], a["gt_boxes"],
            a["gt_valid"], params, det_cfg, bw_kbps=a["bw_kbps"],
            queue_delay=a["queue_delay"], total_bits=a["total_bits"])["f1"]

    us_single = _timeit(single)

    mesh = jax.make_mesh((n_dev,), ("data",))
    run = shard_streams(mesh, SINGLE_POD_RULES, det_cfg=det_cfg)
    n_shards = stream_shard_count(mesh, SINGLE_POD_RULES)

    def sharded():
        return run(a["enc"], a["types"], a["anchor_hd"], a["gt_boxes"],
                   a["gt_valid"], params, bw_kbps=a["bw_kbps"],
                   queue_delay=a["queue_delay"],
                   total_bits=a["total_bits"])["f1"]

    us_sharded = _timeit(sharded)
    fps = N_STREAMS * T / (us_sharded / 1e6)
    rows = [
        [f"stream_batched_single_dev_{N_STREAMS}streams", us_single,
         f"oracle_{n_dev}devhost"],
        [f"stream_sharded_{n_shards}shard_{N_STREAMS}streams", us_sharded,
         f"fps:{fps:.0f};vs_single:{us_single / max(us_sharded, 1e-9):.2f}x"],
    ]
    print(json.dumps(rows))


if __name__ == "__main__":
    main()
