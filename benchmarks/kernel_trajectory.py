"""Kernel perf trajectory: ``PYTHONPATH=src python -m benchmarks.kernel_trajectory``.

Renders a speedup-vs-committed-baseline table for the kernel-facing bench
rows (``kernel_*`` and ``encoder_block_sad_*``) so kernel wins and
regressions are visible per PR instead of rotting silently inside
``BENCH_pipeline.json`` (the way the original ``motion_sad`` kernel fell
to 0.7× vs its oracle without anything flagging it).

Modes:

  * default — compare the working-tree ``BENCH_pipeline.json`` against
    the committed baseline (``git show HEAD:BENCH_pipeline.json``).  This
    is what the CI bench-smoke job runs after the smoke harness rewrites
    the working-tree file.
  * ``--run`` (``make bench-kernels``) — execute just the kernel/encoder
    micro-benches (``kernel_microbench``, ``realistic_shape_bench``,
    ``encoder_bench``) in-process and compare the fresh timings against
    the committed baseline.  Much faster than the full harness.

Exit policy: the summary is NON-blocking — slowdowns print a ``REGR``
marker but exit 0 (CI timing noise must not gate merges).  ERROR rows in
the current data exit non-zero: a bench that stopped executing is
breakage, not noise.  Smoke-run timings are labelled as such since their
magnitudes are meaningless (1 rep, no warmup, tiny shapes).
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

BENCH_JSON = os.environ.get("BENCH_JSON", "BENCH_pipeline.json")
PREFIXES = ("kernel_", "encoder_block_sad_")
# current/baseline ratio below this prints a REGR marker (non-blocking)
REGRESSION_RATIO = 0.8


def _is_kernel_row(name: str) -> bool:
    return name.startswith(PREFIXES)


def _rows_by_name(payload: dict) -> dict:
    out = {}
    for r in payload.get("rows", []):
        if _is_kernel_row(str(r.get("name", ""))):
            out[r["name"]] = r
    return out


def _load_baseline(ref: str):
    """Committed BENCH payload, or None when unavailable (fresh clone
    without the artifact, or git missing in the environment)."""
    if ref.startswith("git:"):
        try:
            r = subprocess.run(
                ["git", "show", f"{ref[4:]}:{os.path.basename(BENCH_JSON)}"],
                capture_output=True, text=True, timeout=60)
        except (OSError, subprocess.TimeoutExpired):
            return None
        if r.returncode != 0:
            return None
        try:
            return json.loads(r.stdout)
        except json.JSONDecodeError:
            return None
    if not os.path.exists(ref):
        return None
    with open(ref) as f:
        return json.load(f)


def _fresh_rows() -> dict:
    """--run mode: execute only the kernel/encoder benches in-process."""
    from benchmarks.encoder import encoder_bench
    from benchmarks.run import (bench_row, kernel_microbench,
                                realistic_shape_bench)
    rows = []
    for fn in (kernel_microbench, realistic_shape_bench, encoder_bench):
        try:
            rows.extend(fn())
        except Exception as e:  # mirror benchmarks.run robustness
            rows.append((fn.__name__, -1.0, f"ERROR:{type(e).__name__}:{e}"))
    payload = {"rows": [bench_row(n, u, d) for n, u, d in rows],
               "smoke": os.environ.get("BISWIFT_BENCH_SMOKE") == "1"}
    return payload


def render(current: dict, baseline: dict | None) -> int:
    cur = _rows_by_name(current)
    base = _rows_by_name(baseline) if baseline else {}
    smoke = bool(current.get("smoke"))

    title = "kernel perf trajectory (current vs committed baseline)"
    if smoke:
        title += "  [SMOKE timings — informational only]"
    print(title)
    hdr = (f"{'row':44s} {'base_us':>10s} {'cur_us':>10s} "
           f"{'vs_base':>8s}  derived")
    print(hdr)
    print("-" * len(hdr))

    errors = []
    n_regr = 0
    for name in sorted(set(cur) | set(base)):
        c, b = cur.get(name), base.get(name)
        cu = c.get("us_per_call") if c else None
        bu = b.get("us_per_call") if b else None
        derived = str(c.get("derived", "")) if c else "(row removed)"
        if derived.startswith("ERROR"):
            errors.append(name)
        if cu is not None and cu >= 0 and bu and bu > 0:
            ratio = bu / cu
            mark = "  REGR" if (ratio < REGRESSION_RATIO and not smoke) \
                else ""
            n_regr += bool(mark)
            print(f"{name:44s} {bu:10.1f} {cu:10.1f} {ratio:7.2f}x "
                  f" {derived}{mark}")
        else:
            bs = f"{bu:.1f}" if isinstance(bu, (int, float)) else "-"
            cs = f"{cu:.1f}" if isinstance(cu, (int, float)) else "-"
            print(f"{name:44s} {bs:>10s} {cs:>10s} {'-':>8s}  {derived}")

    if baseline is None:
        print("# no committed baseline found — ratios omitted")
    if n_regr:
        print(f"# {n_regr} row(s) slower than {REGRESSION_RATIO:.2f}x "
              "baseline (non-blocking; timing noise does not gate merges)")
    if errors:
        print(f"# BLOCKING: {len(errors)} kernel bench row(s) errored: "
              f"{', '.join(errors)}")
        return 1
    return 0


def main() -> int:
    args = sys.argv[1:]
    baseline_ref = "git:HEAD"
    current_path = BENCH_JSON
    if "--baseline" in args:
        baseline_ref = args[args.index("--baseline") + 1]
    if "--current" in args:
        current_path = args[args.index("--current") + 1]

    if "--run" in args:
        current = _fresh_rows()
    else:
        if not os.path.exists(current_path):
            print(f"# {current_path} not found — run "
                  "`python -m benchmarks.run` (or --run) first")
            return 1
        with open(current_path) as f:
            current = json.load(f)
    return render(current, _load_baseline(baseline_ref))


if __name__ == "__main__":
    sys.exit(main())
