"""One benchmark per paper table/figure (CSV to stdout + dict returns).

fig8   — transfer PSNR gain + reuse time savings (paper Fig. 8)
fig11  — end-to-end throughput / bandwidth / accuracy / latency vs
         baselines (paper Fig. 11)
fig12  — accuracy distribution + fairness percentiles (paper Fig. 12)
fig13  — component ablations: hybrid-encoder off, even-bandwidth
         (paper Fig. 13a) + latency breakdown at 8/16 Mbps (Fig. 13b)
fig14  — accuracy/throughput across video types (paper Fig. 14)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.baselines.policies import BASELINES, COST_INFER, COST_REUSE, \
    run_biswift
from repro.core.fairness import jain_index
from repro.sim.network import even_allocation
from repro.sim.video_source import StreamConfig, generate_chunk, \
    paper_stream_mix

KEY = jax.random.PRNGKey(0)
GPU_FPS = 120.0          # edge DNN budget (frames/s), RTX-3070-calibrated
FPS = 30.0


def _mix(n, T=8):
    mix = paper_stream_mix(n, 64, 96)
    return [(sc, *map(np.asarray, generate_chunk(KEY, sc, 0, T)))
            for sc in mix]


# ---------------------------------------------------------------- fig 8
def fig8_transfer_reuse():
    from repro.codec.motion import block_sad
    from repro.codec.rate_model import downscale, upscale_nearest
    from repro.core.quality_transfer import transfer_frame, \
        transfer_gain_psnr
    rows = []
    for scale in (0.25, 1 / 3, 0.5):
        frames, _, _ = generate_chunk(KEY, StreamConfig(height=64, width=96,
                                                        n_objects=4), 0, 2)
        raw, anchor = frames[1], frames[0]
        lr_up = upscale_nearest(downscale(frames[1:2], scale), 64, 96)[0]
        mv, _ = block_sad(raw, anchor, radius=8)
        enhanced = transfer_frame(anchor, mv, jnp.zeros_like(raw))
        gain = float(transfer_gain_psnr(raw, lr_up, enhanced))
        rows.append(("fig8a_transfer_gain_db", f"scale={scale:.2f}", gain))
    # reuse acceleration: frames/s headroom vs per-frame inference
    rows.append(("fig8b_reuse_speedup", "frames",
                 COST_INFER / COST_REUSE))
    return rows


# ---------------------------------------------------------------- fig 11
def fig11_end_to_end(n_streams=4, total_bw_kbps=16000.0):
    data = _mix(n_streams, T=30)          # paper: 1 s chunks @ 30 fps
    rows = []
    for name, fn in BASELINES.items():
        alloc = even_allocation(total_bw_kbps, n_streams)
        rs = [fn(f, b, v, alloc[i], sc)
              for i, (sc, f, b, v) in enumerate(data)]
        acc = float(np.mean([r["accuracy"] for r in rs]))
        lat = float(np.mean([r["latency"] for r in rs]))
        bits = float(np.sum([r["bits"] for r in rs]))
        # throughput: max streams whose per-chunk GPU time fits real time
        # (reuse + DRL run on CPU per paper §VII; SR cost caps
        # AccDecoder/NeuroScaler* at 1 stream — Fig. 11a)
        chunk_s = 30 / FPS
        t_gpu = float(np.mean([r["t_gpu"] for r in rs]))
        max_streams = max(int(chunk_s / max(t_gpu, 1e-9)), 1)
        rows.append((f"fig11_{name}", "acc;lat_s;kbits;max_streams",
                     f"{acc:.3f};{lat:.3f};{bits / 1e3:.0f};{max_streams}"))
    return rows


# ---------------------------------------------------------------- fig 12
def fig12_fairness(n_streams=6, total_bw_kbps=7200.0):
    data = _mix(n_streams)
    rows = []
    for policy, alloc in (
        ("even", even_allocation(total_bw_kbps, n_streams)),
        ("aware", _aware_allocation(data, total_bw_kbps)),
    ):
        accs = [run_biswift(f, b, v, alloc[i], sc)["accuracy"]
                for i, (sc, f, b, v) in enumerate(data)]
        accs = np.sort(np.asarray(accs))
        p50 = float(np.percentile(accs, 50))
        p75 = float(np.percentile(accs, 75))
        rows.append((f"fig12_{policy}",
                     "min;mean;p75-p50;jain",
                     f"{accs.min():.3f};{accs.mean():.3f};"
                     f"{p75 - p50:.3f};{float(jain_index(jnp.asarray(accs))):.3f}"))
    return rows


def _aware_allocation(data, total):
    """Analytics-aware heuristic: weight by object density (the
    controller's learned behavior, paper Fig. 3d: dense-small streams are
    fragile and need bandwidth; large-sparse ones are robust at 270p)."""
    dens = np.asarray([v[0].sum() / max(b[0, :, 2:].mean(), 1.0)
                       for (_, _, b, v) in data], np.float64)
    w = 0.25 + 0.75 * dens / dens.max()
    return total * w / w.sum()


# ---------------------------------------------------------------- fig 13
def fig13_ablations(n_streams=4, total_bw_kbps=5000.0):
    data = _mix(n_streams)
    alloc = even_allocation(total_bw_kbps, n_streams)
    rows = []
    full = [run_biswift(f, b, v, alloc[i], sc)
            for i, (sc, f, b, v) in enumerate(data)]
    # ablation 1: no adaptive classification -> fixed sparse anchors and
    # no transfer pipeline (everything else reuses)
    uniform = [run_biswift(f, b, v, alloc[i], sc, tr1=1e9, tr2=1e9)
               for i, (sc, f, b, v) in enumerate(data)]
    # ablation 2: even vs aware allocation
    aware = _aware_allocation(data, total_bw_kbps)
    aware_res = [run_biswift(f, b, v, aware[i], sc)
                 for i, (sc, f, b, v) in enumerate(data)]
    acc = lambda rs: float(np.mean([r["accuracy"] for r in rs]))
    rows.append(("fig13a_full", "mean_acc", f"{acc(full):.3f}"))
    rows.append(("fig13a_no_hybrid_encoder", "mean_acc(delta)",
                 f"{acc(uniform):.3f}({acc(uniform) - acc(full):+.3f})"))
    rows.append(("fig13a_aware_vs_even", "min_acc_even;min_acc_aware",
                 f"{min(r['accuracy'] for r in full):.3f};"
                 f"{min(r['accuracy'] for r in aware_res):.3f}"))
    for bw_mbps in (8.0, 16.0):
        alloc2 = even_allocation(bw_mbps * 1000, n_streams)
        rs = [run_biswift(f, b, v, alloc2[i], sc)
              for i, (sc, f, b, v) in enumerate(data)]
        tt = float(np.mean([r["t_trans"] for r in rs]))
        tc = float(np.mean([r["t_comp"] for r in rs]))
        rows.append((f"fig13b_breakdown_{bw_mbps:.0f}mbps",
                     "t_trans_s;t_comp_s;trans_share",
                     f"{tt:.3f};{tc:.3f};{tt / (tt + tc):.2f}"))
    return rows


# ---------------------------------------------------------------- fig 14
def fig14_video_types(total_bw_kbps=12000.0):
    rows = []
    for kind, cfg in (
        ("highway", StreamConfig(name="highway", height=64, width=96,
                                 n_objects=4, min_size=16, max_size=30,
                                 speed=3.0, seed=11)),
        ("crossroad", StreamConfig(name="crossroad", height=64, width=96,
                                   n_objects=10, min_size=8, max_size=16,
                                   speed=1.5, seed=12)),
    ):
        frames, boxes, valid = map(np.asarray,
                                   generate_chunk(KEY, cfg, 0, 8))
        for name, fn in BASELINES.items():
            r = fn(frames, boxes, valid, total_bw_kbps / 4, cfg)
            rows.append((f"fig14_{kind}_{name}", "acc;n_infer",
                         f"{r['accuracy']:.3f};{r['n_infer']}"))
    return rows


ALL = {
    "fig8": fig8_transfer_reuse,
    "fig11": fig11_end_to_end,
    "fig12": fig12_fairness,
    "fig13": fig13_ablations,
    "fig14": fig14_video_types,
}
