"""Benchmark harness: ``PYTHONPATH=src python -m benchmarks.run``.

One function per paper table/figure (benchmarks/figures.py) + kernel
micro-benchmarks (toy and 720p-shaped) + the fused-vs-legacy chunk
pipeline comparison + fused round-trip rows + multi-stream runtime
throughput + the roofline summary from the dry-run artifacts.  Prints
``name,us_per_call,derived`` CSV rows and mirrors every row into
``BENCH_pipeline.json`` so the perf trajectory is machine-readable across
PRs.

``--smoke`` (CI bench-smoke job): tiny shapes, 1 rep, no warmup — every
bench still imports, traces and executes, so import/trace breakage in
bench code is caught without timing noise.  Timings from a smoke run are
meaningless; the JSON payload is tagged ``"smoke": true``.
"""
from __future__ import annotations

import json
import os
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

BENCH_JSON = os.environ.get("BENCH_JSON", "BENCH_pipeline.json")

# set by --smoke (or inherited by subprocess children via the env var):
# 1 rep, no warmup, tiny shapes in the shape-parameterized benches
SMOKE = os.environ.get("BISWIFT_BENCH_SMOKE") == "1"


def bench_row(name, us, derived) -> dict:
    """Serialize one (name, us-or-label, derived) bench tuple as a
    schema-v2 row: ``us_per_call`` stays numeric (or null), string labels
    move to ``params``."""
    if isinstance(us, (int, float)) and not isinstance(us, bool):
        return {"name": name, "us_per_call": float(us), "params": None,
                "derived": str(derived)}
    return {"name": name, "us_per_call": None, "params": str(us),
            "derived": str(derived)}


def migrate_rows_v2(rows: list[dict]) -> list[dict]:
    """Upgrade v1 row dicts to v2 (see ``bench_row``).  v1 rows abused
    ``us_per_call`` for label strings; v2 rows pass through unchanged."""
    out = []
    for r in rows:
        us = r.get("us_per_call")
        if us is None and r.get("params") is not None:
            us = r["params"]          # already v2
        out.append(bench_row(r["name"], us, r.get("derived", "")))
    return out


def _timeit(fn, *args, n=3, warmup=1):
    if SMOKE:
        n, warmup = 1, 0
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(n):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / n * 1e6


def kernel_microbench():
    rows = []
    from repro.kernels.flash_attention.ops import flash_attention
    from repro.kernels.qtransfer.ops import qtransfer
    from repro.kernels.blockdct.ops import blockdct_quantize
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (1, 256, 4, 64), jnp.float32)
    k = jax.random.normal(ks[1], (1, 256, 2, 64), jnp.float32)
    v = jax.random.normal(ks[2], (1, 256, 2, 64), jnp.float32)
    us = _timeit(lambda: flash_attention(q, k, v, interpret=True), n=2)
    rows.append(("kernel_flash_attention_interp", us, "B1S256H4D64"))
    anchor = jax.random.uniform(ks[0], (64, 96), jnp.float32) * 255
    mv = jax.random.randint(ks[1], (4, 6, 2), -8, 9, jnp.int32)
    resid = jnp.zeros((64, 96), jnp.float32)
    us = _timeit(lambda: qtransfer(anchor, mv, resid, interpret=True), n=2)
    rows.append(("kernel_qtransfer_interp", us, "64x96"))
    blocks = jax.random.uniform(ks[2], (256, 8, 8), jnp.float32) * 255 - 128
    us = _timeit(lambda: blockdct_quantize(blocks, 50.0, interpret=True),
                 n=2)
    rows.append(("kernel_blockdct_interp", us, "256blocks"))
    from repro.codec.motion import block_sad, block_sad_scan
    from repro.kernels.motion_sad.ops import motion_sad
    cur = jax.random.uniform(ks[0], (64, 96), jnp.float32) * 255
    ref = jnp.roll(cur, (2, -3), (0, 1))
    # oracle-relative columns: the kernel-trajectory CI summary tracks
    # vs_scan / vs_fallback per PR so kernel regressions can't hide
    scan = jax.jit(lambda c, r: block_sad_scan(c, r, 8))
    us_scan = _timeit(lambda: scan(cur, ref), n=2)
    fb = jax.jit(lambda c, r: block_sad(c, r, 8))
    us_fb = _timeit(lambda: fb(cur, ref), n=2)
    us = _timeit(lambda: motion_sad(cur, ref, radius=8, interpret=True), n=2)
    rows.append(("kernel_motion_sad_interp", us,
                 f"64x96r8;vs_scan:{us_scan / max(us, 1e-9):.2f}x;"
                 f"vs_fallback:{us_fb / max(us, 1e-9):.2f}x"))
    us_d = _timeit(lambda: motion_sad(cur, ref, radius=8, interpret=True,
                                      search="diamond"), n=2)
    rows.append(("kernel_motion_sad_diamond_interp", us_d,
                 f"64x96r8;evals:37/289;"
                 f"vs_exhaustive_kernel:{us / max(us_d, 1e-9):.2f}x"))
    return rows


def realistic_shape_bench():
    """720p-shaped kernel rows — the resolution the paper's edge actually
    serves, so regressions on real tile counts (45×80 macroblocks) show up
    even though CI runs interpret mode on CPU.  (--smoke shrinks to 144p:
    same code paths, tiny tile counts.)"""
    from repro.codec.motion import block_sad_scan
    from repro.kernels.motion_sad.ops import motion_sad
    from repro.kernels.qtransfer.ops import qtransfer
    ks = jax.random.split(jax.random.PRNGKey(7), 2)
    H, W = (144, 256) if SMOKE else (720, 1280)
    tag = "144p" if SMOKE else "720p"
    cur = jax.random.uniform(ks[0], (H, W), jnp.float32) * 255
    ref = jnp.roll(cur, (3, -2), (0, 1))
    rows = []
    scan = jax.jit(lambda c, r: block_sad_scan(c, r, 8))
    us_scan = _timeit(lambda: scan(cur, ref), n=2)
    rows.append((f"motion_sad_scan_{tag}", us_scan, "r8scan289cand"))
    us = _timeit(lambda: motion_sad(cur, ref, radius=8, interpret=True), n=2)
    rows.append((f"kernel_motion_sad_interp_{tag}", us,
                 f"r8band;vs_scan:{us_scan / max(us, 1e-9):.2f}x"))
    us_d = _timeit(lambda: motion_sad(cur, ref, radius=8, interpret=True,
                                      search="diamond"), n=2)
    rows.append((f"kernel_motion_sad_diamond_interp_{tag}", us_d,
                 f"r8;evals:37/289;"
                 f"vs_exhaustive_kernel:{us / max(us_d, 1e-9):.2f}x"))
    # static diamond dispatch at a realistic block count (720p = 3600
    # macroblocks): on CPU CI this routes to the traced descent (interpret
    # mode loses at every shape), on TPU to the kernel — either way the
    # row must track the fallback row (vs_fallback ~1.0x or better).  The
    # small-canvas twin is encoder_block_sad_diamond_dispatch_64x96.
    from repro.codec.motion import (block_sad, block_sad_diamond,
                                    diamond_kernel_profitable)
    routed = "kernel" if diamond_kernel_profitable(H, W) else "fallback"
    fb_dia = jax.jit(lambda c, r: block_sad_diamond(c, r, 8))
    us_fbd = _timeit(lambda: fb_dia(cur, ref), n=2)
    disp = jax.jit(lambda c, r: block_sad(c, r, 8, use_kernel=True,
                                          search="diamond"))
    us_disp = _timeit(lambda: disp(cur, ref), n=2)
    rows.append((f"motion_sad_diamond_dispatch_{tag}", us_disp,
                 f"routed:{routed};"
                 f"vs_fallback:{us_fbd / max(us_disp, 1e-9):.2f}x"))
    mv = jax.random.randint(ks[1], (H // 16, W // 16, 2), -8, 9, jnp.int32)
    resid = jnp.zeros((H, W), jnp.float32)
    us = _timeit(lambda: qtransfer(cur, mv, resid, interpret=True), n=2)
    rows.append((f"kernel_qtransfer_interp_{tag}", us,
                 f"{H // 16}x{W // 16}blocks"))
    return rows


def pipeline_bench():
    """Fused single-jit chunk execution vs the legacy host-orchestrated
    path on the SAME 4-frame 64×96 chunk, plus 1..N-stream EdgeRuntime
    throughput (one padded detector dispatch per chunk)."""
    from repro.core.hybrid_decoder import (decode_and_execute,
                                           decode_execute_chunk)
    from repro.core.hybrid_encoder import encode_hybrid
    from repro.models import detection as D
    from repro.serving.runtime import EdgeRuntime
    from repro.serving.scheduler import ServingConfig
    from repro.sim.video_source import StreamConfig, generate_chunk

    frames, gtb, gtv = generate_chunk(
        jax.random.PRNGKey(0), StreamConfig(height=64, width=96,
                                            n_objects=3), 0, 4)
    det_cfg = D.TinyDetectorConfig()
    params = D.init(jax.random.PRNGKey(1), det_cfg)
    packet = encode_hybrid(np.asarray(frames), 8000.0, 0.05, 0.1)

    us_legacy = _timeit(
        lambda: decode_and_execute(packet, params, det_cfg, gtb, gtv,
                                   bw_kbps=8000.0), n=5)
    types = jnp.asarray(packet.types)
    ahd = jnp.asarray(packet.anchor_hd)
    gb, gv = jnp.asarray(gtb), jnp.asarray(gtv)
    us_fused = _timeit(
        lambda: decode_execute_chunk(packet.video, types, ahd, gb, gv,
                                     params, det_cfg, bw_kbps=8000.0,
                                     total_bits=packet.total_bits)["boxes"],
        n=5)
    rows = [
        ("pipeline_legacy_per_frame_4f_64x96", us_legacy, "host-orchestrated"),
        ("pipeline_fused_jit_4f_64x96", us_fused,
         f"speedup:{us_legacy / max(us_fused, 1e-9):.1f}x"),
    ]

    for n_streams in (1, 2, 4):
        rt = EdgeRuntime(ServingConfig(n_streams=n_streams), params, det_cfg)

        def run_all():
            for s in range(n_streams):
                rt.process_chunk(s, 0, packet)

        us = _timeit(run_all, n=3)
        fps = n_streams * packet.types.shape[0] / (us / 1e6)
        rows.append((f"runtime_process_chunk_{n_streams}stream", us,
                     f"fps:{fps:.0f}"))
    return rows


def codec_bench():
    from repro.codec.video_codec import VideoCodecConfig, encode_chunk
    from repro.sim.video_source import StreamConfig, generate_chunk
    frames, _, _ = generate_chunk(jax.random.PRNGKey(0),
                                  StreamConfig(height=64, width=96), 0, 4)
    cfg = VideoCodecConfig()
    try:
        hash(cfg)
    except TypeError as e:
        # encode_chunk is jitted with the config as a static argument; an
        # unhashable config would otherwise surface as an opaque jit
        # TypeError deep inside tracing.
        raise TypeError(
            "codec_bench jits encode_chunk with static_argnums=1, which "
            f"requires VideoCodecConfig to stay hashable; got {cfg!r}. "
            "Keep it a frozen dataclass with hashable fields (or switch "
            "this bench to static_argnames/jax.tree_util registration)."
        ) from e
    fn = jax.jit(encode_chunk, static_argnums=1)
    us = _timeit(lambda: fn(frames, cfg), n=3)
    return [("codec_encode_chunk_4f_64x96", us, "mv+dct+bits")]


def _forced_cpu_env(n_devices: int = 4) -> dict:
    """os.environ copy forcing an n-device CPU platform in a CHILD process
    (append, not clobber, so caller XLA flags survive; XLA takes the last
    occurrence on conflict).  Mirrors tests/conftest.forced_multidevice_env
    — benchmarks must stay importable without the test tree."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        env.get("XLA_FLAGS", "")
        + f" --xla_force_host_platform_device_count={n_devices}").strip()
    env["JAX_PLATFORMS"] = "cpu"
    return env


def _json_rows_subprocess(module: str, fallback_name: str):
    """Run a bench module in a subprocess (forced 4-device CPU platform if
    this process sees fewer than 4 devices — XLA only honours the
    device-count flag before the first jax import) and parse the JSON row
    payload from its last stdout line.  On a machine with real
    accelerators the child inherits them instead (the flag only affects
    the host platform)."""
    import subprocess
    env = os.environ if not (jax.default_backend() == "cpu"
                             and len(jax.devices()) < 4) \
        else _forced_cpu_env()
    r = subprocess.run([sys.executable, "-m", module],
                       capture_output=True, text=True, env=env, timeout=900)
    if r.returncode != 0:
        tail = (r.stderr or r.stdout).strip().replace("\n", ";")[-160:]
        return [(fallback_name, -1.0, f"ERROR:{tail}")]
    return [tuple(row) for row in json.loads(r.stdout.strip().splitlines()[-1])]


def stream_sharding_bench():
    """Sharded-vs-single-device stream throughput (ROADMAP multi-host
    sharding item)."""
    return _json_rows_subprocess("benchmarks.stream_shard",
                                 "stream_sharding_bench")


def roundtrip_sharding_bench():
    """Mesh-sharded fused round trip vs the single-device batched jit
    (``benchmarks.roundtrip`` main, forced multi-device child)."""
    return _json_rows_subprocess("benchmarks.roundtrip",
                                 "roundtrip_sharding_bench")


def roofline_summary():
    from benchmarks.roofline import load_cells
    rows = []
    for mesh in ("single", "multi"):
        cells = load_cells("experiments/dryrun", mesh)
        runnable = [c for c in cells if "skipped" not in c]
        if not runnable:
            continue
        dom = {k: sum(c["dominant"] == k for c in runnable)
               for k in ("compute", "memory", "collective")}
        rows.append((f"roofline_{mesh}_cells", len(runnable) * 1.0,
                     f"dominant:{dom}".replace(",", ";")))
    return rows


def main() -> None:
    global SMOKE
    if "--smoke" in sys.argv:
        # export so subprocess children (stream_shard / roundtrip
        # multi-device benches, --multidevice re-exec) smoke too
        SMOKE = True
        os.environ["BISWIFT_BENCH_SMOKE"] = "1"
    # --multidevice: re-run the whole harness on a forced 4-device CPU
    # platform (fresh process; jax in THIS one is already committed)
    if "--multidevice" in sys.argv \
            and os.environ.get("BISWIFT_MULTIDEVICE_CHILD") != "1":
        import subprocess
        env = _forced_cpu_env()
        env["BISWIFT_MULTIDEVICE_CHILD"] = "1"
        sys.exit(subprocess.run(
            [sys.executable, "-m", "benchmarks.run"], env=env).returncode)

    print("name,us_per_call,derived")
    all_rows = []
    t0 = time.time()
    from benchmarks.figures import ALL
    from benchmarks.bilevel import bilevel_bench
    from benchmarks.encoder import encoder_bench
    from benchmarks.roundtrip import roundtrip_bench, roundtrip_roi_bench
    benches = list(ALL.items()) + [
        (fn.__name__, fn)
        for fn in (kernel_microbench, realistic_shape_bench, pipeline_bench,
                   codec_bench, encoder_bench, roundtrip_bench,
                   roundtrip_roi_bench, bilevel_bench, stream_sharding_bench,
                   roundtrip_sharding_bench, roofline_summary)]
    for name, fn in benches:
        try:
            all_rows.extend(fn())
        except Exception as e:  # keep the harness robust
            all_rows.append((name, -1.0, f"ERROR:{type(e).__name__}:{e}"))
    for name, us, derived in all_rows:
        if isinstance(us, float):
            print(f"{name},{us:.1f},{derived}")
        else:
            print(f"{name},{us},{derived}")
    print(f"# total wall: {time.time() - t0:.1f}s")
    errors = [n for n, _, d in all_rows if str(d).startswith("ERROR")]
    payload = {
        # v2: ``us_per_call`` is numeric-or-null, always.  Figure rows
        # whose middle slot is a parameter label (e.g. "scale=0.25") land
        # in ``params`` instead of corrupting the numeric field — numeric
        # trajectory tooling can trust every us_per_call it reads.
        "schema": "biswift-bench-v2",
        "backend": jax.default_backend(),
        "smoke": SMOKE,
        "wall_s": round(time.time() - t0, 2),
        "rows": [bench_row(n, u, d) for n, u, d in all_rows],
    }
    with open(BENCH_JSON, "w") as f:
        json.dump(payload, f, indent=1)
    print(f"# wrote {BENCH_JSON} ({len(all_rows)} rows)")
    if SMOKE and errors:
        # the smoke gate EXISTS to catch import/trace breakage — an ERROR
        # row swallowed into a green exit would defeat it (the full
        # harness stays permissive so one flaky bench can't kill a run)
        sys.exit(f"# smoke FAILED: {len(errors)} bench(es) errored: "
                 f"{', '.join(errors)}")


if __name__ == "__main__":
    main()
