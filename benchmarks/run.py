"""Benchmark harness: ``PYTHONPATH=src python -m benchmarks.run``.

One function per paper table/figure (benchmarks/figures.py) + kernel
micro-benchmarks + the roofline summary from the dry-run artifacts.
Prints ``name,us_per_call,derived`` CSV rows.
"""
from __future__ import annotations

import os
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np


def _timeit(fn, *args, n=3, warmup=1):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(n):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / n * 1e6


def kernel_microbench():
    rows = []
    from repro.kernels.flash_attention.ops import flash_attention
    from repro.kernels.qtransfer.ops import qtransfer
    from repro.kernels.blockdct.ops import blockdct_quantize
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (1, 256, 4, 64), jnp.float32)
    k = jax.random.normal(ks[1], (1, 256, 2, 64), jnp.float32)
    v = jax.random.normal(ks[2], (1, 256, 2, 64), jnp.float32)
    us = _timeit(lambda: flash_attention(q, k, v, interpret=True), n=2)
    rows.append(("kernel_flash_attention_interp", us, "B1S256H4D64"))
    anchor = jax.random.uniform(ks[0], (64, 96), jnp.float32) * 255
    mv = jax.random.randint(ks[1], (4, 6, 2), -8, 9, jnp.int32)
    resid = jnp.zeros((64, 96), jnp.float32)
    us = _timeit(lambda: qtransfer(anchor, mv, resid, interpret=True), n=2)
    rows.append(("kernel_qtransfer_interp", us, "64x96"))
    blocks = jax.random.uniform(ks[2], (256, 8, 8), jnp.float32) * 255 - 128
    us = _timeit(lambda: blockdct_quantize(blocks, 50.0, interpret=True),
                 n=2)
    rows.append(("kernel_blockdct_interp", us, "256blocks"))
    return rows


def codec_bench():
    from repro.codec.video_codec import VideoCodecConfig, encode_chunk
    from repro.sim.video_source import StreamConfig, generate_chunk
    frames, _, _ = generate_chunk(jax.random.PRNGKey(0),
                                  StreamConfig(height=64, width=96), 0, 4)
    cfg = VideoCodecConfig()
    fn = jax.jit(encode_chunk, static_argnums=1)
    us = _timeit(lambda: fn(frames, cfg), n=3)
    return [("codec_encode_chunk_4f_64x96", us, "mv+dct+bits")]


def roofline_summary():
    from benchmarks.roofline import load_cells
    rows = []
    for mesh in ("single", "multi"):
        cells = load_cells("experiments/dryrun", mesh)
        runnable = [c for c in cells if "skipped" not in c]
        if not runnable:
            continue
        dom = {k: sum(c["dominant"] == k for c in runnable)
               for k in ("compute", "memory", "collective")}
        rows.append((f"roofline_{mesh}_cells", len(runnable) * 1.0,
                     f"dominant:{dom}".replace(",", ";")))
    return rows


def main() -> None:
    print("name,us_per_call,derived")
    all_rows = []
    t0 = time.time()
    from benchmarks.figures import ALL
    for name, fn in ALL.items():
        try:
            all_rows.extend(fn())
        except Exception as e:  # keep the harness robust
            all_rows.append((name, -1.0, f"ERROR:{type(e).__name__}:{e}"))
    all_rows.extend(kernel_microbench())
    all_rows.extend(codec_bench())
    all_rows.extend(roofline_summary())
    for name, us, derived in all_rows:
        if isinstance(us, float):
            print(f"{name},{us:.1f},{derived}")
        else:
            print(f"{name},{us},{derived}")
    print(f"# total wall: {time.time() - t0:.1f}s")


if __name__ == "__main__":
    main()
