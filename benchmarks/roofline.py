"""Roofline analysis from the dry-run artifacts (EXPERIMENTS.md §Roofline).

Per (arch × shape × mesh) cell, three per-device time terms on TPU v5e:

    compute    = HLO_FLOPs / peak_FLOPs          (197 TFLOP/s bf16)
    memory     = HLO_bytes / HBM_bw              (819 GB/s)
    collective = wire_bytes / ICI_bw             (~50 GB/s/link)

plus MODEL_FLOPS = 6·N·D (train) or 2·N·D (forward) with N = active
params, and the usefulness ratio MODEL_FLOPS / HLO_FLOPs (catches remat /
padding / capacity-factor waste).  The dominant term is the bottleneck the
perf loop iterates on.
"""
from __future__ import annotations

import glob
import json
import os
import sys

PEAK = 197e12
HBM = 819e9
ICI = 50e9


def model_flops_global(arch_id: str, shape: str) -> float:
    """Analytic useful FLOPs for the whole step (all devices)."""
    from repro.configs import get_arch
    arch = get_arch(arch_id)
    cfg = arch.cfg
    case = arch.shapes[shape]
    if arch.family == "lm":
        n_act = cfg.active_param_count()
        if case.kind == "train":
            return 6.0 * n_act * case.batch * case.seq_len
        if case.kind == "prefill":
            return 2.0 * n_act * case.batch * case.seq_len
        return 2.0 * n_act * case.batch          # decode: one token each
    if arch.family == "diffusion":
        n = cfg.param_count()
        toks = cfg.n_tokens(case.img_res) * case.batch
        factor = 6.0 if case.kind == "train" else 2.0
        return factor * n * toks
    # vision: 6/2 · N · images is a crude proxy (convs reuse weights
    # spatially, so HLO_FLOPs >> 6·N·D is EXPECTED for convnets — noted)
    n = cfg.param_count()
    factor = 6.0 if case.kind == "train" else 2.0
    return factor * n * case.batch


def load_cells(dryrun_dir: str, mesh: str = "single",
               variant: str = "baseline"):
    rows = []
    for f in sorted(glob.glob(os.path.join(
            dryrun_dir, f"*__{mesh}__{variant}.json"))):
        r = json.load(open(f))
        if r.get("status") != "ok":
            if r.get("status") == "skipped":
                rows.append({"arch": r["arch"], "shape": r["shape"],
                             "mesh": mesh, "variant": variant,
                             "skipped": r["reason"]})
            continue
        nd = r["n_devices"]
        c = r["cost"]
        compute_s = c["flops"] / PEAK
        memory_s = c["bytes accessed"] / HBM
        coll_s = c["wire_bytes"] / ICI
        terms = {"compute": compute_s, "memory": memory_s,
                 "collective": coll_s}
        dominant = max(terms, key=terms.get)
        mf = model_flops_global(r["arch"], r["shape"]) / nd
        rows.append({
            "arch": r["arch"], "shape": r["shape"], "mesh": mesh,
            "variant": variant, "n_devices": nd,
            "compute_s": compute_s, "memory_s": memory_s,
            "collective_s": coll_s, "dominant": dominant,
            "model_flops_per_dev": mf,
            "useful_ratio": mf / max(c["flops"], 1e-9),
            "roofline_fraction": max(compute_s, 1e-12) / max(
                sum(terms.values()), 1e-12),
            "step_time_bound_s": max(terms.values()),
            "hbm_args_gb": r["memory"]["argument_size_in_bytes"] / 1e9,
            "hbm_temp_gb": r["memory"]["temp_size_in_bytes"] / 1e9,
        })
    return rows


def markdown_table(rows) -> str:
    hdr = ("| arch | shape | compute s | memory s | collective s | "
           "dominant | 6ND/HLO | args GB | temp GB |\n"
           "|---|---|---|---|---|---|---|---|---|\n")
    out = [hdr]
    for r in rows:
        if "skipped" in r:
            out.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                       f"skip | — | — | — |\n")
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.4f} | "
            f"{r['memory_s']:.4f} | {r['collective_s']:.4f} | "
            f"**{r['dominant']}** | {r['useful_ratio']:.2f} | "
            f"{r['hbm_args_gb']:.2f} | {r['hbm_temp_gb']:.2f} |\n")
    return "".join(out)


def main():
    dryrun_dir = sys.argv[1] if len(sys.argv) > 1 else "experiments/dryrun"
    mesh = sys.argv[2] if len(sys.argv) > 2 else "single"
    rows = load_cells(dryrun_dir, mesh)
    print(markdown_table(rows))
    runnable = [r for r in rows if "skipped" not in r]
    print(f"\n{len(runnable)} cells; dominant-term histogram:",
          {k: sum(r['dominant'] == k for r in runnable)
           for k in ("compute", "memory", "collective")})


if __name__ == "__main__":
    main()
