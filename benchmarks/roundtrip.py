"""Fused encode->decode round-trip benchmark rows (``roundtrip_*`` in
BENCH_pipeline.json).

Single-device (invoked from ``benchmarks.run``): the sequential two-jit
path (per-stream ``roundtrip_oracle`` — ``encode_chunk`` jit + host glue +
``decode_execute_chunk`` jit) against the fused ``roundtrip_batched`` jit
at 1..8 streams, plus a mixed-bitrate-ladder row through the padded
heterogeneous dispatch.

Multi-device: run this module directly under a forced multi-device CPU
platform (``benchmarks.run`` spawns it the same way as
``benchmarks.stream_shard``); it prints a JSON payload of
``roundtrip_sharded_*`` rows as the LAST stdout line, comparing the
single-device batched jit to ``shard_roundtrip`` over the mesh.
"""
from __future__ import annotations

import json

import jax
import jax.numpy as jnp


def _inputs(S, H=64, W=96, T=4):
    from repro.models import detection as D
    from repro.sim.video_source import StreamConfig, generate_chunk

    data = [generate_chunk(None, StreamConfig(height=H, width=W,
                                              n_objects=3, seed=s), 0, T)
            for s in range(S)]
    raw = jnp.stack([d[0] for d in data])
    gtb = jnp.stack([d[1] for d in data])
    gtv = jnp.stack([d[2] for d in data])
    det_cfg = D.TinyDetectorConfig()
    params = D.init(jax.random.PRNGKey(1), det_cfg)
    scalars = dict(tr1=jnp.full((S,), 0.05), tr2=jnp.full((S,), 0.1),
                   bw_kbps=jnp.full((S,), 4000.0),
                   queue_delay=jnp.zeros((S,)))
    return raw, gtb, gtv, params, det_cfg, scalars


def roundtrip_bench():
    """Sequential two-jit vs fused round-trip, 1..8 streams + mixed
    ladder (single device)."""
    from benchmarks.run import SMOKE, _timeit
    from repro.core.roundtrip import (RoundtripConfig, roundtrip_batched,
                                      roundtrip_ladder_batched,
                                      roundtrip_oracle)

    rows = []
    stream_counts = (1, 2) if SMOKE else (1, 2, 4, 8)
    levels = (4, 3, 2)               # the mixed-ladder row's rungs
    S_max = max(*stream_counts, len(levels))
    raw, gtb, gtv, params, det_cfg, sc = _inputs(S_max)
    cfg = RoundtripConfig(level=3, det_cfg=det_cfg)
    T = raw.shape[1]

    for S in stream_counts:
        def seq():
            return [roundtrip_oracle(
                raw[s], gtb[s], gtv[s], params, tr1=0.05, tr2=0.1,
                bw_kbps=4000.0, cfg=cfg) for s in range(S)]

        us_seq = _timeit(seq, n=3)
        rows.append((f"roundtrip_seq_twojit_{S}stream", us_seq,
                     "encode-jit+host-glue+decode-jit"))

        def fused():
            return roundtrip_batched(
                raw[:S], gtb[:S], gtv[:S], params, tr1=sc["tr1"][:S],
                tr2=sc["tr2"][:S], bw_kbps=sc["bw_kbps"][:S],
                queue_delay=sc["queue_delay"][:S], cfg=cfg)

        us_fused = _timeit(fused, n=3)
        fps = S * T / (us_fused / 1e6)
        rows.append((f"roundtrip_fused_{S}stream", us_fused,
                     f"fps:{fps:.0f};speedup_vs_twojit:"
                     f"{us_seq / max(us_fused, 1e-9):.2f}x"))

    # ---- kernel + bf16 codec configs on the TIMED fused path (these were
    # dead flags before: every headline row above ran the f32 fallback
    # search).  Same batched jit, only RoundtripConfig.codec changes.
    from repro.codec.video_codec import VideoCodecConfig
    variant_counts = (1,) if SMOKE else (1, 4)
    cfg_bf16 = RoundtripConfig(
        level=3, det_cfg=det_cfg,
        codec=VideoCodecConfig(use_kernel=True, dtype="bfloat16"))
    cfg_diamond = RoundtripConfig(
        level=3, det_cfg=det_cfg,
        codec=VideoCodecConfig(use_kernel=True, search="diamond"))

    def fused_with(cfg_v, S):
        return roundtrip_batched(
            raw[:S], gtb[:S], gtv[:S], params, tr1=sc["tr1"][:S],
            tr2=sc["tr2"][:S], bw_kbps=sc["bw_kbps"][:S],
            queue_delay=sc["queue_delay"][:S], cfg=cfg_v)

    f32_us = {int(n.split("_")[2][:-6]): u for n, u, _ in rows
              if n.startswith("roundtrip_fused_") and n.endswith("stream")}
    for S in variant_counts:
        us_bf = _timeit(lambda: fused_with(cfg_bf16, S), n=3)
        ref = f32_us.get(S)
        derived = "use_kernel+bf16"
        if ref:
            derived += f";vs_f32:{ref / max(us_bf, 1e-9):.2f}x"
        rows.append((f"roundtrip_fused_{S}stream_bf16", us_bf, derived))
    S_d = variant_counts[-1]
    us_dia = _timeit(lambda: fused_with(cfg_diamond, S_d), n=3)
    ref = f32_us.get(S_d)
    derived = "use_kernel+diamond-search"
    if ref:
        derived += f";vs_f32_exhaustive:{ref / max(us_dia, 1e-9):.2f}x"
    rows.append((f"roundtrip_fused_{S_d}stream_diamond", us_dia, derived))

    # ---- in-trace anchor-quality budget search (bench-adaptive): the
    # masked ladder sweep + traced argmax vs the pinned-quality trace —
    # the cost of making anchor quality adapt per chunk without retracing
    import dataclasses
    cfg_qs = dataclasses.replace(cfg, anchor_search=True)
    S_q = variant_counts[-1]
    us_qs = _timeit(lambda: fused_with(cfg_qs, S_q), n=3)
    ref = f32_us.get(S_q)
    derived = "in-trace-anchor-budget-search"
    if ref:
        derived += f";vs_pinned:{ref / max(us_qs, 1e-9):.2f}x"
    rows.append((f"roundtrip_fused_{S_q}stream_qsearch", us_qs, derived))

    S = len(levels)

    def ladder():
        return roundtrip_ladder_batched(
            raw[:S], gtb[:S], gtv[:S], params, tr1=sc["tr1"][:S],
            tr2=sc["tr2"][:S], bw_kbps=sc["bw_kbps"][:S],
            queue_delay=sc["queue_delay"][:S], levels=levels, cfg=cfg)

    us_lad = _timeit(ladder, n=3)
    rungs = "/".join(str(lv) for lv in levels)
    rows.append((f"roundtrip_fused_mixed_ladder_{S}stream", us_lad,
                 f"rungs:{rungs};one-padded-jit"))
    return rows


def roundtrip_roi_bench():
    """ROI-gated vs full-frame fused round trip on the fig.14-style
    scenarios (``roundtrip_roi_*`` rows).

    Both regimes run the SAME fused ``roundtrip_batched`` jit; only
    ``RoundtripConfig.roi`` differs.  Gating is capacity-only
    (threshold=0.0, K < n_regions), so the per-chunk detector work is
    deterministic — K packed patches instead of the full frame — and the
    speedup column measures the gate, not scene luck.  The sparse row is
    the acceptance gate (>= 1.5x); the dense row documents where the gate
    saturates (larger K, smaller win).  ``f1`` rides the derived column
    as accuracy evidence."""
    import dataclasses

    from benchmarks.run import SMOKE, _timeit
    from repro.core.roi import RoiConfig, region_grid
    from repro.core.roundtrip import RoundtripConfig, roundtrip_batched
    from repro.models import detection as D
    from repro.sim.video_source import generate_chunk, scenario_streams

    H, W = (96, 128) if SMOKE else (192, 256)
    T = 4 if SMOKE else 8
    det_cfg = D.TinyDetectorConfig()
    params = D.init(jax.random.PRNGKey(1), det_cfg)
    cfg0 = RoundtripConfig(level=3, det_cfg=det_cfg)
    nry, nrx = region_grid((H, W), RoiConfig())
    nreg = nry * nrx

    rows = []
    for label, scenario, cap in (
            ("sparse", "sparse-highway", max(nreg // 16, 2)),
            ("dense", "crowded-crossroad", max(nreg // 4, 4))):
        sc = scenario_streams(scenario, 1, height=H, width=W)[0]
        frames, gtb, gtv = generate_chunk(None, sc, 0, T)
        raw = frames[None]
        args = (raw, gtb[None], gtv[None], params)
        kw = dict(tr1=jnp.full((1,), 0.05), tr2=jnp.full((1,), 0.1),
                  bw_kbps=jnp.full((1,), 4000.0),
                  queue_delay=jnp.zeros((1,)))

        def off():
            return roundtrip_batched(*args, **kw, cfg=cfg0)

        # n=10/warmup=2: at n=3 run-to-run noise on a loaded CPU swamps
        # the ~2x gating effect these rows exist to witness
        us_off = _timeit(off, n=10, warmup=2)
        f1_off = float(off()["mean_f1"].mean())
        rows.append((f"roundtrip_roi_{label}_off", us_off,
                     f"full-frame;regions:{nreg};f1:{f1_off:.3f}"))

        # ref gather: the Pallas kernel only runs interpret-mode on CPU,
        # whose per-step overhead would mask the gating win this row is
        # measuring (kernel parity + timing have their own rows/tests)
        roi = RoiConfig(capacity=cap, threshold=0.0, use_kernel=False)
        cfg1 = dataclasses.replace(cfg0, roi=roi)

        def on():
            return roundtrip_batched(*args, **kw, cfg=cfg1)

        us_on = _timeit(on, n=10, warmup=2)
        f1_on = float(on()["mean_f1"].mean())
        rows.append((f"roundtrip_roi_{label}_on", us_on,
                     f"capacity:{cap}/{nreg};vs_off:"
                     f"{us_off / max(us_on, 1e-9):.2f}x;f1:{f1_on:.3f}"))
    return rows


def main():
    """Forced-multi-device entry: sharded vs single-device round trip."""
    from benchmarks.run import SMOKE, _timeit
    from repro.core.roundtrip import RoundtripConfig, roundtrip_batched
    from repro.distributed.sharding import SINGLE_POD_RULES
    from repro.distributed.stream_sharding import (shard_roundtrip,
                                                   stream_shard_count)

    n_dev = len(jax.devices())
    S = 4 if SMOKE else 8
    raw, gtb, gtv, params, det_cfg, sc = _inputs(S)
    cfg = RoundtripConfig(level=3, det_cfg=det_cfg)
    T = raw.shape[1]

    def single():
        return roundtrip_batched(raw, gtb, gtv, params, cfg=cfg, **sc)

    us_single = _timeit(single)

    mesh = jax.make_mesh((n_dev,), ("data",))
    run = shard_roundtrip(mesh, SINGLE_POD_RULES, cfg=cfg)
    n_shards = stream_shard_count(mesh, SINGLE_POD_RULES)

    def sharded():
        return run(raw, gtb, gtv, params, **sc)

    us_sharded = _timeit(sharded)
    fps = S * T / (us_sharded / 1e6)
    rows = [
        [f"roundtrip_batched_single_dev_{S}streams", us_single,
         f"oracle_{n_dev}devhost"],
        [f"roundtrip_sharded_{n_shards}shard_{S}streams", us_sharded,
         f"fps:{fps:.0f};vs_single:"
         f"{us_single / max(us_sharded, 1e-9):.2f}x"],
    ]
    print(json.dumps(rows))


if __name__ == "__main__":
    main()
