"""Async serving-plane benchmark:
``PYTHONPATH=src python -m benchmarks.async_serving``.

Measures the continuous-batching dispatcher (``EdgeRuntime.submit_chunk``
/ ``flush`` / ``poll``) end to end:

  * ``runtime_async_{1,2,4,8}stream`` — N concurrent streams submitted
    into one padded batch-signature group, flushed as a single async
    detector dispatch, polled once.  The rows that close the ROADMAP's
    "100x jit-vs-runtime gap" item: compare against the pre-async
    ``runtime_process_chunk_*`` rows kept in ``BENCH_pipeline.json``.
  * ``runtime_async_soak_*`` — the 64-stream churn soak
    (``run_soak(batch_submit=True)`` under ``churn_schedule``): staggered
    joins/leaves/stalls plus a flaky-loss window.  The run FAILS (exit
    non-zero) on any accounting violation
    (``frames_in != inferred + reused + skipped``) or queue leak, so the
    CI ``async-soak`` job gates on the serving invariants.

Row management: new rows are MERGED into ``BENCH_pipeline.json`` by name
(other rows preserved), migrating the payload to the v2 schema
(``us_per_call`` numeric-or-null, labels in ``params``).  ``--smoke`` /
``BISWIFT_BENCH_SMOKE=1`` shrinks shapes/reps and skips the merge
(timings would be meaningless), writing ``BENCH_async.json`` only — the
invariant gate still runs at full strictness.
"""
from __future__ import annotations

import json
import os
import sys
import time

import numpy as np

BENCH_JSON = os.environ.get("BENCH_JSON", "BENCH_pipeline.json")
ASYNC_JSON = os.environ.get("BENCH_ASYNC_JSON", "BENCH_async.json")
SMOKE = os.environ.get("BISWIFT_BENCH_SMOKE") == "1"


def _median_timeit(fn, n=7) -> float:
    """Median per-call microseconds.  The async rows' guard against
    one-off contamination: the first-measured config used to absorb
    GC pauses and deferred one-time work into a 5-rep MEAN, which is how
    the committed ``runtime_async_1stream`` row came out slower than the
    2-stream row.  A median over more reps shrugs off a single bad call."""
    if SMOKE:
        n = 1
    times = []
    for _ in range(n):
        t0 = time.perf_counter()
        fn()
        times.append((time.perf_counter() - t0) * 1e6)
    times.sort()
    return times[len(times) // 2]


def _throughput_rows(reference_fps: dict) -> list:
    import jax
    from repro.core.hybrid_encoder import encode_hybrid
    from repro.models import detection as D
    from repro.serving.runtime import EdgeRuntime
    from repro.serving.scheduler import ServingConfig
    from repro.sim.video_source import StreamConfig, generate_chunk

    frames, _, _ = generate_chunk(
        jax.random.PRNGKey(0), StreamConfig(height=64, width=96,
                                            n_objects=3), 0, 4)
    det_cfg = D.TinyDetectorConfig()
    params = D.init(jax.random.PRNGKey(1), det_cfg)
    packet = encode_hybrid(np.asarray(frames), 8000.0, 0.05, 0.1)
    T = packet.types.shape[0]

    # process-level prime: the first runtime in the process pays the
    # module-level jit compiles (stage/gather/finish) plus XLA one-time
    # setup — run a throwaway config so no MEASURED config goes first
    with EdgeRuntime(ServingConfig(n_streams=1), params, det_cfg) as rt:
        for _ in range(2):
            tk = rt.submit_chunk(0, 0, packet)
            rt.flush()
            rt.poll(tk)

    rows = []
    for n_streams in ((1, 4) if SMOKE else (1, 2, 4, 8)):
        with EdgeRuntime(ServingConfig(n_streams=n_streams), params,
                         det_cfg) as rt:

            def run_all():
                tks = [rt.submit_chunk(s, 0, packet)
                       for s in range(n_streams)]
                rt.flush()
                for tk in tks:
                    rt.poll(tk)

            # three warmups: the first chunk compiles the no-carry finish
            # and this batch shape, the second the carried-init variant,
            # the third guards the first timed call
            run_all()
            run_all()
            run_all()
            us = _median_timeit(run_all)
            fps = n_streams * T / (us / 1e6)
            ref = reference_fps.get(f"runtime_process_chunk_"
                                    f"{n_streams}stream")
            derived = f"fps:{fps:.0f}"
            if ref:
                derived += f";vs_pre_async:{fps / ref:.1f}x"
            rows.append((f"runtime_async_{n_streams}stream", us, derived))
    return rows


def _soak_row(errors: list) -> tuple:
    from repro.serving.faults import SoakConfig, churn_schedule, run_soak
    n_streams = 16 if SMOKE else 64
    n_chunks = 6 if SMOKE else 12
    cfg = SoakConfig(n_streams=n_streams, n_chunks=n_chunks,
                     chunk_frames=3, gpu_capacity_fps=4000.0,
                     content_groups=8, seed=7)
    sched = churn_schedule(n_chunks, n_streams, seed=7)
    rep = run_soak(cfg, sched, batch_submit=True)
    bad = [c for c, s in rep["stream_stats"].items()
           if s["frames_in"] != s["frames_inferred"] + s["frames_reused"]
           + s["frames_skipped"]]
    if bad:
        errors.append(f"accounting leak on streams {bad}")
    if rep["queue_leaks"]:
        errors.append(f"{len(rep['queue_leaks'])} queue leaks")
    total_in = sum(s["frames_in"] for s in rep["stream_stats"].values())
    fps = total_in / max(rep["wall_s"], 1e-9)
    return (f"runtime_async_soak_{n_streams}stream",
            rep["wall_s"] * 1e6 / n_chunks,
            f"churn;frames:{total_in};fps:{fps:.0f};"
            f"accounting_ok:{not bad};queue_leaks:{len(rep['queue_leaks'])}")


def _merge_into_bench(rows: list) -> None:
    """Merge the async rows into BENCH_pipeline.json by name, migrating
    any v1 payload to schema v2 on the way."""
    from benchmarks.run import bench_row, migrate_rows_v2
    payload = {"schema": "biswift-bench-v2", "rows": []}
    if os.path.exists(BENCH_JSON):
        with open(BENCH_JSON) as f:
            payload = json.load(f)
    payload["schema"] = "biswift-bench-v2"
    new = {n for n, _, _ in rows}
    payload["rows"] = [r for r in migrate_rows_v2(payload.get("rows", []))
                       if r["name"] not in new] \
        + [bench_row(n, u, d) for n, u, d in rows]
    with open(BENCH_JSON, "w") as f:
        json.dump(payload, f, indent=1)
    print(f"# merged {len(rows)} rows into {BENCH_JSON} "
          f"({len(payload['rows'])} total)")


def main() -> None:
    global SMOKE
    if "--smoke" in sys.argv:
        SMOKE = True
        os.environ["BISWIFT_BENCH_SMOKE"] = "1"
    import jax

    reference_fps = {}
    if os.path.exists(BENCH_JSON):
        with open(BENCH_JSON) as f:
            for r in json.load(f).get("rows", []):
                d = str(r.get("derived", ""))
                if d.startswith("fps:"):
                    try:
                        reference_fps[r["name"]] = \
                            float(d.split(";")[0][4:])
                    except ValueError:
                        pass

    t0 = time.time()
    errors: list = []
    print("name,us_per_call,derived")
    rows = _throughput_rows(reference_fps)
    rows.append(_soak_row(errors))
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
    print(f"# total wall: {time.time() - t0:.1f}s")

    # identical full-precision bench_row payloads in BOTH artifacts:
    # BENCH_async.json used to round us_per_call to 1 decimal while the
    # BENCH_pipeline.json merge kept full precision, so trajectory
    # tooling diffing the two files saw phantom drift on every run
    from benchmarks.run import bench_row
    payload = {
        "schema": "biswift-bench-v2",
        "backend": jax.default_backend(),
        "smoke": SMOKE,
        "wall_s": round(time.time() - t0, 2),
        "rows": [bench_row(n, u, d) for n, u, d in rows],
        "errors": errors,
    }
    with open(ASYNC_JSON, "w") as f:
        json.dump(payload, f, indent=1)
    print(f"# wrote {ASYNC_JSON} ({len(rows)} rows)")
    if not SMOKE:
        _merge_into_bench(rows)
    if errors:
        sys.exit("# async soak FAILED: " + "; ".join(errors))


if __name__ == "__main__":
    main()
