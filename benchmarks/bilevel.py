"""Bi-level control-plane benchmark: per-stream loop vs fused stacked step.

Rows (mirrored into BENCH_pipeline.json by benchmarks/run.py):

  bilevel_loop_C{N}     — us per SCHEDULER STEP, per-stream oracle
                          (2C+2 dispatches: C acts, C updates, SAC act
                          every interval, SAC update)
  bilevel_stacked_C{N}  — us per scheduler step, single-jit
                          ``bilevel_step``

Both trainers drive the REAL BiLevelTrainer code paths (replay writes,
buffer sampling, controller cache, deferred-update bookkeeping) against a
frozen environment that replays one recorded chunk: the simulator's
rendering/step cost is identical in the two modes and an order of
magnitude larger than the control plane at small C, so timing it would
only measure the simulator.  ``low_batch=32`` keeps the paper-ish A2C
minibatch on the timed update path.  C=9 is the paper's operating point;
16 probes the scaling trend.
"""
from __future__ import annotations

import copy
import json
import os
import time


SMOKE = os.environ.get("BISWIFT_BENCH_SMOKE") == "1"


class _FrozenEnv:
    """Replays one recorded chunk forever — same observation/step API as
    ``MultiStreamEnv``, none of the rendering cost.  The host feature
    assembly the two control planes share (allocation insertion into the
    cached base states) is kept, so the comparison stays apples-to-apples
    with the real trainer loop."""

    def __init__(self, real, results, info):
        import numpy as np
        self.cfg, self.C, self.t = real.cfg, real.C, real.t
        self._s_high = real.observe_high()
        self._base = real.observe_low_batched(None)
        self._results, self._info = results, info
        self._off = None
        self._np = np

    def observe_high(self):
        return self._s_high

    def observe_low_batched(self, allocations=None):
        if allocations is None:
            return self._base
        from repro.sim.env import low_alloc_offset
        if self._off is None:
            self._off = low_alloc_offset(self.cfg)
        out = self._base.copy()
        out[:, self._off:self._off + self.C] = allocations
        return out

    def observe_low(self, c, allocations):
        return self.observe_low_batched(
            self._np.asarray(allocations, self._np.float32))[c]

    def step(self, proportions, thresholds):
        self.t += 1
        return copy.deepcopy(self._results), dict(self._info)


def _frozen_trainer(C, low_batch):
    import dataclasses
    from repro.core.bilevel import BiLevelTrainer
    from repro.sim.env import EnvConfig
    from repro.sim.video_source import paper_stream_mix
    cfg = EnvConfig(streams=tuple(paper_stream_mix(C, 64, 96)),
                    chunk_frames=4)
    tr = BiLevelTrainer.create(cfg, seed=0, low_batch=low_batch)
    # paper SAC minibatch is 128 -> the controller update would need 128
    # warmup chunks; shrink it so the timed rows include the SAC island
    # (the heaviest dispatch of the loop's 2C+2) after the same warmup
    tr.controller.cfg = dataclasses.replace(tr.controller.cfg,
                                            minibatch=low_batch)
    # record one real chunk, then freeze the env around it
    _, results, info, _ = tr.run_chunk_loop()
    tr.env = _FrozenEnv(tr.env, results, info)
    return tr


def bilevel_bench():
    stream_counts = (1, 4) if SMOKE else (1, 4, 9, 16)
    low_batch = 4 if SMOKE else 32
    # warm until the deferred A2C update is on the timed path (buffer
    # fill = low_batch chunks) and every trace is compiled
    warmup = low_batch + 3
    reps = 1 if SMOKE else 10
    rows = []
    for C in stream_counts:
        per = {}
        for mode in ("loop", "stacked"):
            tr = _frozen_trainer(C, low_batch)
            step = tr.run_chunk_loop if mode == "loop" else tr.run_chunk
            for _ in range(warmup):
                step()
            t0 = time.perf_counter()
            for _ in range(reps):
                step()
            per[mode] = (time.perf_counter() - t0) / reps * 1e6
        rows.append((f"bilevel_loop_C{C}", per["loop"],
                     "2C+2-dispatch scheduler step"))
        rows.append((f"bilevel_stacked_C{C}", per["stacked"],
                     f"speedup:{per['loop'] / max(per['stacked'], 1e-9):.2f}x"))
    return rows


if __name__ == "__main__":
    print(json.dumps(bilevel_bench()))
