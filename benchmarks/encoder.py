"""Encoder-path benchmark rows (``encoder_*`` in BENCH_pipeline.json).

Three motion-search implementations on the same P-frame (legacy
whole-frame scan vs the vmapped per-macroblock fallback vs the Pallas
kernel, f32 and bf16), plus the single-jit ``encode_chunk`` against
``encode_chunk_batched`` at 1..4 streams — the batched row's derived
field carries the measured speedup over encoding the same streams
sequentially.  Invoked from ``benchmarks.run``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def encoder_bench():
    # deferred: benchmarks.run imports this module inside main(), so a
    # module-level import back into run would create an import cycle
    from benchmarks.run import _timeit
    from repro.codec.motion import block_sad, block_sad_scan
    from repro.codec.video_codec import (VideoCodecConfig, encode_chunk,
                                         encode_chunk_batched)
    from repro.kernels.motion_sad.ops import motion_sad
    from repro.sim.video_source import StreamConfig, generate_chunk_batched

    rows = []
    H, W, T, radius = 64, 96, 4, 8
    cfgs = [StreamConfig(height=H, width=W, n_objects=3, seed=s)
            for s in range(4)]
    frames4 = generate_chunk_batched(cfgs, 0, T)[0]
    cur, ref = frames4[0, 1], frames4[0, 0]

    # ---- motion search: scan vs vmapped fallback vs kernel, f32 vs bf16.
    # Kernel rows carry BOTH oracle-relative ratios (two decimals — one
    # decimal rounded 0.95x up to "1.0x" and hid regressions): the
    # kernel-trajectory CI summary reads vs_scan/vs_fallback per PR.
    scan = jax.jit(lambda c, r: block_sad_scan(c, r, radius))
    us_scan = _timeit(lambda: scan(cur, ref), n=3)
    rows.append((f"encoder_block_sad_scan_{H}x{W}", us_scan,
                 f"r{radius}whole-frame"))
    vmapped = jax.jit(lambda c, r: block_sad(c, r, radius))
    us_v = _timeit(lambda: vmapped(cur, ref), n=3)
    rows.append((f"encoder_block_sad_vmapped_{H}x{W}", us_v,
                 f"vs_scan:{us_scan / max(us_v, 1e-9):.2f}x"))
    us_k = _timeit(lambda: motion_sad(cur, ref, radius=radius,
                                      interpret=True), n=3)
    rows.append((f"encoder_block_sad_kernel_interp_{H}x{W}", us_k,
                 f"vs_scan:{us_scan / max(us_k, 1e-9):.2f}x;"
                 f"vs_fallback:{us_v / max(us_k, 1e-9):.2f}x"))
    vm_bf = jax.jit(lambda c, r: block_sad(c, r, radius,
                                           dtype=jnp.bfloat16))
    us_vbf = _timeit(lambda: vm_bf(cur, ref), n=3)
    rows.append((f"encoder_block_sad_vmapped_bf16_{H}x{W}", us_vbf,
                 f"vs_f32:{us_v / max(us_vbf, 1e-9):.2f}x"))
    us_kbf = _timeit(lambda: motion_sad(cur, ref, radius=radius,
                                        interpret=True,
                                        dtype=jnp.bfloat16), n=3)
    rows.append((f"encoder_block_sad_kernel_bf16_interp_{H}x{W}", us_kbf,
                 f"vs_f32:{us_k / max(us_kbf, 1e-9):.2f}x;"
                 f"vs_fallback:{us_vbf / max(us_kbf, 1e-9):.2f}x"))

    # ---- diamond search: traced coarse-to-fine, 37 of 289 candidates at
    # ±8 (quality contract in docs/fused_encoder.md, not bit-exactness)
    from repro.codec.motion import diamond_num_evals
    evals = f"evals:{diamond_num_evals(radius)}/{(2 * radius + 1) ** 2}"
    dia = jax.jit(lambda c, r: block_sad(c, r, radius, search="diamond"))
    us_d = _timeit(lambda: dia(cur, ref), n=3)
    rows.append((f"encoder_block_sad_diamond_{H}x{W}", us_d,
                 f"{evals};vs_exhaustive:{us_v / max(us_d, 1e-9):.2f}x"))
    us_dk = _timeit(lambda: motion_sad(cur, ref, radius=radius,
                                       interpret=True, search="diamond"),
                    n=3)
    rows.append((f"encoder_block_sad_kernel_diamond_interp_{H}x{W}", us_dk,
                 f"{evals};vs_scan:{us_scan / max(us_dk, 1e-9):.2f}x;"
                 f"vs_fallback:{us_d / max(us_dk, 1e-9):.2f}x"))

    # ---- diamond DISPATCH (block_sad with use_kernel=True): below
    # ~256 macroblocks the kernel trails the traced descent, so block_sad
    # statically routes small canvases to the fallback — this row must
    # track the fallback row above (vs_best ~1.0x), where the raw kernel
    # row trails it.  The 720p-shaped twin lives in
    # realistic_shape_bench (there the kernel side of the dispatch wins).
    disp = jax.jit(lambda c, r: block_sad(c, r, radius, use_kernel=True,
                                          search="diamond"))
    us_disp = _timeit(lambda: disp(cur, ref), n=3)
    rows.append((f"encoder_block_sad_diamond_dispatch_{H}x{W}", us_disp,
                 f"{evals};routed:fallback;"
                 f"vs_fallback:{us_d / max(us_disp, 1e-9):.2f}x;"
                 f"vs_kernel:{us_dk / max(us_disp, 1e-9):.2f}x"))

    # ---- chunk encode: single jit vs batched vmap over 1..4 streams
    cfg = VideoCodecConfig(quality=50.0, search_radius=radius)
    us_one = _timeit(lambda: encode_chunk(frames4[0], cfg), n=3)
    rows.append((f"encoder_chunk_single_{T}f_{H}x{W}", us_one, "one-jit"))
    cfg_bf = VideoCodecConfig(quality=50.0, search_radius=radius,
                              dtype="bfloat16")
    us_bf = _timeit(lambda: encode_chunk(frames4[0], cfg_bf), n=3)
    rows.append((f"encoder_chunk_single_bf16_{T}f_{H}x{W}", us_bf,
                 f"vs_f32:{us_one / max(us_bf, 1e-9):.2f}x"))
    for S in (1, 2, 4):
        batch = frames4[:S]
        us_b = _timeit(lambda: encode_chunk_batched(batch, cfg), n=3)
        seq = S * us_one
        fps = S * T / (us_b / 1e6)
        rows.append((f"encoder_chunk_batched_{S}stream", us_b,
                     f"fps:{fps:.0f};speedup_vs_sequential:"
                     f"{seq / max(us_b, 1e-9):.2f}x"))
    return rows
