"""Fused encode->decode round-trip (ISSUE 4 tentpole): bit-exactness of
``roundtrip_chunk`` / ``roundtrip_batched`` / ``roundtrip_ladder_batched``
/ ``shard_roundtrip`` against the compose-the-two-jits oracle, the sim
env's grouped dispatch, and internal consistency of the traced rate model.

Like ``test_stream_sharding.py``, the mesh-parity matrix needs a real
multi-device platform: a driver test re-runs this file's ``forced``-named
tests in a subprocess with 4 fake CPU devices
(``conftest.forced_multidevice_run``).
"""
import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import conftest
from repro.core.roundtrip import (RoundtripConfig, roundtrip_batched,
                                  roundtrip_chunk, roundtrip_ladder_batched,
                                  roundtrip_oracle)
from repro.distributed.sharding import SINGLE_POD_RULES, SINGLE_POD_RULES_DP
from repro.distributed.stream_sharding import (shard_roundtrip,
                                               stream_shard_count)
from repro.models import detection as D
from repro.sim.video_source import StreamConfig, generate_chunk

_FORCED = int(os.environ.get(conftest.FORCED_MULTIDEVICE_ENV, "0"))

forced_only = pytest.mark.skipif(
    _FORCED < 4, reason="needs the forced multi-device child process")

H, W, T = 64, 96, 4
MIXED_LEVELS = (4, 3, 2)        # full / 2-3 scale / half rung in one batch


@pytest.fixture(scope="module")
def det():
    cfg = D.TinyDetectorConfig()
    return D.init(jax.random.PRNGKey(1), cfg), cfg


@pytest.fixture(scope="module")
def cfg(det):
    return RoundtripConfig(level=3, det_cfg=det[1])


def _streams(S):
    data = [generate_chunk(None, StreamConfig(height=H, width=W,
                                              n_objects=3, seed=s), 0, T)
            for s in range(S)]
    return (jnp.stack([d[0] for d in data]),
            jnp.stack([d[1] for d in data]),
            jnp.stack([d[2] for d in data]))


def _scalars(S):
    return dict(tr1=jnp.full((S,), 0.05), tr2=jnp.full((S,), 0.1),
                bw_kbps=jnp.asarray([6000.0, 3000.0, 1500.0, 8000.0,
                                     2000.0, 900.0, 4000.0, 700.0][:S]),
                queue_delay=jnp.zeros((S,)))


def _assert_lane_equal(lane: dict, ref: dict, label: str):
    assert set(lane) == set(ref)
    for k in ref:
        np.testing.assert_array_equal(
            np.asarray(lane[k]), np.asarray(ref[k]),
            err_msg=f"{label}: key {k!r} diverged from the two-jit oracle")


def _oracle_lane(raw, gtb, gtv, params, sc: dict, s: int, cfg):
    return roundtrip_oracle(
        raw[s], gtb[s], gtv[s], params, tr1=float(sc["tr1"][s]),
        tr2=float(sc["tr2"][s]), bw_kbps=float(sc["bw_kbps"][s]),
        queue_delay=float(sc["queue_delay"][s]), cfg=cfg)


# ------------------------------------------------- single-stream round trip
def test_roundtrip_chunk_matches_oracle(det, cfg):
    params, _ = det
    raw, gtb, gtv = _streams(1)
    sc = _scalars(1)
    fused = roundtrip_chunk(raw[0], gtb[0], gtv[0], params, tr1=0.05,
                            tr2=0.1, bw_kbps=6000.0, cfg=cfg)
    oracle = _oracle_lane(raw, gtb, gtv, params, sc, 0, cfg)
    _assert_lane_equal(fused, oracle, "roundtrip_chunk")


def test_roundtrip_chunk_is_one_jit_boundary(det, cfg):
    params, _ = det
    assert hasattr(roundtrip_chunk, "lower")
    raw, gtb, gtv = _streams(1)
    out = roundtrip_chunk(raw[0], gtb[0], gtv[0], params, tr1=0.05,
                          tr2=0.1, bw_kbps=6000.0, cfg=cfg)
    assert all(isinstance(v, jax.Array) for v in out.values())


def test_roundtrip_rate_model_consistency(det, cfg):
    """total_bits = video + anchor; latency = trans + queue + compute; the
    chunk I-frame is always an anchor so anchor bits are never zero."""
    params, _ = det
    raw, gtb, gtv = _streams(1)
    out = roundtrip_chunk(raw[0], gtb[0], gtv[0], params, tr1=0.05,
                          tr2=0.1, bw_kbps=6000.0, queue_delay=0.02,
                          cfg=cfg)
    assert float(out["total_bits"]) == pytest.approx(
        float(out["video_bits"]) + float(out["anchor_bits"]))
    assert float(out["latency"]) == pytest.approx(
        float(out["t_trans"]) + 0.02 + float(out["t_comp"]), rel=1e-6)
    assert int(out["types"][0]) == 1 and float(out["anchor_bits"]) > 0.0
    assert float(out["t_trans"]) == pytest.approx(
        float(out["total_bits"]) / (6000.0 * 1000.0), rel=1e-6)


# ------------------------------------------------------- batched round trip
@pytest.mark.parametrize("S", [1, 3, 4, 8])
def test_roundtrip_batched_matches_oracle(det, cfg, S):
    params, _ = det
    raw, gtb, gtv = _streams(S)
    sc = _scalars(S)
    out = roundtrip_batched(raw, gtb, gtv, params, cfg=cfg, **sc)
    for s in range(S):
        lane = {k: v[s] for k, v in out.items()}
        _assert_lane_equal(lane, _oracle_lane(raw, gtb, gtv, params, sc, s,
                                              cfg), f"batched[{s}/{S}]")


def test_roundtrip_ladder_batched_mixed_rungs(det, cfg):
    """A mixed bitrate-ladder batch (one padded encode dispatch) is lane-
    for-lane bit-exact vs each stream's own single-rung two-jit oracle."""
    params, _ = det
    S = len(MIXED_LEVELS)
    raw, gtb, gtv = _streams(S)
    sc = _scalars(S)
    out = roundtrip_ladder_batched(raw, gtb, gtv, params,
                                   levels=MIXED_LEVELS, cfg=cfg, **sc)
    for s, level in enumerate(MIXED_LEVELS):
        ocfg = dataclasses.replace(cfg, level=level)
        lane = {k: v[s] for k, v in out.items()}
        _assert_lane_equal(lane, _oracle_lane(raw, gtb, gtv, params, sc, s,
                                              ocfg), f"ladder[{s}]")


def test_roundtrip_padded_batched_full_canvas_matches_oracle(det, cfg):
    """The env's shape-stable dispatch (fixed FULL-size LR canvas, rungs
    as data) is lane-for-lane bit-exact vs each stream's own single-rung
    two-jit oracle — canvas margin beyond the batch's largest rung is
    irrelevant to the masked encode."""
    from repro.codec.rate_model import (QUALITY_LADDER, downscale,
                                        ladder_lr_shape)
    from repro.core.roundtrip import full_lr_canvas, roundtrip_padded_batched
    params, _ = det
    S = len(MIXED_LEVELS)
    raw, gtb, gtv = _streams(S)
    sc = _scalars(S)
    hp, wp = full_lr_canvas(H, W)
    lr_pad, extents, quals = [], [], []
    for s, level in enumerate(MIXED_LEVELS):
        lr = downscale(raw[s], QUALITY_LADDER[level].scale)
        h, w = ladder_lr_shape(level, H, W)
        lr_pad.append(jnp.pad(lr, ((0, 0), (0, hp - h), (0, wp - w))))
        extents.append((h, w))
        quals.append(QUALITY_LADDER[level].quality)
    out = roundtrip_padded_batched(
        raw, jnp.stack(lr_pad), jnp.asarray(extents, jnp.int32),
        jnp.asarray(quals, jnp.float32), gtb, gtv, params, cfg=cfg, **sc)
    for s, level in enumerate(MIXED_LEVELS):
        ocfg = dataclasses.replace(cfg, level=level)
        lane = {k: v[s] for k, v in out.items()}
        _assert_lane_equal(lane, _oracle_lane(raw, gtb, gtv, params, sc, s,
                                              ocfg), f"padded[{s}]")


def test_roundtrip_ladder_batched_uniform_matches_batched(det, cfg):
    """All-equal rungs through the padded heterogeneous path reproduce the
    homogeneous vmap exactly (full-extent masking is the identity)."""
    params, _ = det
    raw, gtb, gtv = _streams(3)
    sc = _scalars(3)
    hom = roundtrip_batched(raw, gtb, gtv, params, cfg=cfg, **sc)
    het = roundtrip_ladder_batched(raw, gtb, gtv, params,
                                   levels=(cfg.level,) * 3, cfg=cfg, **sc)
    for k in hom:
        np.testing.assert_array_equal(np.asarray(het[k]),
                                      np.asarray(hom[k]), err_msg=k)


def test_env_detector_backend_uses_roundtrip(det):
    """The sim env's detector backend dispatches per signature group and
    reports the round-trip's accuracy/latency/bits per stream."""
    from repro.sim.env import EnvConfig, MultiStreamEnv
    from repro.sim.video_source import paper_stream_mix
    params, det_cfg = det
    cfg = EnvConfig(streams=tuple(paper_stream_mix(3, H, W)),
                    chunk_frames=T, accuracy_backend="detector")
    env = MultiStreamEnv(cfg, detector=(params, det_cfg))
    results, info = env.step(np.full(3, 1 / 3),
                             np.full((3, 2), 0.05, np.float32))
    assert len(results) == 3
    for c, r in enumerate(results):
        assert r["stream"] == c
        assert r["n_anchor"] >= 1              # I-frame is always an anchor
        assert r["n_anchor"] + r["n_transfer"] == r["n_infer"]
        assert r["bits"] > 0 and r["latency"] > 0
        assert 0.0 <= r["accuracy"] <= 1.0
        assert r["types"].shape == (T,)


def test_shard_roundtrip_single_device_matches_batched(det, cfg):
    """On a 1-extent mesh the sharded wrapper degrades to the batched path
    — parity guards the padding/broadcast plumbing."""
    params, _ = det
    mesh = jax.make_mesh((len(jax.devices()),), ("data",))
    raw, gtb, gtv = _streams(3)
    sc = _scalars(3)
    run = shard_roundtrip(mesh, SINGLE_POD_RULES, cfg=cfg)
    out = run(raw, gtb, gtv, params, **sc)
    ref = roundtrip_batched(raw, gtb, gtv, params, cfg=cfg, **sc)
    for k in ref:
        np.testing.assert_array_equal(np.asarray(out[k]),
                                      np.asarray(ref[k]), err_msg=k)


# --------------------------------------------------- forced 4-device child
def test_spawns_multidevice_roundtrip_child():
    """Driver: re-run ONLY this file's ``forced``-named tests under 4
    forced CPU devices (mirrors test_stream_sharding.py)."""
    if _FORCED:
        pytest.skip("already inside the forced multi-device child")
    r = conftest.forced_multidevice_run(
        "tests/test_roundtrip.py", extra_args=["-k", "forced"])
    assert r.returncode == 0, (
        f"forced multi-device round-trip child failed\n--- stdout ---\n"
        f"{r.stdout}\n--- stderr ---\n{r.stderr}")
    assert "passed" in r.stdout


@forced_only
@pytest.mark.parametrize("S", [1, 3, 4, 8])
def test_forced_shard_roundtrip_bit_exact_vs_batched(det, cfg, S):
    """Mesh-sharded round trip equals the single-device batched jit
    bit-for-bit — including S=1 and S=3, which zero-pad the stream axis up
    to the mesh extent and drop the padded lanes on exit."""
    params, _ = det
    mesh = jax.make_mesh((4,), ("data",))
    assert stream_shard_count(mesh, SINGLE_POD_RULES) == 4
    raw, gtb, gtv = _streams(S)
    sc = _scalars(S)
    run = shard_roundtrip(mesh, SINGLE_POD_RULES, cfg=cfg)
    out = run(raw, gtb, gtv, params, **sc)
    ref = roundtrip_batched(raw, gtb, gtv, params, cfg=cfg, **sc)
    for k in ref:
        np.testing.assert_array_equal(
            np.asarray(out[k]), np.asarray(ref[k]),
            err_msg=f"S={S} key {k!r} diverged under sharding")


@forced_only
def test_forced_shard_roundtrip_mixed_ladder_non_divisible(det, cfg):
    """The heterogeneous-ladder batch shards too: 3 mixed-rung streams on
    a 4-device mesh (non-divisible — one padded lane) stay bit-exact vs
    the single-device mixed-ladder jit."""
    params, _ = det
    mesh = jax.make_mesh((4,), ("data",))
    S = len(MIXED_LEVELS)
    raw, gtb, gtv = _streams(S)
    sc = _scalars(S)
    run = shard_roundtrip(mesh, SINGLE_POD_RULES, cfg=cfg)
    out = run(raw, gtb, gtv, params, levels=MIXED_LEVELS, **sc)
    ref = roundtrip_ladder_batched(raw, gtb, gtv, params,
                                   levels=MIXED_LEVELS, cfg=cfg, **sc)
    for k in ref:
        np.testing.assert_array_equal(
            np.asarray(out[k]), np.asarray(ref[k]),
            err_msg=f"mixed-ladder key {k!r} diverged under sharding")


@forced_only
def test_forced_shard_roundtrip_two_dimensional_mesh(det, cfg):
    params, _ = det
    mesh = jax.make_mesh((2, 2), ("data", "model"))
    assert stream_shard_count(mesh, SINGLE_POD_RULES_DP) == 4
    raw, gtb, gtv = _streams(4)
    sc = _scalars(4)
    run = shard_roundtrip(mesh, SINGLE_POD_RULES_DP, cfg=cfg)
    out = run(raw, gtb, gtv, params, **sc)
    ref = roundtrip_batched(raw, gtb, gtv, params, cfg=cfg, **sc)
    for k in ref:
        np.testing.assert_array_equal(np.asarray(out[k]),
                                      np.asarray(ref[k]), err_msg=k)
