"""Beyond-paper extensions: int8 KV cache, DiT step-cached sampling."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

KEY = jax.random.PRNGKey(0)


def test_int8_kv_cache_decode_close_to_bf16():
    from repro.configs import get_arch, ShapeCase
    from repro.launch.steps import build_cell, materialize
    arch = get_arch("llama3_2_1b", reduced=True)
    case = ShapeCase("d", "decode", batch=2, seq_len=64)
    # bf16 cache
    cell = build_cell(arch, case)
    params, cache, batch = materialize(KEY, arch, case)
    logits_bf16, _ = jax.jit(cell.fn)(params, cache, batch)
    # int8 cache (same params; fresh quantized cache)
    arch8 = dataclasses.replace(
        arch, cfg=dataclasses.replace(arch.cfg, kv_cache_dtype="int8"))
    cell8 = build_cell(arch8, case)
    _, cache8, _ = materialize(KEY, arch8, case)
    logits_int8, new_cache = jax.jit(cell8.fn)(params, cache8, batch)
    assert new_cache["k"].dtype == jnp.int8
    a = np.asarray(jax.nn.softmax(logits_bf16, -1), np.float32)
    b = np.asarray(jax.nn.softmax(logits_int8, -1), np.float32)
    # caches start empty, so only the new token is attended: distributions
    # must match closely despite 8-bit storage
    np.testing.assert_allclose(a, b, atol=0.05)


def test_int8_cache_halves_bytes():
    from repro.configs import get_arch
    from repro.models import transformer_lm as M
    from repro.models.params import param_bytes
    cfg = get_arch("llama3_2_1b").cfg
    bf16 = param_bytes(M.init_cache_specs(cfg, 128, 32768))
    cfg8 = dataclasses.replace(cfg, kv_cache_dtype="int8")
    int8 = param_bytes(M.init_cache_specs(cfg8, 128, 32768))
    assert int8 < 0.6 * bf16        # ~0.53x (values + scales)


def test_dit_step_cache_matches_full_sampling():
    from repro.configs import get_arch
    from repro.models import dit as M
    from repro.models.params import init_params
    arch = get_arch("dit_b2", reduced=True)
    cfg = arch.cfg
    params = init_params(KEY, M.param_specs(cfg))
    lr = cfg.latent_res(32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, lr, lr, 4), jnp.float32)
    y = jnp.zeros((2,), jnp.int32)
    ts = list(range(1000, -1, -125))          # 9 timesteps, 8 updates
    full = M.sample_with_cache(params, cfg, x, ts, y, refresh_every=1)
    cached = M.sample_with_cache(params, cfg, x, ts, y, refresh_every=2)
    # half the DNN forwards; trajectories stay close (untrained net ->
    # compare relative deviation against the signal scale)
    rel = float(jnp.linalg.norm(full - cached) /
                jnp.maximum(jnp.linalg.norm(full), 1e-9))
    assert rel < 0.35, rel
    assert np.isfinite(np.asarray(cached)).all()
