"""Shared fixtures.  NOTE: no XLA_FLAGS here — smoke tests and benches must
see the real (1-device) platform; only launch/dryrun.py forces 512 fake
devices, in its own process."""
import sys

try:                # real hypothesis wins whenever it is installed
    import hypothesis  # noqa: F401
except ImportError:
    # this container cannot pip-install; property tests fall back to the
    # deterministic shim (src/_hypothesis_shim.py, on PYTHONPATH=src)
    import _hypothesis_shim

    sys.modules["hypothesis"] = _hypothesis_shim
    sys.modules["hypothesis.strategies"] = _hypothesis_shim.strategies

import jax
import pytest


@pytest.fixture(scope="session")
def key():
    return jax.random.PRNGKey(0)
