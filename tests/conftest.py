"""Shared fixtures.  NOTE: no XLA_FLAGS here — smoke tests and benches must
see the real (1-device) platform; multi-device coverage runs in its own
subprocess via :func:`forced_multidevice_run` (and launch/dryrun.py forces
512 fake devices the same way)."""
import os
import subprocess
import sys

try:                # real hypothesis wins whenever it is installed
    import hypothesis  # noqa: F401
except ImportError:
    # this container cannot pip-install; property tests fall back to the
    # deterministic shim (src/_hypothesis_shim.py, on PYTHONPATH=src)
    import _hypothesis_shim

    sys.modules["hypothesis"] = _hypothesis_shim
    sys.modules["hypothesis.strategies"] = _hypothesis_shim.strategies

import jax
import pytest

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# set in child processes spawned by forced_multidevice_run: tests that need
# a real multi-device platform skip themselves unless this is present
FORCED_MULTIDEVICE_ENV = "BISWIFT_FORCED_MULTIDEVICE"


def forced_multidevice_env(n_devices: int = 4) -> dict:
    """Environment for a subprocess with ``n_devices`` fake CPU devices.

    XLA only honours --xla_force_host_platform_device_count before the
    first jax import, which has already happened in the test process —
    hence a fresh subprocess rather than a fixture-scoped flag."""
    env = dict(os.environ)
    # append (not clobber) so caller/CI XLA flags survive; ours wins on
    # conflict because XLA takes the last occurrence
    env["XLA_FLAGS"] = (
        env.get("XLA_FLAGS", "")
        + f" --xla_force_host_platform_device_count={n_devices}").strip()
    env["JAX_PLATFORMS"] = "cpu"
    env[FORCED_MULTIDEVICE_ENV] = str(n_devices)
    src = os.path.join(REPO_ROOT, "src")
    env["PYTHONPATH"] = src + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    return env


def forced_multidevice_run(pytest_target: str, n_devices: int = 4,
                           timeout: float = 900.0,
                           extra_args: list | None = None):
    """Run ``pytest <pytest_target>`` in a forced-multi-device subprocess.

    ``extra_args`` (e.g. a ``-k`` selection) keeps the child from
    re-running tests already covered in the parent process."""
    return subprocess.run(
        [sys.executable, "-m", "pytest", "-q", "-x",
         "-p", "no:cacheprovider", *(extra_args or []), pytest_target],
        env=forced_multidevice_env(n_devices), cwd=REPO_ROOT,
        capture_output=True, text=True, timeout=timeout)


def pytest_configure(config):
    config.addinivalue_line(
        "markers", "slow: long-running integration test (still tier-1; "
        "deselect with -m 'not slow' for a quick pass)")


@pytest.fixture(scope="session")
def key():
    return jax.random.PRNGKey(0)
