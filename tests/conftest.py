"""Shared fixtures.  NOTE: no XLA_FLAGS here — smoke tests and benches must
see the real (1-device) platform; only launch/dryrun.py forces 512 fake
devices, in its own process."""
import jax
import pytest


@pytest.fixture(scope="session")
def key():
    return jax.random.PRNGKey(0)
