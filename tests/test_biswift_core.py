"""BiSwift core invariants: Eq.3 classification, quality transfer gain,
reuse shifting, fairness metrics, hybrid encoder budgets."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.classification import classify_frames, pipeline_fractions
from repro.core.fairness import jain_index, min_reward_fairness
from repro.core.quality_transfer import transfer_frame, transfer_gain_psnr
from repro.core.reuse import shift_boxes
from repro.sim.video_source import StreamConfig, generate_chunk

KEY = jax.random.PRNGKey(0)


# ------------------------------------------------------------ Eq. 3
def test_classification_extreme_thresholds():
    fd = jnp.asarray(np.random.default_rng(0).uniform(0, 0.3, 10))
    rm = fd * 0.5
    # huge thresholds -> everything (but frame 0) is reuse
    t, _, _ = classify_frames(fd, rm, 1e9, 1e9)
    assert int(t[0]) == 1 and (np.asarray(t[1:]) == 3).all()
    # tr1 = -inf -> everything is an anchor
    t, _, _ = classify_frames(fd, rm, -1.0, 1e9)
    assert (np.asarray(t) == 1).all()
    # tr1 huge, tr2 = -1 -> type 2 everywhere after frame 0
    t, _, _ = classify_frames(fd, rm, 1e9, -1.0)
    assert (np.asarray(t[1:]) == 2).all()


@settings(deadline=None, max_examples=20)
@given(tr1=st.floats(0.0, 0.5), tr2=st.floats(0.0, 0.5))
def test_classification_resets_accumulators(tr1, tr2):
    """After any inferred frame (type 1/2), accumulated X restarts below
    tr1 on the next frame unless that frame's own diff exceeds it."""
    fd = jnp.asarray(np.random.default_rng(1).uniform(0, 0.2, 16))
    rm = fd
    types, X, R = classify_frames(fd, rm, tr1, tr2)
    types, X = np.asarray(types), np.asarray(X)
    for i in range(1, 16):
        if types[i - 1] != 3:       # accumulator reset at i-1
            assert X[i] == pytest.approx(float(fd[i]), abs=1e-5)


def test_pipeline_fractions_sum_to_one():
    fd = jnp.asarray(np.random.default_rng(2).uniform(0, 0.3, 30))
    t, _, _ = classify_frames(fd, fd, 0.1, 0.1)
    f = np.asarray(pipeline_fractions(t))
    assert f.sum() == pytest.approx(1.0, abs=1e-6)


# ------------------------------------------------------- quality transfer
def test_transfer_beats_plain_upscale():
    """Paper Fig. 8a: transfer from an HD anchor beats nearest upscale."""
    from repro.codec.rate_model import downscale, upscale_nearest
    frames, _, _ = generate_chunk(KEY, StreamConfig(height=64, width=96,
                                                    n_objects=4), 0, 2)
    raw = frames[1]
    anchor = frames[0]                      # HD anchor = previous frame
    lr_up = upscale_nearest(downscale(frames[1:2], 0.25), 64, 96)[0]
    from repro.codec.motion import block_sad
    mv, _ = block_sad(raw, anchor, radius=8)
    enhanced = transfer_frame(anchor, mv, jnp.zeros_like(raw))
    gain = transfer_gain_psnr(raw, lr_up, enhanced)
    assert float(gain) > 3.0                # >3 dB over nearest upscale


# ------------------------------------------------------------------ reuse
def test_reuse_shifts_by_mean_mv():
    """Codec MV (3, -2) => object displacement (-3, +2)."""
    boxes = jnp.asarray([[32.0, 32.0, 16.0, 16.0]])
    scores = jnp.asarray([0.9])
    mv = jnp.zeros((4, 4, 2), jnp.int32).at[..., 0].set(3).at[..., 1].set(-2)
    shifted, sc = shift_boxes(boxes, scores, mv)
    np.testing.assert_allclose(np.asarray(shifted[0, :2]), [29.0, 34.0],
                               atol=1e-4)
    assert float(sc[0]) == pytest.approx(0.9)


# --------------------------------------------------------------- fairness
def test_fairness_metrics():
    assert float(min_reward_fairness(jnp.asarray([0.3, 0.8]))) == \
        pytest.approx(0.3)
    assert float(jain_index(jnp.asarray([1.0, 1.0, 1.0]))) == \
        pytest.approx(1.0, abs=1e-6)
    assert float(jain_index(jnp.asarray([1.0, 0.0, 0.0]))) == \
        pytest.approx(1 / 3, abs=1e-6)


# --------------------------------------------------------- hybrid encoder
def test_hybrid_encoder_respects_bandwidth_ordering():
    frames, _, _ = generate_chunk(KEY, StreamConfig(height=64, width=96),
                                  0, 4)
    from repro.core.hybrid_encoder import encode_hybrid
    lo = encode_hybrid(np.asarray(frames), 1200.0, 0.05, 0.1)
    hi = encode_hybrid(np.asarray(frames), 20000.0, 0.05, 0.1)
    assert hi.ladder_level >= lo.ladder_level
    assert hi.anchor_quality >= lo.anchor_quality
    assert (lo.types == 1).sum() >= 1       # chunk I-frame is an anchor
