"""Regenerate ``tests/golden/codec_golden.npz`` from the LEGACY scan oracle.

The fixture pins ``EncodedChunk`` field checksums (per-frame recon PSNR,
bits, residual magnitudes, frame diffs, MV component histograms, quant
table) for two chunk shapes, computed with the motion search forced
through ``repro.codec.motion.block_sad_scan`` — the scan-over-candidates
oracle every newer search path (vmapped fallback, Pallas kernel, batched
encode) must reproduce bit-exactly in f32.

Run from the repo root whenever the codec *intentionally* changes:

    PYTHONPATH=src python tests/golden/generate_codec_golden.py --force

and commit the refreshed .npz together with the change that motivated it.
The ``--force`` flag is required to overwrite an existing fixture — a
bare run refuses, so a stray invocation cannot silently re-baseline the
regression net around an unintended codec drift.
"""
from __future__ import annotations

import os
import sys

import jax
import numpy as np

import repro.codec.motion as M
import repro.codec.video_codec as VC
from repro.codec.video_codec import VideoCodecConfig, chunk_psnr
from repro.sim.video_source import StreamConfig, generate_chunk

OUT = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                   "codec_golden.npz")

# Two chunk shapes: the CI workhorse and a short non-square chunk.
CASES = {
    "a": dict(T=4, height=64, width=96, n_objects=3, seed=0,
              quality=50.0, radius=8),
    "b": dict(T=3, height=48, width=80, n_objects=5, seed=7,
              quality=30.0, radius=4),
}


def golden_frames(case: dict):
    sc = StreamConfig(height=case["height"], width=case["width"],
                      n_objects=case["n_objects"], seed=case["seed"])
    frames, _, _ = generate_chunk(None, sc, 0, case["T"])
    return frames


def mv_histograms(mv: np.ndarray, radius: int) -> np.ndarray:
    """(2, 2R+1) per-component counts over the candidate range."""
    side = 2 * radius + 1
    return np.stack([
        np.bincount(mv[..., i].reshape(-1) + radius, minlength=side)
        for i in (0, 1)]).astype(np.int64)


def checksums(frames, enc, radius: int) -> dict:
    return {
        "psnr": np.asarray(chunk_psnr(frames, enc.recon), np.float32),
        "bits": np.asarray(enc.bits, np.float32),
        "residual_mag": np.asarray(enc.residual_mag, np.float32),
        "frame_diff": np.asarray(enc.frame_diff, np.float32),
        "qtab": np.asarray(enc.qtab, np.float32),
        "mv_hist": mv_histograms(np.asarray(enc.mv), radius),
    }


def encode_with_scan_oracle(frames, cfg: VideoCodecConfig):
    """Encode with the motion search pinned to the legacy scan oracle —
    a fresh jit around the unjitted body so the module-level
    ``encode_chunk`` cache never sees the patched search."""
    orig = M.block_sad
    M.block_sad = lambda cur, ref, radius=8, **_kw: \
        M.block_sad_scan(cur, ref, radius)
    try:
        return jax.jit(VC._encode_chunk, static_argnums=1)(frames, cfg)
    finally:
        M.block_sad = orig


def main(argv=None) -> None:
    argv = sys.argv[1:] if argv is None else argv
    if os.path.exists(OUT) and "--force" not in argv:
        sys.exit(
            f"refusing to overwrite {OUT}: the golden fixture is the codec "
            "regression baseline.  Re-run with --force ONLY for an "
            "intentional codec change, and commit the refreshed .npz "
            "together with the change that motivated it.")
    payload = {}
    for name, case in CASES.items():
        frames = golden_frames(case)
        cfg = VideoCodecConfig(quality=case["quality"],
                               search_radius=case["radius"])
        enc = encode_with_scan_oracle(frames, cfg)
        for key, val in checksums(frames, enc, case["radius"]).items():
            payload[f"{name}_{key}"] = val
        print(f"case {name}: shape {tuple(frames.shape)} "
              f"psnr {payload[f'{name}_psnr']}")
    np.savez(OUT, **payload)
    print(f"wrote {OUT} ({len(payload)} arrays)")


if __name__ == "__main__":
    main()
