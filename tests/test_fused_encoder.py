"""Fused encoder path: vmapped-fallback-vs-scan-oracle parity (property
based), warp/accumulate invariants, encoder edge cases (T=1, non-square,
GOP boundaries), batched/sharded encode parity, and the bf16 kernel
variants.

Like ``test_stream_sharding.py``, the mesh-parity matrix needs a real
multi-device platform: a driver test re-runs this file's ``forced``-named
tests in a subprocess with 4 fake CPU devices
(``conftest.forced_multidevice_run``).
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from jax import lax

import conftest
from repro.codec.motion import (MB, accumulate_mv, block_sad, block_sad_scan,
                                diamond_num_evals, diamond_steps, warp_blocks)
from repro.kernels.motion_sad.ops import motion_sad
from repro.codec.rate_model import QUALITY_LADDER, downscale, ladder_lr_shape
from repro.codec.video_codec import (VideoCodecConfig, encode_chunk,
                                     encode_chunk_batched,
                                     encode_chunk_ladder_batched,
                                     pad_ladder_batch)
from repro.distributed.sharding import SINGLE_POD_RULES, SINGLE_POD_RULES_DP
from repro.distributed.stream_sharding import shard_encode, stream_shard_count
from repro.sim.video_source import (StreamConfig, generate_chunk,
                                    generate_chunk_batched)

_FORCED = int(os.environ.get(conftest.FORCED_MULTIDEVICE_ENV, "0"))

forced_only = pytest.mark.skipif(
    _FORCED < 4, reason="needs the forced multi-device child process")

CFG = VideoCodecConfig(quality=50.0, search_radius=4)


def _streams(S, T=3, H=32, W=48):
    cfgs = [StreamConfig(height=H, width=W, n_objects=2, seed=s)
            for s in range(S)]
    frames, _, _ = generate_chunk_batched(cfgs, 0, T)
    return frames


def _block_sads(cur, pred):
    d = jnp.abs(cur.astype(jnp.float32) - pred.astype(jnp.float32))
    nby, nbx = cur.shape[0] // MB, cur.shape[1] // MB
    return d.reshape(nby, MB, nbx, MB).sum(axis=(1, 3))


def _assert_enc_equal(a, b, err=""):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y),
                                      err_msg=err)


# ----------------------------------------- vmapped fallback vs scan oracle
@settings(deadline=None, max_examples=10)
@given(nby=st.integers(1, 4), nbx=st.integers(1, 5),
       radius=st.sampled_from([2, 4, 8]), seed=st.integers(0, 9999))
def test_block_sad_fallback_matches_scan_property(nby, nbx, radius, seed):
    """The per-macroblock-window fallback reproduces the legacy whole-frame
    scan over random grids/radii/contents: MVs bit-exact, SADs to fp
    tolerance."""
    H, W = nby * MB, nbx * MB
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    cur = jax.random.uniform(k1, (H, W), jnp.float32) * 255
    ref = jnp.roll(cur, (seed % 3 - 1, -(seed % 5 - 2)), (0, 1)) \
        + jax.random.normal(k2, (H, W)) * 1.5
    mv_v, sad_v = block_sad(cur, ref, radius)
    mv_s, sad_s = block_sad_scan(cur, ref, radius)
    np.testing.assert_array_equal(np.asarray(mv_v), np.asarray(mv_s))
    np.testing.assert_allclose(np.asarray(sad_v), np.asarray(sad_s),
                               rtol=1e-6, atol=1e-4)


@settings(deadline=None, max_examples=8)
@given(nby=st.integers(1, 3), nbx=st.integers(1, 4),
       radius=st.sampled_from([2, 4]), period=st.integers(1, 7),
       vertical=st.booleans())
def test_block_sad_fallback_tie_breaking_property(nby, nbx, radius, period,
                                                  vertical):
    """Periodic stripes tie whole bands of candidates; the fallback must
    resolve them first-wins in dy-major order exactly like the scan."""
    H, W = nby * MB, nbx * MB
    ramp = (jnp.arange(H if vertical else W) % period).astype(jnp.float32)
    frame = jnp.tile(ramp[:, None], (1, W)) if vertical \
        else jnp.tile(ramp[None, :], (H, 1))
    mv_v, sad_v = block_sad(frame, frame, radius)
    mv_s, sad_s = block_sad_scan(frame, frame, radius)
    np.testing.assert_array_equal(np.asarray(mv_v), np.asarray(mv_s))
    np.testing.assert_allclose(np.asarray(sad_v), np.asarray(sad_s),
                               rtol=1e-6, atol=1e-4)


@settings(deadline=None, max_examples=8)
@given(radius=st.sampled_from([2, 4, 8]), seed=st.integers(0, 999))
def test_warp_prediction_error_never_exceeds_zero_mv(radius, seed):
    """warp_blocks∘block_sad: motion-compensated prediction error is
    per-block no worse than the zero-MV (no-motion) prediction — the
    (0, 0) candidate is always in the search set, so the argmin can only
    improve on it."""
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    cur = jax.random.uniform(k1, (48, 64), jnp.float32) * 255
    ref = jnp.roll(cur, (seed % 5 - 2, -(seed % 7 - 3)), (0, 1)) \
        + jax.random.normal(k2, (48, 64)) * 3
    mv, best_sad = block_sad(cur, ref, radius)
    pred = warp_blocks(ref, mv)
    zero = warp_blocks(ref, jnp.zeros_like(mv))
    sad_pred = np.asarray(_block_sads(cur, pred))
    sad_zero = np.asarray(_block_sads(cur, zero))
    assert (sad_pred <= sad_zero + 1e-3).all()
    # the search's reported SAD is the SAD of the compensated prediction
    np.testing.assert_allclose(sad_pred, np.asarray(best_sad), atol=1e-2)


@settings(deadline=None, max_examples=8)
@given(T=st.integers(1, 6), split=st.integers(1, 5), seed=st.integers(0, 99))
def test_accumulate_mv_chaining_matches_sequential(T, split, seed):
    """cumsum chaining == sequential composition, and accumulating a
    concatenated MV stream == accumulating the parts with the carry."""
    split = min(split, T)
    mvs = jax.random.randint(jax.random.PRNGKey(seed), (T, 2, 3, 2),
                             -8, 9, jnp.int32)
    acc = np.asarray(accumulate_mv(mvs))
    seq = np.zeros_like(acc)
    run = np.zeros(acc.shape[1:], np.int32)
    for t in range(T):
        run = run + np.asarray(mvs[t])
        seq[t] = run
    np.testing.assert_array_equal(acc, seq)
    a, b = mvs[:split], mvs[split:]
    acc_a = accumulate_mv(a)
    chained = jnp.concatenate([acc_a, acc_a[-1][None] + accumulate_mv(b)]
                              if b.shape[0] else [acc_a], axis=0)
    np.testing.assert_array_equal(np.asarray(chained), acc)


# ------------------------------------------- diamond search (quality contract)
def _translated_pair(field, dy, dx, H, W, margin=MB):
    """cur and an EXACT (dy, dx)-translated ref cut from one oversized
    field — no wraparound, so interior macroblocks have a true zero-SAD
    candidate at (dy, dx).  Border blocks still see edge-replicated
    padding instead of the real field, hence the interior restriction in
    the assertions below."""
    cur = lax.dynamic_slice(field, (margin, margin), (H, W))
    ref = lax.dynamic_slice(field, (margin - dy, margin - dx), (H, W))
    return cur, ref


def _interior(a):
    return np.asarray(a)[1:-1, 1:-1]


@settings(deadline=None, max_examples=10)
@given(nby=st.integers(1, 4), nbx=st.integers(1, 5),
       radius=st.sampled_from([2, 4, 8]), seed=st.integers(0, 9999))
def test_block_sad_diamond_never_beats_exhaustive_property(nby, nbx, radius,
                                                           seed):
    """Diamond probes a SUBSET of the exhaustive candidate set with the
    identical per-candidate SAD expression, so its found SAD is ≥ the
    exhaustive minimum EXACTLY (no fp tolerance), and ≤ its own (0, 0)
    starting point (strict-< updates only improve) — on any content,
    including adversarial noise where the greedy descent traps."""
    H, W = nby * MB, nbx * MB
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    cur = jax.random.uniform(k1, (H, W), jnp.float32) * 255
    ref = jnp.roll(cur, (seed % 5 - 2, -(seed % 7 - 3)), (0, 1)) \
        + jax.random.normal(k2, (H, W)) * 4
    _, sad_e = block_sad(cur, ref, radius)
    _, sad_d = block_sad(cur, ref, radius, search="diamond")
    assert (np.asarray(sad_d) >= np.asarray(sad_e)).all()
    sad_zero = np.asarray(_block_sads(cur, ref))   # the (0, 0) candidate
    assert (np.asarray(sad_d) <= sad_zero + 1e-3).all()


@settings(deadline=None, max_examples=12)
@given(radius=st.sampled_from([2, 4, 8]), ringy=st.integers(-1, 1),
       ringx=st.integers(-1, 1), seed=st.integers(0, 9999))
def test_block_sad_diamond_first_ring_translation_exact_property(
        radius, ringy, ringx, seed):
    """A translation on the first diamond ring ({-s0, 0, s0}², s0 the
    largest power of two ≤ R) is found EXACTLY on any non-periodic
    content: the zero-SAD candidate is probed in round one and strict-<
    makes it absorbing.  MVs and SADs equal the exhaustive search
    bit-for-bit (integer-valued frames keep every summation order exact)."""
    s0 = diamond_steps(radius)[0]
    dy, dx = ringy * s0, ringx * s0
    H, W = 64, 96
    field = jnp.round(jax.random.uniform(jax.random.PRNGKey(seed),
                                         (H + 2 * MB, W + 2 * MB)) * 255)
    cur, ref = _translated_pair(field, dy, dx, H, W)
    mv_d, sad_d = block_sad(cur, ref, radius, search="diamond")
    mv_e, sad_e = block_sad(cur, ref, radius)
    assert (_interior(mv_d) == (dy, dx)).all()
    assert (_interior(sad_d) == 0).all()
    np.testing.assert_array_equal(_interior(mv_d), _interior(mv_e))
    np.testing.assert_array_equal(_interior(sad_d), _interior(sad_e))


def test_diamond_candidate_budget():
    """The acceptance contract: ≤ ¼ of the exhaustive candidate count at
    the production radius (37 vs 289 at ±8), and the static schedule halves
    down to a final 1-pel refinement ring at every radius."""
    assert diamond_num_evals(8) * 4 <= 17 * 17
    for radius in (2, 4, 8, 16):
        steps = diamond_steps(radius)
        assert steps[0] * 2 > radius and steps[-1] == 1
        assert all(a == 2 * b for a, b in zip(steps, steps[1:]))
        assert diamond_num_evals(radius) == 1 + 9 * len(steps)


@settings(deadline=None, max_examples=10)
@given(nby=st.integers(1, 3), nbx=st.integers(1, 4),
       radius=st.sampled_from([2, 4, 8]), seed=st.integers(0, 9999),
       bf16=st.booleans())
def test_motion_sad_diamond_kernel_matches_fallback_property(nby, nbx,
                                                             radius, seed,
                                                             bf16):
    """The Pallas diamond kernel replays the fallback's probe schedule
    (same order, same clip, same first-wins) — MVs and SADs bit-exact on
    integer content in BOTH storage dtypes."""
    H, W = nby * MB, nbx * MB
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    cur = jnp.round(jax.random.uniform(k1, (H, W)) * 255)
    ref = jnp.round(jnp.clip(jnp.roll(cur, (seed % 3 - 1, seed % 5 - 2),
                                      (0, 1))
                             + jax.random.normal(k2, (H, W)) * 2, 0, 255))
    dt = jnp.bfloat16 if bf16 else None
    mv_f, sad_f = block_sad(cur, ref, radius, search="diamond", dtype=dt)
    # call the kernel entry directly: block_sad's static dispatch routes
    # small/interpret-mode canvases to the traced descent, which would
    # make this parity check compare the fallback with itself
    mv_k, sad_k = motion_sad(cur, ref, radius=radius, dtype=dt,
                             search="diamond")
    np.testing.assert_array_equal(np.asarray(mv_k), np.asarray(mv_f))
    np.testing.assert_array_equal(np.asarray(sad_k), np.asarray(sad_f))


def test_block_sad_rejects_unknown_search():
    cur = jnp.zeros((32, 32), jnp.float32)
    with pytest.raises(ValueError, match="unknown search strategy"):
        block_sad(cur, cur, 4, search="hexagon")


# ----------------------------------- retiled exhaustive kernel bit-exactness
@settings(deadline=None, max_examples=12)
@given(nby=st.integers(1, 4), nbx=st.integers(1, 5),
       radius=st.sampled_from([2, 4, 8]), seed=st.integers(0, 9999),
       bf16=st.booleans())
def test_motion_sad_kernel_bit_exact_vs_scan_property(nby, nbx, radius,
                                                      seed, bf16):
    """The retiled kernel (multi-row grid steps, fast two-stage selection
    reduce + oracle-order winner recompute) reproduces ``block_sad_scan``
    bit-for-bit — MVs including tie-breaks AND SADs — on integer-valued
    (real-video-domain) frames, where every f32 summation order is exact.
    bf16 storage is lossless for 0..255 integers, so even the bf16 kernel
    must match the f32 scan oracle exactly."""
    H, W = nby * MB, nbx * MB
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    cur = jnp.round(jax.random.uniform(k1, (H, W)) * 255)
    ref = jnp.round(jnp.clip(jnp.roll(cur, (seed % 5 - 2, seed % 7 - 3),
                                      (0, 1))
                             + jax.random.normal(k2, (H, W)) * 3, 0, 255))
    mv_s, sad_s = block_sad_scan(cur, ref, radius)
    mv_k, sad_k = motion_sad(cur, ref, radius=radius, interpret=True,
                             dtype=jnp.bfloat16 if bf16 else None)
    np.testing.assert_array_equal(np.asarray(mv_k), np.asarray(mv_s))
    np.testing.assert_array_equal(np.asarray(sad_k), np.asarray(sad_s))


@settings(deadline=None, max_examples=8)
@given(nby=st.integers(1, 3), nbx=st.integers(1, 4),
       radius=st.sampled_from([2, 4]), period=st.integers(1, 7),
       vertical=st.booleans())
def test_motion_sad_kernel_tie_breaking_property(nby, nbx, radius, period,
                                                 vertical):
    """Periodic stripes tie whole bands of candidates; the kernel's
    selection loop must resolve them first-wins in dy-major order exactly
    like the scan oracle — the case a fast-but-reordered reduce would
    silently break."""
    H, W = nby * MB, nbx * MB
    ramp = (jnp.arange(H if vertical else W) % period).astype(jnp.float32)
    frame = jnp.tile(ramp[:, None], (1, W)) if vertical \
        else jnp.tile(ramp[None, :], (H, 1))
    mv_k, sad_k = motion_sad(frame, frame, radius=radius, interpret=True)
    mv_s, sad_s = block_sad_scan(frame, frame, radius)
    np.testing.assert_array_equal(np.asarray(mv_k), np.asarray(mv_s))
    np.testing.assert_array_equal(np.asarray(sad_k), np.asarray(sad_s))


@pytest.mark.parametrize("H,W,radius", [(64, 96, 8), (80, 112, 4),
                                        (32, 48, 2)])
def test_motion_sad_kernel_matches_scan_continuous(H, W, radius):
    """Deterministic continuous-f32 fixtures: MVs bit-exact, SADs to fp
    tolerance (the winner recompute replays the oracle's per-block reduce
    order, so in practice these are bit-equal too)."""
    k1, k2 = jax.random.split(jax.random.PRNGKey(11))
    cur = jax.random.uniform(k1, (H, W), jnp.float32) * 255
    ref = jnp.roll(cur, (3, -2), (0, 1)) + jax.random.normal(k2, (H, W)) * 2
    mv_s, sad_s = block_sad_scan(cur, ref, radius)
    mv_k, sad_k = motion_sad(cur, ref, radius=radius, interpret=True)
    np.testing.assert_array_equal(np.asarray(mv_k), np.asarray(mv_s))
    np.testing.assert_allclose(np.asarray(sad_k), np.asarray(sad_s),
                               rtol=1e-6, atol=1e-4)


# ------------------------------------------------------ encoder edge cases
def test_encode_single_frame_chunk_is_iframe_only():
    frames = _streams(1, T=1)[0]
    enc = encode_chunk(frames, CFG)
    assert enc.recon.shape == frames.shape
    assert enc.mv.shape == (1, 2, 3, 2) and (np.asarray(enc.mv) == 0).all()
    assert enc.bits.shape == (1,) and float(enc.bits[0]) > 0
    assert float(enc.frame_diff[0]) == 0.0
    # batched T=1 stays consistent
    encb = encode_chunk_batched(_streams(2, T=1), CFG)
    assert encb.recon.shape == (2, 1, 32, 48)


@pytest.mark.parametrize("H,W", [(48, 80), (128, 64), (32, 144)])
def test_encode_non_square_frames(H, W):
    sc = StreamConfig(height=H, width=W, n_objects=3, seed=1)
    frames, _, _ = generate_chunk(None, sc, 0, 3)
    enc = encode_chunk(frames, CFG)
    enc_k = encode_chunk(frames, VideoCodecConfig(
        quality=50.0, search_radius=4, use_kernel=True))
    assert enc.mv.shape == (3, H // MB, W // MB, 2)
    _assert_enc_equal(enc, enc_k, err=f"kernel parity at {H}x{W}")


def test_gop_boundary_alignment():
    """Chunks cut at GOP boundaries are self-contained: the tail chunk of
    a continuous scene encodes identically whether its frames come from a
    long render or a t0-offset render (producer continuity), and frame 0
    of every chunk is an I-frame (zero MV row)."""
    sc = StreamConfig(height=32, width=48, n_objects=2, seed=5)
    T = 4
    long, _, _ = generate_chunk(None, sc, 0, 2 * T)
    tail, _, _ = generate_chunk(None, sc, T, T)
    np.testing.assert_array_equal(np.asarray(long[T:]), np.asarray(tail))
    _assert_enc_equal(encode_chunk(long[T:], CFG), encode_chunk(tail, CFG),
                      err="GOP-aligned tail chunk diverged")
    for chunk in (long[:T], tail):
        assert (np.asarray(encode_chunk(chunk, CFG).mv[0]) == 0).all()


@pytest.mark.parametrize("S", [1, 3, 4, 8])
def test_encode_batched_matches_per_stream(S):
    frames = _streams(S)
    enc = encode_chunk_batched(frames, CFG)
    for s in range(S):
        _assert_enc_equal(jax.tree.map(lambda x: x[s], enc),
                          encode_chunk(frames[s], CFG),
                          err=f"stream {s} of {S}")


def test_shard_encode_single_device_matches_oracle():
    """The sharded wrapper degrades to the vmap oracle on a 1-extent mesh
    — parity here guards the zero-padding/unpadding plumbing."""
    mesh = jax.make_mesh((len(jax.devices()),), ("data",))
    frames = _streams(3)
    run = shard_encode(mesh, SINGLE_POD_RULES, cfg=CFG)
    _assert_enc_equal(run(frames), encode_chunk_batched(frames, CFG))


# --------------------------------------------- heterogeneous ladder batching
def _mixed_ladder_lrs(levels=(4, 3, 2), H=96, W=160, T=4):
    """Per-stream LR chunks at MIXED ladder rungs from one HD source shape
    (the 1080p/720p/480p analogue at sim scale)."""
    lrs, quals = [], []
    for s, level in enumerate(levels):
        raw, _, _ = generate_chunk(None, StreamConfig(
            height=H, width=W, n_objects=3, seed=s), 0, T)
        lrs.append(downscale(raw, QUALITY_LADDER[level].scale))
        quals.append(QUALITY_LADDER[level].quality)
        assert lrs[-1].shape[1:] == ladder_lr_shape(level, H, W)
    return lrs, jnp.asarray(quals, jnp.float32)


def _assert_ladder_lane_equal(lane, single, h, w, err=""):
    """Valid-extent bit-exactness of one padded lane vs the unpadded
    single-stream encode (padded blocks are zeroed / edge-replicated)."""
    Hp, Wp = lane.recon.shape[1:]
    np.testing.assert_array_equal(np.asarray(lane.recon[:, :h, :w]),
                                  np.asarray(single.recon), err_msg=err)
    np.testing.assert_array_equal(
        np.asarray(lane.mv[:, :h // MB, :w // MB]), np.asarray(single.mv),
        err_msg=err)
    bm = ((np.arange(Hp // 8)[:, None] < h // 8)
          & (np.arange(Wp // 8)[None, :] < w // 8)).reshape(-1)
    np.testing.assert_array_equal(np.asarray(lane.residual_q)[:, bm],
                                  np.asarray(single.residual_q), err_msg=err)
    for field in ("qtab", "bits", "residual_mag", "frame_diff"):
        np.testing.assert_array_equal(np.asarray(getattr(lane, field)),
                                      np.asarray(getattr(single, field)),
                                      err_msg=f"{err}: {field}")


@pytest.mark.parametrize("use_kernel", [False, True],
                         ids=["vmapped_fallback", "kernel"])
def test_encode_ladder_batched_mixed_rungs_bit_exact(use_kernel):
    """One padded dispatch over a 3-rung mixed batch is lane-for-lane
    bit-exact vs sequentially encoding each stream unpadded at its own
    rung — rate model (bits), codec features and recon included."""
    lrs, quals = _mixed_ladder_lrs()
    frames, extents = pad_ladder_batch(lrs)
    cfg = VideoCodecConfig(quality=50.0, search_radius=4,
                           use_kernel=use_kernel)
    enc = encode_chunk_ladder_batched(frames, extents, quals, cfg)
    for s, lr in enumerate(lrs):
        single = encode_chunk(lr, VideoCodecConfig(
            quality=float(quals[s]), search_radius=4, use_kernel=use_kernel))
        lane = jax.tree.map(lambda x: x[s], enc)
        _assert_ladder_lane_equal(lane, single, *lr.shape[1:],
                                  err=f"mixed-rung lane {s}")


def test_encode_ladder_batched_padding_content_irrelevant():
    """Garbage in the padded margin must not leak into any output: the
    masked encode re-edge-replicates the canvas in-trace."""
    lrs, quals = _mixed_ladder_lrs(levels=(4, 2))
    frames, extents = pad_ladder_batch(lrs)
    noise = jax.random.uniform(jax.random.PRNGKey(9), frames.shape) * 255
    h, w = lrs[1].shape[1:]
    poisoned = frames.at[1, :, h:, :].set(noise[1, :, h:, :])
    poisoned = poisoned.at[1, :, :, w:].set(noise[1, :, :, w:])
    cfg = VideoCodecConfig(quality=50.0, search_radius=4)
    a = encode_chunk_ladder_batched(frames, extents, quals, cfg)
    b = encode_chunk_ladder_batched(poisoned, extents, quals, cfg)
    _assert_enc_equal(a, b, err="padding content leaked into the encode")


def test_encode_ladder_batched_full_extent_matches_batched():
    """Uniform rungs through the ladder path == the homogeneous vmap
    (full-extent masking is the identity transformation)."""
    frames = _streams(3)
    S = frames.shape[0]
    extents = jnp.tile(jnp.asarray(frames.shape[2:], jnp.int32), (S, 1))
    quals = jnp.full((S,), CFG.quality, jnp.float32)
    enc = encode_chunk_ladder_batched(frames, extents, quals, CFG)
    _assert_enc_equal(enc, encode_chunk_batched(frames, CFG),
                      err="full-extent ladder encode diverged from vmap")


def test_encode_ladder_batched_padded_outputs_deterministic():
    """Padded MVs/coefficients are zero and the padded recon margin is the
    edge replication of the valid region — downstream consumers can rely
    on the canvas contract."""
    lrs, quals = _mixed_ladder_lrs(levels=(4, 2))
    frames, extents = pad_ladder_batch(lrs)
    enc = encode_chunk_ladder_batched(
        frames, extents, quals, VideoCodecConfig(quality=50.0,
                                                 search_radius=4))
    h, w = lrs[1].shape[1:]
    mv = np.asarray(enc.mv[1])
    assert (mv[:, h // MB:, :] == 0).all() and (mv[:, :, w // MB:] == 0).all()
    recon = np.asarray(enc.recon[1])
    np.testing.assert_array_equal(recon[:, h:, :],
                                  np.broadcast_to(recon[:, h - 1:h, :],
                                                  recon[:, h:, :].shape))
    np.testing.assert_array_equal(recon[:, :, w:],
                                  np.broadcast_to(recon[:, :, w - 1:w],
                                                  recon[:, :, w:].shape))


# ------------------------------------------------------------ bf16 variants
def test_motion_sad_bf16_kernel_matches_bf16_fallback():
    k1, k2 = jax.random.split(jax.random.PRNGKey(2))
    cur = jax.random.uniform(k1, (64, 96), jnp.float32) * 255
    ref = jnp.roll(cur, (2, -1), (0, 1)) + jax.random.normal(k2, (64, 96))
    mv_f, sad_f = block_sad(cur, ref, 4, dtype=jnp.bfloat16)
    mv_k, sad_k = block_sad(cur, ref, 4, use_kernel=True,
                            dtype=jnp.bfloat16)
    np.testing.assert_array_equal(np.asarray(mv_f), np.asarray(mv_k))
    np.testing.assert_allclose(np.asarray(sad_f), np.asarray(sad_k),
                               rtol=1e-6, atol=1e-3)


def test_motion_sad_bf16_close_to_f32():
    k1, k2 = jax.random.split(jax.random.PRNGKey(3))
    cur = jax.random.uniform(k1, (64, 96), jnp.float32) * 255
    ref = jnp.roll(cur, (3, -2), (0, 1)) + jax.random.normal(k2, (64, 96))
    mv32, _ = block_sad(cur, ref, 8)
    mvbf, _ = block_sad(cur, ref, 8, dtype=jnp.bfloat16)
    # bf16 rounding may move near-tied candidates, but the dominant
    # motion must survive quantization
    agree = (np.asarray(mv32) == np.asarray(mvbf)).all(axis=-1).mean()
    assert agree >= 0.9, f"bf16 search agrees on only {agree:.0%} of blocks"


def test_qtransfer_bf16_within_tolerance():
    from repro.kernels.qtransfer.ops import qtransfer
    from repro.kernels.qtransfer.ref import qtransfer_ref
    ks = jax.random.split(jax.random.PRNGKey(4), 3)
    anchor = jax.random.uniform(ks[0], (64, 96), jnp.float32) * 255
    mv = jax.random.randint(ks[1], (4, 6, 2), -8, 9, jnp.int32)
    resid = jax.random.normal(ks[2], (64, 96), jnp.float32) * 8
    o = qtransfer(anchor, mv, resid, interpret=True, dtype=jnp.bfloat16)
    assert o.dtype == jnp.bfloat16
    r = qtransfer_ref(anchor, mv, resid)
    # bf16 has ~8 bits of mantissa: |err| <= ~1 grey level at 255 scale
    np.testing.assert_allclose(np.asarray(o, np.float32), np.asarray(r),
                               atol=1.5)


def test_video_codec_config_dtype_policy():
    assert VideoCodecConfig().search_dtype is None
    assert VideoCodecConfig(dtype="bfloat16").search_dtype == jnp.bfloat16
    assert VideoCodecConfig(dtype="bf16").search_dtype == jnp.bfloat16
    hash(VideoCodecConfig(use_kernel=True, dtype="bfloat16"))  # stays static


# --------------------------------------------------- forced 4-device child
def test_spawns_multidevice_encoder_child():
    """Driver: re-run ONLY this file's ``forced``-named tests under 4
    forced CPU devices (mirrors test_stream_sharding.py)."""
    if _FORCED:
        pytest.skip("already inside the forced multi-device child")
    r = conftest.forced_multidevice_run(
        "tests/test_fused_encoder.py", extra_args=["-k", "forced"])
    assert r.returncode == 0, (
        f"forced multi-device encoder child failed\n--- stdout ---\n"
        f"{r.stdout}\n--- stderr ---\n{r.stderr}")
    assert "passed" in r.stdout


@forced_only
@pytest.mark.parametrize("S", [1, 3, 4, 8])
def test_forced_encode_bit_exact_vs_vmap_oracle(S):
    """Mesh-sharded batched encode equals the single-device vmap oracle
    bit-for-bit — including S=1 and S=3, which zero-pad the stream axis up
    to the mesh extent and drop the padded lanes on exit."""
    mesh = jax.make_mesh((4,), ("data",))
    assert stream_shard_count(mesh, SINGLE_POD_RULES) == 4
    frames = _streams(S)
    run = shard_encode(mesh, SINGLE_POD_RULES, cfg=CFG)
    sharded = run(frames)
    assert sharded.recon.shape[0] == S
    _assert_enc_equal(sharded, encode_chunk_batched(frames, CFG),
                      err=f"sharded encode diverged at S={S}")


@forced_only
def test_forced_encode_two_dimensional_mesh_parity():
    mesh = jax.make_mesh((2, 2), ("data", "model"))
    assert stream_shard_count(mesh, SINGLE_POD_RULES_DP) == 4
    frames = _streams(6)
    run = shard_encode(mesh, SINGLE_POD_RULES_DP, cfg=CFG)
    _assert_enc_equal(run(frames), encode_chunk_batched(frames, CFG),
                      err="2-D mesh encode diverged")
