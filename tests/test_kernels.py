"""Per-kernel correctness: shape/dtype sweeps against the ref.py oracles,
all in interpret mode (the kernel body executes in Python on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels.blockdct.ops import blockdct_quantize
from repro.kernels.blockdct.ref import blockdct_ref
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.qtransfer.ops import qtransfer
from repro.kernels.qtransfer.ref import qtransfer_ref


# ---------------------------------------------------------------- flash
@pytest.mark.parametrize("B,H,Hk,Sq,Sk,D,causal,window,dtype", [
    (2, 4, 2, 128, 128, 64, True, None, jnp.float32),
    (1, 4, 4, 256, 256, 64, False, None, jnp.float32),
    (1, 8, 2, 256, 256, 128, True, 96, jnp.float32),
    (2, 2, 1, 64, 192, 64, True, None, jnp.float32),   # cross Sq != Sk
    (1, 4, 2, 128, 128, 64, True, None, jnp.bfloat16),
])
def test_flash_attention_matches_ref(B, H, Hk, Sq, Sk, D, causal, window,
                                     dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, Sq, H, D), dtype)
    k = jax.random.normal(ks[1], (B, Sk, Hk, D), dtype)
    v = jax.random.normal(ks[2], (B, Sk, Hk, D), dtype)
    o = flash_attention(q, k, v, causal=causal, window=window,
                        q_blk=64, k_blk=64, interpret=True)
    r = attention_ref(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                      v.transpose(0, 2, 1, 3), causal=causal,
                      window=window).transpose(0, 2, 1, 3)
    tol = 0.03 if dtype == jnp.bfloat16 else 0.02
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(r, np.float32), atol=tol)


def test_flash_attention_block_shape_independence():
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (1, 128, 4, 64), jnp.float32)
    k = jax.random.normal(ks[1], (1, 128, 2, 64), jnp.float32)
    v = jax.random.normal(ks[2], (1, 128, 2, 64), jnp.float32)
    o1 = flash_attention(q, k, v, q_blk=32, k_blk=64, interpret=True)
    o2 = flash_attention(q, k, v, q_blk=128, k_blk=128, interpret=True)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=2e-3)


# ------------------------------------------------------------- qtransfer
@pytest.mark.parametrize("H,W,radius", [(64, 96, 8), (64, 96, 16),
                                        (128, 128, 16), (48, 160, 8)])
def test_qtransfer_matches_ref(H, W, radius):
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    anchor = jax.random.uniform(ks[0], (H, W), jnp.float32) * 255
    mv = jax.random.randint(ks[1], (H // 16, W // 16, 2), -radius,
                            radius + 1, jnp.int32)
    resid = jax.random.normal(ks[2], (H, W), jnp.float32) * 8
    o = qtransfer(anchor, mv, resid, radius=radius, interpret=True)
    r = qtransfer_ref(anchor, mv, resid, radius=radius)
    np.testing.assert_allclose(np.asarray(o), np.asarray(r), atol=1e-4)


@settings(deadline=None, max_examples=10)
@given(dy=st.integers(-16, 16), dx=st.integers(-16, 16))
def test_qtransfer_uniform_shift_property(dy, dx):
    """A uniform MV field equals a (clamped) whole-frame shift."""
    H, W = 48, 64
    anchor = jnp.arange(H * W, dtype=jnp.float32).reshape(H, W) % 251
    mv = jnp.full((H // 16, W // 16, 2), 0, jnp.int32
                  ).at[..., 0].set(dy).at[..., 1].set(dx)
    resid = jnp.zeros((H, W), jnp.float32)
    o = np.asarray(qtransfer(anchor, mv, resid, radius=16, interpret=True))
    r = np.asarray(qtransfer_ref(anchor, mv, resid, radius=16))
    np.testing.assert_allclose(o, r, atol=1e-4)


def test_qtransfer_batched():
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    anchor = jax.random.uniform(ks[0], (3, 32, 32), jnp.float32) * 255
    mv = jax.random.randint(ks[1], (3, 2, 2, 2), -4, 5, jnp.int32)
    resid = jnp.zeros((3, 32, 32), jnp.float32)
    o = qtransfer(anchor, mv, resid, interpret=True)
    assert o.shape == (3, 32, 32)
    assert not np.any(np.isnan(np.asarray(o)))


# --------------------------------------------------------------- blockdct
@pytest.mark.parametrize("nb,tile,quality", [
    (64, 32, 50.0), (100, 32, 20.0), (256, 256, 80.0), (7, 8, 95.0),
])
def test_blockdct_matches_ref(nb, tile, quality):
    blocks = jax.random.uniform(jax.random.PRNGKey(4), (nb, 8, 8),
                                jnp.float32) * 255 - 128
    q, rec = blockdct_quantize(blocks, quality, tile=tile, interpret=True)
    qr, recr = blockdct_ref(blocks, quality)
    # round() at the exact .5 boundary may differ by 1 ulp of quantization
    assert float(jnp.max(jnp.abs(q - qr))) <= 1.0
    assert float(jnp.mean(jnp.abs(q - qr))) < 0.01
    np.testing.assert_allclose(np.asarray(rec), np.asarray(recr), atol=1.0)


def test_blockdct_energy_decreases_with_quality():
    blocks = jax.random.uniform(jax.random.PRNGKey(5), (32, 8, 8),
                                jnp.float32) * 255 - 128
    nz = []
    for q in (10.0, 50.0, 90.0):
        qq, _ = blockdct_quantize(blocks, q, interpret=True)
        nz.append(int((jnp.abs(qq) > 0).sum()))
    assert nz[0] <= nz[1] <= nz[2]
