"""Budget-parity net for the in-trace anchor-quality search (ISSUE 10).

Locks three contracts:

  * selection parity — the traced argmax (``budget_rung``) picks the
    SAME ladder rung as the host ``quality_for_budget`` probe across a
    golden budget sweep (exact-boundary budgets included) and under
    hypothesis-driven budgets, and chosen quality is monotone
    non-decreasing in budget;
  * sweep exactness — ``ladder_sweep``'s per-rung (recon, bits) planes
    are bit-exact vs a per-rung ``jpeg_encode_decode`` Python loop, and
    the hoisted-DCT probe runs ONE DCT for the whole ladder;
  * mode parity — ``anchor_search=True`` through ``roundtrip_chunk`` /
    ``roundtrip_batched`` / ``shard_roundtrip`` is bit-exact vs the
    extended host oracle, ``anchor_search=False`` stays bit-exact vs the
    pinned-quality path (fused, oracle, and the async serving plane),
    and chunk-varying ``bw_kbps`` NEVER retraces the searched jit.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.codec import blockdct as B
from repro.codec.image_codec import (ANCHOR_QUALITY_LADDER, budget_rung,
                                     jpeg_bits, jpeg_encode_decode,
                                     ladder_bits, ladder_sweep,
                                     quality_for_budget)
from repro.core.roundtrip import (RoundtripConfig, roundtrip_batched,
                                  roundtrip_chunk, roundtrip_oracle)
from repro.models import detection as D
from repro.sim.video_source import StreamConfig, generate_chunk

f32 = jnp.float32
H, W, T = 64, 96, 4
QS = np.asarray(ANCHOR_QUALITY_LADDER, np.float32)


@pytest.fixture(scope="module")
def det():
    cfg = D.TinyDetectorConfig()
    return D.init(jax.random.PRNGKey(1), cfg), cfg


@pytest.fixture(scope="module")
def img():
    frames, _, _ = generate_chunk(None, StreamConfig(height=H, width=W,
                                                     n_objects=3, seed=3),
                                  0, 1)
    return jnp.asarray(frames[0], f32)


def _streams(S):
    data = [generate_chunk(None, StreamConfig(height=H, width=W,
                                              n_objects=3, seed=s), 0, T)
            for s in range(S)]
    return (jnp.stack([d[0] for d in data]),
            jnp.stack([d[1] for d in data]),
            jnp.stack([d[2] for d in data]))


def _host_pick(bits: np.ndarray, budget: float) -> int:
    """Per-rung Python loop oracle: highest ladder rung fitting budget,
    else the cheapest rung (index 0)."""
    best = 0
    for r in range(len(bits)):
        if bits[r] <= budget and QS[r] >= QS[best if bits[best] <= budget
                                             else r]:
            best = r
    return best if bits[best] <= budget else 0


# ----------------------------------------------------- selection parity
def test_budget_rung_matches_host_probe_golden_sweep(img):
    """Traced argmax == host quality_for_budget across a golden sweep
    including EXACT per-rung boundary budgets and budget < cheapest."""
    bits = np.asarray(ladder_bits(img))
    jit_rung = jax.jit(budget_rung)
    budgets = ([0.0, float(bits.min()) - 1.0, float(bits.max()) + 1.0,
                1e9] + [float(b) for b in bits]            # exact boundary
               + [float(b) - 0.5 for b in bits]
               + [float(b) + 0.5 for b in bits])
    for budget in budgets:
        traced = int(jit_rung(jnp.asarray(bits), budget))
        q_host, b_host = quality_for_budget(img, budget)
        assert QS[traced] == float(q_host), (budget, bits)
        assert bits[traced] == float(b_host)
        assert traced == _host_pick(bits, budget)


def test_budget_rung_below_cheapest_ships_rung_zero(img):
    bits = np.asarray(ladder_bits(img))
    assert int(jax.jit(budget_rung)(jnp.asarray(bits), 0.0)) == 0
    q, b = quality_for_budget(img, 0.0)
    assert float(q) == QS[0] and float(b) == bits[0]


def _golden_bits():
    """Ladder bits of one seeded image, cached: the hypothesis shim's
    runner takes no pytest fixtures."""
    if not hasattr(_golden_bits, "_v"):
        frames, _, _ = generate_chunk(
            None, StreamConfig(height=H, width=W, n_objects=3, seed=3), 0, 1)
        _golden_bits._v = np.asarray(ladder_bits(jnp.asarray(frames[0], f32)))
    return _golden_bits._v


@settings(max_examples=24)
@given(b1=st.floats(min_value=0.0, max_value=3e5),
       b2=st.floats(min_value=0.0, max_value=3e5))
def test_budget_rung_property_matches_loop_oracle_and_monotone(b1, b2):
    bits = _golden_bits()
    r1 = int(budget_rung(jnp.asarray(bits), b1))
    r2 = int(budget_rung(jnp.asarray(bits), b2))
    assert r1 == _host_pick(bits, b1)
    assert r2 == _host_pick(bits, b2)
    lo, hi = (r1, r2) if b1 <= b2 else (r2, r1)
    assert QS[lo] <= QS[hi], "chosen quality must be monotone in budget"


def test_budget_rung_batched_rows_match_scalar(img):
    """The last-axis form (the fused path's per-frame argmax) equals the
    scalar form row by row."""
    bits = np.asarray(ladder_bits(img))
    tiled = jnp.stack([jnp.asarray(bits)] * 3)
    budgets = jnp.asarray([0.0, float(bits[2]), 1e9], f32)
    rows = budget_rung(tiled, budgets[:, None])
    for i, budget in enumerate(np.asarray(budgets)):
        assert int(rows[i]) == int(budget_rung(jnp.asarray(bits),
                                               float(budget)))


# ------------------------------------------------------- sweep exactness
def test_ladder_sweep_bit_exact_vs_per_rung_loop(img):
    recons, bits = ladder_sweep(img)
    assert recons.shape == (len(QS), H, W) and bits.shape == (len(QS),)
    for r, q in enumerate(ANCHOR_QUALITY_LADDER):
        rec_ref, bits_ref = jpeg_encode_decode(img, q)
        np.testing.assert_array_equal(np.asarray(recons[r]),
                                      np.asarray(rec_ref), err_msg=f"q={q}")
        np.testing.assert_array_equal(np.asarray(bits[r]),
                                      np.asarray(bits_ref))


def test_ladder_bits_bit_exact_vs_jpeg_bits(img):
    bits = ladder_bits(img)
    for r, q in enumerate(ANCHOR_QUALITY_LADDER):
        np.testing.assert_array_equal(np.asarray(bits[r]),
                                      np.asarray(jpeg_bits(img, q)))


def test_quality_for_budget_runs_one_dct_for_whole_ladder(monkeypatch):
    """Regression for the hoist: the probe used to re-encode the full
    image (blockify + DCT) at every ladder quality; now the
    quality-independent half runs ONCE and only quantize/bit-charge is
    per rung."""
    calls = []
    orig = B.dct2
    monkeypatch.setattr(B, "dct2", lambda x: (calls.append(1), orig(x))[1])
    jax.eval_shape(lambda f: quality_for_budget(f, 5e4),
                   jax.ShapeDtypeStruct((H, W), f32))
    assert len(calls) == 1, \
        f"dct2 ran {len(calls)}x for one {len(QS)}-rung probe"
    monkeypatch.undo()


# ----------------------------------------------------------- mode parity
def _scalars(S):
    return dict(tr1=jnp.full((S,), 0.05), tr2=jnp.full((S,), 0.1),
                bw_kbps=jnp.asarray([900.0, 3000.0, 60.0, 8000.0][:S]),
                queue_delay=jnp.zeros((S,)))


def test_roundtrip_chunk_search_matches_extended_oracle(det):
    params, det_cfg = det
    cfg = RoundtripConfig(level=3, det_cfg=det_cfg, anchor_search=True)
    raw, gtb, gtv = _streams(1)
    for bw in (60.0, 900.0, 8000.0):
        fused = roundtrip_chunk(raw[0], gtb[0], gtv[0], params, tr1=0.05,
                                tr2=0.1, bw_kbps=bw, cfg=cfg)
        oracle = roundtrip_oracle(raw[0], gtb[0], gtv[0], params, tr1=0.05,
                                  tr2=0.1, bw_kbps=bw, cfg=cfg)
        assert set(fused) == set(oracle)
        for k in oracle:
            np.testing.assert_array_equal(
                np.asarray(fused[k]), np.asarray(oracle[k]),
                err_msg=f"bw={bw}: key {k!r}")


def test_roundtrip_batched_search_matches_oracle_lanes(det):
    params, det_cfg = det
    cfg = RoundtripConfig(level=3, det_cfg=det_cfg, anchor_search=True)
    S = 3
    raw, gtb, gtv = _streams(S)
    sc = _scalars(S)
    out = roundtrip_batched(raw, gtb, gtv, params, cfg=cfg, **sc)
    for s in range(S):
        ref = roundtrip_oracle(
            raw[s], gtb[s], gtv[s], params, tr1=float(sc["tr1"][s]),
            tr2=float(sc["tr2"][s]), bw_kbps=float(sc["bw_kbps"][s]),
            queue_delay=0.0, cfg=cfg)
        for k in ref:
            np.testing.assert_array_equal(
                np.asarray(out[k][s]), np.asarray(ref[k]),
                err_msg=f"lane {s}: key {k!r}")


def test_search_responds_to_bandwidth_and_charges_chosen_bits(det):
    """Starved links pick the cheapest rung, rich links the best; the
    charged anchor bits equal the chosen rungs' sweep bits."""
    params, det_cfg = det
    cfg = RoundtripConfig(level=3, det_cfg=det_cfg, anchor_search=True)
    raw, gtb, gtv = _streams(1)
    lo = roundtrip_chunk(raw[0], gtb[0], gtv[0], params, tr1=0.05, tr2=0.1,
                         bw_kbps=30.0, cfg=cfg)
    hi = roundtrip_chunk(raw[0], gtb[0], gtv[0], params, tr1=0.05, tr2=0.1,
                         bw_kbps=50000.0, cfg=cfg)
    anchors = np.asarray(lo["types"]) == 1
    assert anchors.any()
    assert (np.asarray(lo["anchor_q"])[anchors] == QS[0]).all()
    assert (np.asarray(hi["anchor_q"])[anchors] == QS[-1]).all()
    _, bits = jax.vmap(ladder_sweep)(jnp.asarray(raw[0], f32))
    for out in (lo, hi):
        aq = np.asarray(out["anchor_q"])
        rungs = np.asarray([int(np.flatnonzero(QS == q)[0]) if q else 0
                            for q in aq])
        charged = np.asarray(bits)[np.arange(T), rungs]
        total = B.seq_sum(jnp.where(jnp.asarray(anchors),
                                    jnp.asarray(charged), 0.0))
        np.testing.assert_array_equal(np.asarray(out["anchor_bits"]),
                                      np.asarray(total))


def test_search_off_bit_exact_vs_pinned_path(det):
    """anchor_search=False must be indistinguishable from the pinned
    config — same trace semantics, same outputs."""
    params, det_cfg = det
    pinned = RoundtripConfig(level=3, det_cfg=det_cfg)
    off = dataclasses.replace(pinned, anchor_search=False)
    raw, gtb, gtv = _streams(1)
    a = roundtrip_chunk(raw[0], gtb[0], gtv[0], params, tr1=0.05, tr2=0.1,
                        bw_kbps=3000.0, cfg=off)
    b = roundtrip_chunk(raw[0], gtb[0], gtv[0], params, tr1=0.05, tr2=0.1,
                        bw_kbps=3000.0, cfg=pinned)
    for k in b:
        np.testing.assert_array_equal(np.asarray(a[k]), np.asarray(b[k]),
                                      err_msg=k)
    anchors = np.asarray(b["types"]) == 1
    np.testing.assert_array_equal(
        np.asarray(b["anchor_q"]),
        np.where(anchors, np.float32(pinned.anchor_quality),
                 np.float32(0.0)))


def test_shard_roundtrip_search_matches_batched(det):
    """The mesh-sharded wrapper carries the search mode (and the new
    anchor_q plane) through shard_map unchanged."""
    from repro.distributed.sharding import SINGLE_POD_RULES
    from repro.distributed.stream_sharding import shard_roundtrip
    params, det_cfg = det
    cfg = RoundtripConfig(level=3, det_cfg=det_cfg, anchor_search=True)
    mesh = jax.make_mesh((len(jax.devices()),), ("data",))
    raw, gtb, gtv = _streams(3)
    sc = _scalars(3)
    run = shard_roundtrip(mesh, SINGLE_POD_RULES, cfg=cfg)
    out = run(raw, gtb, gtv, params, **sc)
    ref = roundtrip_batched(raw, gtb, gtv, params, cfg=cfg, **sc)
    for k in ref:
        np.testing.assert_array_equal(np.asarray(out[k]),
                                      np.asarray(ref[k]), err_msg=k)


def test_search_zero_retrace_across_varying_bandwidth(det):
    """The acceptance check: chunk-varying bw_kbps through the searched
    trace compiles ONCE, while the picked rungs actually change."""
    from repro.core.roundtrip import _roundtrip_chunk
    params, det_cfg = det
    cfg = RoundtripConfig(level=3, det_cfg=det_cfg, anchor_search=True)
    raw, gtb, gtv = _streams(1)
    traces = []

    @jax.jit
    def counted(r, gb, gv, p, bw):
        traces.append(1)
        return _roundtrip_chunk(r, gb, gv, p, 0.05, 0.1, bw, 0.0, cfg)

    picks = []
    for bw in (30.0, 300.0, 3000.0, 30000.0):
        out = counted(raw[0], gtb[0], gtv[0], params, jnp.asarray(bw, f32))
        picks.append(tuple(np.asarray(out["anchor_q"]).tolist()))
    assert len(traces) == 1, f"retraced {len(traces)}x across bw values"
    assert len(set(picks)) > 1, "rung picks never varied with bandwidth"


def test_env_detector_backend_threads_anchor_search(det):
    """EnvConfig.anchor_search reaches the fused dispatch: a starved
    allocation and a rich one produce different anchor bit charges."""
    from repro.sim.env import EnvConfig, MultiStreamEnv
    from repro.sim.video_source import paper_stream_mix
    params, det_cfg = det
    outs = {}
    for bw_scale in (1.0, 40.0):
        from repro.sim.network import TraceConfig
        cfg = EnvConfig(streams=tuple(paper_stream_mix(2, H, W)),
                        chunk_frames=T, accuracy_backend="detector",
                        anchor_search=True,
                        trace=TraceConfig(mean_kbps=200.0 * bw_scale))
        env = MultiStreamEnv(cfg, detector=(params, det_cfg))
        assert env._roundtrip_cfg().anchor_search
        results, _ = env.step(np.full(2, 0.5),
                              np.full((2, 2), 0.05, np.float32))
        outs[bw_scale] = sum(r["bits"] for r in results)
    assert outs[1.0] < outs[40.0]


def test_serving_stage_search_off_bit_exact_and_rung_bits_staged(det):
    """The async serving plane: anchor_search staging changes NOTHING
    about detections/stats (off-mode parity through serving) and the
    staged (T, Q) rung-bit planes equal ladder_bits on the anchor
    plane."""
    from repro.core.hybrid_encoder import encode_hybrid
    from repro.serving.runtime import EdgeRuntime
    from repro.serving.scheduler import ServingConfig
    params, det_cfg = det
    frames, _, _ = generate_chunk(None, StreamConfig(height=32, width=48,
                                                     n_objects=2, seed=5),
                                  0, 3)
    pkt = encode_hybrid(np.asarray(frames), 8000.0, 0.05, 0.1)
    outs = {}
    for search in (False, True):
        scfg = ServingConfig(n_streams=1, anchor_search=search)
        rt = EdgeRuntime(scfg, params, det_cfg)
        tk = rt.submit_chunk(0, 0, pkt)
        rt.flush()
        boxes, scores, types = rt.poll(tk)
        outs[search] = (np.asarray(boxes), np.asarray(scores),
                        np.asarray(types), rt.stats[0].as_dict(), tk)
        rt.close()
    for a, b in zip(outs[False][:3], outs[True][:3]):
        np.testing.assert_array_equal(a, b)
    assert outs[False][3] == outs[True][3]
    assert outs[False][4].rung_bits_dev is None
    staged = outs[True][4].rung_bits_dev
    assert staged is not None and staged.shape == (3, len(QS))
    # close, not bit-equal: fused into the larger stage program XLA may
    # reassociate the entropy_bits reduction (the bit-exact contract for
    # the SEARCH path lives in the roundtrip parity tests above)
    ref = jax.vmap(ladder_bits)(jnp.asarray(pkt.anchor_hd, f32))
    np.testing.assert_allclose(np.asarray(staged), np.asarray(ref),
                               rtol=1e-3)
    assert (np.diff(np.asarray(staged), axis=1) >= 0).all(), \
        "per-frame rung bits must be non-decreasing in quality"
