"""Training substrate: optimizer convergence, checkpoint round trips +
resume, fault-tolerant supervision, gradient compression invariants."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train import checkpoint as CKPT
from repro.train import compression as COMP
from repro.train import fault_tolerance as FT
from repro.train import loop as LOOP
from repro.train.optimizer import AdamWConfig, apply_updates, init_state

KEY = jax.random.PRNGKey(0)


# ---------------------------------------------------------------- optimizer
def test_adamw_minimizes_quadratic():
    params = {"w": jnp.asarray([3.0, -2.0])}
    opt = init_state(params)
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=0,
                      total_steps=200)

    def loss(p):
        return jnp.sum(jnp.square(p["w"] - 1.0))

    for _ in range(150):
        g = jax.grad(loss)(params)
        params, opt, m = apply_updates(params, g, opt, cfg)
    np.testing.assert_allclose(np.asarray(params["w"]), [1.0, 1.0],
                               atol=0.1)
    assert float(m["grad_norm"]) < 1.0


def test_grad_clipping():
    params = {"w": jnp.zeros(4)}
    opt = init_state(params)
    cfg = AdamWConfig(lr=1e-3, clip_norm=1.0, warmup_steps=0)
    g = {"w": jnp.full(4, 100.0)}
    _, _, m = apply_updates(params, g, opt, cfg)
    assert float(m["grad_norm"]) == pytest.approx(200.0, rel=1e-3)


# --------------------------------------------------------------- checkpoint
def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "nested": {"b": jnp.asarray([1, 2], jnp.int32)},
            "scalar": jnp.asarray(3.5)}
    path = CKPT.save(str(tmp_path), 7, tree)
    assert os.path.isdir(path)
    back = CKPT.restore(str(tmp_path), 7, tree)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b))


def test_checkpoint_gc_keeps_latest(tmp_path):
    tree = {"x": jnp.zeros(2)}
    for s in (1, 2, 3, 4, 5):
        CKPT.save(str(tmp_path), s, tree, keep=2)
    assert CKPT.all_steps(str(tmp_path)) == [4, 5]


def test_loop_resumes_from_checkpoint(tmp_path):
    def step(state, batch):
        return {"n": state["n"] + 1}, {"loss": 1.0 / (state["n"] + 1)}

    def gen():
        while True:
            yield None

    cfg = LOOP.LoopConfig(total_steps=6, ckpt_dir=str(tmp_path),
                          ckpt_every=2, log_every=1)
    state, _ = LOOP.run(step, {"n": jnp.asarray(0)}, gen(), cfg)
    assert int(state["n"]) == 6
    # resume: loop must start from step 6 (latest ckpt), not 0
    cfg2 = LOOP.LoopConfig(total_steps=8, ckpt_dir=str(tmp_path),
                           ckpt_every=2, log_every=1)
    state2, hist = LOOP.run(step, {"n": jnp.asarray(0)}, gen(), cfg2)
    assert int(state2["n"]) == 8
    assert hist[0]["step"] == 7


# ----------------------------------------------------------- fault tolerance
def test_supervised_restart_completes(tmp_path):
    calls = {"fails": 0}

    def make(attempt):
        def step(state, batch):
            return {"n": state["n"] + 1}, {"loss": 0.0}
        return step, {"n": jnp.asarray(0)}, None

    def data():
        while True:
            yield None

    def injector(step):
        if step == 3 and calls["fails"] == 0:
            calls["fails"] += 1
            return True
        return False

    cfg = LOOP.LoopConfig(total_steps=6, ckpt_dir=str(tmp_path),
                          ckpt_every=1, log_every=1)
    res = FT.supervise(make, data, cfg, fail_injector=injector)
    assert res.restarts == 1
    assert int(res.state["n"]) == 6         # lost work bounded by ckpt_every


# -------------------------------------------------------------- compression
@pytest.mark.parametrize("scheme", ["topk", "int8", "topk_int8"])
def test_compression_error_feedback_conserves_signal(scheme):
    cfg = COMP.CompressionConfig(scheme=scheme, topk_fraction=0.25)
    grads = {"w": jax.random.normal(KEY, (64,), jnp.float32)}
    err = COMP.init_error(grads)
    out, new_err = COMP.compress(cfg, grads, err)
    # compressed + error == original (+ old error)
    np.testing.assert_allclose(
        np.asarray(out["w"] + new_err["w"]),
        np.asarray(grads["w"]), atol=1e-5)
    assert COMP.compressed_bytes(cfg, grads) < \
        COMP.compressed_bytes(COMP.CompressionConfig("none"), grads)


def test_compression_error_decays_over_steps():
    """With error feedback, every component is eventually transmitted and
    nothing is lost: sent + residual error == steps * g exactly."""
    cfg = COMP.CompressionConfig(scheme="topk", topk_fraction=0.25)
    g = {"w": jnp.asarray([1.0, 0.5, 0.25, 0.1])}
    err = COMP.init_error(g)
    sent_total = jnp.zeros(4)
    steps = 16
    for _ in range(steps):
        out, err = COMP.compress(cfg, g, err)
        sent_total = sent_total + out["w"]
    assert (np.asarray(sent_total) > 0).all()   # every coord eventually sent
    np.testing.assert_allclose(
        np.asarray(sent_total + err["w"]),
        np.asarray(g["w"]) * steps, rtol=1e-5)  # conservation
