"""Codec golden-vector regression net (ISSUE 3).

``tests/golden/codec_golden.npz`` pins EncodedChunk field checksums
(per-frame recon PSNR, bits, residual magnitudes, frame diffs, MV
histograms, quant table) computed with the motion search forced through
the LEGACY scan oracle (``block_sad_scan``).  Every production search
path must reproduce those checksums:

  * the vmapped per-macroblock fallback (``encode_chunk`` default) and
    the Pallas kernel path (``use_kernel=True``) — bit-exact in f32,
  * ``encode_chunk_batched`` — bit-exact in f32 lane-for-lane,
  * the bf16 dtype-policy variants — within the documented tolerance
    contract (docs/fused_encoder.md): MVs may move between near-tied
    candidates, so PSNR within 1 dB, bits within 5 %, residual magnitude
    within 5 %, MV histograms within 10 % total-count L1 drift.

Regenerate the fixture ONLY for intentional codec changes:
``PYTHONPATH=src python tests/golden/generate_codec_golden.py --force``.
"""
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                "golden"))
from generate_codec_golden import (CASES, checksums, encode_with_scan_oracle,
                                   golden_frames)  # noqa: E402
from repro.codec.video_codec import (VideoCodecConfig, encode_chunk,
                                     encode_chunk_batched)  # noqa: E402

GOLDEN_PATH = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                           "golden", "codec_golden.npz")
GOLDEN = dict(np.load(GOLDEN_PATH))

REGEN_HINT = (
    "If (and ONLY if) this divergence is an intentional codec change, "
    "regenerate the fixture with:\n"
    "    PYTHONPATH=src python tests/golden/generate_codec_golden.py --force\n"
    "and commit the refreshed .npz together with the change.")


def _case_cfg(case, **overrides):
    return VideoCodecConfig(quality=case["quality"],
                            search_radius=case["radius"], **overrides)


def _assert_bit_exact(name, got: dict):
    for key, val in got.items():
        np.testing.assert_array_equal(
            val, GOLDEN[f"{name}_{key}"],
            err_msg=(f"{name}_{key} diverged from the scan-oracle golden.\n"
                     f"{REGEN_HINT}"))


def _assert_bf16_tolerance(name, got: dict):
    g = {k: GOLDEN[f"{name}_{k}"] for k in got}
    np.testing.assert_allclose(got["psnr"], g["psnr"], atol=1.0,
                               err_msg=REGEN_HINT)
    np.testing.assert_allclose(got["bits"], g["bits"], rtol=0.05,
                               err_msg=REGEN_HINT)
    np.testing.assert_allclose(got["residual_mag"], g["residual_mag"],
                               rtol=0.05, err_msg=REGEN_HINT)
    np.testing.assert_array_equal(got["qtab"], g["qtab"],
                                  err_msg=REGEN_HINT)
    total = g["mv_hist"].sum(axis=1, keepdims=True)
    l1 = np.abs(got["mv_hist"] - g["mv_hist"]).sum(axis=1)
    assert (l1 <= 0.1 * total[:, 0] + 1).all(), \
        f"{name} bf16 MV histogram drifted more than 10%: L1={l1}\n{REGEN_HINT}"


@pytest.mark.parametrize("name", list(CASES))
def test_scan_oracle_reproduces_golden(name):
    """The committed fixture IS the scan oracle's output — guards against
    silent drift of the oracle itself (or of the synthetic source)."""
    case = CASES[name]
    frames = golden_frames(case)
    enc = encode_with_scan_oracle(frames, _case_cfg(case))
    _assert_bit_exact(name, checksums(frames, enc, case["radius"]))


@pytest.mark.parametrize("name", list(CASES))
@pytest.mark.parametrize("use_kernel", [False, True],
                         ids=["vmapped_fallback", "kernel"])
def test_encode_paths_bit_exact_f32(name, use_kernel):
    case = CASES[name]
    frames = golden_frames(case)
    enc = encode_chunk(frames, _case_cfg(case, use_kernel=use_kernel))
    _assert_bit_exact(name, checksums(frames, enc, case["radius"]))


@pytest.mark.parametrize("name", list(CASES))
def test_encode_batched_bit_exact_f32(name):
    """Every lane of the batched encoder reproduces the golden — the
    stream vmap must not perturb the per-stream computation."""
    case = CASES[name]
    frames = golden_frames(case)
    batch = jnp.stack([frames, frames, frames])
    enc = encode_chunk_batched(batch, _case_cfg(case))
    for s in range(batch.shape[0]):
        lane = jax.tree.map(lambda x: x[s], enc)
        _assert_bit_exact(name, checksums(frames, lane, case["radius"]))


@pytest.mark.parametrize("name", list(CASES))
@pytest.mark.parametrize("use_kernel", [False, True],
                         ids=["vmapped_fallback", "kernel"])
def test_encode_bf16_within_tolerance(name, use_kernel):
    case = CASES[name]
    frames = golden_frames(case)
    enc = encode_chunk(frames, _case_cfg(case, use_kernel=use_kernel,
                                         dtype="bfloat16"))
    _assert_bf16_tolerance(name, checksums(frames, enc, case["radius"]))


def _assert_diamond_tolerance(name, got: dict):
    """The diamond-search quality contract (docs/fused_encoder.md): the
    coarse-to-fine search may settle on locally-optimal MVs, so vs the
    exhaustive scan-oracle golden we require PSNR within 0.5 dB, bits and
    residual magnitude within 5 %, frame diffs (recon-drift sensitive)
    within 5 %, quant table untouched, MV histograms within 10 %
    total-count L1 drift.  Measured on the fixture: ≤ 0.22 dB / ≤ 4.1 %
    bits on case a, bit-identical on case b."""
    g = {k: GOLDEN[f"{name}_{k}"] for k in got}
    np.testing.assert_allclose(got["psnr"], g["psnr"], atol=0.5,
                               err_msg=REGEN_HINT)
    np.testing.assert_allclose(got["bits"], g["bits"], rtol=0.05,
                               err_msg=REGEN_HINT)
    np.testing.assert_allclose(got["residual_mag"], g["residual_mag"],
                               rtol=0.05, err_msg=REGEN_HINT)
    np.testing.assert_allclose(got["frame_diff"], g["frame_diff"],
                               rtol=0.05, atol=1e-6, err_msg=REGEN_HINT)
    np.testing.assert_array_equal(got["qtab"], g["qtab"],
                                  err_msg=REGEN_HINT)
    total = g["mv_hist"].sum(axis=1, keepdims=True)
    l1 = np.abs(got["mv_hist"] - g["mv_hist"]).sum(axis=1)
    assert (l1 <= 0.1 * total[:, 0] + 1).all(), \
        f"{name} diamond MV histogram drifted more than 10%: L1={l1}\n" \
        f"{REGEN_HINT}"


@pytest.mark.parametrize("name", list(CASES))
@pytest.mark.parametrize("use_kernel", [False, True],
                         ids=["vmapped_fallback", "kernel"])
def test_encode_diamond_within_quality_contract(name, use_kernel):
    """search='diamond' trades bit-exactness for a ≤ ¼ candidate budget;
    this pins the trade to the documented tolerance contract on the same
    golden fixture the exhaustive paths must match exactly."""
    case = CASES[name]
    frames = golden_frames(case)
    enc = encode_chunk(frames, _case_cfg(case, use_kernel=use_kernel,
                                         search="diamond"))
    _assert_diamond_tolerance(name, checksums(frames, enc, case["radius"]))


def test_golden_fixture_is_complete():
    expected = {f"{n}_{k}" for n in CASES
                for k in ("psnr", "bits", "residual_mag", "frame_diff",
                          "qtab", "mv_hist")}
    assert set(GOLDEN) == expected
