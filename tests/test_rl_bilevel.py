"""DRL layers: A2C and SAC learn simple synthetic tasks; the stacked
bi-level control plane is bit-exact (f32) against the per-stream loop
oracle — actions, rewards, replay sampling order, and post-update
parameters for C ∈ {1, 3, 8} (ISSUE 5 parity contract, docs/bilevel.md).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.rl import a2c, sac
from repro.rl.replay import ReplayBuffer, StackedReplayBuffer

KEY = jax.random.PRNGKey(0)


def _tree_equal(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    return len(la) == len(lb) and all(
        np.array_equal(np.asarray(x), np.asarray(y))
        for x, y in zip(la, lb))


# ------------------------------------------------------------- learning
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_a2c_learns_threshold_bandit(seed):
    """Reward = 1 - |a - 0.7|: the actor mean converges to the optimum.

    Deterministically seeded and asserted on a ROBUST trend statistic —
    the trailing-window mean of the deterministic action — instead of the
    final iterate: at the paper's lr (0.005) single iterates oscillate
    around the optimum (tanh-squash saturation excursions), which made a
    point-in-time assertion flaky.  lr 0.002 + a 50-iteration window is
    stable across the FIXED SEED LIST [0, 1, 2] (window error 0.02-0.06
    vs the 0.15 bound); the list is part of the regression contract —
    when retuning hyper-parameters, re-verify ALL THREE seeds rather than
    shrinking the list, or the pre-PR-2 flake comes back.
    """
    from repro.rl import networks as N
    cfg = a2c.A2CConfig(state_dim=4, action_dim=1, lr_actor=0.002,
                        lr_critic=0.01, entropy_coef=0.003)
    agent = a2c.init(jax.random.PRNGKey(seed), cfg)
    rng = np.random.default_rng(seed)
    key = jax.random.PRNGKey(seed)
    det_hist = []
    for it in range(400):
        s = rng.normal(size=(32, 4)).astype(np.float32)
        key, k = jax.random.split(key)
        mu, log_std = jax.vmap(
            lambda row: N.low_actor_apply(agent["actor"], row))(
            jnp.asarray(s))
        a, _ = N.sample_squashed(k, mu, log_std)
        r = 1.0 - np.abs(np.asarray(a[:, 0]) - 0.7)
        batch = {"states": jnp.asarray(s), "actions": jnp.asarray(a),
                 "rewards": jnp.asarray(r.astype(np.float32)),
                 "next_states": jnp.asarray(s),
                 "dones": jnp.ones((32,), jnp.float32)}
        agent, logs = a2c.update(agent, batch, cfg)
        det_hist.append(float(np.asarray(
            N.deterministic_action(mu)).mean()))
    trailing = float(np.mean(det_hist[-50:]))
    assert abs(trailing - 0.7) < 0.15, (seed, det_hist[0], det_hist[-1],
                                        trailing)


def test_sac_update_runs_and_targets_track():
    cfg = sac.SACConfig(state_dim=6, action_dim=3)
    agent = sac.init(KEY, cfg)
    buf = ReplayBuffer(512, 6, 3)
    rng = np.random.default_rng(1)
    for _ in range(200):
        s = rng.normal(size=6).astype(np.float32)
        a = rng.uniform(0, 1, size=3).astype(np.float32)
        r = float(-np.square(a - 0.5).sum())
        buf.add(s, a, r, s, False)
    before = jax.tree.leaves(agent["value_target"])[0].copy()
    for i in range(5):
        batch = {k: jnp.asarray(v) for k, v in buf.sample(64).items()}
        agent, logs = sac.update(jax.random.PRNGKey(i), agent, batch, cfg)
    after = jax.tree.leaves(agent["value_target"])[0]
    assert not np.allclose(np.asarray(before), np.asarray(after))
    for v in logs.values():
        assert np.isfinite(float(v))


# --------------------------------------------------------------- replay
def test_replay_buffer_wraps():
    buf = ReplayBuffer(8, 2, 1)
    for i in range(20):
        buf.add(np.zeros(2) + i, np.zeros(1), float(i), np.zeros(2), False)
    assert len(buf) == 8
    s = buf.sample(4)
    assert s["states"].shape == (4, 2)
    assert (s["rewards"] >= 12).all()       # only recent entries survive


@pytest.mark.parametrize("C", [1, 3, 8])
def test_stacked_replay_matches_per_stream_buffers(C):
    """Stream c of a StackedReplayBuffer is bit-identical — contents AND
    sampling order under the shared seed — to a standalone
    ``ReplayBuffer(..., seed=c)`` fed the same transitions, including
    after wrap-around."""
    cap, S, A = 16, 3, 2
    stacked = StackedReplayBuffer(cap, C, S, A)
    singles = [ReplayBuffer(cap, S, A, seed=c) for c in range(C)]
    rng = np.random.default_rng(7)
    for t in range(40):                              # 40 > cap: wraps
        s = rng.normal(size=(C, S)).astype(np.float32)
        a = rng.uniform(0, 1, size=(C, A)).astype(np.float32)
        r = rng.normal(size=C).astype(np.float32)
        s2 = rng.normal(size=(C, S)).astype(np.float32)
        stacked.add_batch(s, a, r, s2, np.zeros(C))
        for c in range(C):
            singles[c].add(s[c], a[c], r[c], s2[c], False)
        if t in (5, 20, 39):                         # interleave samples
            got = stacked.sample(4)
            for c in range(C):
                want = singles[c].sample(4)
                for k in want:
                    np.testing.assert_array_equal(got[k][c], want[k], k)
    assert len(stacked) == cap
    np.testing.assert_array_equal(stacked.lens(), [cap] * C)


# ------------------------------------------------- stacked agent parity
@pytest.mark.parametrize("C", [1, 3, 8])
def test_stacked_act_update_bit_exact_vs_per_stream(C):
    """`act_stacked`/`update_stacked` (one vmapped dispatch for all C
    agents) are bit-exact against C per-stream `act`/`update` calls on
    the sliced agents — the micro-level parity the fused bilevel_step
    builds on."""
    cfg = a2c.A2CConfig(state_dim=10)
    keys = jax.random.split(KEY, C)
    stack = a2c.init_stacked(keys, cfg)
    assert a2c.n_stacked(stack) == C
    rng = np.random.default_rng(3)
    states = jnp.asarray(rng.normal(size=(C, 10)).astype(np.float32))
    klo = jax.random.split(jax.random.PRNGKey(5), C)

    batched = np.asarray(a2c.act_stacked(klo, stack, states, True))
    for c in range(C):
        one = np.asarray(a2c.act(klo[c], a2c.slice_agent(stack, c),
                                 states[c], True))
        np.testing.assert_array_equal(batched[c], one)

    B = 8
    batch = {"states": rng.normal(size=(C, B, 10)).astype(np.float32),
             "actions": rng.uniform(0.1, 0.9,
                                    size=(C, B, 2)).astype(np.float32),
             "rewards": rng.normal(size=(C, B)).astype(np.float32),
             "next_states": rng.normal(size=(C, B, 10)).astype(np.float32),
             "dones": np.zeros((C, B), np.float32)}
    new_stack, logs = a2c.update_stacked(stack, batch, cfg)
    for c in range(C):
        want, wlog = a2c.update(a2c.slice_agent(stack, c),
                                {k: v[c] for k, v in batch.items()}, cfg)
        assert _tree_equal(a2c.slice_agent(new_stack, c), want)
        for k in wlog:
            np.testing.assert_array_equal(np.asarray(logs[k][c]),
                                          np.asarray(wlog[k]), k)


# ------------------------------------------------ bi-level trainer parity
def _mk_trainer(C, seed=0, low_batch=4, detector=None, sac_minibatch=None,
                **cfg_kwargs):
    from repro.core.bilevel import BiLevelTrainer
    from repro.sim.env import EnvConfig
    from repro.sim.video_source import paper_stream_mix
    cfg_kwargs.setdefault("chunk_frames", 4)
    cfg = EnvConfig(streams=tuple(paper_stream_mix(C, 64, 96)),
                    **cfg_kwargs)
    tr = BiLevelTrainer.create(cfg, seed=seed, detector=detector,
                               low_batch=low_batch)
    if sac_minibatch is not None:   # paper minibatch 128 needs 128 chunks
        import dataclasses
        tr.controller.cfg = dataclasses.replace(tr.controller.cfg,
                                                minibatch=sac_minibatch)
    return tr


def _run(tr, n, mode):
    hist, logs = [], []
    step = tr.run_chunk if mode == "stacked" else tr.run_chunk_loop
    for _ in range(n):
        m, results, info, lg = step()
        hist.append(m)
        logs.append(lg)
    if mode == "stacked":
        tr.flush()
    return hist, logs


@pytest.mark.parametrize("C", [1, 3, 8])
def test_bilevel_stacked_vs_loop_bit_exact(C):
    """THE tentpole contract: the single-jit ``bilevel_step`` path equals
    the per-stream loop oracle bit-for-bit — every action, state and
    reward written to replay (low_batch=4 engages the A2C update path
    from chunk 4), the chunk metrics, and the post-update parameters of
    all C agents after the deferred-update flush."""
    n = 6
    t_loop = _mk_trainer(C)
    t_stack = _mk_trainer(C)
    h_loop, _ = _run(t_loop, n, "loop")
    h_stack, _ = _run(t_stack, n, "stacked")

    assert h_loop == h_stack                      # metrics, exactly
    for name in ("s", "a", "r", "s2"):            # replay = full history
        np.testing.assert_array_equal(
            getattr(t_loop.low_buffer, name),
            getattr(t_stack.low_buffer, name), name)
    assert _tree_equal(t_loop.low_stack, t_stack.low_stack)
    assert _tree_equal(t_loop.controller.agent, t_stack.controller.agent)
    np.testing.assert_array_equal(t_loop.controller.buffer.s,
                                  t_stack.controller.buffer.s)
    np.testing.assert_array_equal(t_loop.controller._current,
                                  t_stack.controller._current)


def test_bilevel_parity_across_controller_interval():
    """The traced recompute/cached-proportions select stays exact across
    a reallocation boundary (controller_interval=3 -> recompute fires at
    t=0 and t=3 inside a 5-chunk run)."""
    t_loop = _mk_trainer(2, controller_interval=3)
    t_stack = _mk_trainer(2, controller_interval=3)
    h_loop, _ = _run(t_loop, 5, "loop")
    h_stack, _ = _run(t_stack, 5, "stacked")
    assert h_loop == h_stack
    assert _tree_equal(t_loop.low_stack, t_stack.low_stack)


def test_bilevel_parity_with_sac_update_engaged():
    """The fused SAC-update island (do_high) equals the oracle's
    ``controller.train``: with the controller minibatch shrunk to 6 the
    update engages at chunk 5 of an 8-chunk run (the paper's 128 would
    need 128 chunks), covering the inlined ``sac._update``, the
    ``pend['k_tr']`` routing, and the controller-buffer sampling order."""
    t_loop = _mk_trainer(2, sac_minibatch=6)
    t_stack = _mk_trainer(2, sac_minibatch=6)
    h_loop, _ = _run(t_loop, 8, "loop")
    h_stack, _ = _run(t_stack, 8, "stacked")
    assert t_loop.controller.updates >= 2      # the island really ran
    assert t_loop.controller.updates == t_stack.controller.updates
    assert h_loop == h_stack
    assert _tree_equal(t_loop.controller.agent, t_stack.controller.agent)
    assert _tree_equal(t_loop.low_stack, t_stack.low_stack)


def test_bilevel_mode_mixing_flushes_pending():
    """Switching fused -> loop on one trainer applies the deferred update
    first, so a mixed run equals a pure loop run of the same length."""
    t_mixed = _mk_trainer(2, seed=3)
    t_pure = _mk_trainer(2, seed=3)
    for _ in range(6):
        t_pure.run_chunk_loop()
    for _ in range(5):                     # chunk 4 defers chunk 4's
        t_mixed.run_chunk()                # update (low_batch=4)...
    assert t_mixed._pending and t_mixed._pending["do_low"]
    t_mixed.run_chunk_loop()               # ...flushed on mode switch
    assert _tree_equal(t_pure.low_stack, t_mixed.low_stack)
    np.testing.assert_array_equal(t_pure.low_buffer.a, t_mixed.low_buffer.a)


def test_bilevel_forecast_widens_sac_state_and_keeps_parity():
    """EnvConfig.forecast widens the SAC controller's state vector by
    forecast_dim(C) (the forecaster's EWMA features ride S_high into
    ``bilevel_step`` with no control-plane code change) and the
    stacked-vs-loop contract stays bit-exact with the forecast ON —
    both paths share env.step/observe_high, so the appended features
    are identical chunk by chunk."""
    from repro.core.forecast import ForecastConfig, forecast_dim
    from repro.sim.env import high_state_dim
    C = 2
    t_loop = _mk_trainer(C, forecast=ForecastConfig())
    t_stack = _mk_trainer(C, forecast=ForecastConfig())
    dim = high_state_dim(t_loop.env.cfg)
    assert dim == 6 * C + forecast_dim(C)
    assert t_loop.controller.buffer.s.shape[1] == dim
    h_loop, _ = _run(t_loop, 6, "loop")
    h_stack, _ = _run(t_stack, 6, "stacked")
    assert h_loop == h_stack
    assert _tree_equal(t_loop.low_stack, t_stack.low_stack)
    assert _tree_equal(t_loop.controller.agent, t_stack.controller.agent)
    np.testing.assert_array_equal(t_loop.controller.buffer.s,
                                  t_stack.controller.buffer.s)
    # the forecast head actually observed the run on both paths
    for tr in (t_loop, t_stack):
        assert tr.env.forecaster is not None and tr.env.forecaster.t == 6
    np.testing.assert_array_equal(t_loop.env.forecaster.rate,
                                  t_stack.env.forecaster.rate)


def test_bilevel_forecast_off_state_dim_unchanged():
    """forecast=None (the default) keeps the SAC state at 6*C — the
    reactive controller is byte-identical to pre-forecast builds."""
    from repro.sim.env import high_state_dim
    tr = _mk_trainer(3)
    assert high_state_dim(tr.env.cfg) == 18
    assert tr.env.forecaster is None
    assert tr.controller.buffer.s.shape[1] == 18


def test_bilevel_seeded_determinism():
    """Two fused runs from the same seed produce IDENTICAL chunk logs —
    catches host-side RNG leaks / dict-ordering nondeterminism in the
    stacked refactor (metrics, train logs, and replay contents all
    compare exactly)."""
    a_hist, a_logs = _run(_mk_trainer(3, seed=11), 6, "stacked")
    b_hist, b_logs = _run(_mk_trainer(3, seed=11), 6, "stacked")
    assert a_hist == b_hist
    assert a_logs == b_logs


@pytest.mark.slow
def test_bilevel_trainer_runs_and_is_finite():
    tr = _mk_trainer(2, low_batch=32)
    hist = tr.train_steps(4)
    assert len(hist) == 4
    for m in hist:
        assert 0.0 <= m["mean_acc"] <= 1.0
        assert np.isfinite(m["reward_min"])
        assert 0.0 <= m["jain"] <= 1.0


@pytest.mark.slow
def test_bilevel_stacked_composes_with_detector_backend():
    """The fused control plane drives the real-detector env (one
    ``roundtrip_padded_batched`` dispatch per signature group) and stays
    bit-exact vs the loop oracle there too."""
    from repro.models import detection as D
    det_cfg = D.TinyDetectorConfig()
    det = (D.init(jax.random.PRNGKey(1), det_cfg), det_cfg)
    t_loop = _mk_trainer(2, accuracy_backend="detector", detector=det)
    t_stack = _mk_trainer(2, accuracy_backend="detector", detector=det)
    h_loop, _ = _run(t_loop, 2, "loop")
    h_stack, _ = _run(t_stack, 2, "stacked")
    assert h_loop == h_stack
    np.testing.assert_array_equal(t_loop.low_buffer.a, t_stack.low_buffer.a)
