"""DRL layers: A2C and SAC learn simple synthetic tasks; the bi-level
trainer improves min-stream reward over random allocation."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.rl import a2c, sac
from repro.rl.replay import ReplayBuffer

KEY = jax.random.PRNGKey(0)


def test_a2c_learns_threshold_bandit():
    """Reward = 1 - |a - 0.7|: the actor mean converges to the optimum.

    Deterministically seeded (PRNGKey(0) policy noise, default_rng(0)
    states) and asserted on a ROBUST trend statistic — the trailing-window
    mean of the deterministic action — instead of the final iterate: at
    the paper's lr (0.005) single iterates oscillate around the optimum
    (tanh-squash saturation excursions), which made the old point-in-time
    assertion flaky.  lr 0.002 + a 50-iteration window is stable across
    seeds (window error 0.02-0.06 vs the 0.15 bound for seeds 0/1/2)."""
    from repro.rl import networks as N
    cfg = a2c.A2CConfig(state_dim=4, action_dim=1, lr_actor=0.002,
                        lr_critic=0.01, entropy_coef=0.003)
    agent = a2c.init(KEY, cfg)
    rng = np.random.default_rng(0)
    key = KEY
    det_hist = []
    for it in range(400):
        s = rng.normal(size=(32, 4)).astype(np.float32)
        key, k = jax.random.split(key)
        mu, log_std = jax.vmap(
            lambda row: N.low_actor_apply(agent["actor"], row))(
            jnp.asarray(s))
        a, _ = N.sample_squashed(k, mu, log_std)
        r = 1.0 - np.abs(np.asarray(a[:, 0]) - 0.7)
        batch = {"states": jnp.asarray(s), "actions": jnp.asarray(a),
                 "rewards": jnp.asarray(r.astype(np.float32)),
                 "next_states": jnp.asarray(s),
                 "dones": jnp.ones((32,), jnp.float32)}
        agent, logs = a2c.update(agent, batch, cfg)
        det_hist.append(float(np.asarray(
            N.deterministic_action(mu)).mean()))
    trailing = float(np.mean(det_hist[-50:]))
    assert abs(trailing - 0.7) < 0.15, (det_hist[0], det_hist[-1], trailing)


def test_sac_update_runs_and_targets_track():
    cfg = sac.SACConfig(state_dim=6, action_dim=3)
    agent = sac.init(KEY, cfg)
    buf = ReplayBuffer(512, 6, 3)
    rng = np.random.default_rng(1)
    for _ in range(200):
        s = rng.normal(size=6).astype(np.float32)
        a = rng.uniform(0, 1, size=3).astype(np.float32)
        r = float(-np.square(a - 0.5).sum())
        buf.add(s, a, r, s, False)
    before = jax.tree.leaves(agent["value_target"])[0].copy()
    for i in range(5):
        batch = {k: jnp.asarray(v) for k, v in buf.sample(64).items()}
        agent, logs = sac.update(jax.random.PRNGKey(i), agent, batch, cfg)
    after = jax.tree.leaves(agent["value_target"])[0]
    assert not np.allclose(np.asarray(before), np.asarray(after))
    for v in logs.values():
        assert np.isfinite(float(v))


def test_replay_buffer_wraps():
    buf = ReplayBuffer(8, 2, 1)
    for i in range(20):
        buf.add(np.zeros(2) + i, np.zeros(1), float(i), np.zeros(2), False)
    assert len(buf) == 8
    s = buf.sample(4)
    assert s["states"].shape == (4, 2)
    assert (s["rewards"] >= 12).all()       # only recent entries survive


@pytest.mark.slow
def test_bilevel_trainer_runs_and_is_finite():
    from repro.core.bilevel import BiLevelTrainer
    from repro.sim.env import EnvConfig
    from repro.sim.video_source import paper_stream_mix
    cfg = EnvConfig(streams=tuple(paper_stream_mix(2, 64, 96)),
                    chunk_frames=4)
    tr = BiLevelTrainer.create(cfg, seed=0)
    hist = tr.train_steps(4)
    assert len(hist) == 4
    for m in hist:
        assert 0.0 <= m["mean_acc"] <= 1.0
        assert np.isfinite(m["reward_min"])
        assert 0.0 <= m["jain"] <= 1.0
