"""Serving plane: scheduler queues/admission, straggler hedging/eviction,
elastic pool, end-to-end EdgeRuntime chunk."""
import jax
import numpy as np
import pytest

from repro.serving.elastic import ElasticPool, remesh
from repro.serving.scheduler import (AdmissionController, InferRequest,
                                     PipelineQueues, ServingConfig)
from repro.serving.straggler import (DetectorConfig, HedgeConfig,
                                     HedgedExecutor, StragglerDetector)

KEY = jax.random.PRNGKey(0)


def test_scheduler_batches_and_prioritizes_pipeline1():
    cfg = ServingConfig(n_streams=2, batch_size=4)
    seen = []

    def infer(frames):
        seen.append(frames.shape[0])
        return [(np.zeros((1, 4)), np.zeros(1))] * frames.shape[0]

    q = PipelineQueues(cfg, infer)
    frame = np.zeros((16, 16), np.float32)
    for i in range(3):
        q.submit(InferRequest(0, 0, i, 2, frame))
    for i in range(3):
        q.submit(InferRequest(1, 0, i, 1, frame))
    done = q.drain()
    assert len(done) == 6
    assert seen[0] == 4                       # batched
    # pipeline ① requests executed before ②
    first_batch_pipelines = [r.pipeline for r, _ in done[:3]]
    assert first_batch_pipelines == [1, 1, 1]


def test_admission_defers_on_backlog():
    cfg = ServingConfig(n_streams=1, gpu_capacity_fps=30.0,
                        latency_budget=1.0)
    adm = AdmissionController(cfg)
    assert adm.admit(np.asarray([0.0, 0.0]), 10)
    assert not adm.admit(np.asarray([40.0, 0.0]), 10)


def test_hedged_executor_cuts_tail():
    cfg = HedgeConfig(quantile=0.9, min_history=10)
    calls = {"n": 0}
    ex = HedgedExecutor(cfg, [lambda x: ("r0", x), lambda x: ("r1", x)])
    rng = np.random.default_rng(0)

    def lat(replica):
        calls["n"] += 1
        return 10.0 if (calls["n"] % 7 == 0 and replica == 0) else \
            float(rng.uniform(0.01, 0.02))

    for i in range(50):
        out, winner = ex.run(i, simulate_latency=lat)
    assert ex.hedges > 0
    # once history is warm (first min_history calls run unhedged), hedging
    # caps the tail: the last 30 effective latencies stay fast
    warm = np.asarray(ex.lat)[-30:]
    assert float(np.quantile(warm, 0.99)) < 1.0


def test_straggler_detector_flags_slow_replica():
    det = StragglerDetector(DetectorConfig(threshold=1.5, patience=3), 4)
    for step in range(6):
        for r in range(4):
            det.record(r, 1.0 if r != 2 else 3.0)
        flagged = det.flagged()
    assert flagged == [2]


def test_elastic_pool_power_of_two():
    pool = ElasticPool(n_groups=8)
    assert pool.usable_power_of_two() == 8
    pool.fail(3)
    assert pool.usable_power_of_two() == 4
    pool.recover(3)
    assert pool.usable_power_of_two() == 8
    mesh = remesh(pool)
    assert mesh.shape["data"] >= 1


def test_edge_runtime_end_to_end_chunk():
    from repro.core.hybrid_encoder import encode_hybrid
    from repro.models import detection as D
    from repro.serving.runtime import EdgeRuntime
    from repro.sim.video_source import StreamConfig, generate_chunk

    frames, boxes, valid = generate_chunk(
        KEY, StreamConfig(height=64, width=96, n_objects=3), 0, 4)
    det_cfg = D.TinyDetectorConfig()
    params = D.init(jax.random.PRNGKey(1), det_cfg)
    rt = EdgeRuntime(ServingConfig(n_streams=1), params, det_cfg)
    packet = encode_hybrid(np.asarray(frames), 8000.0, 0.05, 0.1)
    b, s, types = rt.process_chunk(0, 0, packet)
    assert b.shape[0] == 4 and s.shape[0] == 4
    lat = rt.compute_latency(types, packet.total_bits, 8000.0)
    assert lat["total"] > 0
    assert not np.any(np.isnan(b))
