"""Serving plane: scheduler queues/admission, straggler hedging/eviction,
elastic pool, end-to-end EdgeRuntime chunk."""
import jax
import numpy as np

from repro.serving.elastic import ElasticPool, remesh
from repro.serving.scheduler import (AdmissionController, InferRequest,
                                     PipelineQueues, ServingConfig)
from repro.serving.straggler import (DetectorConfig, HedgeConfig,
                                     HedgedExecutor, StragglerDetector)

KEY = jax.random.PRNGKey(0)


def test_scheduler_batches_and_prioritizes_pipeline1():
    cfg = ServingConfig(n_streams=2, batch_size=4)
    seen = []

    def infer(frames):
        seen.append(frames.shape[0])
        return [(np.zeros((1, 4)), np.zeros(1))] * frames.shape[0]

    q = PipelineQueues(cfg, infer)
    frame = np.zeros((16, 16), np.float32)
    for i in range(3):
        q.submit(InferRequest(0, 0, i, 2, frame))
    for i in range(3):
        q.submit(InferRequest(1, 0, i, 1, frame))
    done = q.drain()
    assert len(done) == 6
    assert seen[0] == 4                       # batched
    # pipeline ① requests executed before ②
    first_batch_pipelines = [r.pipeline for r, _ in done[:3]]
    assert first_batch_pipelines == [1, 1, 1]


def test_admission_defers_on_backlog():
    cfg = ServingConfig(n_streams=1, gpu_capacity_fps=30.0,
                        latency_budget=1.0)
    adm = AdmissionController(cfg)
    assert adm.admit(np.asarray([0.0, 0.0]), 10)
    assert not adm.admit(np.asarray([40.0, 0.0]), 10)


def test_admission_per_shard_matches_global_at_one_shard():
    """admit_shard is the drop-in generalization: identical verdicts to
    the legacy global controller when n_shards == 1."""
    cfg = ServingConfig(n_streams=1, gpu_capacity_fps=30.0,
                        latency_budget=1.0)
    adm = AdmissionController(cfg)
    for depth, n_new in [(0.0, 10), (40.0, 10), (25.0, 5), (25.0, 6)]:
        depths = np.asarray([depth, 0.0])
        assert adm.admit(depths, n_new) == \
            adm.admit_shard(depths[None, :], 0, n_new)


def test_admission_per_shard_uses_own_backlog_only():
    cfg = ServingConfig(n_streams=4, n_shards=4, gpu_capacity_fps=120.0,
                        latency_budget=1.0)
    adm = AdmissionController(cfg)
    assert cfg.shard_capacity_fps == 30.0
    depths = np.zeros((4, 2), np.float32)
    depths[2] = [40.0, 5.0]                   # only shard 2 is hot
    for shard in (0, 1, 3):
        assert adm.admit_shard(depths, shard, 10)
    assert not adm.admit_shard(depths, 2, 10)


def test_drain_fused_pads_to_batch_multiple():
    """Padding at batch boundaries: n == k*batch dispatches exactly n
    frames (no spurious pad batch); n == k*batch + 1 rounds up to the
    next multiple; the pad lanes are zero and their outputs are dropped."""
    cfg = ServingConfig(n_streams=1, batch_size=4)
    shapes, payloads = [], []

    def infer(frames):
        shapes.append(frames.shape[0])
        payloads.append(frames)
        return [(np.full((1, 4), i, np.float32), np.zeros(1))
                for i in range(frames.shape[0])]

    frame = np.ones((8, 8), np.float32)
    q = PipelineQueues(cfg, infer)
    for n, expect in [(4, 4), (5, 8), (8, 8), (1, 4)]:
        for i in range(n):
            q.submit(InferRequest(0, 0, i, 1, frame))
        done = q.drain_fused()
        assert shapes[-1] == expect
        assert len(done) == n                 # pad outputs dropped
        # results align 1:1 with the submitted requests, in order
        assert [r.frame_idx for r, _ in done] == list(range(n))
        if expect > n:                        # pad lanes are zero frames
            assert float(np.abs(payloads[-1][n:]).sum()) == 0.0
    assert q.drain_fused() == []              # empty queues: no dispatch
    assert len(shapes) == 4


def test_drain_fused_per_shard_leaves_other_shards_queued():
    cfg = ServingConfig(n_streams=2, n_shards=2, batch_size=2)
    calls = []

    def infer(frames):
        calls.append(frames.shape[0])
        return [(np.zeros((1, 4)), np.zeros(1))] * frames.shape[0]

    q = PipelineQueues(cfg, infer)
    frame = np.zeros((8, 8), np.float32)
    for i in range(3):
        q.submit(InferRequest(0, 0, i, 1, frame, shard=0))
    for i in range(2):
        q.submit(InferRequest(1, 0, i, 2, frame, shard=1))
    done0 = q.drain_fused(shard=0)
    assert len(done0) == 3
    assert all(r.shard == 0 for r, _ in done0)
    # shard 1's backlog untouched by shard 0's dispatch
    np.testing.assert_array_equal(q.shard_depths,
                                  [[0.0, 0.0], [0.0, 2.0]])
    done1 = q.drain_fused(shard=1)
    assert len(done1) == 2 and q.depths.sum() == 0


def test_edge_runtime_pipeline3_fallback_accounting():
    """Overload demotions are attributed to the right shard: ②->③
    demotions, whole-chunk reuse fallbacks, and per-shard deferrals."""
    from repro.core.hybrid_encoder import encode_hybrid
    from repro.models import detection as D
    from repro.serving.runtime import EdgeRuntime
    from repro.sim.video_source import StreamConfig, generate_chunk

    frames, _, _ = generate_chunk(
        KEY, StreamConfig(height=32, width=48, n_objects=2), 0, 4)
    packet = encode_hybrid(np.asarray(frames), 8000.0, 0.05, 0.1)
    det_cfg = D.TinyDetectorConfig()
    params = D.init(jax.random.PRNGKey(1), det_cfg)
    cfg = ServingConfig(n_streams=2, n_shards=2, gpu_capacity_fps=1.0,
                        latency_budget=1.0)   # admits nothing anywhere
    rt = EdgeRuntime(cfg, params, det_cfg)
    n2 = int((packet.types == 2).sum())
    # chunk 0 on stream 0 (shard 0): no carry -> anchors survive, type-2
    # frames demoted
    _, _, t0 = rt.process_chunk(0, 0, packet)
    assert rt.deferred_by_shard.tolist() == [1, 0]
    assert rt.demoted_frames[0] == n2
    assert rt.reuse_fallback_chunks[0] == 0
    # chunk 1 on stream 0: carry exists -> whole chunk to pipeline ③
    _, _, t1 = rt.process_chunk(0, 1, packet)
    assert (t1 == 3).all()
    assert rt.reuse_fallback_chunks.tolist() == [1, 0]
    assert rt.demoted_frames[0] == n2 * 2 + int((packet.types == 1).sum())
    # stream 1 lands on shard 1: its counters are independent
    rt.process_chunk(1, 0, packet)
    assert rt.deferred_by_shard.tolist() == [2, 1]
    assert rt.demoted_frames[1] == n2
    assert rt.deferred == 3


def test_hedged_executor_cuts_tail():
    cfg = HedgeConfig(quantile=0.9, min_history=10)
    calls = {"n": 0}
    ex = HedgedExecutor(cfg, [lambda x: ("r0", x), lambda x: ("r1", x)])
    rng = np.random.default_rng(0)

    def lat(replica):
        calls["n"] += 1
        return 10.0 if (calls["n"] % 7 == 0 and replica == 0) else \
            float(rng.uniform(0.01, 0.02))

    for i in range(50):
        out, winner = ex.run(i, simulate_latency=lat)
    assert ex.hedges > 0
    # once history is warm (first min_history calls run unhedged), hedging
    # caps the tail: the last 30 effective latencies stay fast
    warm = np.asarray(ex.lat)[-30:]
    assert float(np.quantile(warm, 0.99)) < 1.0


def test_straggler_detector_flags_slow_replica():
    det = StragglerDetector(DetectorConfig(threshold=1.5, patience=3), 4)
    for step in range(6):
        for r in range(4):
            det.record(r, 1.0 if r != 2 else 3.0)
        flagged = det.flagged()
    assert flagged == [2]


def test_elastic_pool_power_of_two():
    pool = ElasticPool(n_groups=8)
    assert pool.usable_power_of_two() == 8
    pool.fail(3)
    assert pool.usable_power_of_two() == 4
    pool.recover(3)
    assert pool.usable_power_of_two() == 8
    mesh = remesh(pool)
    assert mesh.shape["data"] >= 1


def test_edge_runtime_end_to_end_chunk():
    from repro.core.hybrid_encoder import encode_hybrid
    from repro.models import detection as D
    from repro.serving.runtime import EdgeRuntime
    from repro.sim.video_source import StreamConfig, generate_chunk

    frames, boxes, valid = generate_chunk(
        KEY, StreamConfig(height=64, width=96, n_objects=3), 0, 4)
    det_cfg = D.TinyDetectorConfig()
    params = D.init(jax.random.PRNGKey(1), det_cfg)
    rt = EdgeRuntime(ServingConfig(n_streams=1), params, det_cfg)
    packet = encode_hybrid(np.asarray(frames), 8000.0, 0.05, 0.1)
    b, s, types = rt.process_chunk(0, 0, packet)
    assert b.shape[0] == 4 and s.shape[0] == 4
    lat = rt.compute_latency(types, packet.total_bits, 8000.0)
    assert lat["total"] > 0
    assert not np.any(np.isnan(b))


# --------------------------------------------- wall-clock hedging (chaos PR)
def _warm_executor(replicas, n=12, quantile=0.9):
    """Executor with enough real (fast) history that the deadline is a
    few milliseconds rather than inf."""
    import time
    ex = HedgedExecutor(HedgeConfig(quantile=quantile, min_history=8),
                        replicas)
    for _ in range(n):
        ex.run(None, primary=len(replicas) - 1)
        time.sleep(0.001)
    return ex


def test_hedged_wallclock_issues_backup_and_backup_wins():
    """The regression this guards: the wall-clock path used to time the
    primary and NEVER hedge.  A primary that blows the deadline must get
    a backup issued, and the faster backup must win."""
    import time
    mode = {"slow": False}

    def r0(_):
        if mode["slow"]:
            time.sleep(0.4)
        return "r0"

    ex = _warm_executor([r0, lambda _: "r1"])
    assert np.isfinite(ex._deadline()) and ex._deadline() < 0.1
    mode["slow"] = True
    out, winner = ex.run(None, primary=0)
    assert ex.hedges == 1
    assert winner == 1 and out == "r1"
    ex.close()


def test_hedged_wallclock_first_finisher_wins_even_if_primary():
    """If the primary misses the deadline but still finishes before the
    backup, the primary's (earlier) result is the one returned."""
    import time

    def primary(_):
        time.sleep(0.06)
        return "primary"

    def backup(_):
        time.sleep(0.5)
        return "backup"

    ex = _warm_executor([primary, backup, lambda _: "fast"])
    # warm on replica 2; now pin primary=0 (0.06 s) with backup=1 (0.5 s)
    out, winner = ex.run(None, primary=0)
    assert ex.hedges == 1
    assert winner == 0 and out == "primary"
    ex.close()


def test_hedged_wallclock_fast_primary_never_hedges():
    ex = _warm_executor([lambda _: "r0", lambda _: "r1"])
    out, winner = ex.run(None, primary=0)
    assert winner == 0 and out == "r0" and ex.hedges == 0
    ex.close()


def test_hedged_wallclock_cold_history_runs_unhedged():
    import time

    def slow(_):
        time.sleep(0.05)
        return "slow"

    ex = HedgedExecutor(HedgeConfig(min_history=20), [slow, lambda _: "x"])
    out, winner = ex.run(None)         # deadline inf: no thread, no hedge
    assert out == "slow" and winner == 0 and ex.hedges == 0
    assert ex._pool is None
    ex.close()


def test_hedged_simulated_path_respects_primary_pin_and_max_hedges():
    ex = HedgedExecutor(HedgeConfig(min_history=2, max_hedges=0),
                        [lambda x: "r0", lambda x: "r1"])
    ex.lat.extend([0.01, 0.01])
    out, winner = ex.run(None, simulate_latency=lambda i: 9.0, primary=1)
    assert winner == 1 and out == "r1" and ex.hedges == 0


# --------------------------------------------- elastic pool contract (S1)
def test_elastic_pool_healthy_contract():
    pool = ElasticPool(3)
    assert pool.healthy.dtype == np.bool_ and pool.n_healthy == 3
    # caller-provided arrays are validated, coerced to bool, and copied
    src = np.asarray([1, 0, 1], np.int64)
    pool = ElasticPool(3, healthy=src)
    assert pool.healthy.dtype == np.bool_ and pool.n_healthy == 2
    src[0] = 0
    assert pool.n_healthy == 2                # a copy, not a view
    import pytest
    with pytest.raises(ValueError, match="shape"):
        ElasticPool(3, healthy=np.ones(4, bool))
    with pytest.raises(ValueError, match="n_groups"):
        ElasticPool(0)
    with pytest.raises(IndexError):
        pool.fail(3)
    with pytest.raises(IndexError):
        pool.recover(-1)
    assert pool.healthy_groups() == [0, 2]


def test_remesh_raises_instead_of_zero_sized_mesh():
    import pytest
    pool = ElasticPool(2)
    pool.fail(0)
    pool.fail(1)
    with pytest.raises(RuntimeError, match="0 of 2 groups healthy"):
        remesh(pool)
    pool.recover(0)
    # healthy groups exist but cannot host the model replica count
    with pytest.raises(RuntimeError, match="n_model=2"):
        remesh(pool, n_model=2)
    with pytest.raises(ValueError, match="n_model"):
        remesh(pool, n_model=0)


# ------------------------------------------ straggler detector edges (S4)
def test_straggler_threshold_edge_does_not_flag():
    """Exactly threshold x median is NOT a straggler (strict >).  Three
    replicas keep the global median pinned at the healthy pace."""
    det = StragglerDetector(DetectorConfig(threshold=2.0, patience=1), 3)
    for _ in range(5):
        det.record(0, 1.0)
        det.record(1, 1.0)
        det.record(2, 2.0)
    assert det.flagged() == []
    det2 = StragglerDetector(DetectorConfig(threshold=2.0, patience=1), 3)
    for _ in range(5):
        det2.record(0, 1.0)
        det2.record(1, 1.0)
        det2.record(2, 2.1)
    assert det2.flagged() == [2]


def test_straggler_patience_requires_consecutive_strikes():
    det = StragglerDetector(DetectorConfig(threshold=1.5, patience=3), 2)
    for _ in range(4):
        det.record(0, 1.0)
        det.record(1, 5.0)
    assert det.flagged() == [] and det.strikes[1] == 1
    assert det.flagged() == [] and det.strikes[1] == 2
    # a healthy interval resets the strike count
    for _ in range(20):
        det.record(1, 1.0)
    assert det.flagged() == [] and det.strikes[1] == 0


def test_straggler_window_ages_out_old_slowness():
    """A small sliding window forgets a past slowdown: after enough
    healthy samples the replica stops striking."""
    det = StragglerDetector(DetectorConfig(threshold=1.5, patience=2,
                                           window=4), 2)
    for _ in range(4):
        det.record(0, 1.0)
        det.record(1, 8.0)
    assert det.flagged() == []                # strike 1 of 2
    for _ in range(4):                        # slow samples age out
        det.record(0, 1.0)
        det.record(1, 1.0)
    assert det.flagged() == [] and det.strikes[1] == 0
    assert len(det.history[1]) == 4


def test_straggler_reset_clears_history_and_strikes():
    det = StragglerDetector(DetectorConfig(threshold=1.5, patience=2), 2)
    for _ in range(5):
        det.record(0, 1.0)
        det.record(1, 9.0)
    det.flagged()
    assert det.strikes[1] == 1
    det.reset(1)
    assert det.strikes[1] == 0 and len(det.history[1]) == 0
    assert det.flagged() == []
