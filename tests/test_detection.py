"""Detection head + F1 metric: metric properties and a short real training
run that must lift F1 above the untrained baseline."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import detection as D
from repro.sim.video_source import StreamConfig, generate_chunk
from repro.train.optimizer import AdamWConfig, apply_updates, init_state

KEY = jax.random.PRNGKey(0)


def test_f1_perfect_prediction():
    gt = jnp.asarray([[20.0, 20.0, 10.0, 10.0], [40.0, 50.0, 8.0, 8.0]])
    valid = jnp.asarray([True, True])
    scores = jnp.asarray([0.9, 0.9])
    f1 = D.f1_score(gt, scores, gt, valid)
    assert float(f1) == pytest.approx(1.0, abs=1e-5)


def test_f1_no_predictions():
    gt = jnp.asarray([[20.0, 20.0, 10.0, 10.0]])
    f1 = D.f1_score(gt, jnp.asarray([0.0]), gt, jnp.asarray([True]))
    assert float(f1) == pytest.approx(0.0, abs=1e-5)


def test_f1_empty_scene():
    pred = jnp.asarray([[20.0, 20.0, 10.0, 10.0]])
    f1 = D.f1_score(pred, jnp.asarray([0.0]), pred, jnp.asarray([False]))
    assert float(f1) == pytest.approx(1.0)  # nothing to find, nothing found


def test_iou_identity_and_disjoint():
    a = jnp.asarray([10.0, 10.0, 4.0, 4.0])
    b = jnp.asarray([100.0, 100.0, 4.0, 4.0])
    assert float(D.iou_cxcywh(a, a)) == pytest.approx(1.0)
    assert float(D.iou_cxcywh(a, b)) == pytest.approx(0.0)


@pytest.mark.slow
def test_tiny_detector_learns():
    cfg = D.TinyDetectorConfig()
    params = D.init(KEY, cfg)
    opt = init_state(params)
    ocfg = AdamWConfig(lr=3e-3, weight_decay=0.0, warmup_steps=10,
                       total_steps=150)
    sc = StreamConfig(height=64, width=96, n_objects=2, min_size=16,
                      max_size=28, seed=7)

    @jax.jit
    def step(params, opt, frames, boxes, valid):
        loss, g = jax.value_and_grad(
            lambda p: D.loss_fn(p, cfg, frames, boxes, valid))(params)
        params, opt, _ = apply_updates(params, g, opt, ocfg)
        return params, opt, loss

    losses = []
    for i in range(300):
        frames, boxes, valid = generate_chunk(KEY, sc, i * 4, 4)
        params, opt, loss = step(params, opt, frames, boxes, valid)
        losses.append(float(loss))
    assert losses[-1] < losses[0] * 0.3

    frames, boxes, valid = generate_chunk(KEY, sc, 10_000, 2)
    raw = D.forward(params, cfg, frames)
    pb, ps = D.decode_boxes(raw, cfg)
    nms = jax.jit(lambda b, s: D.greedy_nms(b, s, iou_thresh=0.4, top_k=16))
    f1 = np.mean([float(D.f1_score(*nms(pb[i], ps[i]), boxes[i], valid[i],
                                   score_thresh=0.5))
                  for i in range(2)])
    assert f1 > 0.25, f"trained detector F1 too low: {f1}"
