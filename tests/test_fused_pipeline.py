"""Fused analytics path: motion-SAD kernel parity vs the scan oracle,
single-jit chunk execution parity vs the legacy host-orchestrated path,
and the batched runtime's dispatch/carry invariants."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.codec.motion import block_sad
from repro.kernels.motion_sad.ops import motion_sad
from repro.kernels.motion_sad.ref import motion_sad_ref

KEY = jax.random.PRNGKey(0)


# ------------------------------------------------------------- motion_sad
@pytest.mark.parametrize("H,W,radius", [
    (64, 96, 4), (64, 96, 8), (64, 96, 16),
    (48, 160, 8), (128, 64, 8), (32, 32, 4),
])
def test_motion_sad_matches_scan_oracle(H, W, radius):
    ks = jax.random.split(KEY, 2)
    cur = jax.random.uniform(ks[0], (H, W), jnp.float32) * 255
    ref = jnp.roll(cur, (3, -2), (0, 1)) \
        + jax.random.normal(ks[1], (H, W)) * 2
    mv_k, sad_k = motion_sad(cur, ref, radius=radius, interpret=True)
    mv_o, sad_o = motion_sad_ref(cur, ref, radius)
    np.testing.assert_array_equal(np.asarray(mv_k), np.asarray(mv_o))
    np.testing.assert_allclose(np.asarray(sad_k), np.asarray(sad_o),
                               rtol=1e-6)


@pytest.mark.parametrize("radius", [4, 8])
def test_motion_sad_tie_breaking_matches_oracle(radius):
    # constant frame: every candidate SAD is identical — both paths must
    # pick the FIRST candidate in dy-major order, i.e. (-R, -R)
    cur = jnp.full((32, 48), 9.0, jnp.float32)
    mv_k, _ = motion_sad(cur, cur, radius=radius, interpret=True)
    mv_o, _ = motion_sad_ref(cur, cur, radius)
    np.testing.assert_array_equal(np.asarray(mv_k), np.asarray(mv_o))
    assert (np.asarray(mv_k) == -radius).all()
    # horizontal stripes: exact ties along dx at fixed dy
    stripes = jnp.tile((jnp.arange(32) % 7).astype(jnp.float32)[:, None],
                       (1, 48))
    mv_k, _ = motion_sad(stripes, stripes, radius=radius, interpret=True)
    mv_o, _ = motion_sad_ref(stripes, stripes, radius)
    np.testing.assert_array_equal(np.asarray(mv_k), np.asarray(mv_o))


def test_motion_sad_recovers_known_shift():
    """pred(y) = ref(y + mv): for ref = roll(cur, s), interior MVs = s."""
    cur = jax.random.uniform(KEY, (64, 96), jnp.float32) * 255
    s = (3, -2)
    ref = jnp.roll(cur, s, (0, 1))
    mv, sad = motion_sad(cur, ref, radius=8, interpret=True)
    mv = np.asarray(mv)
    assert (mv[1:-1, 1:-1, 0] == s[0]).all()
    assert (mv[1:-1, 1:-1, 1] == s[1]).all()
    assert float(jnp.max(sad[1:-1, 1:-1])) < 1e-3


def test_block_sad_use_kernel_flag_dispatches():
    ks = jax.random.split(jax.random.PRNGKey(3), 2)
    cur = jax.random.uniform(ks[0], (48, 64), jnp.float32) * 255
    ref = jax.random.uniform(ks[1], (48, 64), jnp.float32) * 255
    mv_a, sad_a = block_sad(cur, ref, 4)
    mv_b, sad_b = block_sad(cur, ref, 4, use_kernel=True)
    np.testing.assert_array_equal(np.asarray(mv_a), np.asarray(mv_b))
    np.testing.assert_allclose(np.asarray(sad_a), np.asarray(sad_b),
                               rtol=1e-6)


@settings(deadline=None, max_examples=10)
@given(nby=st.integers(1, 4), nbx=st.integers(1, 5),
       radius=st.sampled_from([2, 4, 8]), seed=st.integers(0, 9999))
def test_motion_sad_property_random_shapes(nby, nbx, radius, seed):
    """Kernel-vs-oracle parity over random macroblock grids, search radii
    and contents: MVs bit-exact, SADs to fp tolerance.  Runs under the
    real hypothesis when installed, else the deterministic shim."""
    H, W = nby * 16, nbx * 16
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    cur = jax.random.uniform(k1, (H, W), jnp.float32) * 255
    ref = jnp.roll(cur, (seed % 3 - 1, -(seed % 5 - 2)), (0, 1)) \
        + jax.random.normal(k2, (H, W)) * 1.5
    mv_k, sad_k = motion_sad(cur, ref, radius=radius, interpret=True)
    mv_o, sad_o = motion_sad_ref(cur, ref, radius)
    np.testing.assert_array_equal(np.asarray(mv_k), np.asarray(mv_o))
    np.testing.assert_allclose(np.asarray(sad_k), np.asarray(sad_o),
                               rtol=1e-6, atol=1e-4)


@settings(deadline=None, max_examples=8)
@given(nby=st.integers(1, 3), nbx=st.integers(1, 4),
       radius=st.sampled_from([2, 4]), period=st.integers(1, 7),
       vertical=st.booleans())
def test_motion_sad_property_tie_breaking(nby, nbx, radius, period,
                                          vertical):
    """Periodic stripe patterns produce exact SAD ties along whole bands
    of candidate offsets; both paths must resolve them first-wins in
    dy-major order (period=1 is the all-ties constant frame)."""
    H, W = nby * 16, nbx * 16
    ramp = (jnp.arange(H if vertical else W) % period).astype(jnp.float32)
    frame = jnp.tile(ramp[:, None], (1, W)) if vertical \
        else jnp.tile(ramp[None, :], (H, 1))
    mv_k, sad_k = motion_sad(frame, frame, radius=radius, interpret=True)
    mv_o, sad_o = motion_sad_ref(frame, frame, radius)
    np.testing.assert_array_equal(np.asarray(mv_k), np.asarray(mv_o))
    np.testing.assert_allclose(np.asarray(sad_k), np.asarray(sad_o),
                               rtol=1e-6, atol=1e-4)


def test_motion_sad_batched_entry():
    ks = jax.random.split(jax.random.PRNGKey(4), 2)
    cur = jax.random.uniform(ks[0], (3, 32, 32), jnp.float32) * 255
    ref = jax.random.uniform(ks[1], (3, 32, 32), jnp.float32) * 255
    mv, sad = motion_sad(cur, ref, radius=4, interpret=True)
    assert mv.shape == (3, 2, 2, 2) and sad.shape == (3, 2, 2)


# ----------------------------------------------------- fused chunk pipeline
def _setup_chunk(seed=0, T=4):
    from repro.core.hybrid_encoder import encode_hybrid
    from repro.models import detection as D
    from repro.sim.video_source import StreamConfig, generate_chunk
    frames, gtb, gtv = generate_chunk(
        jax.random.PRNGKey(seed),
        StreamConfig(height=64, width=96, n_objects=3), 0, T)
    det_cfg = D.TinyDetectorConfig()
    params = D.init(jax.random.PRNGKey(1), det_cfg)
    packet = encode_hybrid(np.asarray(frames), 8000.0, 0.05, 0.1)
    return packet, params, det_cfg, gtb, gtv


def test_anchor_index_matches_python_loop():
    from repro.core.hybrid_decoder import anchor_index
    rng = np.random.default_rng(0)
    for _ in range(5):
        types = rng.choice([1, 2, 3], size=12)
        ref = np.zeros(12, np.int64)
        last = 0
        for i in range(12):
            if types[i] == 1:
                last = i
            ref[i] = last
        got = np.asarray(anchor_index(jnp.asarray(types)))
        np.testing.assert_array_equal(got, ref)


def test_decode_execute_chunk_matches_legacy():
    from repro.core.hybrid_decoder import (decode_and_execute,
                                           decode_and_execute_fused)
    packet, params, det_cfg, gtb, gtv = _setup_chunk()
    a = decode_and_execute(packet, params, det_cfg, gtb, gtv,
                           bw_kbps=8000.0, queue_delay=0.01)
    b = decode_and_execute_fused(packet, params, det_cfg, gtb, gtv,
                                 bw_kbps=8000.0, queue_delay=0.01)
    np.testing.assert_allclose(a.boxes, b.boxes, atol=1e-2)
    np.testing.assert_allclose(a.scores, b.scores, atol=1e-4)
    np.testing.assert_allclose(a.f1, b.f1, atol=1e-5)
    assert a.latency == pytest.approx(b.latency, rel=1e-5)
    assert a.t_comp == pytest.approx(b.t_comp, rel=1e-5)


def test_decode_execute_chunk_is_one_jit_boundary():
    from repro.core import hybrid_decoder as HD
    # the public callable IS the jit wrapper (lower/trace API present) …
    assert hasattr(HD.decode_execute_chunk, "lower")
    # … and its traced body never leaves jax: no np.asarray / Python
    # per-frame loops inside (they would fail under tracing)
    packet, params, det_cfg, gtb, gtv = _setup_chunk()
    out = HD.decode_execute_chunk(
        packet.video, jnp.asarray(packet.types),
        jnp.asarray(packet.anchor_hd), jnp.asarray(gtb), jnp.asarray(gtv),
        params, det_cfg, bw_kbps=8000.0, total_bits=packet.total_bits)
    assert all(isinstance(v, jax.Array) for v in out.values())


def test_decode_execute_batched_matches_per_stream():
    from repro.core.hybrid_decoder import (decode_execute_batched,
                                           decode_execute_chunk)
    p0, params, det_cfg, gtb0, gtv0 = _setup_chunk(seed=0)
    p1, _, _, gtb1, gtv1 = _setup_chunk(seed=5)
    stack = lambda a, b: jnp.stack([jnp.asarray(a), jnp.asarray(b)])
    enc = jax.tree.map(lambda *xs: jnp.stack(xs), p0.video, p1.video)
    out = decode_execute_batched(
        enc, stack(p0.types, p1.types), stack(p0.anchor_hd, p1.anchor_hd),
        stack(gtb0, gtb1), stack(gtv0, gtv1), params, det_cfg,
        bw_kbps=jnp.asarray([8000.0, 8000.0]),
        queue_delay=jnp.zeros(2),
        total_bits=jnp.asarray([p0.total_bits, p1.total_bits]))
    for i, (p, gb, gv) in enumerate([(p0, gtb0, gtv0), (p1, gtb1, gtv1)]):
        one = decode_execute_chunk(
            p.video, jnp.asarray(p.types), jnp.asarray(p.anchor_hd),
            jnp.asarray(gb), jnp.asarray(gv), params, det_cfg,
            bw_kbps=8000.0, total_bits=p.total_bits)
        np.testing.assert_allclose(np.asarray(out["boxes"][i]),
                                   np.asarray(one["boxes"]), atol=1e-3)
        np.testing.assert_allclose(np.asarray(out["f1"][i]),
                                   np.asarray(one["f1"]), atol=1e-5)


def test_video_codec_config_stays_hashable():
    """encode_chunk is jitted with the config as a static argument at its
    production call site (hybrid_encoder) and in benchmarks; an unhashable
    config would fail there with an opaque jit TypeError."""
    from repro.codec.video_codec import VideoCodecConfig
    hash(VideoCodecConfig())


# ------------------------------------------------------------ reuse carry
def test_reuse_chunk_init_carry():
    from repro.core.reuse import reuse_chunk, shift_boxes
    T, N = 3, 2
    types = jnp.full((T,), 3, jnp.int32)
    mvs = jnp.zeros((T, 4, 6, 2), jnp.int32).at[..., 0].set(2)
    infer_b = jnp.zeros((T, N, 4), jnp.float32)
    infer_s = jnp.zeros((T, N), jnp.float32)
    init_b = jnp.asarray([[32.0, 48.0, 16.0, 16.0]] * N)
    init_s = jnp.asarray([0.9] * N)
    boxes, scores = reuse_chunk(types, mvs, infer_b, infer_s,
                                init_boxes=init_b, init_scores=init_s)
    exp0, _ = shift_boxes(init_b, init_s, mvs[0])
    np.testing.assert_allclose(np.asarray(boxes[0]), np.asarray(exp0),
                               atol=1e-5)
    # codec mv dy=+2 => object shifts -2 per frame
    np.testing.assert_allclose(np.asarray(boxes[:, 0, 0]),
                               [30.0, 28.0, 26.0], atol=1e-4)
    assert float(scores[0, 0]) == pytest.approx(0.9)
    # default (no carry) preserves the legacy within-chunk behavior:
    # the carry seeds from infer_boxes[0], shifted by mvs[0]
    b2, _ = reuse_chunk(types, mvs, infer_b, infer_s)
    legacy0, _ = shift_boxes(infer_b[0], infer_s[0], mvs[0])
    np.testing.assert_allclose(np.asarray(b2[0]), np.asarray(legacy0),
                               atol=1e-5)


# -------------------------------------------------------------- runtime
def test_runtime_one_padded_dispatch_per_chunk():
    from repro.serving.runtime import EdgeRuntime
    from repro.serving.scheduler import ServingConfig
    packet, params, det_cfg, _, _ = _setup_chunk()
    cfg = ServingConfig(n_streams=1, batch_size=8)
    rt = EdgeRuntime(cfg, params, det_cfg)
    calls = []
    inner = rt._infer
    rt._infer = lambda frames: (calls.append(frames.shape), inner(frames))[1]
    rt.process_chunk(0, 0, packet)
    n_infer = int((packet.types != 3).sum())
    if n_infer:
        assert len(calls) == 1                    # one dispatch per chunk
        assert calls[0][0] % cfg.batch_size == 0  # padded, fixed shape set


def test_runtime_deep_overload_falls_back_to_full_reuse():
    """When even anchors-only would blow the latency budget and a carry
    exists, the whole chunk runs on pipeline ③ through the REAL admission
    path (no hand-built packet)."""
    from repro.serving.runtime import EdgeRuntime
    from repro.serving.scheduler import ServingConfig
    packet, params, det_cfg, _, _ = _setup_chunk()
    cfg = ServingConfig(n_streams=1, gpu_capacity_fps=0.5,
                        latency_budget=1.0)   # admits nothing
    rt = EdgeRuntime(cfg, params, det_cfg)
    # chunk 0: no carry yet -> anchors are kept even under overload
    _, _, t0 = rt.process_chunk(0, 0, packet)
    assert (t0 == np.where(packet.types == 2, 3, packet.types)).all()
    assert (t0 == 1).sum() >= 1
    # chunk 1: carry exists -> full fallback to reuse, zero dispatches
    calls = []
    inner = rt._infer
    rt._infer = lambda f: (calls.append(1), inner(f))[1]
    _, _, t1 = rt.process_chunk(0, 1, packet)
    assert (t1 == 3).all()
    assert calls == []
    assert rt.deferred == 2


def test_runtime_carries_boxes_across_chunks():
    from repro.core.reuse import shift_boxes
    from repro.core.hybrid_decoder import _upscale_mvs
    from repro.serving.runtime import EdgeRuntime
    from repro.serving.scheduler import ServingConfig
    packet, params, det_cfg, _, _ = _setup_chunk()
    rt = EdgeRuntime(ServingConfig(n_streams=1), params, det_cfg)
    rt.process_chunk(0, 0, packet)
    prev = rt.streams[0]
    assert prev.last_boxes is not None
    # second chunk forced to all-reuse: no inference happens, so frame 0
    # must be the previous chunk's last boxes shifted by mv[0]
    p2 = dataclasses.replace(packet, types=np.full_like(packet.types, 3))
    H, W = packet.anchor_hd.shape[1:]
    mvs_hd = np.asarray(_upscale_mvs(packet.video.mv, (H, W)))
    exp0, _ = shift_boxes(jnp.asarray(prev.last_boxes),
                          jnp.asarray(prev.last_scores),
                          jnp.asarray(mvs_hd[0]))
    boxes, scores, types = rt.process_chunk(0, 1, p2)
    assert (types == 3).all()
    np.testing.assert_allclose(boxes[0], np.asarray(exp0), atol=1e-4)
    # stream state advanced to the new chunk's last frame
    np.testing.assert_allclose(rt.streams[0].last_boxes, boxes[-1],
                               atol=1e-6)
