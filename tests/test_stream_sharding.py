"""Multi-device parity harness for the mesh-sharded stream runtime.

The interesting tests need a REAL multi-device platform, but XLA only
honours ``--xla_force_host_platform_device_count`` before the first jax
import — so the driver test re-runs this file in a subprocess with 4 fake
CPU devices (``conftest.forced_multidevice_run``).  Inside that child the
``_FORCED``-guarded tests activate and assert the sharded
``decode_execute_batched`` path is BIT-EXACT against the single-device
vmap oracle for divisible (1, 4, 8) and non-divisible (3) stream counts.

Everything else (rule tables, padding semantics, per-shard admission)
runs on the ordinary 1-device platform in-process.
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import conftest
from repro.distributed.sharding import (MULTI_POD_RULES, SINGLE_POD_RULES,
                                        SINGLE_POD_RULES_DP)
from repro.distributed.stream_sharding import (pad_stream_axis,
                                               shard_streams,
                                               stream_axis_names,
                                               stream_partition_spec,
                                               stream_shard_count)

_FORCED = int(os.environ.get(conftest.FORCED_MULTIDEVICE_ENV, "0"))

forced_only = pytest.mark.skipif(
    _FORCED < 4, reason="needs the forced multi-device child process")


# ---------------------------------------------------------------- fixtures
def _setup_streams(n, T=4, H=32, W=48):
    """n independent encoded chunks stacked along the stream axis."""
    from repro.core.hybrid_encoder import encode_hybrid
    from repro.models import detection as D
    from repro.sim.video_source import StreamConfig, generate_chunk

    packs = []
    for s in range(n):
        frames, gtb, gtv = generate_chunk(
            jax.random.PRNGKey(s),
            StreamConfig(height=H, width=W, n_objects=2), 0, T)
        packet = encode_hybrid(np.asarray(frames), 8000.0, 0.05, 0.1)
        packs.append((packet, gtb, gtv))
    enc = jax.tree.map(lambda *xs: jnp.stack(xs),
                       *[p.video for p, _, _ in packs])
    args = dict(
        enc=enc,
        types=jnp.stack([jnp.asarray(p.types) for p, _, _ in packs]),
        anchor_hd=jnp.stack([jnp.asarray(p.anchor_hd) for p, _, _ in packs]),
        gt_boxes=jnp.stack([jnp.asarray(g) for _, g, _ in packs]),
        gt_valid=jnp.stack([jnp.asarray(v) for _, _, v in packs]),
        bw_kbps=jnp.full((n,), 8000.0, jnp.float32),
        queue_delay=jnp.zeros((n,), jnp.float32),
        total_bits=jnp.asarray([p.total_bits for p, _, _ in packs],
                               jnp.float32),
    )
    det_cfg = D.TinyDetectorConfig()
    params = D.init(jax.random.PRNGKey(1), det_cfg)
    return args, params, det_cfg


def _run_oracle_and_sharded(S, mesh, rules):
    from repro.core.hybrid_decoder import decode_execute_batched

    args, params, det_cfg = _setup_streams(S)
    oracle = decode_execute_batched(
        args["enc"], args["types"], args["anchor_hd"], args["gt_boxes"],
        args["gt_valid"], params, det_cfg, bw_kbps=args["bw_kbps"],
        queue_delay=args["queue_delay"], total_bits=args["total_bits"])
    run = shard_streams(mesh, rules, det_cfg=det_cfg)
    sharded = run(args["enc"], args["types"], args["anchor_hd"],
                  args["gt_boxes"], args["gt_valid"], params,
                  bw_kbps=args["bw_kbps"], queue_delay=args["queue_delay"],
                  total_bits=args["total_bits"])
    return oracle, sharded


def _assert_bit_exact(oracle, sharded):
    assert set(oracle) == set(sharded)
    for k in oracle:
        np.testing.assert_array_equal(
            np.asarray(oracle[k]), np.asarray(sharded[k]),
            err_msg=f"output {k!r} diverged from the vmap oracle")


# ------------------------------------------------------- rules and padding
def test_stream_axis_in_rule_tables():
    assert SINGLE_POD_RULES.mesh_axes("stream") == ("data",)
    assert MULTI_POD_RULES.mesh_axes("stream") == ("pod", "data")
    assert SINGLE_POD_RULES_DP.mesh_axes("stream") == ("data", "model")


def test_stream_axis_names_drop_missing_mesh_axes():
    mesh = jax.make_mesh((1,), ("data",))
    # MULTI_POD names (pod, data) but this mesh has no pod axis
    assert stream_axis_names(mesh, MULTI_POD_RULES) == ("data",)
    assert stream_shard_count(mesh, MULTI_POD_RULES) == 1
    assert stream_partition_spec(mesh, MULTI_POD_RULES) == \
        jax.sharding.PartitionSpec("data")


def test_pad_stream_axis_rounds_up_and_zero_fills():
    tree = {"a": jnp.arange(3, dtype=jnp.float32),
            "b": jnp.ones((3, 2, 2))}
    out = pad_stream_axis(tree, 4)
    assert out["a"].shape == (4,) and out["b"].shape == (4, 2, 2)
    np.testing.assert_array_equal(np.asarray(out["a"]), [0, 1, 2, 0])
    assert float(jnp.abs(out["b"][3]).sum()) == 0.0
    # divisible stream counts pass through untouched
    same = pad_stream_axis(tree, 3)
    assert same["a"].shape == (3,)
    np.testing.assert_array_equal(np.asarray(same["b"]),
                                  np.asarray(tree["b"]))
    assert pad_stream_axis({"a": jnp.zeros((5,))}, 1)["a"].shape == (5,)


def test_shard_streams_single_device_matches_oracle():
    """The wrapper degrades to the oracle on a 1-extent mesh (the CI
    platform) — parity there guards the padding/unpadding plumbing."""
    mesh = jax.make_mesh((len(jax.devices()),), ("data",))
    oracle, sharded = _run_oracle_and_sharded(2, mesh, SINGLE_POD_RULES)
    _assert_bit_exact(oracle, sharded)


# ----------------------------------------------- per-shard admission (CPU)
def test_runtime_defers_on_per_shard_not_global_depth():
    """Two shards, shard 0 saturated: the stream on shard 0 defers while
    the stream on shard 1 — same global backlog — still admits."""
    from repro.serving.scheduler import (AdmissionController, InferRequest,
                                         PipelineQueues, ServingConfig)
    cfg = ServingConfig(n_streams=2, n_shards=2, gpu_capacity_fps=40.0,
                        latency_budget=1.0)
    adm = AdmissionController(cfg)
    q = PipelineQueues(cfg, lambda f: [])
    frame = np.zeros((8, 8), np.float32)
    for i in range(18):                       # saturate shard 0 only
        q.submit(InferRequest(0, 0, i, 1, frame, shard=0))
    depths = q.shard_depths
    assert depths.shape == (2, 2)
    assert depths[0, 0] == 18 and depths[1].sum() == 0
    # per-shard capacity is 20 fps -> 18 + 4 new frames blows the 1 s
    # budget on shard 0 but not on the idle shard 1
    assert not adm.admit_shard(depths, 0, 4)
    assert adm.admit_shard(depths, 1, 4)
    # the legacy GLOBAL controller would have admitted the hot shard's
    # stream (18 + 4 over 40 fps = 0.55 s) — the regression this guards
    assert adm.admit(q.depths, 4)


def test_edge_runtime_hot_shard_defers_stream_to_reuse():
    from repro.core.hybrid_encoder import encode_hybrid
    from repro.models import detection as D
    from repro.serving.runtime import EdgeRuntime
    from repro.serving.scheduler import InferRequest, ServingConfig
    from repro.sim.video_source import StreamConfig, generate_chunk

    frames, _, _ = generate_chunk(
        jax.random.PRNGKey(0), StreamConfig(height=32, width=48), 0, 4)
    packet = encode_hybrid(np.asarray(frames), 8000.0, 0.05, 0.1)
    det_cfg = D.TinyDetectorConfig()
    params = D.init(jax.random.PRNGKey(1), det_cfg)
    cfg = ServingConfig(n_streams=2, n_shards=2, gpu_capacity_fps=16.0,
                        latency_budget=1.0)
    rt = EdgeRuntime(cfg, params, det_cfg)
    assert rt.stream_shard(0) == 0 and rt.stream_shard(1) == 1
    # saturate shard 0's queue behind stream 0
    frame = np.zeros((32, 48), np.float32)
    for i in range(12):
        rt.queues.submit(InferRequest(9, 9, i, 1, frame, shard=0))
    _, _, t0 = rt.process_chunk(0, 0, packet)     # hot shard -> deferred
    _, _, t1 = rt.process_chunk(1, 0, packet)     # idle shard -> admitted
    assert rt.deferred_by_shard[0] == 1 and rt.deferred_by_shard[1] == 0
    assert (t0 == np.where(packet.types == 2, 3, packet.types)).all()
    assert (t1 == packet.types).all()


# --------------------------------------------------- forced 4-device child
def test_spawns_multidevice_child_suite():
    """Driver: re-run ONLY this file's ``forced``-named tests under 4
    forced CPU devices; any parity break fails here with the child's
    output attached.  (``make test-multidevice`` instead runs the whole
    suite on the forced platform in-process, and this driver skips.)"""
    if _FORCED:
        pytest.skip("already inside the forced multi-device child")
    r = conftest.forced_multidevice_run(
        "tests/test_stream_sharding.py", extra_args=["-k", "forced"])
    assert r.returncode == 0, (
        f"forced multi-device child failed\n--- stdout ---\n{r.stdout}"
        f"\n--- stderr ---\n{r.stderr}")
    # the child must have RUN the forced tests, not skipped them
    assert "passed" in r.stdout


@forced_only
def test_forced_child_platform_has_devices():
    assert jax.default_backend() == "cpu"
    assert len(jax.devices()) >= 4


@forced_only
@pytest.mark.parametrize("S", [1, 3, 4, 8])
def test_forced_bit_exact_vs_vmap_oracle(S):
    """Data-parallel stream execution over a real 4-device mesh equals the
    single-device vmap bit-for-bit — including S=1 and S=3, which pad the
    stream axis up to the mesh extent and drop the zero lanes on exit."""
    mesh = jax.make_mesh((4,), ("data",))
    assert stream_shard_count(mesh, SINGLE_POD_RULES) == 4
    oracle, sharded = _run_oracle_and_sharded(S, mesh, SINGLE_POD_RULES)
    assert np.asarray(sharded["f1"]).shape[0] == S
    _assert_bit_exact(oracle, sharded)


@forced_only
def test_forced_streams_spread_over_mesh():
    """The padded stream batch really lands one shard per device (no
    silent replication): each device holds exactly S/4 streams."""
    from repro.distributed.stream_sharding import stream_sharding
    args, params, det_cfg = _setup_streams(8)
    mesh = jax.make_mesh((4,), ("data",))
    sharding = stream_sharding(mesh, SINGLE_POD_RULES)
    types = jax.device_put(args["types"], sharding)
    assert len(types.addressable_shards) == 4
    for shard in types.addressable_shards:
        assert shard.data.shape[0] == 2


@forced_only
def test_forced_two_dimensional_mesh_parity():
    """Streams shard over ("data", "model") with the DP rule table — the
    layout the replicated tiny detector serves on vision meshes."""
    mesh = jax.make_mesh((2, 2), ("data", "model"))
    assert stream_shard_count(mesh, SINGLE_POD_RULES_DP) == 4
    oracle, sharded = _run_oracle_and_sharded(6, mesh, SINGLE_POD_RULES_DP)
    _assert_bit_exact(oracle, sharded)


@forced_only
def test_forced_edge_runtime_places_shard_detectors_on_devices():
    """Sharded EdgeRuntime commits shard i's detector to mesh device i —
    per-shard capacity corresponds to real hardware, not bookkeeping."""
    from repro.models import detection as D
    from repro.serving.runtime import EdgeRuntime
    from repro.serving.scheduler import ServingConfig

    det_cfg = D.TinyDetectorConfig()
    params = D.init(jax.random.PRNGKey(1), det_cfg)
    mesh = jax.make_mesh((4,), ("data",))
    with pytest.raises(ValueError):
        EdgeRuntime(ServingConfig(n_streams=4), params, det_cfg, mesh=mesh)
    rt = EdgeRuntime(ServingConfig(n_streams=4), params, det_cfg,
                     mesh=mesh, rules=SINGLE_POD_RULES)
    assert rt.n_shards == 4 and len(rt._shard_infer) == 4
    frames = np.zeros((2, 32, 48), np.float32)
    devices = set()
    for shard in range(4):
        boxes, _ = zip(*rt._infer_batch(frames, shard=shard))
        devices.add(rt._shard_infer[shard](jnp.asarray(frames))[0].device)
    assert len(devices) == 4                  # one detector per device
