"""Codec substrate: DCT round trips, rate-quality monotonicity, motion
estimation correctness (property-based where natural)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.codec import blockdct as B
from repro.codec.image_codec import jpeg_encode_decode, psnr
from repro.codec.motion import block_sad, warp_blocks
from repro.codec.rate_model import (QUALITY_LADDER, downscale,
                                    ladder_for_bandwidth, upscale_nearest)
from repro.codec.video_codec import VideoCodecConfig, encode_chunk, \
    chunk_psnr
from repro.sim.video_source import StreamConfig, generate_chunk

KEY = jax.random.PRNGKey(0)


def test_dct_orthonormal_roundtrip():
    blocks = jax.random.uniform(KEY, (16, 8, 8), jnp.float32) * 255 - 128
    rec = B.idct2(B.dct2(blocks))
    np.testing.assert_allclose(np.asarray(rec), np.asarray(blocks),
                               atol=1e-3)


def test_blockify_roundtrip():
    img = jax.random.uniform(KEY, (32, 48), jnp.float32)
    back = B.unblockify(B.blockify(img), 32, 48)
    np.testing.assert_allclose(np.asarray(back), np.asarray(img))


@pytest.mark.parametrize("q1,q2", [(20.0, 50.0), (50.0, 85.0)])
def test_jpeg_quality_monotone(q1, q2):
    img = np.asarray(generate_chunk(KEY, StreamConfig(height=64, width=96),
                                    0, 1)[0][0])
    r1, b1 = jpeg_encode_decode(jnp.asarray(img), q1)
    r2, b2 = jpeg_encode_decode(jnp.asarray(img), q2)
    assert float(b1) < float(b2)                       # more bits
    assert float(psnr(img, r1)) < float(psnr(img, r2)) # better quality


@settings(deadline=None, max_examples=8)
@given(dy=st.integers(-6, 6), dx=st.integers(-6, 6))
def test_motion_estimation_recovers_global_shift(dy, dx):
    """A globally shifted frame must be recovered by full-search ME."""
    frames, _, _ = generate_chunk(KEY, StreamConfig(height=64, width=96,
                                                    n_objects=5), 0, 1)
    ref = np.asarray(frames[0])
    cur = np.roll(np.roll(ref, dy, axis=0), dx, axis=1)
    mv, sad = block_sad(jnp.asarray(cur), jnp.asarray(ref), radius=8)
    mv = np.asarray(mv)
    # interior blocks (away from the wrap-around border) match exactly;
    # ME returns the *gather* offset: pred(y) = ref(y + mv) -> mv = -shift
    inner = mv[1:-1, 1:-1]
    assert (inner[..., 0] == -dy).all()
    assert (inner[..., 1] == -dx).all()


def test_warp_blocks_identity():
    frames, _, _ = generate_chunk(KEY, StreamConfig(height=48, width=64),
                                  0, 1)
    f = frames[0]
    mv = jnp.zeros((3, 4, 2), jnp.int32)
    np.testing.assert_allclose(np.asarray(warp_blocks(f, mv)),
                               np.asarray(f), atol=1e-4)


def test_video_codec_quality_and_bits_monotone():
    frames, _, _ = generate_chunk(KEY, StreamConfig(height=64, width=96),
                                  0, 3)
    lo = encode_chunk(frames, VideoCodecConfig(quality=25.0))
    hi = encode_chunk(frames, VideoCodecConfig(quality=75.0))
    assert float(lo.bits.sum()) < float(hi.bits.sum())
    assert float(chunk_psnr(frames, lo.recon).mean()) < \
        float(chunk_psnr(frames, hi.recon).mean())
    assert float(chunk_psnr(frames, hi.recon).min()) > 28.0


def test_qtab_computed_once_and_threaded(monkeypatch):
    """The encoder builds the quant table ONCE per chunk from cfg.quality
    and threads it through the I-frame and every P-frame — the legacy path
    rebuilt (and discarded) it per frame inside B.quantize."""
    import repro.codec.video_codec as VC
    calls = []
    orig = B.quant_table
    monkeypatch.setattr(B, "quant_table",
                        lambda q: (calls.append(1), orig(q))[1])
    cfg = VideoCodecConfig(quality=42.0)
    jax.eval_shape(lambda f: VC._encode_chunk(f, cfg),
                   jax.ShapeDtypeStruct((3, 32, 48), jnp.float32))
    assert len(calls) == 1, \
        f"quant_table built {len(calls)}x during one chunk trace"
    monkeypatch.undo()
    # the threaded table is the cfg-quality table (I-frame included)
    frames, _, _ = generate_chunk(KEY, StreamConfig(height=32, width=48),
                                  0, 2)
    enc = encode_chunk(frames, cfg)
    np.testing.assert_array_equal(np.asarray(enc.qtab),
                                  np.asarray(B.quant_table(42.0)))


def test_ladder_selection():
    assert ladder_for_bandwidth(400.0) == 0
    assert ladder_for_bandwidth(1200.0) >= 1
    assert ladder_for_bandwidth(20000.0) == len(QUALITY_LADDER) - 1
    # monotone in bandwidth
    lv = [ladder_for_bandwidth(b) for b in (300, 600, 1200, 2500, 9000)]
    assert lv == sorted(lv)


def test_down_up_scale_shapes():
    frames = jax.random.uniform(KEY, (2, 96, 160), jnp.float32)
    for ql in QUALITY_LADDER:
        small = downscale(frames, ql.scale)
        assert small.shape[1] % 16 == 0 and small.shape[2] % 16 == 0
        up = upscale_nearest(small, 96, 160)
        assert up.shape == (2, 96, 160)
