"""Property tests for ``repro.core.fairness`` (paper Eq. 1/6, Fig. 12).

Runs under real ``hypothesis`` when installed, else the deterministic
shim (src/_hypothesis_shim.py registered by conftest) — same test code
either way.  These are the invariants the bi-level controller's reward
head relies on; the module previously had only two spot checks in
test_biswift_core.py.
"""
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.fairness import (accuracy_spread, fairness_head,
                                 jain_index, min_reward_fairness)

finite_floats = st.floats(min_value=0.01, max_value=1.0)


@settings(deadline=None, max_examples=25)
@given(vals=st.lists(finite_floats, min_size=1, max_size=16))
def test_jain_index_bounds(vals):
    """1/n (one stream hogs everything) <= J <= 1 (perfect equality)."""
    n = len(vals)
    j = float(jain_index(jnp.asarray(vals, jnp.float32)))
    assert 1.0 / n - 1e-5 <= j <= 1.0 + 1e-5, (vals, j)


@settings(deadline=None, max_examples=25)
@given(vals=st.lists(finite_floats, min_size=1, max_size=12),
       scale=st.floats(min_value=0.1, max_value=100.0))
def test_jain_index_scale_invariant(vals, scale):
    """Jain's index depends only on the SHAPE of the allocation: J(c*v)
    == J(v) (f32 tolerance — the reductions see rescaled values)."""
    v = jnp.asarray(vals, jnp.float32)
    a, b = float(jain_index(v)), float(jain_index(scale * v))
    assert abs(a - b) < 1e-4, (vals, scale, a, b)


def test_jain_index_extremes():
    assert float(jain_index(jnp.ones(9))) == 1.0
    one_hot = jnp.zeros(8).at[3].set(5.0)
    np.testing.assert_allclose(float(jain_index(one_hot)), 1.0 / 8,
                               rtol=1e-5)


@settings(deadline=None, max_examples=25)
@given(vals=st.lists(st.floats(min_value=-1.0, max_value=1.0),
                     min_size=1, max_size=16))
def test_min_reward_fairness_is_true_min_under_permutation(vals):
    """Eq. 6's reduction is exactly the minimum, invariant to stream
    order (bit-exact: min is order-free in fp)."""
    v = np.asarray(vals, np.float32)
    want = v.min()
    assert float(min_reward_fairness(jnp.asarray(v))) == want
    rng = np.random.default_rng(0)
    for _ in range(3):
        perm = rng.permutation(len(v))
        assert float(min_reward_fairness(jnp.asarray(v[perm]))) == want


@settings(deadline=None, max_examples=25)
@given(vals=st.lists(finite_floats, min_size=1, max_size=16))
def test_accuracy_spread_nonnegative(vals):
    """p75 - p50 of the sorted accuracies can never be negative."""
    assert float(accuracy_spread(jnp.asarray(vals, jnp.float32))) >= 0.0


@settings(deadline=None, max_examples=10)
@given(val=finite_floats, n=st.integers(min_value=1, max_value=12))
def test_accuracy_spread_zero_for_constant(val, n):
    v = jnp.full((n,), val, jnp.float32)
    assert float(accuracy_spread(v)) == 0.0


def test_fairness_head_matches_components():
    """The fused-step reduction head is exactly its three components."""
    rewards = jnp.asarray([0.3, -0.1, 0.5], jnp.float32)
    accs = jnp.asarray([0.9, 0.6, 0.8], jnp.float32)
    out = fairness_head(rewards, accs)
    assert float(out["r_high"]) == float(min_reward_fairness(rewards))
    assert float(out["jain"]) == float(jain_index(accs))
    assert float(out["spread"]) == float(accuracy_spread(accs))
