"""ROI-gated inference (ISSUE 9): selection determinism, gather-kernel
parity, the admit-all bit-exactness contract against the full-frame
detector (standalone, fused round trip, and the serving plane), and the
temporal-carry semantics of the region scatter."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.roi import (RoiConfig, region_grid, region_scores,
                            required_halo, roi_raw_maps, roi_select,
                            validate_roi)
from repro.kernels.roi_gather.ops import roi_gather, roi_gather_ref
from repro.models import detection as D

KEY = jax.random.PRNGKey(0)
DET = D.TinyDetectorConfig()


def _params(seed=1):
    return D.init(jax.random.PRNGKey(seed), DET)


def _frames(T=3, H=64, W=96, seed=2):
    return jax.random.uniform(jax.random.PRNGKey(seed), (T, H, W),
                              jnp.float32) * 255


# ----------------------------------------------------------- roi_select
def test_roi_select_threshold_and_tiebreak():
    """Ties break toward the LOWER flat region index (lax.top_k stable
    order); sub-threshold regions never occupy a lane."""
    scores = jnp.asarray([[5.0, 1.0, 5.0, 0.0, 5.0, 5.0]])
    idx, valid = roi_select(scores, capacity=3, threshold=2.0)
    np.testing.assert_array_equal(np.asarray(idx), [[0, 2, 4]])
    assert np.asarray(valid).all()


def test_roi_select_zero_admitted_regions():
    """A threshold above every score leaves all lanes invalid with the
    safe index 0 — downstream the scatter drops them all."""
    scores = jnp.asarray([[0.3, 0.1, 0.2, 0.0]])
    idx, valid = roi_select(scores, capacity=2, threshold=10.0)
    assert not np.asarray(valid).any()
    np.testing.assert_array_equal(np.asarray(idx), 0)


def test_roi_select_capacity_exceeds_regions():
    """capacity > R pads with invalid lanes rather than repeating
    regions."""
    scores = jnp.asarray([[2.0, 3.0, 1.0]])
    idx, valid = roi_select(scores, capacity=5, threshold=-1.0)
    np.testing.assert_array_equal(np.asarray(valid),
                                  [[True, True, True, False, False]])
    np.testing.assert_array_equal(np.asarray(idx)[0, :3], [1, 0, 2])


# ------------------------------------------------------- static validation
def test_required_halo_default_detector():
    # 3 layers, all downsampling at stride 8: rf = 1 + 2 + 4
    assert required_halo(DET) == 7


@pytest.mark.parametrize("roi,hd_hw", [
    (RoiConfig(region_px=24), (64, 96)),          # 24 does not divide 64
    (RoiConfig(region_px=32), (64, 100)),         # W not divisible
    (RoiConfig(halo=0), (64, 96)),                # halo < rf (7)
    (RoiConfig(halo=12), (64, 96)),               # halo % stride != 0
    (RoiConfig(capacity=0), (64, 96)),            # capacity < 1
])
def test_validate_roi_rejects_bad_bindings(roi, hd_hw):
    with pytest.raises(ValueError):
        validate_roi(roi, DET, hd_hw)


def test_validate_roi_accepts_default_binding():
    validate_roi(RoiConfig(), DET, (64, 96))
    assert region_grid((64, 96), RoiConfig()) == (2, 3)


# ------------------------------------------------------- gather kernel
@pytest.mark.parametrize("T,K,region_px,halo", [
    (2, 3, 32, 8), (1, 6, 32, 8), (3, 2, 16, 8)])
def test_roi_gather_kernel_matches_ref(T, K, region_px, halo):
    H, W = 64, 96
    nry, nrx = H // region_px, W // region_px
    ks = jax.random.split(KEY, 3)
    planes = jax.random.uniform(
        ks[0], (T, H + 2 * halo, W + 2 * halo), jnp.float32)
    ry = jax.random.randint(ks[1], (T, K), 0, nry)
    rx = jax.random.randint(ks[2], (T, K), 0, nrx)
    ref = roi_gather_ref(planes, ry, rx, region_px=region_px, halo=halo)
    ker = roi_gather(planes, ry, rx, region_px=region_px, halo=halo,
                     interpret=True)
    np.testing.assert_array_equal(np.asarray(ker), np.asarray(ref))


# ------------------------------------------- admit-all bit-exactness
@pytest.mark.parametrize("use_kernel", [False, True])
def test_admit_all_raw_maps_bitexact_vs_fullframe(use_kernel):
    """The core contract: every region selected -> assembled raw maps
    equal detection.forward bit-for-bit (boundary masking + pre-pad
    normalization make the patch forward exact, nonzero biases and
    all)."""
    frames = _frames()
    params = _params()
    roi = RoiConfig(capacity=6, threshold=-1.0, use_kernel=use_kernel)
    T = frames.shape[0]
    idx = jnp.tile(jnp.arange(6, dtype=jnp.int32)[None], (T, 1))
    valid = jnp.ones((T, 6), bool)
    maps = roi_raw_maps(params, DET, roi, frames, idx, valid, carry=True)
    full = D.forward(params, DET, frames)
    np.testing.assert_array_equal(np.asarray(maps), np.asarray(full))


@pytest.mark.parametrize("use_kernel", [False, True])
def test_roundtrip_admit_all_bitexact_vs_ungated(use_kernel):
    """Fused round trip with an admit-all gate reproduces the ungated
    round trip exactly (boxes, scores, f1)."""
    from repro.core.roundtrip import RoundtripConfig, roundtrip_chunk
    from repro.sim.video_source import StreamConfig, generate_chunk

    frames, gt_b, gt_v = generate_chunk(
        KEY, StreamConfig(height=64, width=96, n_objects=3), 0, 3)
    params = _params()
    cfg0 = RoundtripConfig(level=3)
    roi = RoiConfig(capacity=6, threshold=-1.0, use_kernel=use_kernel)
    kw = dict(tr1=0.1, tr2=0.3, bw_kbps=2000.0, cfg=cfg0)
    o0 = roundtrip_chunk(frames, gt_b, gt_v, params, **kw)
    kw["cfg"] = dataclasses.replace(cfg0, roi=roi)
    o1 = roundtrip_chunk(frames, gt_b, gt_v, params, **kw)
    for k in ("boxes", "scores", "f1"):
        np.testing.assert_array_equal(np.asarray(o0[k]),
                                      np.asarray(o1[k]), err_msg=k)


def test_roundtrip_batched_admit_all_bitexact():
    from repro.core.roundtrip import RoundtripConfig, roundtrip_batched
    from repro.sim.video_source import StreamConfig, generate_chunk

    ks = jax.random.split(KEY, 2)
    chunks = [generate_chunk(k, StreamConfig(height=64, width=96,
                                             n_objects=2, seed=10 + i),
                             0, 3)
              for i, k in enumerate(ks)]
    raw = jnp.stack([c[0] for c in chunks])
    gt_b = jnp.stack([c[1] for c in chunks])
    gt_v = jnp.stack([c[2] for c in chunks])
    params = _params()
    S = raw.shape[0]
    sc = jnp.full((S,), 0.1), jnp.full((S,), 0.3), jnp.full((S,), 2000.0)
    cfg0 = RoundtripConfig(level=3)
    roi = RoiConfig(capacity=6, threshold=-1.0)
    o0 = roundtrip_batched(raw, gt_b, gt_v, params, tr1=sc[0], tr2=sc[1],
                           bw_kbps=sc[2], queue_delay=jnp.zeros(S),
                           cfg=cfg0)
    o1 = roundtrip_batched(raw, gt_b, gt_v, params, tr1=sc[0], tr2=sc[1],
                           bw_kbps=sc[2], queue_delay=jnp.zeros(S),
                           cfg=dataclasses.replace(cfg0, roi=roi))
    for k in ("boxes", "scores", "f1"):
        np.testing.assert_array_equal(np.asarray(o0[k]),
                                      np.asarray(o1[k]), err_msg=k)


# ----------------------------------------------------- carry semantics
def test_carry_holds_last_computed_region():
    """carry=True: a region the gate skips at frame t keeps its frame
    t-1 raw output (region-granular pipeline-③ reuse); carry=False
    scatters into fresh zeros every row."""
    frames = _frames(T=2)
    params = _params()
    roi = RoiConfig(capacity=1, threshold=-1.0)
    idx = jnp.asarray([[0], [0]], jnp.int32)
    valid = jnp.asarray([[True], [False]])
    maps_c = roi_raw_maps(params, DET, roi, frames, idx, valid,
                          carry=True)
    maps_f = roi_raw_maps(params, DET, roi, frames, idx, valid,
                          carry=False)
    rc = roi.region_px // DET.stride
    # frame 1 (gate skipped region 0): carry retains frame 0's raw there
    np.testing.assert_array_equal(np.asarray(maps_c[1, :rc, :rc]),
                                  np.asarray(maps_c[0, :rc, :rc]))
    assert np.abs(np.asarray(maps_c[0, :rc, :rc])).max() > 0
    # carry=False: frame 1 saw no scatter at all -> raw 0 everywhere
    np.testing.assert_array_equal(np.asarray(maps_f[1]), 0.0)


def test_never_selected_regions_stay_below_confidence_cut():
    """Raw 0 decodes to objectness sigmoid(0) = 0.5 — exactly at, not
    above, the strict > 0.5 confidence cut, so gated-off regions never
    emit detections."""
    frames = _frames(T=2)
    params = _params()
    roi = RoiConfig(capacity=2, threshold=-1.0)
    idx = jnp.zeros((2, 2), jnp.int32)
    valid = jnp.zeros((2, 2), bool)
    maps = roi_raw_maps(params, DET, roi, frames, idx, valid, carry=True)
    np.testing.assert_array_equal(np.asarray(maps), 0.0)
    _, scores = D.decode_boxes(maps, DET)
    np.testing.assert_array_equal(np.asarray(scores), 0.5)
    assert not np.any(np.asarray(scores) > 0.5)


# ------------------------------------------------------ relevance head
def test_region_scores_localize_motion():
    """A single moving macroblock lights up exactly the regions whose
    8-px sample sub-grid maps onto it."""
    T, H, W = 1, 64, 96
    lr_hw = (32, 48)                              # level with scale 2
    mv = jnp.zeros((T, 2, 3, 2), jnp.int32)       # 16-px macroblocks
    mv = mv.at[0, 0, 0].set(jnp.asarray([4, 3]))  # top-left block moves
    nblk = (32 // 8) * (48 // 8)
    resid = jnp.zeros((T, nblk, 8, 8), jnp.float32)
    roi = RoiConfig(region_px=32)
    s = region_scores(mv, resid, lr_hw, (H, W), roi)
    assert s.shape == (T, 2, 3)
    s = np.asarray(s)
    assert s[0, 0, 0] == pytest.approx(7.0)       # |4| + |3|
    assert (s[0].ravel()[1:] == 0).all() or s[0, 0, 0] == s.max()
    assert np.count_nonzero(s) < s.size           # gate separates regions


# ------------------------------------------------------- serving plane
def test_serving_roi_admit_all_matches_ungated():
    """EdgeRuntime in ROI mode with an admit-all gate returns the same
    boxes/scores/types as the full-frame runtime across two consecutive
    chunks (the frame-level pipeline-③ carry still runs downstream)."""
    from repro.core.hybrid_encoder import encode_hybrid
    from repro.serving.runtime import EdgeRuntime
    from repro.serving.scheduler import ServingConfig
    from repro.sim.video_source import StreamConfig, generate_chunk

    params = _params()
    scfg = StreamConfig(height=64, width=96, n_objects=3)
    roi = RoiConfig(capacity=6, threshold=-1.0)
    rt0 = EdgeRuntime(ServingConfig(n_streams=1), params, DET)
    rt1 = EdgeRuntime(ServingConfig(n_streams=1, roi=roi), params, DET)
    for t in range(2):
        frames, _, _ = generate_chunk(KEY, scfg, t, 4)
        packet = encode_hybrid(np.asarray(frames), 8000.0, 0.05, 0.1)
        b0, s0, ty0 = rt0.process_chunk(0, t, packet)
        b1, s1, ty1 = rt1.process_chunk(0, t, packet)
        np.testing.assert_array_equal(np.asarray(ty0), np.asarray(ty1))
        np.testing.assert_array_equal(np.asarray(b0), np.asarray(b1))
        np.testing.assert_array_equal(np.asarray(s0), np.asarray(s1))


def test_serving_roi_gated_runs_with_static_capacity():
    """A real (non-admit-all) gate keeps shapes static and produces
    finite outputs."""
    from repro.core.hybrid_encoder import encode_hybrid
    from repro.serving.runtime import EdgeRuntime
    from repro.serving.scheduler import ServingConfig
    from repro.sim.video_source import StreamConfig, generate_chunk

    params = _params()
    frames, _, _ = generate_chunk(
        KEY, StreamConfig(height=64, width=96, n_objects=2), 0, 4)
    packet = encode_hybrid(np.asarray(frames), 8000.0, 0.05, 0.1)
    roi = RoiConfig(capacity=2, threshold=0.0)
    rt = EdgeRuntime(ServingConfig(n_streams=1, roi=roi), params, DET)
    b, s, types = rt.process_chunk(0, 0, packet)
    assert b.shape[0] == 4 and s.shape[0] == 4
    assert not np.any(np.isnan(np.asarray(b)))
    assert not np.any(np.isnan(np.asarray(s)))
