"""Forecast head determinism + predictive-admission coverage (ISSUE 10).

The forecast layer must be boring in exactly the right ways: pure
deterministic f32 numpy (seeded soak replays bit-identical), invisible
when off (``forecast=None`` leaves every path byte-identical to
pre-forecast builds — the bilevel parity half of that contract lives in
``tests/test_rl_bilevel.py``), and strictly useful when on (fewer
deadline misses than the reactive ladder under the ``bw-collapse``
preset).
"""
import numpy as np
import pytest

from repro.core.forecast import (FEATURES_PER_STREAM, ForecastConfig,
                                 StreamForecaster, forecast_dim)
from repro.serving.faults import (SoakConfig, churn_schedule,
                                  preset_schedule, run_soak)

f32 = np.float32


def _drive(fc: StreamForecaster, seed: int, n: int = 17):
    rng = np.random.default_rng(seed)
    for _ in range(n):
        fc.update(rng.uniform(10.0, 9000.0, fc.n).astype(f32),
                  rng.uniform(0.0, 2e5, fc.n).astype(f32))


# ------------------------------------------------------------ determinism
def test_forecaster_replay_bit_identical():
    a, b = (StreamForecaster(ForecastConfig(), 4) for _ in range(2))
    _drive(a, 9)
    _drive(b, 9)
    for k, va in a.state().items():
        np.testing.assert_array_equal(va, b.state()[k], err_msg=k)
    np.testing.assert_array_equal(a.features(), b.features())
    np.testing.assert_array_equal(a.predict_bw(), b.predict_bw())


def test_forecaster_shapes_dtypes_and_cold_start():
    C = 3
    fc = StreamForecaster(ForecastConfig(), C)
    assert forecast_dim(C) == FEATURES_PER_STREAM * C
    assert fc.features().shape == (forecast_dim(C),)
    assert fc.features().dtype == f32
    # cold streams predict +inf: no history must never cause a hold
    assert np.isinf(fc.predict_bw()).all()
    fc.update(np.full(C, 800.0, f32), np.zeros(C, f32))
    np.testing.assert_array_equal(fc.predict_bw(), np.full(C, 800.0, f32))
    assert np.isfinite(fc.features()).all()


def test_forecaster_masked_update_leaves_unobserved_untouched():
    fc = StreamForecaster(ForecastConfig(), 3)
    fc.update(np.asarray([100.0, 200.0, 300.0], f32),
              np.asarray([1e4, 2e4, 3e4], f32),
              mask=np.asarray([True, False, True]))
    assert fc.state()["warm"].tolist() == [True, False, True]
    assert fc.rate[1] == 0.0
    # an unwarmed stream still predicts +inf (must not hold on zeros)
    assert np.isinf(fc.predict_bw()[1])
    before = fc.state()
    fc.update(np.full(3, 999.0, f32), np.full(3, 5e4, f32),
              mask=np.zeros(3, bool))
    for k in ("rate", "var", "demand", "warm"):
        np.testing.assert_array_equal(fc.state()[k], before[k], err_msg=k)


def test_forecaster_ewma_tracks_rate_and_variance():
    fc = StreamForecaster(ForecastConfig(alpha=0.4), 1)
    fc.update(np.asarray([1000.0], f32), np.asarray([0.0], f32))
    assert fc.rate[0] == f32(1000.0) and fc.var[0] == 0.0
    fc.update(np.asarray([2000.0], f32), np.asarray([0.0], f32))
    assert fc.rate[0] == pytest.approx(1400.0)      # 1000 + .4 * 1000
    assert fc.var[0] > 0.0                          # dispersion appeared
    for _ in range(30):
        fc.update(np.asarray([2000.0], f32), np.asarray([0.0], f32))
    assert fc.rate[0] == pytest.approx(2000.0, rel=1e-3)
    assert fc.var[0] == pytest.approx(0.0, abs=1.0)  # steady link converges


# ------------------------------------------------------------ soak replay
def test_churn_soak_forecast_state_replays_bit_identical():
    cfg = SoakConfig(n_chunks=10, n_streams=4, chunk_frames=3, seed=11)
    reports = []
    for _ in range(2):
        sched = churn_schedule(cfg.n_chunks, cfg.n_streams, seed=11)
        reports.append(run_soak(cfg, sched, batch_submit=True,
                                forecast=ForecastConfig()))
    a, b = reports
    assert a["forecast_state"] is not None
    for k in ("rate", "var", "demand", "warm"):
        np.testing.assert_array_equal(a["forecast_state"][k],
                                      b["forecast_state"][k], err_msg=k)
    assert a["forecast_state"]["t"] == b["forecast_state"]["t"]
    assert a["forecast_holds"] == b["forecast_holds"]
    assert a["stream_stats"] == b["stream_stats"]
    assert a["accounting_ok"] and b["accounting_ok"]


def test_soak_forecast_off_reports_no_forecast_fields():
    cfg = SoakConfig(n_chunks=6, n_streams=2, chunk_frames=3, seed=3)
    r = run_soak(cfg, churn_schedule(cfg.n_chunks, cfg.n_streams, seed=3),
                 batch_submit=True)
    assert r["forecast_state"] is None and r["forecast_holds"] == 0


# ----------------------------------------------------- predictive admission
def test_forecast_lowers_deadline_misses_under_bw_collapse():
    """The acceptance mechanism: under the bw-collapse preset the
    predictive gate holds chunks the link cannot deliver (pipeline-③
    reuse) instead of transmitting into the outage, so deadline misses
    drop strictly below the reactive ladder's — with recovery and
    accounting intact."""
    cfg = SoakConfig(n_chunks=12, n_streams=3, chunk_frames=3, seed=7)

    def misses(r):
        return sum(s["deadline_misses"] for s in r["stream_stats"].values())

    sched = preset_schedule("bw-collapse", n_chunks=cfg.n_chunks, seed=7)
    reactive = run_soak(cfg, sched, batch_submit=True)
    forecast = run_soak(cfg, sched, batch_submit=True,
                        forecast=ForecastConfig())
    assert misses(reactive) > 0, "preset must actually stress the deadline"
    assert misses(forecast) < misses(reactive)
    assert forecast["forecast_holds"] > 0
    assert forecast["accounting_ok"]
    assert not forecast["queue_leaks"]
    assert all(e["ok"] for e in forecast["recovery"] if "ok" in e)
    assert all(e["ok"] for e in forecast["recovery_infer"] if "ok" in e)


def test_hold_chunk_accounting_invariant():
    """EdgeRuntime.hold_chunk keeps frames_in == inferred+reused+skipped
    for both the carry (reuse-hold) and no-carry (frame-skip) branches."""
    import jax
    from repro.core.hybrid_encoder import encode_hybrid
    from repro.models import detection as D
    from repro.serving.runtime import EdgeRuntime
    from repro.serving.scheduler import ServingConfig
    from repro.sim.video_source import StreamConfig, generate_chunk
    det_cfg = D.TinyDetectorConfig()
    params = D.init(jax.random.PRNGKey(0), det_cfg)
    frames, _, _ = generate_chunk(None, StreamConfig(height=32, width=48,
                                                     n_objects=2, seed=5),
                                  0, 3)
    pkt = encode_hybrid(np.asarray(frames), 8000.0, 0.05, 0.1)
    rt = EdgeRuntime(ServingConfig(n_streams=1), params, det_cfg)
    # no carry yet: hold must frame-skip with explicit accounting
    tk0 = rt.hold_chunk(0, 0, pkt)
    assert tk0.done and (np.asarray(tk0.types) == 0).all()
    # build a carry, then hold again: pipeline-③ reuse-hold
    rt.submit_chunk(0, 1, pkt)
    rt.flush()
    tk2 = rt.hold_chunk(0, 2, pkt)
    assert (np.asarray(tk2.types) == 3).all()
    boxes, scores, _ = rt.poll(tk2)
    assert boxes.shape[0] == 3
    s = rt.stats[0].as_dict()
    assert s["frames_in"] == 9
    assert s["frames_in"] == s["frames_inferred"] + s["frames_reused"] \
        + s["frames_skipped"]
    assert not rt.stats[0].last_transmitted
    rt.close()


# ------------------------------------------------------------- env plumbing
def test_env_high_state_widens_with_forecast():
    from repro.sim.env import EnvConfig, MultiStreamEnv, high_state_dim
    from repro.sim.video_source import paper_stream_mix
    C = 3
    streams = tuple(paper_stream_mix(C, 64, 96))
    off = EnvConfig(streams=streams, chunk_frames=4)
    on = EnvConfig(streams=streams, chunk_frames=4,
                   forecast=ForecastConfig())
    assert high_state_dim(off) == 6 * C
    assert high_state_dim(on) == 6 * C + forecast_dim(C)
    env_off, env_on = MultiStreamEnv(off), MultiStreamEnv(on)
    assert env_off.observe_high().shape == (6 * C,)
    s_on = env_on.observe_high()
    assert s_on.shape == (high_state_dim(on),)
    # before any step the appended features are the forecaster's zeros
    # except the periodic phase column
    np.testing.assert_array_equal(s_on[:6 * C], env_off.observe_high())
    # one step folds rate/bits observations into the appended block
    props = np.full(C, 1.0 / C)
    thr = np.full((C, 2), 0.05, np.float32)
    env_on.step(props, thr)
    env_off.step(props, thr)
    s2 = env_on.observe_high()
    assert env_on.forecaster.t == 1
    assert (env_on.forecaster.rate > 0).all()
    np.testing.assert_array_equal(s2[:6 * C], env_off.observe_high())
    assert not np.array_equal(s2[6 * C:], s_on[6 * C:])


def test_env_forecast_off_state_unchanged():
    from repro.sim.env import EnvConfig, MultiStreamEnv
    from repro.sim.video_source import paper_stream_mix
    cfg = EnvConfig(streams=tuple(paper_stream_mix(2, 64, 96)),
                    chunk_frames=4)
    env = MultiStreamEnv(cfg)
    assert env.forecaster is None
    env.step(np.full(2, 0.5), np.full((2, 2), 0.05, np.float32))
    assert env.observe_high().shape == (12,)
