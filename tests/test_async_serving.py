"""Async continuous-batching serving plane (ISSUE 7 tentpole).

The submit/flush/poll API must be a pure latency optimization: every
result bit-equal to the synchronous ``process_chunk`` oracle, one
device->host transfer per chunk (at the poll boundary), in-flight device
work bounded by ``max_inflight``, and a clean teardown path that stops
the hedge executor's threads.
"""
import jax
import numpy as np
import pytest

from repro.serving.straggler import HedgeConfig, HedgedExecutor

KEY = jax.random.PRNGKey(0)


def _mkrt(n_streams=2, **cfg_kw):
    from repro.models import detection as D
    from repro.serving.runtime import EdgeRuntime
    from repro.serving.scheduler import ServingConfig
    det_cfg = D.TinyDetectorConfig()
    params = D.init(jax.random.PRNGKey(1), det_cfg)
    return EdgeRuntime(ServingConfig(n_streams=n_streams, **cfg_kw),
                       params, det_cfg)


def _packet(seed=0, T=3):
    from repro.core.hybrid_encoder import encode_hybrid
    from repro.sim.video_source import StreamConfig, generate_chunk
    frames, _, _ = generate_chunk(
        None, StreamConfig(height=32, width=48, seed=seed), 0, T)
    return encode_hybrid(np.asarray(frames), 8000.0, 0.05, 0.1)


def test_pad_bucket_power_of_two():
    from repro.serving.runtime import _pad_bucket
    assert [_pad_bucket(n, 4) for n in (1, 3, 4, 5, 8, 9)] == \
        [4, 4, 4, 8, 8, 16]
    assert _pad_bucket(1, 1) == 1 and _pad_bucket(3, 1) == 4


def test_submit_poll_bit_equal_to_process_chunk_oracle():
    """Three chunks of two streams through the async path — submitted
    together, flushed as one cross-stream batch per round, polled late —
    match the synchronous oracle bit for bit (boxes, scores, types),
    including the pipeline-3 carry chain across chunk boundaries."""
    rt, oracle = _mkrt(), _mkrt()
    pkts = [_packet(seed=s) for s in range(2)]
    for t in range(3):
        tks = [rt.submit_chunk(s, t, pkts[s]) for s in range(2)]
        assert not any(tk.done for tk in tks)
        outs = rt.poll_all(tks)
        for s, (boxes, scores, types) in enumerate(outs):
            ob, os_, ot = oracle.process_chunk(s, t, pkts[s])
            np.testing.assert_array_equal(types, ot)
            np.testing.assert_array_equal(boxes, ob,
                                          err_msg=f"stream {s} chunk {t}")
            np.testing.assert_array_equal(scores, os_)
    for s in range(2):
        assert rt.stats[s].as_dict() == oracle.stats[s].as_dict()
    rt.close(), oracle.close()


def test_poll_out_of_order_and_cached():
    """Tickets materialize in any order; the host transfer happens once
    (repeat polls return the cached tuple)."""
    rt = _mkrt(n_streams=3)
    tks = [rt.submit_chunk(s, 0, _packet(seed=s)) for s in range(3)]
    outs = [rt.poll(tk) for tk in reversed(tks)]
    assert all(o[0].shape == outs[0][0].shape for o in outs)
    for tk in tks:
        assert tk._dev_out is None            # device refs dropped
        assert rt.poll(tk) is tk._host        # cached, no second transfer
    rt.close()


def test_submit_enqueues_lightweight_requests_and_flush_takes_them():
    """Pipeline-1/2 queue entries are bookkeeping-only (no frame payload
    — frames stay staged on device) and are removed by the dispatch's
    ``take``, so queue depths return to zero after every flush."""
    rt = _mkrt()
    tk = rt.submit_chunk(0, 0, _packet())
    assert len(tk.reqs) == int(np.sum((tk.types == 1) | (tk.types == 2)))
    assert all(r.frame is None for r in tk.reqs)
    assert float(rt.queues.depths.sum()) == len(tk.reqs)
    rt.flush()
    assert float(rt.queues.depths.sum()) == 0.0
    rt.poll(tk)
    rt.close()


def test_take_removes_only_named_requests():
    from repro.serving.scheduler import (InferRequest, PipelineQueues,
                                         ServingConfig)
    q = PipelineQueues(ServingConfig(n_streams=2), lambda frames: [])
    reqs = [InferRequest(0, 0, i, 1, None, shard=0) for i in range(3)]
    for r in reqs:
        q.submit(r)
    assert q.take(reqs[:2]) == 2
    assert list(q.q1) == [reqs[2]]
    assert q.take(reqs[:2]) == 0              # already gone: no-op


def test_double_buffer_caps_in_flight_batches():
    """``max_inflight`` bounds the un-retired device batches per shard:
    the dispatcher blocks on the OLDEST batch before issuing a new one.
    Results stay bit-equal to the oracle while overlapped."""
    rt = _mkrt(max_inflight=1)
    oracle = _mkrt()
    assert rt.max_inflight == 1
    pkts = [_packet(seed=s) for s in range(2)]
    for t in range(3):
        tks = [rt.submit_chunk(s, t, pkts[s]) for s in range(2)]
        rt.flush()
        assert all(len(q) <= 1 for q in rt._inflight.values())
        for s, tk in enumerate(tks):
            np.testing.assert_array_equal(
                rt.poll(tk)[0], oracle.process_chunk(s, t, pkts[s])[0])
    rt.close(), oracle.close()
    assert all(len(q) == 0 for q in rt._inflight.values())


def test_submitting_next_chunk_flushes_previous_ticket():
    """Per-stream ordering barrier: a stream's chunk t+1 submitted while
    chunk t is still pending forces a flush first, keeping the carry
    chain ordered."""
    rt = _mkrt(n_streams=1)
    pkt = _packet()
    tk0 = rt.submit_chunk(0, 0, pkt)
    tk1 = rt.submit_chunk(0, 1, pkt)
    assert tk0.done and not tk1.done
    b0 = rt.poll(tk0)[0]
    oracle = _mkrt(n_streams=1)
    np.testing.assert_array_equal(b0, oracle.process_chunk(0, 0, pkt)[0])
    np.testing.assert_array_equal(rt.poll(tk1)[0],
                                  oracle.process_chunk(0, 1, pkt)[0])
    rt.close(), oracle.close()


def test_runtime_context_manager_closes_hedge_pool():
    """``EdgeRuntime`` teardown retires in-flight work and shuts the
    hedge thread pool down (the pre-fix leak); both paths idempotent."""
    with _mkrt() as rt:
        rt.process_chunk(0, 0, _packet())
        hedge = rt._hedge
    assert all(len(q) == 0 for q in rt._inflight.values())
    if hedge is not None:
        assert hedge._pool is None
    rt.close()                                # second close: no-op


def test_hedged_executor_context_manager_shuts_down_pool():
    with HedgedExecutor(HedgeConfig(min_history=1),
                        [lambda x: x, lambda x: x]) as ex:
        ex.lat.extend([1e-6] * 5)
        out, _ = ex.run(7)                    # wall-clock path, may hedge
        assert out == 7
    assert ex._pool is None
    ex.close()                                # idempotent


def test_batch_submit_soak_report_matches_sync_soak():
    """``run_soak(batch_submit=True)`` is control-equivalent to the
    chunk-sequential soak: accounting, per-chunk fps series, and queue
    state are identical (decisions are made at submit time in both)."""
    from repro.serving.faults import SoakConfig, churn_schedule, run_soak
    cfg = SoakConfig(n_streams=6, n_chunks=6, chunk_frames=3,
                     gpu_capacity_fps=2000.0, content_groups=3, seed=11)
    sched = churn_schedule(6, 6, seed=11)
    a = run_soak(cfg, sched, batch_submit=True)
    b = run_soak(cfg, sched, batch_submit=False)
    assert a["accounting_ok"] and b["accounting_ok"]
    assert a["queue_leaks"] == [] and b["queue_leaks"] == []
    assert a["stream_stats"] == b["stream_stats"]
    np.testing.assert_array_equal(a["delivered_fps"], b["delivered_fps"])
    np.testing.assert_array_equal(a["infer_fps"], b["infer_fps"])
