"""Closed-loop chaos soaks + elastic eviction/recovery (ISSUE 6 tentpole).

CPU tests drive ``run_soak`` across every preset fault schedule and assert
the three chaos invariants: zero accounting leaks (frames in == inferred +
reused + explicitly skipped), no queue leaks/deadlock, and post-fault fps
recovery to >= 90% of the pre-fault steady state within K chunks.  The
degradation-ladder unit tests exercise each rung (retry, demote, forced
reuse, frame-skip) against crafted schedules.

Like ``test_stream_sharding.py``, the eviction -> remesh -> re-dispatch
path needs a real multi-device platform: a driver test re-runs this
file's ``forced``-named tests in a subprocess with 4 fake CPU devices and
proves the rebuilt-mesh round trip BIT-EXACT against the no-fault oracle
for the surviving streams.
"""
import os

import jax
import numpy as np
import pytest

import conftest
from repro.serving.faults import (FaultEvent, FaultSchedule, PRESETS,
                                  SoakConfig, churn_schedule,
                                  preset_schedule, run_soak)

_FORCED = int(os.environ.get(conftest.FORCED_MULTIDEVICE_ENV, "0"))

forced_only = pytest.mark.skipif(
    _FORCED < 4, reason="needs the forced multi-device child process")

N_CHUNKS = 24
_soak_cache: dict = {}


def _soak(name: str):
    """One soak per preset per session (they dominate this file's cost)."""
    if name not in _soak_cache:
        n_shards = 2 if name == "shard-chaos" else 1
        cfg = SoakConfig(n_chunks=N_CHUNKS, n_streams=3, chunk_frames=3,
                         n_shards=n_shards, seed=7)
        sched = preset_schedule(name, n_chunks=N_CHUNKS, n_streams=3,
                                n_shards=n_shards, seed=7)
        _soak_cache[name] = (cfg, sched, run_soak(cfg, sched))
    return _soak_cache[name]


# ------------------------------------------------------------ chaos soaks
@pytest.mark.parametrize("name", PRESETS)
def test_soak_accounting_never_leaks(name):
    """frames_in == frames_inferred + frames_reused + frames_skipped for
    every stream, under every fault mix — degradation is explicit."""
    _, _, rep = _soak(name)
    assert rep["accounting_ok"]
    for c, s in rep["stream_stats"].items():
        assert s["frames_in"] == (s["frames_inferred"] + s["frames_reused"]
                                  + s["frames_skipped"]), (name, c, s)
        assert s["frames_in"] > 0


@pytest.mark.parametrize("name", PRESETS)
def test_soak_no_queue_leaks_or_deadlock(name):
    """The soak ran to completion (no deadlock) and no request was left
    behind in a pipeline queue after any chunk."""
    _, _, rep = _soak(name)
    assert rep["n_chunks"] == N_CHUNKS
    assert rep["queue_leaks"] == []


@pytest.mark.parametrize("name", PRESETS)
def test_soak_recovers_steady_state_fps(name):
    """Every checkable fault region recovers to >= recovery_frac of its
    pre-fault baseline within K chunks of clearing — on both the
    delivered-fps and inferred-fps (through-the-DNN) series."""
    _, _, rep = _soak(name)
    checked = 0
    for series in ("recovery", "recovery_infer"):
        for region in rep[series]:
            if region["ok"] is not None:
                assert region["ok"], (name, series, region)
                checked += 1
    assert checked > 0, f"{name}: no checkable fault region"


def test_soak_is_deterministic():
    cfg, sched, rep = _soak("loss-burst")
    rep2 = run_soak(cfg, sched)
    np.testing.assert_array_equal(rep["fps_norm"], rep2["fps_norm"])
    np.testing.assert_array_equal(rep["infer_norm"], rep2["infer_norm"])
    assert rep["stream_stats"] == rep2["stream_stats"]
    assert rep["fault_log"] == rep2["fault_log"]


def test_soak_ladder_engages_under_faults():
    """The fault mixes actually exercise the ladder: outages cause
    deadline misses and rung demotion (bw-collapse); loss bursts cause
    retries, reuse-holds, and an explicit frame-skip (loss-burst)."""
    _, _, bw = _soak("bw-collapse")
    tot = {k: sum(s[k] for s in bw["stream_stats"].values())
           for k in ("deadline_misses", "demote_events", "promote_events")}
    assert tot["deadline_misses"] > 0 and tot["demote_events"] > 0
    assert tot["promote_events"] > 0          # ...and walks back up

    _, _, loss = _soak("loss-burst")
    tot = {k: sum(s[k] for s in loss["stream_stats"].values())
           for k in ("retries", "chunks_lost", "reuse_fallback_chunks",
                     "frames_skipped")}
    assert tot["retries"] > 0 and tot["chunks_lost"] > 0
    assert tot["reuse_fallback_chunks"] > 0   # rung 3
    assert tot["frames_skipped"] > 0          # rung 4 (pre-carry loss)
    # every decision is surfaced as an event
    assert any(e[1] == "retry_exhausted"
               for s in loss["stream_stats"].values() for e in s["events"])
    assert any(e[1] == "frame_skip"
               for s in loss["stream_stats"].values() for e in s["events"])


def test_soak_churn_masks_streams():
    _, sched, rep = _soak("stream-churn")
    tot_stall = sum(s["chunks_stalled"]
                    for s in rep["stream_stats"].values())
    assert tot_stall > 0
    stats = rep["stream_stats"]
    # the late joiner missed its pre-join chunks; the leaver missed its
    # whole leave window (longer than any stall)
    assert stats[2]["chunks"] == N_CHUNKS - 2
    assert stats[1]["chunks"] < stats[0]["chunks"]
    assert not sched.stream_active(2, 0) and sched.stream_active(2, 5)


def test_soak_evicts_and_recovers_straggler_shard():
    """shard-chaos: the slow shard is flagged, evicted (queued work
    re-homed to survivors), then re-admitted once the slowdown clears —
    without dropping a single admitted stream's accounting."""
    _, _, rep = _soak("shard-chaos")
    actions = [a for _, a, _ in rep["fault_log"]]
    assert "evict" in actions and "recover" in actions
    t_evict = next(t for t, a, _ in rep["fault_log"] if a == "evict")
    t_rec = next(t for t, a, _ in rep["fault_log"] if a == "recover")
    assert t_evict < t_rec
    assert rep["active_shards_final"] == [0, 1]
    assert rep["accounting_ok"]
    assert rep["hedged_dispatches"] > 0       # hedging kicked in too


# ------------------------------------------------- degradation ladder unit
def _tiny_runtime(faults=None, degrade=None, n_streams=1, n_shards=1,
                  **cfg_kw):
    from repro.models import detection as D
    from repro.serving.runtime import EdgeRuntime
    from repro.serving.scheduler import ServingConfig
    det_cfg = D.TinyDetectorConfig()
    params = D.init(jax.random.PRNGKey(1), det_cfg)
    cfg = ServingConfig(n_streams=n_streams, n_shards=n_shards, **cfg_kw)
    return EdgeRuntime(cfg, params, det_cfg, faults=faults, degrade=degrade)


def _packet(seed=0, T=3):
    from repro.core.hybrid_encoder import encode_hybrid
    from repro.sim.video_source import StreamConfig, generate_chunk
    frames, _, _ = generate_chunk(
        None, StreamConfig(height=32, width=48, seed=seed), 0, T)
    return encode_hybrid(np.asarray(frames), 8000.0, 0.05, 0.1)


def test_ladder_demote_force_reuse_and_promote():
    from repro.serving.runtime import DegradeConfig
    rt = _tiny_runtime(degrade=DegradeConfig(
        deadline_s=0.5, demote_patience=2, promote_patience=2,
        max_demotion=1))
    assert rt.suggest_level(0, 3) == 3
    rt.note_chunk_latency(0, 0, 1.0)          # miss 1: no action yet
    assert rt.stats[0].rung_demotion == 0
    rt.note_chunk_latency(0, 1, 1.0)          # miss 2: demote
    assert rt.stats[0].rung_demotion == 1
    assert rt.suggest_level(0, 3) == 2
    assert rt.suggest_level(0, 0) == 0        # never below the floor
    rt.note_chunk_latency(0, 2, 1.0)
    rt.note_chunk_latency(0, 3, 1.0)          # at max_demotion: rung 3
    assert rt.stats[0].force_reuse
    rt.note_chunk_latency(0, 4, 0.1)
    rt.note_chunk_latency(0, 5, 0.1)          # recovery: leave reuse first
    assert not rt.stats[0].force_reuse
    assert rt.stats[0].rung_demotion == 1
    rt.note_chunk_latency(0, 6, 0.1)
    rt.note_chunk_latency(0, 7, 0.1)          # ...then promote the rung
    assert rt.stats[0].rung_demotion == 0
    st = rt.stats[0]
    assert st.demote_events == 1 and st.promote_events == 1
    assert st.deadline_misses == 4
    acts = [a for _, a, _ in st.events]
    assert acts == ["demote", "force_reuse", "resume_infer", "promote"]


def test_lost_chunk_without_carry_frame_skips_with_accounting():
    sched = FaultSchedule([FaultEvent("chunk_loss", 0, 1, magnitude=1.0)])
    rt = _tiny_runtime(faults=sched)
    pkt = _packet()
    boxes, scores, types = rt.process_chunk(0, 0, pkt)
    assert (types == 0).all()                 # explicitly dropped
    assert float(np.abs(boxes).sum()) == 0.0
    st = rt.stats[0]
    assert st.frames_skipped == pkt.types.shape[0]
    assert st.frames_in == st.frames_inferred + st.frames_reused \
        + st.frames_skipped
    assert not st.last_transmitted and st.retries > 0


def test_lost_chunk_with_carry_holds_on_reuse():
    sched = FaultSchedule([FaultEvent("chunk_loss", 1, 2, magnitude=1.0)])
    rt = _tiny_runtime(faults=sched)
    pkt = _packet()
    b0, s0, t0 = rt.process_chunk(0, 0, pkt)      # clean chunk seeds carry
    assert (t0 == pkt.types).all()
    b1, _, t1 = rt.process_chunk(0, 1, pkt)       # lost chunk: hold
    assert (t1 == 3).all()
    np.testing.assert_array_equal(b1[0], b0[-1])  # zero-motion carry
    np.testing.assert_array_equal(b1[-1], b0[-1])
    st = rt.stats[0]
    assert st.reuse_fallback_chunks == 1 and st.frames_skipped == 0
    assert st.frames_in == st.frames_inferred + st.frames_reused


def test_flaky_chunk_recovered_by_retry():
    # magnitude 0 loss never triggers; 0.4 on a seeded schedule where the
    # first coin loses but a retry wins: find such (seed, t) by scanning
    sched = None
    for seed in range(50):
        s = FaultSchedule([FaultEvent("chunk_loss", 0, 1, magnitude=0.4)],
                          seed=seed)
        if s.chunk_lost(0, 0) and s.retry_succeeds(0, 0, 0):
            sched = s
            break
    assert sched is not None
    rt = _tiny_runtime(faults=sched)
    pkt = _packet()
    _, _, types = rt.process_chunk(0, 0, pkt)
    st = rt.stats[0]
    assert (types == pkt.types).all()         # delivered after retry
    assert st.retries == 1 and st.last_penalty_s > 0.0
    assert st.chunks_lost == 1 and st.frames_skipped == 0
    assert any(a == "retry_ok" for _, a, _ in st.events)


def test_forced_reuse_routes_delivered_chunks_to_pipeline3():
    from repro.serving.runtime import DegradeConfig
    rt = _tiny_runtime(faults=FaultSchedule([]), degrade=DegradeConfig(
        deadline_s=0.5, demote_patience=1, max_demotion=0))
    pkt = _packet()
    rt.process_chunk(0, 0, pkt)               # seed the carry
    rt.note_chunk_latency(0, 0, 2.0)          # max_demotion=0: straight
    assert rt.stats[0].force_reuse            # to rung 3
    _, _, types = rt.process_chunk(0, 1, pkt)
    assert (types == 3).all()
    assert rt.stats[0].reuse_fallback_chunks == 1


def test_manual_evict_remaps_queued_requests_and_last_shard_guarded():
    from repro.serving.scheduler import InferRequest
    rt = _tiny_runtime(n_streams=4, n_shards=2)
    frame = np.zeros((32, 48), np.float32)
    rt.queues.submit(InferRequest(1, 0, 0, 1, frame, shard=1))
    assert rt.evict_shard(1, t=0)
    assert rt.active_shards == [0]
    assert all(r.shard == 0 for r in rt.queues.q1)    # re-homed
    assert rt.stream_shard(1) == 0
    assert not rt.evict_shard(0, t=1)         # never evict the last shard
    assert rt.recover_shard(1, t=2)
    assert rt.active_shards == [0, 1]
    assert not rt.recover_shard(1, t=3)       # already active: no-op


# --------------------------------------------------- forced 4-device child
def test_spawns_multidevice_child_suite():
    """Driver: re-run ONLY this file's ``forced``-named tests under 4
    forced CPU devices (see test_stream_sharding.py for the pattern)."""
    if _FORCED:
        pytest.skip("already inside the forced multi-device child")
    r = conftest.forced_multidevice_run(
        "tests/test_chaos.py", extra_args=["-k", "forced"])
    assert r.returncode == 0, (
        f"forced multi-device child failed\n--- stdout ---\n{r.stdout}"
        f"\n--- stderr ---\n{r.stderr}")
    assert "passed" in r.stdout


def _roundtrip_fixtures(S=4, H=64, W=96, T=4):
    import jax.numpy as jnp
    from repro.core.roundtrip import RoundtripConfig
    from repro.models import detection as D
    from repro.sim.video_source import StreamConfig, generate_chunk
    det_cfg = D.TinyDetectorConfig()
    params = D.init(jax.random.PRNGKey(1), det_cfg)
    cfg = RoundtripConfig(level=3, det_cfg=det_cfg)
    data = [generate_chunk(None, StreamConfig(height=H, width=W,
                                              n_objects=3, seed=s), 0, T)
            for s in range(S)]
    raw = jnp.stack([d[0] for d in data])
    gtb = jnp.stack([d[1] for d in data])
    gtv = jnp.stack([d[2] for d in data])
    sc = dict(tr1=jnp.full((S,), 0.05), tr2=jnp.full((S,), 0.1),
              bw_kbps=jnp.asarray([6000.0, 3000.0, 1500.0, 8000.0][:S]),
              queue_delay=jnp.zeros((S,)))
    return raw, gtb, gtv, params, cfg, sc


@forced_only
def test_forced_eviction_remesh_roundtrip_bit_exact():
    """The tentpole's elastic guarantee: kill a device group, rebuild the
    mesh from survivors, re-dispatch the SAME streams — every surviving
    stream's outputs are bit-exact vs the no-fault single-device oracle.
    """
    from repro.core.roundtrip import roundtrip_batched
    from repro.distributed.sharding import SINGLE_POD_RULES
    from repro.distributed.stream_sharding import shard_roundtrip
    from repro.serving.elastic import ElasticPool, remesh

    raw, gtb, gtv, params, cfg, sc = _roundtrip_fixtures()
    ref = roundtrip_batched(raw, gtb, gtv, params, cfg=cfg, **sc)

    pool = ElasticPool(4)
    mesh4 = remesh(pool)
    assert mesh4.devices.size == 4
    out4 = shard_roundtrip(mesh4, SINGLE_POD_RULES, cfg=cfg)(
        raw, gtb, gtv, params, **sc)

    pool.fail(3)                               # kill one device group
    mesh2 = remesh(pool)                       # largest power of two: 2
    assert mesh2.devices.size == 2
    assert set(mesh2.devices.flat) < set(mesh4.devices.flat)
    out2 = shard_roundtrip(mesh2, SINGLE_POD_RULES, cfg=cfg)(
        raw, gtb, gtv, params, **sc)

    for k in ref:
        np.testing.assert_array_equal(
            np.asarray(out4[k]), np.asarray(ref[k]),
            err_msg=f"pre-fault mesh diverged on {k!r}")
        np.testing.assert_array_equal(
            np.asarray(out2[k]), np.asarray(ref[k]),
            err_msg=f"post-eviction mesh diverged on {k!r}")


@forced_only
def test_forced_remesh_respects_power_of_two_and_raises_when_empty():
    from repro.serving.elastic import ElasticPool, remesh
    pool = ElasticPool(4)
    assert remesh(pool).shape["data"] == 4
    pool.fail(0)
    assert pool.usable_power_of_two() == 2
    m = remesh(pool)
    assert m.shape["data"] == 2
    devs = list(m.devices.flat)
    assert jax.devices()[0] not in devs        # failed group really left
    for g in (1, 2, 3):
        pool.fail(g)
    with pytest.raises(RuntimeError, match="0 of 4 groups healthy"):
        remesh(pool)


@forced_only
def test_forced_reshard_params_preserves_values():
    """Post-failure parameter migration: device_put onto the rebuilt mesh
    keeps every weight bit-identical."""
    import jax.numpy as jnp
    from repro.models.params import init_params, spec
    from repro.serving.elastic import ElasticPool, remesh, reshard_params
    specs = {"w": spec((8, 16), (None, "tensor"), dtype=jnp.float32),
             "b": spec((16,), (None,), dtype=jnp.float32)}
    params = init_params(jax.random.PRNGKey(0), specs)
    pool = ElasticPool(4)
    pool.fail(2)
    mesh = remesh(pool)
    moved = reshard_params(params, specs, mesh)
    for k in params:
        np.testing.assert_array_equal(np.asarray(moved[k]),
                                      np.asarray(params[k]))


@forced_only
def test_forced_runtime_eviction_serves_all_streams():
    """Sharded EdgeRuntime on a real 4-device mesh: after evicting a
    shard, every stream (including the evicted shard's) is still served
    on a survivor device with types matching the no-fault runtime."""
    from repro.distributed.sharding import SINGLE_POD_RULES
    from repro.models import detection as D
    from repro.serving.runtime import EdgeRuntime
    from repro.serving.scheduler import ServingConfig
    det_cfg = D.TinyDetectorConfig()
    params = D.init(jax.random.PRNGKey(1), det_cfg)
    mesh = jax.make_mesh((4,), ("data",))
    cfg = ServingConfig(n_streams=4, gpu_capacity_fps=480.0)
    rt = EdgeRuntime(cfg, params, det_cfg, mesh=mesh,
                     rules=SINGLE_POD_RULES)
    oracle = EdgeRuntime(ServingConfig(n_streams=4,
                                       gpu_capacity_fps=480.0),
                         params, det_cfg)
    pkts = [_packet(seed=s) for s in range(4)]
    assert rt.evict_shard(2, t=0)
    assert rt.active_shards == [0, 1, 3]
    for s in range(4):
        assert rt.stream_shard(s) in rt.active_shards
        boxes, scores, types = rt.process_chunk(s, 0, pkts[s])
        ob, os_, ot = oracle.process_chunk(s, 0, pkts[s])
        np.testing.assert_array_equal(types, ot)
        np.testing.assert_array_equal(boxes, np.asarray(ob),
                                      err_msg=f"stream {s} diverged after "
                                              f"eviction")
    assert int(rt.deferred) == 0              # nobody was dropped


# ------------------------------------------- many-stream churn (ISSUE 7)
def test_churn_schedule_deterministic_and_validates():
    a = churn_schedule(12, 32, seed=3)
    b = churn_schedule(12, 32, seed=3)
    assert a.events == b.events
    assert a.events != churn_schedule(12, 32, seed=4).events
    kinds = {e.kind for e in a.events}
    assert {"join", "leave", "stall", "chunk_loss"} <= kinds
    assert churn_schedule(12, 32, seed=3, loss_window=False).events == \
        tuple(e for e in a.events if e.kind != "chunk_loss")
    with pytest.raises(ValueError, match="n_chunks >= 4"):
        churn_schedule(3, 8)


def test_churn_soak_64stream_batch_submit_accounting_and_queues():
    """The O(100)-stream acceptance soak: 64 churning streams through the
    continuous-batching path.  Per-stream frame accounting must balance,
    no request may be left in a pipeline queue after any chunk, and every
    stream that was ever live must have been served."""
    cfg = SoakConfig(n_streams=64, n_chunks=6, chunk_frames=3,
                     gpu_capacity_fps=4000.0, content_groups=8, seed=7)
    sched = churn_schedule(6, 64, seed=7)
    rep = run_soak(cfg, sched, batch_submit=True)
    assert rep["accounting_ok"]
    assert rep["queue_leaks"] == []
    served = 0
    for c, s in rep["stream_stats"].items():
        assert s["frames_in"] == (s["frames_inferred"] + s["frames_reused"]
                                  + s["frames_skipped"]), (c, s)
        served += s["frames_in"] > 0
    ever_live = sum(any(sched.stream_active(c, t) for t in range(6))
                    for c in range(64))
    stalled_out = sum(all(sched.stalled(c, t) or not sched.stream_active(c, t)
                          for t in range(6)) for c in range(64))
    assert served >= ever_live - stalled_out
    assert (rep["delivered_fps"] > 0).all()    # never a dead round


@forced_only
def test_forced_eviction_while_in_flight_bit_exact():
    """Evict a shard BETWEEN submit and flush, with another shard's batch
    already dispatched: the evicted shard's pending ticket re-homes to a
    survivor, every stream still polls bit-exact vs the synchronous
    no-fault oracle, and accounting balances."""
    from repro.distributed.sharding import SINGLE_POD_RULES
    from repro.models import detection as D
    from repro.serving.runtime import EdgeRuntime
    from repro.serving.scheduler import ServingConfig
    det_cfg = D.TinyDetectorConfig()
    params = D.init(jax.random.PRNGKey(1), det_cfg)
    mesh = jax.make_mesh((4,), ("data",))
    scfg = ServingConfig(n_streams=4, gpu_capacity_fps=480.0)
    rt = EdgeRuntime(scfg, params, det_cfg, mesh=mesh,
                     rules=SINGLE_POD_RULES)
    oracle = EdgeRuntime(ServingConfig(n_streams=4,
                                       gpu_capacity_fps=480.0),
                         params, det_cfg)
    pkts = [_packet(seed=s) for s in range(4)]
    tks = [rt.submit_chunk(s, 0, pkts[s]) for s in range(4)]
    rt.flush(shard=rt.stream_shard(0))         # one batch already in flight
    assert tks[0].done
    victim = rt.stream_shard(2)
    assert rt.evict_shard(victim, t=0)
    assert victim not in rt.active_shards
    assert tks[2].shard in rt.active_shards    # pending ticket re-homed
    outs = rt.poll_all(tks)
    for s, (boxes, scores, types) in enumerate(outs):
        ob, os_, ot = oracle.process_chunk(s, 0, pkts[s])
        np.testing.assert_array_equal(types, ot)
        np.testing.assert_array_equal(boxes, np.asarray(ob),
                                      err_msg=f"stream {s} diverged")
        np.testing.assert_array_equal(scores, np.asarray(os_))
    for s in range(4):
        st = rt.stats[s]
        assert st.frames_in == st.frames_inferred + st.frames_reused \
            + st.frames_skipped
    rt.close(), oracle.close()
