"""Kernels wired into the system: the Pallas paths must agree with the
XLA/jnp paths inside the actual models."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

KEY = jax.random.PRNGKey(0)


def test_lm_forward_pallas_attention_matches_xla():
    from repro.configs import get_arch
    from repro.models import transformer_lm as M
    from repro.models.params import init_params
    arch = get_arch("llama3_2_1b", reduced=True)
    cfg = dataclasses.replace(arch.cfg, remat=False)
    params = init_params(KEY, M.param_specs(cfg))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 64), 0, cfg.vocab,
                              jnp.int32)
    lx, _, _ = M.forward(params, cfg, toks)
    cfg_p = dataclasses.replace(cfg, attention_impl="pallas")
    lp, _, _ = M.forward(params, cfg_p, toks)
    a = np.asarray(jax.nn.softmax(lx, -1), np.float32)
    b = np.asarray(jax.nn.softmax(lp, -1), np.float32)
    np.testing.assert_allclose(a, b, atol=0.05)


def test_swa_pallas_matches_xla():
    from repro.configs import get_arch
    from repro.models import transformer_lm as M
    from repro.models.params import init_params
    arch = get_arch("mixtral_8x22b", reduced=True)
    cfg = dataclasses.replace(arch.cfg, remat=False)
    params = init_params(KEY, M.param_specs(cfg))
    toks = jax.random.randint(jax.random.PRNGKey(2), (1, 64), 0, cfg.vocab,
                              jnp.int32)
    lx, _, _ = M.forward(params, cfg, toks)
    cfg_p = dataclasses.replace(cfg, attention_impl="pallas")
    lp, _, _ = M.forward(params, cfg_p, toks)
    a = np.asarray(jax.nn.softmax(lx, -1), np.float32)
    b = np.asarray(jax.nn.softmax(lp, -1), np.float32)
    np.testing.assert_allclose(a, b, atol=0.05)


def test_quality_transfer_kernel_path_in_core():
    """Kernel and jnp paths agree on interior blocks (they differ only in
    border policy: the kernel clamps horizontal offsets, warp_blocks
    edge-pads — both valid codec conventions)."""
    from repro.core.quality_transfer import transfer_frame
    H, W = 64, 96
    ks = jax.random.split(KEY, 3)
    anchor = jax.random.uniform(ks[0], (H, W), jnp.float32) * 255
    mv = jax.random.randint(ks[1], (H // 16, W // 16, 2), -8, 9, jnp.int32)
    resid = jax.random.normal(ks[2], (H, W), jnp.float32) * 4
    a = transfer_frame(anchor, mv, resid, use_kernel=False)
    b = transfer_frame(anchor, mv, resid, use_kernel=True)
    np.testing.assert_allclose(np.asarray(a)[16:-16, 16:-16],
                               np.asarray(b)[16:-16, 16:-16], atol=1e-3)
