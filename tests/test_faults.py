"""Chaos-harness unit tests: fault-schedule determinism and window
semantics, preset shapes, the vectorized AR(1) trace's tolerance contract
against the loop reference, and the fault-profile composition hook.
"""
import numpy as np
import pytest

from repro.serving.faults import (DISRUPTIVE_KINDS, FAULT_KINDS, FaultEvent,
                                  FaultSchedule, PRESETS, preset_schedule)
from repro.sim.network import (TraceConfig, apply_fault_profile,
                               generate_trace, generate_trace_loop)


# ----------------------------------------------------------- fault events
def test_fault_event_validation():
    with pytest.raises(ValueError, match="unknown fault kind"):
        FaultEvent("meteor", 0, 4)
    with pytest.raises(ValueError, match="ends before it starts"):
        FaultEvent("outage", 5, 3)
    with pytest.raises(ValueError, match="magnitude"):
        FaultEvent("bw_collapse", 0, 4, magnitude=-0.5)


def test_fault_windows_are_half_open():
    e = FaultEvent("stall", 3, 6, target=0)
    assert not e.active(2) and e.active(3) and e.active(5) \
        and not e.active(6)


# -------------------------------------------------------------- schedules
def test_bw_multiplier_composes_collapse_and_outage():
    s = FaultSchedule([FaultEvent("bw_collapse", 0, 10, magnitude=0.5),
                       FaultEvent("outage", 5, 7, magnitude=0.1)])
    assert s.bw_multiplier(0) == 0.5
    assert s.bw_multiplier(5) == pytest.approx(0.05)
    assert s.bw_multiplier(10) == 1.0
    np.testing.assert_allclose(
        s.bw_multipliers(11),
        [0.5] * 5 + [0.05, 0.05] + [0.5] * 3 + [1.0])


def test_churn_leave_join_stall_semantics():
    s = FaultSchedule([FaultEvent("leave", 4, 8, target=1),
                       FaultEvent("join", 6, 100, target=2),
                       FaultEvent("stall", 2, 3, target=0)])
    # stream 1 leaves over [4, 8) and rejoins at 8
    assert s.stream_active(1, 3) and not s.stream_active(1, 4)
    assert not s.stream_active(1, 7) and s.stream_active(1, 8)
    # stream 2 is absent UNTIL its join point
    assert not s.stream_active(2, 0) and not s.stream_active(2, 5)
    assert s.stream_active(2, 6)
    # stream 0 is always active but stalled for exactly one chunk
    assert s.stream_active(0, 2) and s.stalled(0, 2)
    assert not s.stalled(0, 3) and not s.stalled(1, 2)
    np.testing.assert_array_equal(s.active_mask(5, 3),
                                  [True, False, False])


def test_shard_slowdown_takes_worst_active_event():
    s = FaultSchedule([FaultEvent("shard_slow", 0, 5, target=1,
                                  magnitude=4.0),
                       FaultEvent("shard_slow", 2, 4, target=-1,
                                  magnitude=8.0)])
    assert s.shard_slowdown(1, 0) == 4.0
    assert s.shard_slowdown(1, 3) == 8.0     # worst wins, not product
    assert s.shard_slowdown(0, 3) == 8.0     # target -1 hits every shard
    assert s.shard_slowdown(0, 0) == 1.0     # healthy floor


def test_loss_coins_are_deterministic_and_seed_sensitive():
    ev = [FaultEvent("chunk_loss", 0, 50, magnitude=0.5)]
    a, b = FaultSchedule(ev, seed=7), FaultSchedule(ev, seed=7)
    other = FaultSchedule(ev, seed=8)
    flips_a = [a.chunk_lost(c, t) for c in range(3) for t in range(50)]
    flips_b = [b.chunk_lost(c, t) for c in range(3) for t in range(50)]
    flips_o = [other.chunk_lost(c, t) for c in range(3) for t in range(50)]
    assert flips_a == flips_b                 # replayable
    assert flips_a != flips_o                 # seed actually matters
    frac = np.mean(flips_a)
    assert 0.3 < frac < 0.7                   # coins track the probability
    # query order cannot change an answer (stateless draws)
    assert a.chunk_lost(2, 49) == flips_a[-1]


def test_loss_magnitude_one_defeats_retries():
    s = FaultSchedule([FaultEvent("chunk_loss", 0, 5, magnitude=1.0)])
    assert all(s.chunk_lost(0, t) for t in range(5))
    assert not any(s.retry_succeeds(0, t, k)
                   for t in range(5) for k in range(4))
    # outside the window nothing is lost and retries always succeed
    assert not s.chunk_lost(0, 5)
    assert s.retry_succeeds(0, 5, 0)


def test_disruption_mask_covers_disruptive_kinds_only():
    s = FaultSchedule([FaultEvent("join", 0, 4, target=1),
                       FaultEvent("outage", 6, 8, magnitude=0.1)])
    m = s.disruption_mask(10)
    assert not m[:6].any() and m[6] and m[7] and not m[8:].any()
    assert "join" not in DISRUPTIVE_KINDS
    assert set(FAULT_KINDS) - DISRUPTIVE_KINDS == {"join"}


# ---------------------------------------------------------------- presets
@pytest.mark.parametrize("name", PRESETS)
def test_presets_build_and_fit_horizon(name):
    s = preset_schedule(name, n_chunks=24, n_streams=3, n_shards=2, seed=0)
    assert s.events and s.horizon() <= 24
    assert all(e.kind in FAULT_KINDS for e in s.events)
    # deterministic construction
    s2 = preset_schedule(name, n_chunks=24, n_streams=3, n_shards=2, seed=0)
    assert s.events == s2.events


def test_preset_errors():
    with pytest.raises(KeyError, match="unknown preset"):
        preset_schedule("nope", n_chunks=24)
    with pytest.raises(ValueError, match="n_chunks"):
        preset_schedule("bw-collapse", n_chunks=4)


# ------------------------------------------- vectorized trace (satellite)
@pytest.mark.parametrize("ar", [0.0, 0.1, 0.5, 0.9, 0.99, -0.7])
def test_generate_trace_matches_loop_reference(ar):
    """Documented-tolerance contract: the blocked cumulative AR(1) form
    agrees with the step-by-step recurrence to fp rounding (both consume
    identical batched draws)."""
    cfg = TraceConfig(ar=ar, seed=3)
    vec = generate_trace(cfg, 4000)
    loop = generate_trace_loop(cfg, 4000)
    np.testing.assert_allclose(vec, loop, rtol=1e-12)


def test_generate_trace_marginals_and_floor():
    cfg = TraceConfig(mean_kbps=16000.0, floor_kbps=1000.0, seed=0)
    bw = generate_trace(cfg, 20000)
    assert bw.min() >= cfg.floor_kbps
    # log-normal correction keeps the mean near mean_kbps (drops pull the
    # observed mean slightly below)
    assert 0.8 * cfg.mean_kbps < bw.mean() < 1.1 * cfg.mean_kbps


def test_generate_trace_rejects_unstable_ar():
    with pytest.raises(ValueError, match=r"\|ar\| < 1"):
        generate_trace(TraceConfig(ar=1.0), 10)


def test_apply_fault_profile():
    trace = np.full(6, 8000.0)
    mult = np.asarray([1.0, 0.5, 0.0, 1.0, 2.0, 1.0])
    out = apply_fault_profile(trace, mult)
    np.testing.assert_allclose(out, [8000.0, 4000.0, 1.0, 8000.0,
                                     16000.0, 8000.0])
    with pytest.raises(ValueError, match="mismatch"):
        apply_fault_profile(trace, mult[:3])
    with pytest.raises(ValueError, match=">= 0"):
        apply_fault_profile(trace, -mult)


def test_schedule_profile_composes_onto_trace():
    s = FaultSchedule([FaultEvent("outage", 2, 4, magnitude=0.001)])
    trace = generate_trace(TraceConfig(seed=1), 6)
    out = apply_fault_profile(trace, s.bw_multipliers(6))
    assert (out[2:4] < trace[2:4] * 0.01).all()
    np.testing.assert_array_equal(out[:2], trace[:2])
    np.testing.assert_array_equal(out[4:], trace[4:])
