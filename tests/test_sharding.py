"""Distribution layer: logical->mesh rules, divisibility demotion,
param-spec consistency across the whole zoo (property-based)."""
import jax
import pytest
from hypothesis import given, settings, strategies as st
from jax.sharding import PartitionSpec as P

from repro.configs import ARCH_IDS, get_arch
from repro.distributed.sharding import (MULTI_POD_RULES, SINGLE_POD_RULES,
                                        logical_to_spec, validated_spec)
from repro.launch.steps import _specs_tree
from repro.models.params import ParamSpec, is_spec, param_count


def test_rule_tables():
    assert SINGLE_POD_RULES.mesh_axes("batch") == ("data",)
    assert MULTI_POD_RULES.mesh_axes("batch") == ("pod", "data")
    assert SINGLE_POD_RULES.mesh_axes(None) == ()
    assert SINGLE_POD_RULES.mesh_axes("unknown_axis") == ()


def test_logical_to_spec_strips_trailing_nones():
    spec = logical_to_spec(("batch", None, None), SINGLE_POD_RULES)
    assert spec == P("data")
    spec = logical_to_spec(("batch", None, "tensor"), MULTI_POD_RULES)
    assert spec == P(("pod", "data"), None, "model")


def test_validated_spec_demotes_indivisible():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    # 7 not divisible by any >1 axis -> replicated, but 1-sized axes pass
    spec = validated_spec(P("data", "model"), (7, 8), mesh)
    assert spec == P("data", "model")       # both axes are size 1 here


@settings(deadline=None, max_examples=30)
@given(dims=st.lists(st.integers(1, 64), min_size=1, max_size=4))
def test_param_spec_shape_axes_equal_rank(dims):
    s = ParamSpec(tuple(dims), tuple([None] * len(dims)))
    assert len(s.shape) == len(s.axes)


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_zoo_param_specs_well_formed(arch_id):
    """Every ParamSpec in every (full-size) arch has rank-matched axes and
    only known logical names."""
    arch = get_arch(arch_id)
    known = {None, "batch", "fsdp", "tensor", "seq_kv", "expert"}
    tree = _specs_tree(arch)
    leaves = jax.tree.leaves(tree, is_leaf=is_spec)
    assert len(leaves) > 0
    for s in leaves:
        assert isinstance(s, ParamSpec)
        assert len(s.shape) == len(s.axes)
        assert set(s.axes) <= known


def test_published_param_counts():
    """Sanity-check the zoo against published parameter counts."""
    expect = {
        "llama3_2_1b": (1.2e9, 1.4e9),
        "chatglm3_6b": (5.5e9, 7.0e9),
        "qwen2_moe_a2_7b": (13.0e9, 15.5e9),   # total (incl. all experts)
        "mixtral_8x22b": (135e9, 145e9),
        "dit_xl2": (0.6e9, 0.72e9),
        "dit_b2": (0.12e9, 0.16e9),
        "resnet_50": (2.2e7, 2.9e7),
        "resnet_152": (5.5e7, 6.8e7),
        "convnext_b": (0.8e8, 1.0e8),
        "vit_b16": (0.8e8, 1.0e8),
    }
    for arch_id, (lo, hi) in expect.items():
        arch = get_arch(arch_id)
        tree = _specs_tree(arch)
        if arch_id.startswith("resnet"):
            n = param_count(tree["params"])
        else:
            n = param_count(tree)
        assert lo <= n <= hi, f"{arch_id}: {n:.3e} outside [{lo:.1e},{hi:.1e}]"


def test_mixtral_active_params():
    cfg = get_arch("mixtral_8x22b").cfg
    active = cfg.active_param_count()
    assert 36e9 <= active <= 42e9             # ~39B active (top-2 of 8)
