"""Per-architecture smoke tests: every assigned arch instantiates a REDUCED
config of the same family and runs one forward/train step on CPU,
asserting output shapes + no NaNs.  (Full configs are exercised only via
the zero-allocation dry-run.)"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, ShapeCase, get_arch
from repro.launch.steps import build_cell, materialize

KEY = jax.random.PRNGKey(0)


def _case_for(arch):
    if arch.family == "lm":
        return ShapeCase("smoke", "train", batch=2, seq_len=64)
    if arch.family == "diffusion":
        return ShapeCase("smoke", "train", batch=2, img_res=32)
    return ShapeCase("smoke", "train", batch=2, img_res=32)


def _assert_finite(tree):
    for leaf in jax.tree.leaves(tree):
        if hasattr(leaf, "dtype") and jnp.issubdtype(leaf.dtype,
                                                     jnp.floating):
            assert not np.any(np.isnan(np.asarray(leaf, np.float32)))


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_train_step_smoke(arch_id):
    arch = get_arch(arch_id, reduced=True)
    case = _case_for(arch)
    cell = build_cell(arch, case)
    args = materialize(KEY, arch, case)
    state, metrics = jax.jit(cell.fn)(*args)
    assert float(metrics["loss"]) > 0
    _assert_finite(metrics)
    _assert_finite(state["params"])


@pytest.mark.parametrize("arch_id", ["llama3_2_1b", "qwen2_moe_a2_7b",
                                     "mixtral_8x22b", "chatglm3_6b"])
def test_lm_decode_smoke(arch_id):
    arch = get_arch(arch_id, reduced=True)
    case = ShapeCase("smoke", "decode", batch=2, seq_len=64)
    cell = build_cell(arch, case)
    args = materialize(KEY, arch, case)
    logits, cache = jax.jit(cell.fn)(*args)
    assert logits.shape == (2, 1, arch.cfg.vocab)
    _assert_finite(logits)


def test_lm_prefill_then_decode_consistent():
    """Prefill cache + one decode step == forward over the full sequence."""
    from repro.models import transformer_lm as M
    from repro.models.params import init_params
    arch = get_arch("llama3_2_1b", reduced=True)
    cfg = arch.cfg
    params = init_params(KEY, M.param_specs(cfg))
    S = 16
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, S + 1), 0,
                              cfg.vocab, jnp.int32)
    full_logits, _, _ = M.forward(params, cfg, toks)
    # prefill on the first S tokens, then decode token S
    _, kv = M.prefill_step(params, cfg, toks[:, :S])
    Sc = M.cache_len(cfg, S + 1)
    cache = {
        "k": jnp.zeros((cfg.n_layers, 1, Sc, cfg.n_kv_heads, cfg.head_dim),
                       jnp.bfloat16).at[:, :, :S].set(
                           kv[0].astype(jnp.bfloat16)[:, :, :S]),
        "v": jnp.zeros((cfg.n_layers, 1, Sc, cfg.n_kv_heads, cfg.head_dim),
                       jnp.bfloat16).at[:, :, :S].set(
                           kv[1].astype(jnp.bfloat16)[:, :, :S]),
        "slot_pos": jnp.concatenate([jnp.arange(S, dtype=jnp.int32),
                                     jnp.full((Sc - S,), -1, jnp.int32)]),
    }
    logits, _ = M.decode_step(params, cfg, cache, toks[:, S:S + 1],
                              jnp.asarray(S, jnp.int32))
    a = np.asarray(jax.nn.softmax(full_logits[:, -1], -1))
    b = np.asarray(jax.nn.softmax(logits[:, 0], -1))
    np.testing.assert_allclose(a, b, atol=0.06)


def test_moe_paths_agree():
    """sorted-dispatch and gathered-expert MoE agree (no dropping)."""
    from repro.models import layers as L
    key = jax.random.PRNGKey(0)
    T, d, E, k, f = 64, 16, 8, 2, 32
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (T, d), jnp.float32) * 0.5
    wr = jax.random.normal(ks[1], (d, E), jnp.float32) * 0.1
    w1 = jax.random.normal(ks[2], (E, d, f), jnp.float32) * 0.1
    w3 = jax.random.normal(ks[3], (E, d, f), jnp.float32) * 0.1
    w2 = jax.random.normal(ks[4], (E, f, d), jnp.float32) * 0.1
    moe = L.MoEConfig(n_experts=E, top_k=k, capacity_factor=8.0)  # no drops
    o1, _ = L.moe_sorted_dispatch(x, wr, w1, w3, w2, moe)
    o2, _ = L.moe_gathered_experts(x, wr, w1, w3, w2, moe)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=5e-2)


def test_rope_fraction_partial_rotation():
    from repro.models.layers import apply_rope
    x = jax.random.normal(KEY, (1, 8, 2, 16), jnp.float32)
    pos = jnp.arange(8)
    full = apply_rope(x, pos, fraction=1.0)
    half = apply_rope(x, pos, fraction=0.5)
    # the un-rotated second half passes through unchanged
    np.testing.assert_allclose(np.asarray(half[..., 8:]),
                               np.asarray(x[..., 8:]), atol=1e-6)
    assert not np.allclose(np.asarray(full[..., 8:]),
                           np.asarray(x[..., 8:]), atol=1e-3)


def test_swa_matches_chunked_when_window_covers_seq():
    from repro.models import layers as L
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (1, 64, 4, 16), jnp.bfloat16)
    k = jax.random.normal(ks[1], (1, 64, 2, 16), jnp.bfloat16)
    v = jax.random.normal(ks[2], (1, 64, 2, 16), jnp.bfloat16)
    full = L.chunked_attention(q, k, v, causal=True, chunk=32)
    swa = L.swa_attention(q, k, v, window=64, q_block=16)
    np.testing.assert_allclose(np.asarray(full, np.float32),
                               np.asarray(swa, np.float32), atol=0.05)
