"""Dry-run tooling: HLO collective parser + roofline model-FLOPs math.

These run without the 512-device env (pure string / arithmetic units).
"""
import sys

import pytest


def _parser():
    # import the module without triggering its XLA_FLAGS side effect twice
    # (safe here: flags only matter before first jax init, and tests run
    # on the 1-device platform regardless)
    import importlib
    import os
    saved = os.environ.get("XLA_FLAGS")
    mod = importlib.import_module("repro.launch.dryrun")
    if saved is None:
        os.environ.pop("XLA_FLAGS", None)
    else:
        os.environ["XLA_FLAGS"] = saved
    return mod


HLO = """
ENTRY main {
  %p0 = bf16[8,1024,512]{2,1,0} parameter(0)
  %ag = bf16[8,1024,8192]{2,1,0} all-gather(%p0), replica_groups=[16,16]<=[256], dimensions={2}
  %ar = f32[1024,1024]{1,0} all-reduce(%x), replica_groups={{0,1,2,3},{4,5,6,7}}, to_apply=%add
  %rs = bf16[8,64]{1,0} reduce-scatter(%y), replica_groups=[2,8]<=[16], dimensions={1}
  %cp = f32[128]{0} collective-permute(%z), source_target_pairs={{0,1}}
  %ard = f32[4]{0} all-reduce-done(%w)
  %ars = f32[2,2]{1,0} all-reduce-start(%v), replica_groups=[1,4]<=[4]
}
"""


def test_parse_collectives_ops_and_groups():
    dr = _parser()
    out = dr.parse_collectives(HLO, n_devices=256)
    wire = out.pop("_total_wire_bytes")
    assert out["all-gather"]["count"] == 1
    # output 8*1024*8192*2 bytes; group 16 -> wire = out*(15/16)
    ag_out = 8 * 1024 * 8192 * 2
    assert out["all-gather"]["output_bytes"] == ag_out
    assert out["all-gather"]["wire_bytes"] == pytest.approx(
        ag_out * 15 / 16)
    # all-reduce: explicit groups of 4 -> 2*out*(3/4); -start counted,
    # -done skipped
    assert out["all-reduce"]["count"] == 2
    ar_out = 1024 * 1024 * 4 + 2 * 2 * 4
    assert out["all-reduce"]["output_bytes"] == ar_out
    # reduce-scatter group 8: wire = out*(8-1)
    rs_out = 8 * 64 * 2
    assert out["reduce-scatter"]["wire_bytes"] == pytest.approx(rs_out * 7)
    assert out["collective-permute"]["wire_bytes"] == 128 * 4
    assert wire > 0


def test_model_flops_formulas():
    sys.path.insert(0, "benchmarks")
    from benchmarks.roofline import model_flops_global
    # llama train: 6 * N * tokens
    mf = model_flops_global("llama3_2_1b", "train_4k")
    from repro.configs import get_arch
    n = get_arch("llama3_2_1b").cfg.param_count()
    assert mf == pytest.approx(6.0 * n * 256 * 4096, rel=1e-6)
    # decode: 2 * N_active * batch
    mfd = model_flops_global("mixtral_8x22b", "decode_32k")
    na = get_arch("mixtral_8x22b").cfg.active_param_count()
    assert mfd == pytest.approx(2.0 * na * 128, rel=1e-6)
