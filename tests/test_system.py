"""End-to-end behaviour tests for BiSwift (the paper's claims, reduced).

These validate the *system-level* properties the paper reports:
  * the hybrid codec path beats pure-video delivery at equal bandwidth
    (Fig. 13a direction),
  * analytics-aware allocation beats even allocation for heterogeneous
    streams (Fig. 13 / Insight #3),
  * the reuse pipeline gives the expected throughput headroom (Fig. 8b),
  * multi-policy comparison ranks BiSwift first (Fig. 11/14 direction).
"""
import jax
import numpy as np

from repro.baselines.policies import BASELINES, run_biswift
from repro.sim.env import EnvConfig, MultiStreamEnv, analytic_f1
from repro.sim.network import even_allocation
from repro.sim.video_source import generate_chunk, paper_stream_mix

KEY = jax.random.PRNGKey(0)


def _streams_and_chunks(n=2, T=8):
    mix = paper_stream_mix(n, 64, 96)
    out = []
    for sc in mix:
        out.append((sc, *generate_chunk(KEY, sc, 0, T)))
    return out


def test_hybrid_beats_pure_video_at_low_bandwidth():
    """BiSwift's HD anchors recover accuracy a pure LR stream cannot."""
    (sc, frames, boxes, valid) = _streams_and_chunks(2)[1]  # dense stream
    frames, boxes, valid = map(np.asarray, (frames, boxes, valid))
    bw = 1500.0
    bs = run_biswift(frames, boxes, valid, bw, sc)
    # pure video: same ladder, no anchors -> every frame at LR quality
    from repro.codec.rate_model import QUALITY_LADDER, ladder_for_bandwidth
    ql = QUALITY_LADDER[ladder_for_bandwidth(bw)]
    obj = float(boxes[0, :, 2:].mean())
    n = int(valid[0].sum())
    pure = np.mean([analytic_f1(ql.scale, ql.quality, obj, n, 2, 0.0,
                                sc.speed) for _ in range(frames.shape[0])])
    assert bs["accuracy"] > pure + 0.02


def test_analytics_aware_allocation_beats_even():
    """Giving the dense-small stream more bandwidth raises min accuracy.

    Evaluated in the contended regime (1 Mbps/stream even split — the
    paper's 9-streams-on-8/16-Mbps operating point); above ~1.2 Mbps per
    stream both ladders saturate and the allocations tie."""
    data = _streams_and_chunks(2)
    total = 2000.0
    even = even_allocation(total, 2)
    res_even = [run_biswift(np.asarray(f), np.asarray(b), np.asarray(v),
                            even[i], sc)
                for i, (sc, f, b, v) in enumerate(data)]
    # analytics-aware: dense stream (idx 1) gets 70%
    aware = np.asarray([0.3 * total, 0.7 * total])
    res_aware = [run_biswift(np.asarray(f), np.asarray(b), np.asarray(v),
                             aware[i], sc)
                 for i, (sc, f, b, v) in enumerate(data)]
    assert min(r["accuracy"] for r in res_aware) > \
        min(r["accuracy"] for r in res_even)


def test_reuse_throughput_headroom():
    """Per-frame reuse (~6 ms) vs inference (~33 ms) -> >3x frame headroom
    when >80% of frames take pipeline 3 (paper Fig. 8b)."""
    (sc, frames, boxes, valid) = _streams_and_chunks(1, T=16)[0]
    frames, boxes, valid = map(np.asarray, (frames, boxes, valid))
    r = run_biswift(frames, boxes, valid, 8000.0, sc, tr1=0.4, tr2=0.5)
    per_frame_all_infer = 0.033
    speedup = per_frame_all_infer * 16 / max(r["t_comp"], 1e-9)
    assert speedup > 3.0


def test_biswift_ranks_first_among_policies():
    data = _streams_and_chunks(2)
    accs = {}
    for name, fn in BASELINES.items():
        per_stream = [fn(np.asarray(f), np.asarray(b), np.asarray(v),
                         4000.0, sc) for (sc, f, b, v) in data]
        accs[name] = np.mean([r["accuracy"] for r in per_stream])
    best = max(accs, key=accs.get)
    assert best == "biswift", accs


def test_env_queue_backpressure():
    cfg = EnvConfig(streams=tuple(paper_stream_mix(2, 64, 96)),
                    chunk_frames=4, gpu_capacity_fps=10.0)
    env = MultiStreamEnv(cfg)
    props = np.asarray([0.5, 0.5])
    thr = np.zeros((2, 2), np.float32)      # tr=0 -> everything inferred
    for i in range(3):
        results, info = env.step(props, thr)
    assert info["queue_delay"] > 0.0         # backlog accumulates
