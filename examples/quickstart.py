"""Quickstart: one chunk through the full BiSwift pipeline on CPU.

    PYTHONPATH=src python examples/quickstart.py

Camera -> hybrid encoder (ladder + Eq.3 classification + JPEG anchors) ->
edge hybrid decoder (3 pipelines) -> detections + accuracy + latency.
"""
import jax
import numpy as np

from repro.core.hybrid_decoder import decode_and_execute
from repro.core.hybrid_encoder import encode_hybrid
from repro.models import detection as D
from repro.sim.video_source import StreamConfig, generate_chunk


def main():
    key = jax.random.PRNGKey(0)
    stream = StreamConfig(height=64, width=96, n_objects=3, min_size=16,
                          max_size=26)
    frames, boxes, valid = generate_chunk(key, stream, t0=0, n_frames=6)
    print(f"camera: {frames.shape[0]} frames @ {frames.shape[1]}x"
          f"{frames.shape[2]}, {int(valid[0].sum())} objects")

    det_cfg = D.TinyDetectorConfig()
    params = D.init(jax.random.PRNGKey(1), det_cfg)

    for bw_kbps in (1500.0, 8000.0):
        packet = encode_hybrid(np.asarray(frames), bw_kbps, tr1=0.05,
                               tr2=0.10)
        res = decode_and_execute(packet, params, det_cfg,
                                 np.asarray(boxes), np.asarray(valid),
                                 bw_kbps=bw_kbps)
        frac = {k: int((packet.types == k).sum()) for k in (1, 2, 3)}
        print(f"\nbw={bw_kbps:.0f} kbps -> ladder level "
              f"{packet.ladder_level}, anchors q={packet.anchor_quality}")
        print(f"  pipelines (1:anchor 2:transfer 3:reuse): {frac}")
        print(f"  bits: video {packet.video_bits / 1e3:.0f}k + anchors "
              f"{packet.anchor_bits / 1e3:.0f}k")
        print(f"  latency: {res.latency * 1e3:.1f} ms "
              f"(trans {res.t_trans * 1e3:.1f} + comp "
              f"{res.t_comp * 1e3:.1f})")
        print(f"  F1 (untrained detector, see train_detector.py): "
              f"{res.mean_f1:.3f}")


if __name__ == "__main__":
    main()
