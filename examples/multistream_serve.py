"""Multi-stream serving example (the paper's headline scenario).

    PYTHONPATH=src python examples/multistream_serve.py --streams 4

Runs the full edge runtime — hybrid codec, 3 pipelines with batched DNN
execution, admission control, bandwidth allocation — over a shared FCC-
style uplink.  See src/repro/launch/serve.py for the flag set.
"""
import sys

from repro.launch.serve import main

if __name__ == "__main__":
    main(sys.argv[1:] or ["--streams", "4", "--chunks", "4"])
