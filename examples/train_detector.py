"""End-to-end training driver: train the detection DNN on synthetic
surveillance streams for a few hundred steps, with checkpointing.

    PYTHONPATH=src python examples/train_detector.py --steps 300

This is the 'train a ~100M model for a few hundred steps'-class driver
scaled to the CPU container (TinyDetector ~30k params; swap in any vision
backbone from src/repro/configs for the full-size path — see
launch/train.py and the dry-run for the production mesh versions).
"""
import argparse
import time

import jax
import numpy as np

from repro.models import detection as D
from repro.sim.video_source import StreamConfig, generate_chunk
from repro.train import checkpoint as CKPT
from repro.train.optimizer import AdamWConfig, apply_updates, init_state


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--ckpt-dir", default="/tmp/biswift_detector")
    ap.add_argument("--eval-every", type=int, default=100)
    args = ap.parse_args()

    key = jax.random.PRNGKey(0)
    cfg = D.TinyDetectorConfig()
    params = D.init(key, cfg)
    opt = init_state(params)
    ocfg = AdamWConfig(lr=3e-3, weight_decay=0.0, warmup_steps=20,
                       total_steps=args.steps)
    streams = [
        StreamConfig(height=64, width=96, n_objects=2, min_size=16,
                     max_size=28, seed=7),
        StreamConfig(height=64, width=96, n_objects=5, min_size=12,
                     max_size=20, seed=8, speed=2.5),
    ]

    @jax.jit
    def step(params, opt, frames, boxes, valid):
        loss, g = jax.value_and_grad(
            lambda p: D.loss_fn(p, cfg, frames, boxes, valid))(params)
        params, opt, m = apply_updates(params, g, opt, ocfg)
        return params, opt, loss

    nms = jax.jit(lambda b, s: D.greedy_nms(b, s, iou_thresh=0.4, top_k=16))

    def evaluate(params):
        f1s = []
        for sc in streams:
            frames, boxes, valid = generate_chunk(key, sc, 50_000, 4)
            raw = D.forward(params, cfg, frames)
            pb, ps = D.decode_boxes(raw, cfg)
            for i in range(4):
                bb, ss = nms(pb[i], ps[i])
                f1s.append(float(D.f1_score(bb, ss, boxes[i], valid[i])))
        return float(np.mean(f1s))

    print(f"initial F1: {evaluate(params):.3f}")
    t0 = time.time()
    for i in range(args.steps):
        sc = streams[i % len(streams)]
        frames, boxes, valid = generate_chunk(key, sc, i * 4, 4)
        params, opt, loss = step(params, opt, frames, boxes, valid)
        if (i + 1) % args.eval_every == 0:
            f1 = evaluate(params)
            print(f"step {i + 1}: loss {float(loss):.4f}  F1 {f1:.3f}  "
                  f"({(i + 1) / (time.time() - t0):.1f} steps/s)")
            CKPT.save(args.ckpt_dir, i + 1, params)
    print(f"checkpoints in {args.ckpt_dir}: steps {CKPT.all_steps(args.ckpt_dir)}")


if __name__ == "__main__":
    main()
