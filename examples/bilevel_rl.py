"""Bi-level DRL training (paper §V): the low-level A2C agents and the
high-level SAC bandwidth controller trained jointly on the multi-stream
environment.

    PYTHONPATH=src python examples/bilevel_rl.py --chunks 60
"""
import argparse

import numpy as np

from repro.core.bilevel import BiLevelTrainer
from repro.sim.env import EnvConfig
from repro.sim.video_source import paper_stream_mix


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--streams", type=int, default=4)
    ap.add_argument("--chunks", type=int, default=60)
    ap.add_argument("--chunk-frames", type=int, default=4)
    ap.add_argument("--mode", choices=("stacked", "loop"), default="stacked",
                    help="fused single-jit control plane (default) or the "
                         "per-stream loop oracle — same numbers, "
                         "bit-for-bit (docs/bilevel.md)")
    args = ap.parse_args()

    cfg = EnvConfig(streams=tuple(paper_stream_mix(args.streams, 64, 96)),
                    chunk_frames=args.chunk_frames)
    trainer = BiLevelTrainer.create(cfg, seed=0)
    if args.mode == "loop":
        hist = [trainer.run_chunk_loop()[0] for _ in range(args.chunks)]
    else:
        hist = trainer.train_steps(args.chunks)

    k = max(args.chunks // 6, 1)
    print("chunk | mean_acc | min_acc | reward_min | jain | util")
    for i in range(0, len(hist), k):
        m = hist[i]
        print(f"{i:5d} | {m['mean_acc']:.3f}    | {m['min_acc']:.3f}   | "
              f"{m['reward_min']:+.3f}     | {m['jain']:.3f} | "
              f"{m['utilization']:.2f}")
    first = np.mean([m["reward_min"] for m in hist[: len(hist) // 3]])
    last = np.mean([m["reward_min"] for m in hist[-len(hist) // 3:]])
    print(f"\nmin-stream reward: first third {first:+.3f} -> "
          f"last third {last:+.3f}")


if __name__ == "__main__":
    main()
