"""convnext-b: depths 3-3-27-3, dims 128-256-512-1024 [arXiv:2201.03545]."""
from repro.configs import ArchSpec, vision_shapes
from repro.models.convnext import ConvNeXtConfig


def build() -> ArchSpec:
    cfg = ConvNeXtConfig(name="convnext-b", depths=(3, 3, 27, 3),
                         dims=(128, 256, 512, 1024))
    return ArchSpec("convnext_b", "vision", cfg, vision_shapes(),
                    source="arXiv:2201.03545")


def build_reduced() -> ArchSpec:
    cfg = ConvNeXtConfig(name="convnext-b-reduced", depths=(1, 1, 2, 1),
                         dims=(16, 32, 64, 128), n_classes=10)
    return ArchSpec("convnext_b", "vision", cfg, vision_shapes())
