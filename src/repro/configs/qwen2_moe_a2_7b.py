"""qwen2-moe-a2.7b: 24L d2048 16H(kv=16) expert_ff 1408 vocab 151936,
60 routed experts top-4 + 4 shared (fused shared width 5632)
[hf:Qwen/Qwen1.5-MoE-A2.7B]."""
from repro.configs import ArchSpec, lm_shapes
from repro.models.layers import MoEConfig
from repro.models.transformer_lm import LMConfig


def build() -> ArchSpec:
    cfg = LMConfig(
        name="qwen2-moe-a2.7b",
        n_layers=24, d_model=2048, n_heads=16, n_kv_heads=16,
        d_ff=1408, vocab=151936,
        moe=MoEConfig(n_experts=60, top_k=4, norm_topk=True),
        d_ff_shared=5632,
        qkv_bias=True,
        rope_theta=1000000.0,
    )
    return ArchSpec("qwen2_moe_a2_7b", "lm", cfg, lm_shapes(cfg.sub_quadratic),
                    source="hf:Qwen/Qwen1.5-MoE-A2.7B")


def build_reduced() -> ArchSpec:
    cfg = LMConfig(
        name="qwen2-moe-a2.7b-reduced",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=32,
        vocab=256, moe=MoEConfig(n_experts=8, top_k=2, norm_topk=True),
        d_ff_shared=64, qkv_bias=True, remat=False, attn_chunk=32,
        q_block=32,
    )
    return ArchSpec("qwen2_moe_a2_7b", "lm", cfg, lm_shapes(cfg.sub_quadratic))
