"""dit-b2: img_res 256, patch 2, 12L d768 12H [arXiv:2212.09748]."""
from repro.configs import ArchSpec, diffusion_shapes
from repro.models.dit import DiTConfig


def build() -> ArchSpec:
    cfg = DiTConfig(name="dit-b2", img_res=256, patch=2, n_layers=12,
                    d_model=768, n_heads=12)
    return ArchSpec("dit_b2", "diffusion", cfg, diffusion_shapes(),
                    source="arXiv:2212.09748")


def build_reduced() -> ArchSpec:
    cfg = DiTConfig(name="dit-b2-reduced", img_res=32, patch=2, n_layers=2,
                    d_model=48, n_heads=4, n_classes=10, remat=False,
                    max_latent=8)
    return ArchSpec("dit_b2", "diffusion", cfg, diffusion_shapes())
