"""chatglm3-6b: 28L d4096 32H GQA(kv=2) d_ff 13696 vocab 65024; 2d RoPE
[arXiv:2406.12793; hf].  GLM's "2d rope" rotates half of each head dim."""
from repro.configs import ArchSpec, lm_shapes
from repro.models.transformer_lm import LMConfig


def build() -> ArchSpec:
    cfg = LMConfig(
        name="chatglm3-6b",
        n_layers=28, d_model=4096, n_heads=32, n_kv_heads=2,
        d_ff=13696, vocab=65024,
        rope_fraction=0.5, rope_theta=10000.0,
    )
    return ArchSpec("chatglm3_6b", "lm", cfg, lm_shapes(cfg.sub_quadratic),
                    source="arXiv:2406.12793")


def build_reduced() -> ArchSpec:
    cfg = LMConfig(
        name="chatglm3-6b-reduced",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=160,
        vocab=256, rope_fraction=0.5, rope_theta=10000.0, remat=False,
        attn_chunk=32, q_block=32,
    )
    return ArchSpec("chatglm3_6b", "lm", cfg, lm_shapes(cfg.sub_quadratic))
