"""dit-xl2: img_res 256, patch 2, 28L d1152 16H [arXiv:2212.09748]."""
from repro.configs import ArchSpec, diffusion_shapes
from repro.models.dit import DiTConfig


def build() -> ArchSpec:
    cfg = DiTConfig(name="dit-xl2", img_res=256, patch=2, n_layers=28,
                    d_model=1152, n_heads=16)
    return ArchSpec("dit_xl2", "diffusion", cfg, diffusion_shapes(),
                    source="arXiv:2212.09748")


def build_reduced() -> ArchSpec:
    cfg = DiTConfig(name="dit-xl2-reduced", img_res=32, patch=2, n_layers=2,
                    d_model=64, n_heads=4, n_classes=10, remat=False,
                    max_latent=8)
    return ArchSpec("dit_xl2", "diffusion", cfg, diffusion_shapes())
