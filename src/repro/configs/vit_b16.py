"""vit-b16: img 224, patch 16, 12L d768 12H d_ff 3072 [arXiv:2010.11929]."""
from repro.configs import ArchSpec, vision_shapes
from repro.models.vit import ViTConfig


def build() -> ArchSpec:
    cfg = ViTConfig(name="vit-b16", img_res=224, patch=16, n_layers=12,
                    d_model=768, n_heads=12, d_ff=3072)
    return ArchSpec("vit_b16", "vision", cfg, vision_shapes(),
                    source="arXiv:2010.11929")


def build_reduced() -> ArchSpec:
    cfg = ViTConfig(name="vit-b16-reduced", img_res=32, patch=8, n_layers=2,
                    d_model=64, n_heads=4, d_ff=128, n_classes=10,
                    remat=False, max_res=64)
    return ArchSpec("vit_b16", "vision", cfg, vision_shapes())
