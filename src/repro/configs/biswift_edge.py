"""The paper's own system configuration (§VI-A/§VI-B) as a config file:
edge server budgets, codec ladder, pipeline costs, and DRL shapes.

This is not one of the 10 assigned archs — it is BiSwift's deployable
edge profile, used by launch/serve.py and the benchmarks.
"""
from __future__ import annotations

import dataclasses

from repro.core.hybrid_decoder import PipelineCosts
from repro.models.detection import TinyDetectorConfig
from repro.serving.scheduler import ServingConfig
from repro.sim.env import EnvConfig
from repro.sim.network import TraceConfig
from repro.sim.video_source import paper_stream_mix


@dataclasses.dataclass(frozen=True)
class BiSwiftEdgeConfig:
    n_streams: int = 9                    # paper: 9 streams on one RTX-3070
    fps: float = 30.0
    chunk_seconds: float = 1.0
    controller_interval_s: float = 10.0   # bandwidth controller cadence
    latency_tau_s: float = 1.0            # Eq. 4 tolerance
    uplink_mbps: tuple = (8.0, 16.0)      # evaluated links (Fig. 13b)
    gpu_memory_gb: float = 8.0
    gpu_capacity_fps: float = 120.0
    costs: PipelineCosts = PipelineCosts()
    detector: TinyDetectorConfig = TinyDetectorConfig()
    # DRL shapes (§VI-B) live in repro.rl.{a2c,sac} defaults:
    #   low: A2C 2x128, lr .005/.01, gamma .9, alpha1=alpha2=.5
    #   high: SAC 4x256 policy / 3x256 value+Q, lr .001/.003/.0003,
    #         tau .02, gamma .9, buffer 1e4, minibatch 128


def build(n_streams: int = 9, height: int = 96, width: int = 160):
    cfg = BiSwiftEdgeConfig(n_streams=n_streams)
    env = EnvConfig(
        streams=tuple(paper_stream_mix(n_streams, height, width)),
        chunk_frames=int(cfg.fps * cfg.chunk_seconds),
        fps=cfg.fps,
        trace=TraceConfig(mean_kbps=cfg.uplink_mbps[1] * 1000),
        gpu_capacity_fps=cfg.gpu_capacity_fps,
        latency_tau=cfg.latency_tau_s,
        controller_interval=int(cfg.controller_interval_s
                                / cfg.chunk_seconds),
    )
    serving = ServingConfig(
        n_streams=n_streams, gpu_capacity_fps=cfg.gpu_capacity_fps,
        latency_budget=cfg.latency_tau_s,
        controller_interval=env.controller_interval,
    )
    return cfg, env, serving
