"""llama3.2-1b [hf:meta-llama/Llama-3.2-1B; unverified]."""
from repro.configs import ArchSpec, lm_shapes
from repro.models.transformer_lm import LMConfig


def build() -> ArchSpec:
    cfg = LMConfig(
        name="llama3.2-1b",
        n_layers=16, d_model=2048, n_heads=32, n_kv_heads=8,
        d_ff=8192, vocab=128256,
        rope_theta=500000.0,
    )
    return ArchSpec("llama3_2_1b", "lm", cfg, lm_shapes(cfg.sub_quadratic),
                    source="hf:meta-llama/Llama-3.2-1B")


def build_reduced() -> ArchSpec:
    cfg = LMConfig(
        name="llama3.2-1b-reduced",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
        vocab=256, rope_theta=500000.0, remat=False, attn_chunk=32,
        q_block=32,
    )
    return ArchSpec("llama3_2_1b", "lm", cfg, lm_shapes(cfg.sub_quadratic))
