"""Architecture registry: the 10 assigned archs + the paper's own edge config.

Each ``configs/<id>.py`` exposes ``build() -> ArchSpec`` with the exact
published configuration and ``build_reduced() -> ArchSpec`` for CPU smoke
tests.  Select with ``--arch <id>`` in the launchers.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Any, Optional


@dataclasses.dataclass(frozen=True)
class ShapeCase:
    name: str
    kind: str             # train | prefill | decode | sample | infer
    batch: int
    seq_len: int = 0      # LM shapes
    img_res: int = 0      # vision / diffusion shapes
    steps: int = 1        # diffusion sampler steps
    grad_accum: int = 1   # microbatches per step (activation memory control)
    skip: Optional[str] = None


@dataclasses.dataclass(frozen=True)
class ArchSpec:
    arch_id: str
    family: str           # lm | diffusion | vision
    cfg: Any
    shapes: dict[str, ShapeCase]
    source: str = ""


def lm_shapes(sub_quadratic: bool) -> dict[str, ShapeCase]:
    return {
        "train_4k": ShapeCase("train_4k", "train", batch=256, seq_len=4096,
                              grad_accum=8),
        "prefill_32k": ShapeCase("prefill_32k", "prefill", batch=32,
                                 seq_len=32768),
        "decode_32k": ShapeCase("decode_32k", "decode", batch=128,
                                seq_len=32768),
        "long_500k": ShapeCase(
            "long_500k", "decode", batch=1, seq_len=524288,
            skip=None if sub_quadratic else
            "pure full-attention arch: long_500k needs sub-quadratic "
            "attention (DESIGN.md §4)"),
    }


def diffusion_shapes() -> dict[str, ShapeCase]:
    return {
        "train_256": ShapeCase("train_256", "train", batch=256, img_res=256,
                               steps=1000),
        "gen_1024": ShapeCase("gen_1024", "sample", batch=4, img_res=1024,
                              steps=50),
        "gen_fast": ShapeCase("gen_fast", "sample", batch=16, img_res=512,
                              steps=4),
        "train_1024": ShapeCase("train_1024", "train", batch=32, img_res=1024,
                                steps=1000),
    }


def vision_shapes() -> dict[str, ShapeCase]:
    return {
        "cls_224": ShapeCase("cls_224", "train", batch=256, img_res=224),
        "cls_384": ShapeCase("cls_384", "train", batch=64, img_res=384),
        "serve_b1": ShapeCase("serve_b1", "infer", batch=1, img_res=224),
        "serve_b128": ShapeCase("serve_b128", "infer", batch=128, img_res=224),
    }


ARCH_IDS = [
    "llama3_2_1b", "chatglm3_6b", "qwen2_moe_a2_7b", "mixtral_8x22b",
    "dit_xl2", "dit_b2",
    "resnet_152", "resnet_50", "convnext_b", "vit_b16",
]

# dashes in the public ids map to underscores in module names
ALIASES = {i.replace("_", "-"): i for i in ARCH_IDS}
ALIASES.update({"llama3.2-1b": "llama3_2_1b", "qwen2-moe-a2.7b":
                "qwen2_moe_a2_7b", "mixtral-8x22b": "mixtral_8x22b",
                "dit-xl2": "dit_xl2", "dit-b2": "dit_b2",
                "resnet-152": "resnet_152", "resnet-50": "resnet_50",
                "convnext-b": "convnext_b", "vit-b16": "vit_b16",
                "chatglm3-6b": "chatglm3_6b"})


def get_arch(arch_id: str, reduced: bool = False) -> ArchSpec:
    arch_id = ALIASES.get(arch_id, arch_id)
    mod = importlib.import_module(f"repro.configs.{arch_id}")
    return mod.build_reduced() if reduced else mod.build()


def all_cells():
    """Yield every (arch_id, shape_name, skip_reason_or_None)."""
    for a in ARCH_IDS:
        spec = get_arch(a)
        for s in spec.shapes.values():
            yield a, s.name, s.skip
