"""mixtral-8x22b: 56L d6144 48H(kv=8) d_ff 16384 vocab 32768, 8 experts
top-2, sliding-window attention [arXiv:2401.04088].  SWA window 4096 ->
sub-quadratic; long_500k decode uses a window-sized ring KV cache."""
from repro.configs import ArchSpec, lm_shapes
from repro.models.layers import MoEConfig
from repro.models.transformer_lm import LMConfig


def build() -> ArchSpec:
    cfg = LMConfig(
        name="mixtral-8x22b",
        n_layers=56, d_model=6144, n_heads=48, n_kv_heads=8,
        d_ff=16384, vocab=32768, head_dim=128,
        moe=MoEConfig(n_experts=8, top_k=2, norm_topk=False),
        window=4096,
        rope_theta=1000000.0,
    )
    return ArchSpec("mixtral_8x22b", "lm", cfg, lm_shapes(cfg.sub_quadratic),
                    source="arXiv:2401.04088")


def build_reduced() -> ArchSpec:
    cfg = LMConfig(
        name="mixtral-8x22b-reduced",
        n_layers=2, d_model=64, n_heads=8, n_kv_heads=4, d_ff=96,
        vocab=256, head_dim=8,
        moe=MoEConfig(n_experts=4, top_k=2, norm_topk=False),
        window=32, remat=False, attn_chunk=32, q_block=16,
    )
    return ArchSpec("mixtral_8x22b", "lm", cfg, lm_shapes(cfg.sub_quadratic))
