"""resnet-50: depths 3-4-6-3, width 64, bottleneck [arXiv:1512.03385]."""
from repro.configs import ArchSpec, vision_shapes
from repro.models.resnet import ResNetConfig


def build() -> ArchSpec:
    cfg = ResNetConfig(name="resnet-50", depths=(3, 4, 6, 3), width=64)
    return ArchSpec("resnet_50", "vision", cfg, vision_shapes(),
                    source="arXiv:1512.03385")


def build_reduced() -> ArchSpec:
    cfg = ResNetConfig(name="resnet-50-reduced", depths=(1, 1, 2, 1),
                       width=8, n_classes=10)
    return ArchSpec("resnet_50", "vision", cfg, vision_shapes())
