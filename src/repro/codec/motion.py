"""Block motion estimation (16×16 macroblocks, full search ±R integer pel).

Vectorized as a scan over candidate offsets: each step computes a shifted
whole-frame SAD and block-sums it — JAX/TPU-friendly (no data-dependent
gathers on the search path).  The warp (motion compensation) is the same
block-gather primitive the hybrid decoder's quality transfer uses; its
Pallas TPU kernel lives in ``repro.kernels.qtransfer``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax

f32 = jnp.float32
MB = 16  # macroblock size


def _offsets(radius: int):
    r = jnp.arange(-radius, radius + 1)
    dy, dx = jnp.meshgrid(r, r, indexing="ij")
    return jnp.stack([dy.reshape(-1), dx.reshape(-1)], axis=1)  # (K, 2)


def block_sad(cur, ref, radius: int = 8, *, use_kernel: bool = False):
    """Returns (mv (nby, nbx, 2) int32, sad (nby, nbx) f32).

    cur/ref: (H, W) with H, W multiples of 16.  ``use_kernel`` routes
    through the Pallas kernel in ``repro.kernels.motion_sad`` (interpret
    mode on CPU), which evaluates every candidate offset against a padded
    reference band resident in VMEM; this scan — one whole-frame shifted
    SAD per candidate — is its oracle.
    """
    if use_kernel:
        from repro.kernels.motion_sad.ops import motion_sad
        return motion_sad(cur, ref, radius=radius)
    H, W = cur.shape
    nby, nbx = H // MB, W // MB
    pad = radius
    refp = jnp.pad(ref.astype(f32), pad, mode="edge")
    cur = cur.astype(f32)
    offs = _offsets(radius)

    def step(carry, off):
        best_sad, best_idx, idx = carry
        dy, dx = off[0], off[1]
        shifted = lax.dynamic_slice(refp, (pad + dy, pad + dx), (H, W))
        diff = jnp.abs(cur - shifted)
        sad = diff.reshape(nby, MB, nbx, MB).sum(axis=(1, 3))
        better = sad < best_sad
        best_sad = jnp.where(better, sad, best_sad)
        best_idx = jnp.where(better, idx, best_idx)
        return (best_sad, best_idx, idx + 1), None

    init = (jnp.full((nby, nbx), jnp.inf, f32),
            jnp.zeros((nby, nbx), jnp.int32), jnp.int32(0))
    (best_sad, best_idx, _), _ = lax.scan(step, init, offs)
    mv = offs[best_idx]  # (nby, nbx, 2)
    return mv.astype(jnp.int32), best_sad


def warp_blocks(ref, mv):
    """Motion compensation: gather 16×16 blocks of ``ref`` at MV offsets.

    ref: (H, W); mv: (nby, nbx, 2) int32 (dy, dx).  Pure-jnp oracle for the
    qtransfer Pallas kernel.
    """
    H, W = ref.shape
    nby, nbx = mv.shape[:2]
    # static padding: the worst-case offset bound is not static under jit,
    # so pad by the fixed maximum supported radius.
    R = 16
    refp = jnp.pad(ref.astype(f32), R, mode="edge")

    by = jnp.arange(nby) * MB
    bx = jnp.arange(nbx) * MB

    def gather_block(y0, x0, d):
        return lax.dynamic_slice(refp, (y0 + R + d[0], x0 + R + d[1]),
                                 (MB, MB))

    rows = jax.vmap(
        lambda y0, mvr: jax.vmap(
            lambda x0, d: gather_block(y0, x0, d))(bx, mvr)
    )(by, mv)                                     # (nby, nbx, MB, MB)
    return rows.transpose(0, 2, 1, 3).reshape(H, W)


def accumulate_mv(mvs):
    """Chain per-frame MVs into anchor-relative MVs (paper Fig. 7).

    mvs: (T, nby, nbx, 2) frame-to-previous-frame vectors.  Returns
    anchor-relative vectors by summation — the first-order approximation of
    following the codec reference index, adequate at small radii.
    """
    return jnp.cumsum(mvs, axis=0)
