"""Block motion estimation (16×16 macroblocks, full search ±R integer pel).

Three search paths with identical semantics (dy-major candidate order,
first-wins tie-breaking):

* ``block_sad_scan`` — the legacy oracle: a ``lax.scan`` over candidate
  offsets, each step materializing a whole-frame shifted copy of the
  padded reference.  Correct but HBM-bound: (2R+1)² full-frame slices.
* ``block_sad`` — the vmapped per-macroblock form the fused decode path
  uses: each macroblock gathers its (MB+2R)² search window once, then the
  candidate loop slices inside those resident windows — no whole-frame
  copies, flat memory in the radius.
* ``block_sad(use_kernel=True)`` — the Pallas TPU kernel in
  ``repro.kernels.motion_sad`` (VMEM-resident padded reference, one
  macroblock row per grid step).

``dtype=jnp.bfloat16`` selects the bf16 storage variant (inputs cast to
bf16, SADs accumulated in f32) on both the fallback and the kernel.

The warp (motion compensation) is the same block-gather primitive the
hybrid decoder's quality transfer uses; its Pallas TPU kernel lives in
``repro.kernels.qtransfer``.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax import lax

f32 = jnp.float32
MB = 16  # macroblock size


def _offsets(radius: int):
    r = jnp.arange(-radius, radius + 1)
    dy, dx = jnp.meshgrid(r, r, indexing="ij")
    return jnp.stack([dy.reshape(-1), dx.reshape(-1)], axis=1)  # (K, 2)


def block_sad_scan(cur, ref, radius: int = 8):
    """Legacy scan-over-candidates full search — the bit-exactness oracle.

    cur/ref: (H, W) with H, W multiples of 16.  One whole-frame shifted
    SAD per candidate offset; kept only as the reference implementation
    for the vmapped fallback and the Pallas kernel.
    """
    H, W = cur.shape
    nby, nbx = H // MB, W // MB
    pad = radius
    refp = jnp.pad(ref.astype(f32), pad, mode="edge")
    cur = cur.astype(f32)
    offs = _offsets(radius)

    def step(carry, off):
        best_sad, best_idx, idx = carry
        dy, dx = off[0], off[1]
        shifted = lax.dynamic_slice(refp, (pad + dy, pad + dx), (H, W))
        diff = jnp.abs(cur - shifted)
        sad = diff.reshape(nby, MB, nbx, MB).sum(axis=(1, 3))
        better = sad < best_sad
        best_sad = jnp.where(better, sad, best_sad)
        best_idx = jnp.where(better, idx, best_idx)
        return (best_sad, best_idx, idx + 1), None

    init = (jnp.full((nby, nbx), jnp.inf, f32),
            jnp.zeros((nby, nbx), jnp.int32), jnp.int32(0))
    (best_sad, best_idx, _), _ = lax.scan(step, init, offs)
    mv = offs[best_idx]  # (nby, nbx, 2)
    return mv.astype(jnp.int32), best_sad


def block_sad(cur, ref, radius: int = 8, *, use_kernel: bool = False,
              dtype=None):
    """Returns (mv (nby, nbx, 2) int32, sad (nby, nbx) f32).

    cur/ref: (H, W) with H, W multiples of 16.  ``use_kernel`` routes
    through the Pallas kernel in ``repro.kernels.motion_sad`` (interpret
    mode on CPU).  The default path gathers one (MB+2R)² search window per
    macroblock and evaluates every candidate offset against those resident
    windows — the same per-block form as the kernel, so memory stays flat
    in the candidate count instead of materializing (2R+1)² whole-frame
    shifted copies like ``block_sad_scan``.  ``dtype`` (e.g. bf16) is the
    input storage dtype; SADs always accumulate in f32.
    """
    if use_kernel:
        from repro.kernels.motion_sad.ops import motion_sad
        return motion_sad(cur, ref, radius=radius, dtype=dtype)
    store = dtype or f32
    H, W = cur.shape
    nby, nbx = H // MB, W // MB
    win = MB + 2 * radius
    refp = jnp.pad(ref.astype(store), radius, mode="edge")
    # (nby, nbx, MB, MB) current blocks, f32 accumulation
    curb = cur.astype(store).astype(f32).reshape(
        nby, MB, nbx, MB).transpose(0, 2, 1, 3)
    # (nby, nbx, MB+2R, MB+2R) per-block search windows — gathered ONCE
    by = jnp.arange(nby) * MB
    bx = jnp.arange(nbx) * MB
    wins = jax.vmap(lambda y0: jax.vmap(
        lambda x0: lax.dynamic_slice(refp, (y0, x0), (win, win)))(bx))(by)
    wins = wins.astype(f32)
    offs = _offsets(radius)

    def step(carry, off):
        best_sad, best_idx, idx = carry
        dy, dx = off[0] + radius, off[1] + radius
        cand = lax.dynamic_slice(wins, (0, 0, dy, dx), (nby, nbx, MB, MB))
        sad = jnp.abs(curb - cand).sum(axis=(2, 3))
        better = sad < best_sad
        best_sad = jnp.where(better, sad, best_sad)
        best_idx = jnp.where(better, idx, best_idx)
        return (best_sad, best_idx, idx + 1), None

    init = (jnp.full((nby, nbx), jnp.inf, f32),
            jnp.zeros((nby, nbx), jnp.int32), jnp.int32(0))
    (best_sad, best_idx, _), _ = lax.scan(step, init, offs)
    mv = offs[best_idx]  # (nby, nbx, 2)
    return mv.astype(jnp.int32), best_sad


def warp_blocks(ref, mv):
    """Motion compensation: gather 16×16 blocks of ``ref`` at MV offsets.

    ref: (H, W); mv: (nby, nbx, 2) int32 (dy, dx).  Pure-jnp oracle for the
    qtransfer Pallas kernel.
    """
    H, W = ref.shape
    nby, nbx = mv.shape[:2]
    # static padding: the worst-case offset bound is not static under jit,
    # so pad by the fixed maximum supported radius.
    R = 16
    refp = jnp.pad(ref.astype(f32), R, mode="edge")

    by = jnp.arange(nby) * MB
    bx = jnp.arange(nbx) * MB

    def gather_block(y0, x0, d):
        return lax.dynamic_slice(refp, (y0 + R + d[0], x0 + R + d[1]),
                                 (MB, MB))

    rows = jax.vmap(
        lambda y0, mvr: jax.vmap(
            lambda x0, d: gather_block(y0, x0, d))(bx, mvr)
    )(by, mv)                                     # (nby, nbx, MB, MB)
    return rows.transpose(0, 2, 1, 3).reshape(H, W)


def accumulate_mv(mvs):
    """Chain per-frame MVs into anchor-relative MVs (paper Fig. 7).

    mvs: (T, nby, nbx, 2) frame-to-previous-frame vectors.  Returns
    anchor-relative vectors by summation — the first-order approximation of
    following the codec reference index, adequate at small radii.
    """
    return jnp.cumsum(mvs, axis=0)
