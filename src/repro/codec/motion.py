"""Block motion estimation (16×16 macroblocks, ±R integer pel).

Two search STRATEGIES, each with a fallback and a Pallas-kernel path:

``search="exhaustive"`` (default) — full ±R search over all (2R+1)²
candidates, identical semantics across three implementations (dy-major
candidate order, first-wins tie-breaking):

* ``block_sad_scan`` — the legacy oracle: a ``lax.scan`` over candidate
  offsets, each step materializing a whole-frame shifted copy of the
  padded reference.  Correct but HBM-bound: (2R+1)² full-frame slices.
* ``block_sad`` — the vmapped per-macroblock form the fused decode path
  uses: each macroblock gathers its (MB+2R)² search window once, then the
  candidate loop slices inside those resident windows — no whole-frame
  copies, flat memory in the radius.
* ``block_sad(use_kernel=True)`` — the Pallas TPU kernel in
  ``repro.kernels.motion_sad`` (VMEM-resident padded reference, multiple
  macroblock rows per grid step, candidates evaluated one dy-row chunk at
  a time).

``search="diamond"`` — traced coarse-to-fine (three-step / diamond)
search: a STATIC step schedule (``diamond_steps``: largest power of two
≤ R, halving to 1) probes the 3×3 neighbourhood of each macroblock's
running best offset, clipped to ±R.  Evaluates 1 + 9·len(steps)
candidates instead of (2R+1)² (37 vs 289 at R=8 — under ¼), all shapes
static so the trace is jit-stable.  The found SAD is ≥ the exhaustive
SAD by construction (the probe set is a subset of the exhaustive
candidate set, and per-candidate SADs are computed identically); quality
vs exhaustive is a documented tolerance contract (docs/fused_encoder.md),
not bit-exactness.  ``block_sad_diamond`` is the pure-jnp form;
``block_sad(search="diamond", use_kernel=True)`` routes to the Pallas
diamond kernel (bit-exact MVs vs the fallback).

``dtype=jnp.bfloat16`` selects the bf16 storage variant (inputs cast to
bf16, SADs accumulated in f32) on both the fallbacks and the kernels.

The warp (motion compensation) is the same block-gather primitive the
hybrid decoder's quality transfer uses; its Pallas TPU kernel lives in
``repro.kernels.qtransfer``.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax import lax

f32 = jnp.float32
MB = 16  # macroblock size
# below this many macroblocks the diamond KERNEL loses to the traced
# descent (per-probe dispatch overhead is amortized over too few
# blocks).  720p is 3600 blocks, the 64x96 bench canvas is 24.
_DIAMOND_KERNEL_MIN_BLOCKS = 256


def diamond_kernel_profitable(H: int, W: int) -> bool:
    """Static dispatch predicate for ``block_sad(use_kernel=True,
    search="diamond")``: route to the Pallas kernel only where it can
    win.  Two static facts decide it — the macroblock count (small
    canvases can't amortize the per-probe kernel dispatch: 0.82x vs the
    traced descent at 64x96) and the backend (in interpret mode the
    kernel's probe loop runs as host Python per grid step, which loses to
    the traced descent at EVERY shape — measured ~0.8x even at 720p).
    Both are known at trace time, so the dispatch never retraces."""
    if (H // MB) * (W // MB) < _DIAMOND_KERNEL_MIN_BLOCKS:
        return False
    from repro.kernels.motion_sad.ops import on_tpu
    return on_tpu()


def _offsets(radius: int):
    r = jnp.arange(-radius, radius + 1)
    dy, dx = jnp.meshgrid(r, r, indexing="ij")
    return jnp.stack([dy.reshape(-1), dx.reshape(-1)], axis=1)  # (K, 2)


def block_sad_scan(cur, ref, radius: int = 8):
    """Legacy scan-over-candidates full search — the bit-exactness oracle.

    cur/ref: (H, W) with H, W multiples of 16.  One whole-frame shifted
    SAD per candidate offset; kept only as the reference implementation
    for the vmapped fallback and the Pallas kernel.
    """
    H, W = cur.shape
    nby, nbx = H // MB, W // MB
    pad = radius
    refp = jnp.pad(ref.astype(f32), pad, mode="edge")
    cur = cur.astype(f32)
    offs = _offsets(radius)

    def step(carry, off):
        best_sad, best_idx, idx = carry
        dy, dx = off[0], off[1]
        shifted = lax.dynamic_slice(refp, (pad + dy, pad + dx), (H, W))
        diff = jnp.abs(cur - shifted)
        sad = diff.reshape(nby, MB, nbx, MB).sum(axis=(1, 3))
        better = sad < best_sad
        best_sad = jnp.where(better, sad, best_sad)
        best_idx = jnp.where(better, idx, best_idx)
        return (best_sad, best_idx, idx + 1), None

    init = (jnp.full((nby, nbx), jnp.inf, f32),
            jnp.zeros((nby, nbx), jnp.int32), jnp.int32(0))
    (best_sad, best_idx, _), _ = lax.scan(step, init, offs)
    mv = offs[best_idx]  # (nby, nbx, 2)
    return mv.astype(jnp.int32), best_sad


def diamond_steps(radius: int) -> tuple:
    """Static step schedule of the coarse-to-fine search: the largest
    power of two ≤ radius, halving down to 1.  Shared by the pure-jnp
    fallback and the Pallas diamond kernel so probe order (and therefore
    tie-breaking) is identical everywhere."""
    s = 1
    while s * 2 <= radius:
        s *= 2
    steps = []
    while s >= 1:
        steps.append(s)
        s //= 2
    return tuple(steps)


def diamond_num_evals(radius: int) -> int:
    """Candidate evaluations the diamond search performs per macroblock
    (center + 9 probes per step) — 37 at R=8 vs (2R+1)² = 289 exhaustive."""
    return 1 + 9 * len(diamond_steps(radius))


def _search_prelude(cur, ref, radius: int, dtype):
    """Shared head of the fallback searches: per-macroblock current
    blocks (f32) and the per-block (MB+2R)² resident search windows."""
    store = dtype or f32
    H, W = cur.shape
    nby, nbx = H // MB, W // MB
    win = MB + 2 * radius
    refp = jnp.pad(ref.astype(store), radius, mode="edge")
    # (nby, nbx, MB, MB) current blocks, f32 accumulation
    curb = cur.astype(store).astype(f32).reshape(
        nby, MB, nbx, MB).transpose(0, 2, 1, 3)
    # (nby, nbx, MB+2R, MB+2R) per-block search windows — gathered ONCE
    by = jnp.arange(nby) * MB
    bx = jnp.arange(nbx) * MB
    wins = jax.vmap(lambda y0: jax.vmap(
        lambda x0: lax.dynamic_slice(refp, (y0, x0), (win, win)))(bx))(by)
    return curb, wins.astype(f32), nby, nbx


def block_sad_diamond(cur, ref, radius: int = 8, *, dtype=None):
    """Traced coarse-to-fine search (pure-jnp form): (mv, sad) like
    ``block_sad`` but evaluating only ``diamond_num_evals(radius)``
    candidates per macroblock.  Every probe's SAD is computed by the SAME
    slice-and-reduce expression the exhaustive fallback uses, so
    SAD(diamond) ≥ SAD(exhaustive) holds exactly (subset of the candidate
    set), and equals it whenever the greedy descent finds the global
    minimum (smooth / translational content)."""
    curb, wins, nby, nbx = _search_prelude(cur, ref, radius, dtype)

    def slice_one(w, oy, ox):
        return lax.dynamic_slice(w, (oy + radius, ox + radius), (MB, MB))

    slice_all = jax.vmap(jax.vmap(slice_one))

    def sad_at(offy, offx):
        cand = slice_all(wins, offy, offx)        # (nby, nbx, MB, MB)
        return jnp.abs(curb - cand).sum(axis=(2, 3))

    zero = jnp.zeros((nby, nbx), jnp.int32)
    best_y, best_x = zero, zero
    best_sad = sad_at(zero, zero)
    # static unroll: len(steps) rounds of 9 probes, dy-major, first-wins
    for s in diamond_steps(radius):
        cy, cx = best_y, best_x
        for py in (-s, 0, s):
            for px in (-s, 0, s):
                oy = jnp.clip(cy + py, -radius, radius)
                ox = jnp.clip(cx + px, -radius, radius)
                sad = sad_at(oy, ox)
                better = sad < best_sad
                best_sad = jnp.where(better, sad, best_sad)
                best_y = jnp.where(better, oy, best_y)
                best_x = jnp.where(better, ox, best_x)
    return jnp.stack([best_y, best_x], axis=-1).astype(jnp.int32), best_sad


def block_sad(cur, ref, radius: int = 8, *, use_kernel: bool = False,
              dtype=None, search: str = "exhaustive"):
    """Returns (mv (nby, nbx, 2) int32, sad (nby, nbx) f32).

    cur/ref: (H, W) with H, W multiples of 16.  ``use_kernel`` routes
    through the Pallas kernels in ``repro.kernels.motion_sad`` (interpret
    mode on CPU).  The default path gathers one (MB+2R)² search window per
    macroblock and evaluates every candidate offset against those resident
    windows — the same per-block form as the kernel, so memory stays flat
    in the candidate count instead of materializing (2R+1)² whole-frame
    shifted copies like ``block_sad_scan``.  ``dtype`` (e.g. bf16) is the
    input storage dtype; SADs always accumulate in f32.  ``search``
    selects the exhaustive full search or the traced diamond search (see
    module docstring for the quality contract).
    """
    if search not in ("exhaustive", "diamond"):
        raise ValueError(f"unknown search strategy {search!r} "
                         "(expected 'exhaustive' or 'diamond')")
    if use_kernel and search == "diamond" \
            and not diamond_kernel_profitable(*cur.shape):
        # both variants share the probe schedule and SAD expression, so
        # results are identical either way — this is purely a perf route
        return block_sad_diamond(cur, ref, radius, dtype=dtype)
    if use_kernel:
        from repro.kernels.motion_sad.ops import motion_sad
        return motion_sad(cur, ref, radius=radius, dtype=dtype,
                          search=search)
    if search == "diamond":
        return block_sad_diamond(cur, ref, radius, dtype=dtype)
    curb, wins, nby, nbx = _search_prelude(cur, ref, radius, dtype)
    offs = _offsets(radius)

    def step(carry, off):
        best_sad, best_idx, idx = carry
        dy, dx = off[0] + radius, off[1] + radius
        cand = lax.dynamic_slice(wins, (0, 0, dy, dx), (nby, nbx, MB, MB))
        sad = jnp.abs(curb - cand).sum(axis=(2, 3))
        better = sad < best_sad
        best_sad = jnp.where(better, sad, best_sad)
        best_idx = jnp.where(better, idx, best_idx)
        return (best_sad, best_idx, idx + 1), None

    init = (jnp.full((nby, nbx), jnp.inf, f32),
            jnp.zeros((nby, nbx), jnp.int32), jnp.int32(0))
    (best_sad, best_idx, _), _ = lax.scan(step, init, offs)
    mv = offs[best_idx]  # (nby, nbx, 2)
    return mv.astype(jnp.int32), best_sad


def warp_blocks(ref, mv):
    """Motion compensation: gather 16×16 blocks of ``ref`` at MV offsets.

    ref: (H, W); mv: (nby, nbx, 2) int32 (dy, dx).  Pure-jnp oracle for the
    qtransfer Pallas kernel.
    """
    H, W = ref.shape
    nby, nbx = mv.shape[:2]
    # static padding: the worst-case offset bound is not static under jit,
    # so pad by the fixed maximum supported radius.
    R = 16
    refp = jnp.pad(ref.astype(f32), R, mode="edge")

    by = jnp.arange(nby) * MB
    bx = jnp.arange(nbx) * MB

    def gather_block(y0, x0, d):
        return lax.dynamic_slice(refp, (y0 + R + d[0], x0 + R + d[1]),
                                 (MB, MB))

    rows = jax.vmap(
        lambda y0, mvr: jax.vmap(
            lambda x0, d: gather_block(y0, x0, d))(bx, mvr)
    )(by, mv)                                     # (nby, nbx, MB, MB)
    return rows.transpose(0, 2, 1, 3).reshape(H, W)


def accumulate_mv(mvs):
    """Chain per-frame MVs into anchor-relative MVs (paper Fig. 7).

    mvs: (T, nby, nbx, 2) frame-to-previous-frame vectors.  Returns
    anchor-relative vectors by summation — the first-order approximation of
    following the codec reference index, adequate at small radii.
    """
    return jnp.cumsum(mvs, axis=0)
