from repro.codec.image_codec import jpeg_encode_decode, jpeg_bits  # noqa: F401
from repro.codec.video_codec import (  # noqa: F401
    VideoCodecConfig, encode_chunk, decode_chunk,
)
from repro.codec.rate_model import QUALITY_LADDER, ladder_for_bandwidth  # noqa: F401
