"""8×8 block DCT + quantization — the JPEG/H.264 transform core (pure JAX).

The TPU-optimized tiled version lives in ``repro.kernels.blockdct``; this
module is the reference implementation used by the codecs and as the kernel
oracle.  DCT is expressed as two 8×8 matmuls (MXU-friendly by design).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax import lax

f32 = jnp.float32

# Standard JPEG luminance quantization table (quality 50).
JPEG_LUMA_Q50 = jnp.array([
    [16, 11, 10, 16, 24, 40, 51, 61],
    [12, 12, 14, 19, 26, 58, 60, 55],
    [14, 13, 16, 24, 40, 57, 69, 56],
    [14, 17, 22, 29, 51, 87, 80, 62],
    [18, 22, 37, 56, 68, 109, 103, 77],
    [24, 35, 55, 64, 81, 104, 113, 92],
    [49, 64, 78, 87, 103, 121, 120, 101],
    [72, 92, 95, 98, 112, 100, 103, 99],
], f32)


@functools.lru_cache()
def dct_matrix(n: int = 8):
    """Orthonormal DCT-II matrix D such that y = D @ x @ D.T.

    Built with numpy so the cached constant is a host array (caching a jnp
    array created under jit would leak a tracer).
    """
    import numpy as np
    k = np.arange(n, dtype=np.float32)[:, None]
    i = np.arange(n, dtype=np.float32)[None, :]
    d = np.cos((2 * i + 1) * k * math.pi / (2 * n)) * math.sqrt(2.0 / n)
    d[0] *= 1.0 / math.sqrt(2.0)
    return d


def quality_scale(quality) -> jnp.ndarray:
    """JPEG quality-factor -> quant-table scale (Annex K convention)."""
    q = jnp.clip(jnp.asarray(quality, f32), 1.0, 100.0)
    return jnp.where(q < 50.0, 5000.0 / q, 200.0 - 2.0 * q) / 100.0


def blockify(img, block: int = 8):
    """(H, W) -> (H/b * W/b, b, b).  H, W must be multiples of b."""
    H, W = img.shape
    x = img.reshape(H // block, block, W // block, block)
    return x.transpose(0, 2, 1, 3).reshape(-1, block, block)


def unblockify(blocks, H: int, W: int, block: int = 8):
    x = blocks.reshape(H // block, W // block, block, block)
    return x.transpose(0, 2, 1, 3).reshape(H, W)


def dct2(blocks):
    D = dct_matrix(blocks.shape[-1])
    return jnp.einsum("ij,njk,lk->nil", D, blocks.astype(f32), D)


def idct2(coefs):
    D = dct_matrix(coefs.shape[-1])
    return jnp.einsum("ji,njk,kl->nil", D, coefs.astype(f32), D)


def quant_table(quality) -> jnp.ndarray:
    """The (8, 8) quantization table for a quality factor — computed once
    per encode and threaded through the per-frame loops (the codecs must
    not rebuild it per frame)."""
    qtab = JPEG_LUMA_Q50 * quality_scale(quality)
    return jnp.maximum(qtab, 1.0)


def quantize_with_table(coefs, qtab):
    return jnp.round(coefs / qtab)


def quantize(coefs, quality):
    qtab = quant_table(quality)
    return quantize_with_table(coefs, qtab), qtab


def dequantize(qcoefs, qtab):
    return qcoefs * qtab


def seq_sum(v) -> jnp.ndarray:
    """Order-stable sequential accumulation of a vector or row-major grid.

    ``lax.scan`` forces left-to-right adds, so the result is independent
    of XLA's shape-dependent reduce tiling — and inserting 0.0 terms is an
    exact fp no-op.  That is the property the heterogeneous-ladder padded
    encode needs: summing valid partials interleaved with zeroed padding
    partials is BIT-identical to summing the valid partials alone, which a
    plain ``jnp.sum`` does not guarantee across different canvas sizes.

    1-D input is one scan.  2-D input (a row-major grid of partials)
    reduces hierarchically — a per-row scan vmapped over rows, then a
    scan over the row totals — cutting the serial dependency chain from
    O(n) to O(rows + cols).  Canvas padding zero-extends each row (column
    suffix) and appends all-zero rows (row suffix), so both scan levels
    see the unpadded add sequence plus exact no-ops.  Use only on small
    partial grids (per-block/per-tile sums), never on raw pixels.
    """
    def scan1d(x):
        total, _ = lax.scan(lambda c, t: (c + t, None),
                            jnp.asarray(0.0, f32), x.astype(f32))
        return total

    if v.ndim == 2:
        return scan1d(jax.vmap(scan1d)(v))
    return scan1d(v.reshape(-1))


def entropy_bits(qcoefs, block_mask=None, n_blocks=None,
                 grid=None) -> jnp.ndarray:
    """Bit-cost proxy: exp-Golomb-style 2*log2(1+|q|)+1 per nonzero coef.

    Calibrated against the paper's 5-level ladder in rate_model.py; the
    proxy is monotone in quality and content complexity, which is what the
    bandwidth controller needs.

    The reduction is a fixed-shape per-block partial sum followed by
    :func:`seq_sum`, so the total is invariant to zero-padded extra
    blocks.  ``grid`` ((block_rows, block_cols), the frame's 8x8 block
    grid shape) lets callers opt into the hierarchical two-level scan —
    pass it whenever the block count is non-trivial.  ``block_mask``
    ((nblocks,) bool, with ``n_blocks`` the valid-block count) restricts
    the cost to valid blocks — the heterogeneous-ladder batched encode
    runs padded frames through one dispatch and must charge bits only for
    the stream's true extent, bit-exactly vs the unpadded encode.
    """
    a = jnp.abs(qcoefs)
    bits = jnp.where(a > 0, 2.0 * jnp.log2(1.0 + a) + 1.0, 0.0)
    per_block = bits.sum(axis=(1, 2))           # fixed (8, 8) tile reduce
    if block_mask is not None:
        per_block = jnp.where(block_mask, per_block, 0.0)
        overhead = n_blocks * 4.0               # per-block EOB overhead
    else:
        overhead = qcoefs.shape[0] * 4.0
    if grid is not None:
        per_block = per_block.reshape(grid)
    return seq_sum(per_block) + overhead


def transform_quantize(img, quality):
    """Full round trip.  Returns (recon, bits)."""
    H, W = img.shape
    blocks = blockify(img.astype(f32) - 128.0)
    q, qtab = quantize(dct2(blocks), quality)
    bits = entropy_bits(q, grid=(H // 8, W // 8))
    rec = unblockify(idct2(dequantize(q, qtab)), H, W) + 128.0
    return jnp.clip(rec, 0.0, 255.0), bits
