"""8×8 block DCT + quantization — the JPEG/H.264 transform core (pure JAX).

The TPU-optimized tiled version lives in ``repro.kernels.blockdct``; this
module is the reference implementation used by the codecs and as the kernel
oracle.  DCT is expressed as two 8×8 matmuls (MXU-friendly by design).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp

f32 = jnp.float32

# Standard JPEG luminance quantization table (quality 50).
JPEG_LUMA_Q50 = jnp.array([
    [16, 11, 10, 16, 24, 40, 51, 61],
    [12, 12, 14, 19, 26, 58, 60, 55],
    [14, 13, 16, 24, 40, 57, 69, 56],
    [14, 17, 22, 29, 51, 87, 80, 62],
    [18, 22, 37, 56, 68, 109, 103, 77],
    [24, 35, 55, 64, 81, 104, 113, 92],
    [49, 64, 78, 87, 103, 121, 120, 101],
    [72, 92, 95, 98, 112, 100, 103, 99],
], f32)


@functools.lru_cache()
def dct_matrix(n: int = 8):
    """Orthonormal DCT-II matrix D such that y = D @ x @ D.T.

    Built with numpy so the cached constant is a host array (caching a jnp
    array created under jit would leak a tracer).
    """
    import numpy as np
    k = np.arange(n, dtype=np.float32)[:, None]
    i = np.arange(n, dtype=np.float32)[None, :]
    d = np.cos((2 * i + 1) * k * math.pi / (2 * n)) * math.sqrt(2.0 / n)
    d[0] *= 1.0 / math.sqrt(2.0)
    return d


def quality_scale(quality) -> jnp.ndarray:
    """JPEG quality-factor -> quant-table scale (Annex K convention)."""
    q = jnp.clip(jnp.asarray(quality, f32), 1.0, 100.0)
    return jnp.where(q < 50.0, 5000.0 / q, 200.0 - 2.0 * q) / 100.0


def blockify(img, block: int = 8):
    """(H, W) -> (H/b * W/b, b, b).  H, W must be multiples of b."""
    H, W = img.shape
    x = img.reshape(H // block, block, W // block, block)
    return x.transpose(0, 2, 1, 3).reshape(-1, block, block)


def unblockify(blocks, H: int, W: int, block: int = 8):
    x = blocks.reshape(H // block, W // block, block, block)
    return x.transpose(0, 2, 1, 3).reshape(H, W)


def dct2(blocks):
    D = dct_matrix(blocks.shape[-1])
    return jnp.einsum("ij,njk,lk->nil", D, blocks.astype(f32), D)


def idct2(coefs):
    D = dct_matrix(coefs.shape[-1])
    return jnp.einsum("ji,njk,kl->nil", D, coefs.astype(f32), D)


def quant_table(quality) -> jnp.ndarray:
    """The (8, 8) quantization table for a quality factor — computed once
    per encode and threaded through the per-frame loops (the codecs must
    not rebuild it per frame)."""
    qtab = JPEG_LUMA_Q50 * quality_scale(quality)
    return jnp.maximum(qtab, 1.0)


def quantize_with_table(coefs, qtab):
    return jnp.round(coefs / qtab)


def quantize(coefs, quality):
    qtab = quant_table(quality)
    return quantize_with_table(coefs, qtab), qtab


def dequantize(qcoefs, qtab):
    return qcoefs * qtab


def entropy_bits(qcoefs) -> jnp.ndarray:
    """Bit-cost proxy: exp-Golomb-style 2*log2(1+|q|)+1 per nonzero coef.

    Calibrated against the paper's 5-level ladder in rate_model.py; the
    proxy is monotone in quality and content complexity, which is what the
    bandwidth controller needs.
    """
    a = jnp.abs(qcoefs)
    bits = jnp.where(a > 0, 2.0 * jnp.log2(1.0 + a) + 1.0, 0.0)
    return bits.sum() + qcoefs.shape[0] * 4.0  # per-block EOB overhead


def transform_quantize(img, quality):
    """Full round trip.  Returns (recon, bits)."""
    H, W = img.shape
    blocks = blockify(img.astype(f32) - 128.0)
    q, qtab = quantize(dct2(blocks), quality)
    bits = entropy_bits(q)
    rec = unblockify(idct2(dequantize(q, qtab)), H, W) + 128.0
    return jnp.clip(rec, 0.0, 255.0), bits
