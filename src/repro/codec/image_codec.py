"""JPEG-like HD image codec for BiSwift anchors (paper §IV-A, Fig. 3b).

Anchors are delivered as high-definition stills whose quality factor is
tuned so that anchors + video share the stream's allocated bandwidth.

Budget search: the ladder probe (:func:`quality_for_budget`) and the
traced masked sweep (:func:`ladder_sweep`, consumed by
``repro.core.roundtrip``) both hoist the quality-INDEPENDENT half of the
encode — level-shift, blockify, DCT — out of the per-rung loop: only the
quantization table depends on the quality factor, so probing Q rungs
costs one DCT, not Q.  Rung selection is one shared jnp expression
(:func:`budget_rung`), so the host probe and the in-trace argmax pick
the same rung by construction.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.codec import blockdct as B

f32 = jnp.float32

# the discrete anchor-quality ladder the budget search evaluates (ISSUE
# 10); distinct from the legacy hybrid_encoder.ANCHOR_QUALITIES probe set
ANCHOR_QUALITY_LADDER = (20.0, 35.0, 50.0, 65.0, 80.0, 92.0)


def jpeg_encode_decode(img, quality):
    """img: (H, W) float [0,255] -> (recon, bits)."""
    return B.transform_quantize(img, quality)


def jpeg_bits(img, quality):
    blocks = B.blockify(img.astype(f32) - 128.0)
    q, _ = B.quantize(B.dct2(blocks), quality)
    return B.entropy_bits(q, grid=(img.shape[0] // 8, img.shape[1] // 8))


def psnr(a, b, peak: float = 255.0):
    mse = jnp.mean(jnp.square(a.astype(f32) - b.astype(f32)))
    return 10.0 * jnp.log10(peak * peak / jnp.maximum(mse, 1e-9))


def _dct_blocks(img):
    """Quality-independent half of the JPEG encode: level-shift,
    blockify, DCT.  Computed ONCE per image and shared by every ladder
    rung — the per-rung work is quantize + bit charge (+ the inverse
    transform when a reconstruction is needed)."""
    return B.dct2(B.blockify(img.astype(f32) - 128.0))


def ladder_bits(img, qualities=ANCHOR_QUALITY_LADDER):
    """(Q,) bit cost of ``img`` at every ladder rung, DCT hoisted.

    Rung q's value is bit-exact vs ``jpeg_bits(img, qualities[q])`` —
    identical op sequence on identical coefficients; only the redundant
    per-rung DCT recompute is gone."""
    coefs = _dct_blocks(img)
    grid = (img.shape[0] // 8, img.shape[1] // 8)
    return jnp.stack([
        B.entropy_bits(B.quantize_with_table(coefs, B.quant_table(q)),
                       grid=grid)
        for q in qualities])


def ladder_sweep(img, qualities=ANCHOR_QUALITY_LADDER):
    """Encode ``img`` at EVERY ladder rung: (recons (Q, H, W), bits (Q,)).

    Each rung's (recon, bits) pair is bit-exact vs
    ``jpeg_encode_decode(img, qualities[q])``.  Static output shapes make
    this the masked-sweep primitive of the in-trace budget search
    (``repro.core.roundtrip``): content and budget never change the
    trace, a traced argmax picks the rung afterwards."""
    H, W = img.shape
    coefs = _dct_blocks(img)
    grid = (H // 8, W // 8)
    recons, bits = [], []
    for q in qualities:
        qtab = B.quant_table(q)
        qc = B.quantize_with_table(coefs, qtab)
        bits.append(B.entropy_bits(qc, grid=grid))
        rec = B.unblockify(B.idct2(B.dequantize(qc, qtab)), H, W) + 128.0
        recons.append(jnp.clip(rec, 0.0, 255.0))
    return jnp.stack(recons), jnp.stack(bits)


def budget_rung(bits, bit_budget, qualities=ANCHOR_QUALITY_LADDER):
    """Index of the highest rung whose bit cost fits the budget (0 when
    none fit — the cheapest rung ships regardless, matching the legacy
    host search).  Operates on the LAST axis of ``bits``, so the same
    expression serves the host probe and the traced per-frame argmax."""
    qs = jnp.asarray(qualities, f32)
    ok = bits <= bit_budget
    return jnp.where(ok.any(axis=-1),
                     jnp.argmax(jnp.where(ok, qs, -1.0), axis=-1), 0)


def quality_for_budget(img, bit_budget, qualities=ANCHOR_QUALITY_LADDER):
    """Highest JPEG quality whose bit cost fits the budget (vectorized probe).

    Mirrors the paper's camera-side adaptation: the hybrid encoder tunes the
    anchor quality factor to the bandwidth share chosen by the agent.
    The DCT runs once (``ladder_bits``); the legacy probe re-encoded the
    full image at every rung.
    """
    qs = jnp.asarray(qualities, f32)
    bits = ladder_bits(img, qualities)
    idx = budget_rung(bits, bit_budget, qualities)
    return qs[idx], bits[idx]
