"""JPEG-like HD image codec for BiSwift anchors (paper §IV-A, Fig. 3b).

Anchors are delivered as high-definition stills whose quality factor is
tuned so that anchors + video share the stream's allocated bandwidth.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.codec import blockdct as B

f32 = jnp.float32


def jpeg_encode_decode(img, quality):
    """img: (H, W) float [0,255] -> (recon, bits)."""
    return B.transform_quantize(img, quality)


def jpeg_bits(img, quality):
    blocks = B.blockify(img.astype(f32) - 128.0)
    q, _ = B.quantize(B.dct2(blocks), quality)
    return B.entropy_bits(q, grid=(img.shape[0] // 8, img.shape[1] // 8))


def psnr(a, b, peak: float = 255.0):
    mse = jnp.mean(jnp.square(a.astype(f32) - b.astype(f32)))
    return 10.0 * jnp.log10(peak * peak / jnp.maximum(mse, 1e-9))


def quality_for_budget(img, bit_budget, qualities=(20., 35., 50., 65., 80., 92.)):
    """Highest JPEG quality whose bit cost fits the budget (vectorized probe).

    Mirrors the paper's camera-side adaptation: the hybrid encoder tunes the
    anchor quality factor to the bandwidth share chosen by the agent.
    """
    qs = jnp.asarray(qualities, f32)
    bits = jnp.stack([jpeg_bits(img, q) for q in qualities])
    ok = bits <= bit_budget
    idx = jnp.where(ok.any(), jnp.argmax(jnp.where(ok, qs, -1.0)), 0)
    return qs[idx], bits[idx]
