"""The 5-level quality ladder of §VI-A and bandwidth->config selection.

bitrate ∈ {500, 1000, 1500, 2000, 5000} kbps  <->
resolution ∈ {270p, 360p, 540p, 720p, 1080p}

In the simulation, resolutions are scale fractions of the raw source frame;
the codec quality factor per level is calibrated so the bit proxy tracks
the ladder ordering.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class QualityLevel:
    name: str
    bitrate_kbps: float
    scale: float          # fraction of raw resolution
    quality: float        # codec quality factor


QUALITY_LADDER = (
    QualityLevel("270p", 500.0, 0.25, 30.0),
    QualityLevel("360p", 1000.0, 1 / 3, 40.0),
    QualityLevel("540p", 1500.0, 0.5, 50.0),
    QualityLevel("720p", 2000.0, 2 / 3, 65.0),
    QualityLevel("1080p", 5000.0, 1.0, 80.0),
)


def ladder_for_bandwidth(bw_kbps: float, headroom: float = 0.95) -> int:
    """Highest ladder level whose bitrate fits within bw_kbps*headroom.

    This is the 'adaptive feedback control' selection of §IV-A: the encoder
    follows the bandwidth allocated by the controller.
    """
    level = 0
    for i, ql in enumerate(QUALITY_LADDER):
        if ql.bitrate_kbps <= bw_kbps * headroom:
            level = i
    return level


def downscale(frames, scale: float):
    """(T, H, W) average-pool downscale to a multiple-of-16 size."""
    T, H, W = frames.shape
    h = max(int(H * scale) // 16 * 16, 16)
    w = max(int(W * scale) // 16 * 16, 16)
    fy, fx = H // h, W // w
    if fy * h != H or fx * w != W:
        # crop to divisible region, then pool
        frames = frames[:, : fy * h, : fx * w]
    x = frames.reshape(T, h, fy, w, fx)
    return x.mean(axis=(2, 4))


def upscale_nearest(frames, H: int, W: int):
    """(T, h, w) -> (T, H, W) nearest-neighbour (the cheap decoder upscale).

    Index-mapped so non-integer factors (e.g. the 2/3-scale 720p level)
    work exactly.
    """
    T, h, w = frames.shape
    yi = jnp.clip(jnp.arange(H) * h // H, 0, h - 1)
    xi = jnp.clip(jnp.arange(W) * w // W, 0, w - 1)
    return frames[:, yi][:, :, xi]
