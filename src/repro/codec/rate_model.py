"""The 5-level quality ladder of §VI-A and bandwidth->config selection.

bitrate ∈ {500, 1000, 1500, 2000, 5000} kbps  <->
resolution ∈ {270p, 360p, 540p, 720p, 1080p}

In the simulation, resolutions are scale fractions of the raw source frame;
the codec quality factor per level is calibrated so the bit proxy tracks
the ladder ordering.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class QualityLevel:
    name: str
    bitrate_kbps: float
    scale: float          # fraction of raw resolution
    quality: float        # codec quality factor


QUALITY_LADDER = (
    QualityLevel("270p", 500.0, 0.25, 30.0),
    QualityLevel("360p", 1000.0, 1 / 3, 40.0),
    QualityLevel("540p", 1500.0, 0.5, 50.0),
    QualityLevel("720p", 2000.0, 2 / 3, 65.0),
    QualityLevel("1080p", 5000.0, 1.0, 80.0),
)


# fraction of a stream's allocation the video encoder may spend — the
# rest is headroom reserved for JPEG anchors (§IV-A).  Shared by the
# legacy host encoder and the fused round-trip's ladder selection so the
# two paths can never silently pick different rungs.
ANCHOR_HEADROOM = 0.65


def video_bandwidth_share(bw_kbps: float) -> float:
    """The bandwidth the ladder selection sees after anchor headroom."""
    return bw_kbps * ANCHOR_HEADROOM


def ladder_for_bandwidth(bw_kbps: float, headroom: float = 0.95) -> int:
    """Highest ladder level whose bitrate fits within bw_kbps*headroom.

    This is the 'adaptive feedback control' selection of §IV-A: the encoder
    follows the bandwidth allocated by the controller.
    """
    level = 0
    for i, ql in enumerate(QUALITY_LADDER):
        if ql.bitrate_kbps <= bw_kbps * headroom:
            level = i
    return level


def lr_shape_for_scale(scale: float, H: int, W: int) -> tuple[int, int]:
    """The multiple-of-16 (h, w) a ``scale`` fraction of (H, W) rounds to.

    The single source of truth for the downscale shape arithmetic: the
    heterogeneous-ladder padding contract (extents, canvases, sharded
    lanes) assumes the host-side extent computation and the shapes
    :func:`downscale` actually produces can never disagree."""
    h = max(int(H * scale) // 16 * 16, 16)
    w = max(int(W * scale) // 16 * 16, 16)
    return h, w


def ladder_lr_shape(level: int, H: int, W: int) -> tuple[int, int]:
    """The (h, w) LR shape ``downscale`` produces for a ladder rung."""
    return lr_shape_for_scale(QUALITY_LADDER[level].scale, H, W)


def downscale(frames, scale: float):
    """(T, H, W) average-pool downscale to a multiple-of-16 size."""
    T, H, W = frames.shape
    h, w = lr_shape_for_scale(scale, H, W)
    fy, fx = H // h, W // w
    if fy * h != H or fx * w != W:
        # crop to divisible region, then pool
        frames = frames[:, : fy * h, : fx * w]
    x = frames.reshape(T, h, fy, w, fx)
    return x.mean(axis=(2, 4))


def upscale_nearest(frames, H: int, W: int, src_hw=None):
    """(T, h, w) -> (T, H, W) nearest-neighbour (the cheap decoder upscale).

    Index-mapped so non-integer factors (e.g. the 2/3-scale 720p level)
    work exactly.  ``src_hw`` ((h, w), traced ints) overrides the source
    extent when ``frames`` carries a padded margin beyond the valid region
    (heterogeneous-ladder batches): the index map then only ever gathers
    valid pixels, so the result is bit-identical to upscaling the unpadded
    array.
    """
    h, w = frames.shape[1:] if src_hw is None else src_hw
    yi = jnp.clip(jnp.arange(H) * h // H, 0, h - 1)
    xi = jnp.clip(jnp.arange(W) * w // W, 0, w - 1)
    return frames[:, yi][:, :, xi]
