"""Block-based video codec simulation (I/P frames, MV + DCT-quant residual).

Preserves exactly the codec features BiSwift consumes (paper §IV):
  * 16×16 macroblock motion vectors and per-block residuals (quality
    transfer + reuse pipelines),
  * I/P frame structure and per-frame residual magnitudes (the R_f feature
    accumulated for Eq. 3 classification and the DRL state),
  * QP-style quantization with a bitrate proxy (rate_model.py calibrates
    the 5-level ladder of §VI-A).

All functions are jit/vmap-compatible; chunks are (T, H, W) luma in
[0, 255].  ``encode_chunk`` is a SINGLE module-level ``jax.jit`` (config
static) so every producer shares one compile cache; ``encode_chunk_batched``
vmaps it over a leading stream axis with the same shape discipline as
``decode_execute_batched`` — its mesh-sharded twin is
``repro.distributed.stream_sharding.shard_encode``.

``VideoCodecConfig.use_kernel`` routes the P-frame motion search through
the ``motion_sad`` Pallas kernel; ``dtype="bfloat16"`` selects the bf16
kernel/fallback variants (inputs stored bf16, SADs accumulated f32).
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
from jax import lax

from repro.codec import blockdct as B
from repro.codec import motion as M

f32 = jnp.float32


@dataclasses.dataclass(frozen=True)
class VideoCodecConfig:
    search_radius: int = 8
    quality: float = 50.0        # quantizer quality factor (QP analogue)
    gop: int = 30                # I-frame period
    use_kernel: bool = False     # P-frame search via the motion_sad kernel
    dtype: str = "float32"       # search storage dtype: float32 | bfloat16

    @property
    def search_dtype(self):
        if self.dtype in ("bfloat16", "bf16"):
            return jnp.bfloat16
        return None              # motion paths default to f32


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class EncodedChunk:
    """Everything the edge receives for one chunk of one stream."""
    recon: jnp.ndarray          # (T, H, W) decoder reconstruction
    mv: jnp.ndarray             # (T, nby, nbx, 2) motion vectors (frame t-1 -> t)
    residual_q: jnp.ndarray     # (T, nblocks, 8, 8) quantized residual coefs
    qtab: jnp.ndarray           # (8, 8) quant table
    bits: jnp.ndarray           # (T,) per-frame bit cost
    residual_mag: jnp.ndarray   # (T,) mean |residual| per frame (R_f feature)
    frame_diff: jnp.ndarray     # (T,) mean |frame_t - frame_{t-1}| (X_f feature)


def _encode_iframe(frame, qtab):
    blocks = B.blockify(frame.astype(f32) - 128.0)
    q = B.quantize_with_table(B.dct2(blocks), qtab)
    bits = B.entropy_bits(q)
    rec = B.unblockify(B.idct2(B.dequantize(q, qtab)),
                       *frame.shape) + 128.0
    return jnp.clip(rec, 0.0, 255.0), q, bits


def _encode_pframe(frame, ref_recon, qtab, cfg: VideoCodecConfig):
    mv, _ = M.block_sad(frame, ref_recon, cfg.search_radius,
                        use_kernel=cfg.use_kernel, dtype=cfg.search_dtype)
    pred = M.warp_blocks(ref_recon, mv)
    resid = frame.astype(f32) - pred
    blocks = B.blockify(resid)
    q = B.quantize_with_table(B.dct2(blocks), qtab)
    bits = B.entropy_bits(q) + mv.size * 3.0        # MV coding cost proxy
    rec_resid = B.unblockify(B.idct2(B.dequantize(q, qtab)), *frame.shape)
    rec = jnp.clip(pred + rec_resid, 0.0, 255.0)
    return rec, mv, q, bits, jnp.mean(jnp.abs(resid))


def _encode_chunk(frames, cfg: VideoCodecConfig) -> EncodedChunk:
    """Traced body shared by ``encode_chunk`` (one stream) and
    ``encode_chunk_batched`` (vmap over streams)."""
    T, H, W = frames.shape
    nby, nbx = H // M.MB, W // M.MB
    qtab = B.quant_table(cfg.quality)        # once per chunk, threaded
    rec0, q0, bits0 = _encode_iframe(frames[0], qtab)

    def step(carry, frame):
        prev_rec = carry
        rec, mv, q, bits, rmag = _encode_pframe(frame, prev_rec, qtab, cfg)
        fdiff = jnp.mean(jnp.abs(frame - prev_rec))
        return rec, (rec, mv, q, bits, rmag, fdiff)

    _, (recs, mvs, qs, bits, rmags, fdiffs) = lax.scan(
        step, rec0, frames[1:])
    recon = jnp.concatenate([rec0[None], recs], axis=0)
    mv = jnp.concatenate([jnp.zeros((1, nby, nbx, 2), jnp.int32), mvs], axis=0)
    residual_q = jnp.concatenate([q0[None], qs], axis=0)
    all_bits = jnp.concatenate([bits0[None], bits], axis=0)
    rmag0 = jnp.mean(jnp.abs(frames[0].astype(f32) - 128.0))
    residual_mag = jnp.concatenate([rmag0[None], rmags], axis=0)
    frame_diff = jnp.concatenate([jnp.zeros((1,), f32), fdiffs], axis=0)
    return EncodedChunk(recon=recon, mv=mv, residual_q=residual_q,
                        qtab=qtab, bits=all_bits,
                        residual_mag=residual_mag, frame_diff=frame_diff)


@partial(jax.jit, static_argnums=(1,))
def encode_chunk(frames, cfg: VideoCodecConfig) -> EncodedChunk:
    """frames: (T, H, W).  Frame 0 is the I-frame (chunks align to GOPs).

    One jit end to end, config static — all call sites (hybrid encoder,
    sim producers, benches) share this compile cache instead of wrapping
    their own ``jax.jit`` per chunk.
    """
    return _encode_chunk(frames, cfg)


def _encode_batch(frames, cfg: VideoCodecConfig) -> EncodedChunk:
    """vmap-over-streams traced body: frames (S, T, H, W) -> every
    EncodedChunk leaf gains a leading stream axis (qtab included, so the
    batched pytree shards uniformly).  Shared by the single-device jit
    below and ``repro.distributed.stream_sharding.shard_encode``."""
    return jax.vmap(lambda f: _encode_chunk(f, cfg))(frames)


@partial(jax.jit, static_argnums=(1,))
def encode_chunk_batched(frames, cfg: VideoCodecConfig) -> EncodedChunk:
    """frames: (S, T, H, W) — one device dispatch encodes S streams.

    Same shape discipline as ``decode_execute_batched``: the leading axis
    is the "stream" logical axis, so the mesh-sharded twin
    (``shard_encode``) splits it over the rule table's stream axes with
    zero-padding for non-divisible stream counts.
    """
    return _encode_batch(frames, cfg)


def decode_chunk(enc: EncodedChunk):
    """The decoder's frame reconstruction (same as encoder's loop)."""
    return enc.recon


def chunk_psnr(raw, recon):
    mse = jnp.mean(jnp.square(raw.astype(f32) - recon.astype(f32)),
                   axis=(1, 2))
    return 10.0 * jnp.log10(255.0 ** 2 / jnp.maximum(mse, 1e-9))
