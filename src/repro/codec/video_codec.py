"""Block-based video codec simulation (I/P frames, MV + DCT-quant residual).

Preserves exactly the codec features BiSwift consumes (paper §IV):
  * 16×16 macroblock motion vectors and per-block residuals (quality
    transfer + reuse pipelines),
  * I/P frame structure and per-frame residual magnitudes (the R_f feature
    accumulated for Eq. 3 classification and the DRL state),
  * QP-style quantization with a bitrate proxy (rate_model.py calibrates
    the 5-level ladder of §VI-A).

All functions are jit/vmap-compatible; chunks are (T, H, W) luma in
[0, 255].  ``encode_chunk`` is a SINGLE module-level ``jax.jit`` (config
static) so every producer shares one compile cache; ``encode_chunk_batched``
vmaps it over a leading stream axis with the same shape discipline as
``decode_execute_batched`` — its mesh-sharded twin is
``repro.distributed.stream_sharding.shard_encode``.

``VideoCodecConfig.use_kernel`` routes the P-frame motion search through
the ``motion_sad`` Pallas kernels; ``dtype="bfloat16"`` selects the bf16
kernel/fallback variants (inputs stored bf16, SADs accumulated f32);
``search="diamond"`` swaps the exhaustive ±R full search for the traced
coarse-to-fine diamond search (≈⅛ the candidate evaluations at R=8,
quality-contract semantics — see docs/fused_encoder.md).

Heterogeneous bitrate ladders: ``encode_chunk_ladder_batched`` encodes a
mixed-rung stream set (different per-stream LR resolutions and QPs) in ONE
padded dispatch.  Streams are padded up to a common (Hp, Wp); a per-stream
valid extent (h, w) is threaded through the motion search, quantization
and the rate model as static-shape masks, and the padded margin is kept
edge-replicated so every valid macroblock sees exactly the search windows
it would see in an unpadded encode.  The contract (held by
``tests/test_fused_encoder.py``) is BIT-exactness in f32: lane s of the
padded batch equals ``encode_chunk`` on stream s's own unpadded frames,
restricted to its valid extent.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
from jax import lax

from repro.codec import blockdct as B
from repro.codec import motion as M

f32 = jnp.float32


@dataclasses.dataclass(frozen=True)
class VideoCodecConfig:
    search_radius: int = 8
    quality: float = 50.0        # quantizer quality factor (QP analogue)
    gop: int = 30                # I-frame period
    use_kernel: bool = False     # P-frame search via the motion_sad kernel
    dtype: str = "float32"       # search storage dtype: float32 | bfloat16
    search: str = "exhaustive"   # motion search strategy: exhaustive | diamond

    @property
    def search_dtype(self):
        if self.dtype in ("bfloat16", "bf16"):
            return jnp.bfloat16
        return None              # motion paths default to f32


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class EncodedChunk:
    """Everything the edge receives for one chunk of one stream."""
    recon: jnp.ndarray          # (T, H, W) decoder reconstruction
    mv: jnp.ndarray             # (T, nby, nbx, 2) motion vectors (frame t-1 -> t)
    residual_q: jnp.ndarray     # (T, nblocks, 8, 8) quantized residual coefs
    qtab: jnp.ndarray           # (8, 8) quant table
    bits: jnp.ndarray           # (T,) per-frame bit cost
    residual_mag: jnp.ndarray   # (T,) mean |residual| per frame (R_f feature)
    frame_diff: jnp.ndarray     # (T,) mean |frame_t - frame_{t-1}| (X_f feature)


def _edge_extend(frame, h, w):
    """Overwrite the padded margin of ``frame`` ((Hp, Wp)) with edge
    replication of the valid (h, w) region (clipped-index gather — equal to
    ``jnp.pad(frame[:h, :w], ..., mode="edge")`` for traced extents).

    This is the invariant the heterogeneous-ladder encode maintains on
    every reference frame: a valid macroblock's search/warp window then
    reads the same edge-replicated content it would read from the radius
    padding of an unpadded encode, which is what makes the masked path
    bit-exact."""
    Hp, Wp = frame.shape
    yy = jnp.minimum(jnp.arange(Hp), h - 1)
    xx = jnp.minimum(jnp.arange(Wp), w - 1)
    return frame[yy][:, xx]


def _extent_masks(Hp: int, Wp: int, h, w) -> dict:
    """Static-shape validity masks + counts for a traced (h, w) extent."""
    mb = M.MB
    return dict(
        h=h, w=w,
        pix=(jnp.arange(Hp)[:, None] < h) & (jnp.arange(Wp)[None, :] < w),
        bm8=((jnp.arange(Hp // 8)[:, None] < h // 8)
             & (jnp.arange(Wp // 8)[None, :] < w // 8)).reshape(-1),
        mb=(jnp.arange(Hp // mb)[:, None] < h // mb)
        & (jnp.arange(Wp // mb)[None, :] < w // mb),
        n8=(h // 8) * (w // 8),
        nmb=(h // mb) * (w // mb),
        # 1/(h*w) as a correctly-rounded f32 reciprocal (the masked mean
        # multiplies by this instead of dividing by a traced count)
        recip=jnp.asarray(1.0, f32) / jnp.asarray(h * w, f32),
    )


def _mean_abs(x, masks) -> jnp.ndarray:
    """mean(|x|), reduced as fixed 16x16 tile partials + an order-stable
    hierarchical accumulation (``blockdct.seq_sum`` on the 2-D tile
    grid: per-row scans, then a scan over row totals).

    Both the plain and the masked form reduce THIS way so the
    heterogeneous-ladder padded encode stays bit-exact: the masked form
    zeroes the padded margin, whose tile partials then contribute exact
    fp no-ops — a column suffix within each row and a suffix of all-zero
    rows — to the same add sequence the unpadded encode performs over its
    (fewer) valid tiles."""
    Hp, Wp = x.shape
    mb = M.MB
    a = jnp.abs(x)
    if masks is None:
        recip = jnp.asarray(1.0, f32) / jnp.asarray(Hp * Wp, f32)
    else:
        a = jnp.where(masks["pix"], a, 0.0)
        recip = masks["recip"]
    tiles = a.reshape(Hp // mb, mb, Wp // mb, mb).sum(axis=(1, 3))
    return B.seq_sum(tiles) * recip


def _encode_iframe(frame, qtab, masks=None):
    grid8 = (frame.shape[0] // 8, frame.shape[1] // 8)
    blocks = B.blockify(frame.astype(f32) - 128.0)
    q = B.quantize_with_table(B.dct2(blocks), qtab)
    if masks is None:
        bits = B.entropy_bits(q, grid=grid8)
    else:
        bits = B.entropy_bits(q, masks["bm8"], masks["n8"], grid=grid8)
        q = jnp.where(masks["bm8"][:, None, None], q, 0.0)
    rec = B.unblockify(B.idct2(B.dequantize(q, qtab)),
                       *frame.shape) + 128.0
    return jnp.clip(rec, 0.0, 255.0), q, bits


def _encode_pframe(frame, ref_recon, qtab, cfg: VideoCodecConfig,
                   masks=None):
    mv, _ = M.block_sad(frame, ref_recon, cfg.search_radius,
                        use_kernel=cfg.use_kernel, dtype=cfg.search_dtype,
                        search=cfg.search)
    if masks is not None:
        mv = jnp.where(masks["mb"][..., None], mv, 0)
    pred = M.warp_blocks(ref_recon, mv)
    resid = frame.astype(f32) - pred
    grid8 = (frame.shape[0] // 8, frame.shape[1] // 8)
    blocks = B.blockify(resid)
    q = B.quantize_with_table(B.dct2(blocks), qtab)
    if masks is None:
        bits = B.entropy_bits(q, grid=grid8) \
            + mv.size * 3.0                         # MV coding cost proxy
    else:
        bits = B.entropy_bits(q, masks["bm8"], masks["n8"], grid=grid8) \
            + masks["nmb"].astype(f32) * 6.0        # 2 components x 3 bits
        q = jnp.where(masks["bm8"][:, None, None], q, 0.0)
    rec_resid = B.unblockify(B.idct2(B.dequantize(q, qtab)), *frame.shape)
    rec = jnp.clip(pred + rec_resid, 0.0, 255.0)
    return rec, mv, q, bits, _mean_abs(resid, masks)


def _encode_chunk(frames, cfg: VideoCodecConfig, extent=None,
                  quality=None) -> EncodedChunk:
    """Traced body shared by ``encode_chunk`` (one stream) and
    ``encode_chunk_batched`` (vmap over streams).

    ``extent`` ((h, w), traced int scalars) activates the masked
    heterogeneous-ladder form: ``frames`` is a zero/garbage-padded
    (T, Hp, Wp) canvas whose valid region is (h, w); the encode then
    reproduces the unpadded (h, w) encode bit-for-bit on the valid
    extent (padded MVs/coefficients are zeroed, padded recon is
    edge-replicated).  ``quality`` (traced f32) overrides ``cfg.quality``
    so one dispatch can serve per-stream QPs."""
    T, H, W = frames.shape
    nby, nbx = H // M.MB, W // M.MB
    qtab = B.quant_table(cfg.quality if quality is None else quality)
    if extent is None:
        masks = None
    else:
        h, w = extent
        masks = _extent_masks(H, W, h, w)
        # normalize whatever padding the caller shipped: the margin must
        # be edge-replicated for the window-content equivalence to hold
        frames = jax.vmap(lambda f: _edge_extend(f, h, w))(frames)
    rec0, q0, bits0 = _encode_iframe(frames[0], qtab, masks)
    if masks is not None:
        # the padded margin's recon is NOT the replication of the valid
        # recon (it is the quantized round trip of the replicated input);
        # re-extend so P-frame search windows match the unpadded encode
        rec0 = _edge_extend(rec0, masks["h"], masks["w"])

    def step(carry, frame):
        prev_rec = carry
        rec, mv, q, bits, rmag = _encode_pframe(frame, prev_rec, qtab, cfg,
                                                masks)
        fdiff = _mean_abs(frame - prev_rec, masks)
        if masks is not None:
            rec = _edge_extend(rec, masks["h"], masks["w"])
        return rec, (rec, mv, q, bits, rmag, fdiff)

    _, (recs, mvs, qs, bits, rmags, fdiffs) = lax.scan(
        step, rec0, frames[1:])
    recon = jnp.concatenate([rec0[None], recs], axis=0)
    mv = jnp.concatenate([jnp.zeros((1, nby, nbx, 2), jnp.int32), mvs], axis=0)
    residual_q = jnp.concatenate([q0[None], qs], axis=0)
    all_bits = jnp.concatenate([bits0[None], bits], axis=0)
    rmag0 = _mean_abs(frames[0].astype(f32) - 128.0, masks)
    residual_mag = jnp.concatenate([rmag0[None], rmags], axis=0)
    frame_diff = jnp.concatenate([jnp.zeros((1,), f32), fdiffs], axis=0)
    return EncodedChunk(recon=recon, mv=mv, residual_q=residual_q,
                        qtab=qtab, bits=all_bits,
                        residual_mag=residual_mag, frame_diff=frame_diff)


@partial(jax.jit, static_argnums=(1,))
def encode_chunk(frames, cfg: VideoCodecConfig) -> EncodedChunk:
    """frames: (T, H, W).  Frame 0 is the I-frame (chunks align to GOPs).

    One jit end to end, config static — all call sites (hybrid encoder,
    sim producers, benches) share this compile cache instead of wrapping
    their own ``jax.jit`` per chunk.
    """
    return _encode_chunk(frames, cfg)


def _encode_batch(frames, cfg: VideoCodecConfig) -> EncodedChunk:
    """vmap-over-streams traced body: frames (S, T, H, W) -> every
    EncodedChunk leaf gains a leading stream axis (qtab included, so the
    batched pytree shards uniformly).  Shared by the single-device jit
    below and ``repro.distributed.stream_sharding.shard_encode``."""
    return jax.vmap(lambda f: _encode_chunk(f, cfg))(frames)


@partial(jax.jit, static_argnums=(1,))
def encode_chunk_batched(frames, cfg: VideoCodecConfig) -> EncodedChunk:
    """frames: (S, T, H, W) — one device dispatch encodes S streams.

    Same shape discipline as ``decode_execute_batched``: the leading axis
    is the "stream" logical axis, so the mesh-sharded twin
    (``shard_encode``) splits it over the rule table's stream axes with
    zero-padding for non-divisible stream counts.
    """
    return _encode_batch(frames, cfg)


def _encode_ladder_batch(frames, extents, qualities,
                         cfg: VideoCodecConfig) -> EncodedChunk:
    """vmap-over-streams traced body of the heterogeneous-ladder encode:
    frames (S, T, Hp, Wp) padded canvases, extents (S, 2) int32 valid
    (h, w) per stream, qualities (S,) f32 per-stream QP.  Shared by
    ``encode_chunk_ladder_batched`` and the mesh-sharded round-trip
    (``repro.distributed.stream_sharding.shard_roundtrip``)."""
    return jax.vmap(
        lambda f, e, q: _encode_chunk(f, cfg, extent=(e[0], e[1]),
                                      quality=q))(frames, extents, qualities)


@partial(jax.jit, static_argnums=(3,))
def encode_chunk_ladder_batched(frames, extents, qualities,
                                cfg: VideoCodecConfig) -> EncodedChunk:
    """One padded device dispatch encodes S streams of MIXED ladder rungs.

    frames: (S, T, Hp, Wp) — each stream's LR chunk zero-padded to the
    common canvas (see ``pad_ladder_batch``); extents: (S, 2) int32 valid
    (h, w); qualities: (S,) f32 per-stream quantizer quality.  Lane s is
    bit-exact (f32) vs ``encode_chunk`` on stream s's unpadded frames over
    the valid extent; padded MVs/coefficients are zero and the padded
    recon margin is edge-replicated.  ``cfg.quality`` is ignored (the
    per-stream array wins); ``use_kernel``/``dtype`` apply to all lanes.
    """
    return _encode_ladder_batch(frames, extents, qualities, cfg)


def pad_ladder_batch(chunks):
    """Host helper: stack mixed-shape LR chunks onto one padded canvas.

    chunks: sequence of (T, h_s, w_s) arrays (same T, heterogeneous
    ladder shapes).  Returns (frames (S, T, Hp, Wp), extents (S, 2) int32)
    for ``encode_chunk_ladder_batched``.  Padding content is irrelevant —
    the masked encode re-edge-replicates the margin in-trace."""
    Hp = max(c.shape[1] for c in chunks)
    Wp = max(c.shape[2] for c in chunks)
    frames = jnp.stack([
        jnp.pad(jnp.asarray(c, f32),
                ((0, 0), (0, Hp - c.shape[1]), (0, Wp - c.shape[2])))
        for c in chunks])
    extents = jnp.asarray([c.shape[1:] for c in chunks], jnp.int32)
    return frames, extents


def decode_chunk(enc: EncodedChunk):
    """The decoder's frame reconstruction (same as encoder's loop)."""
    return enc.recon


def chunk_psnr(raw, recon):
    mse = jnp.mean(jnp.square(raw.astype(f32) - recon.astype(f32)),
                   axis=(1, 2))
    return 10.0 * jnp.log10(255.0 ** 2 / jnp.maximum(mse, 1e-9))
