"""Baseline VAPs re-implemented for comparison (paper §VI-A):

  * AccDecoder [28] — DRL frame classification + super-resolution
    enhancement of LR video on the edge (no HD anchors; SR compute cost).
  * Reducto [6] — camera-side frame filtering by a learned diff threshold;
    sent frames get full inference, filtered frames reuse the last result.
  * NeuroScaler* [25] — selective SR on anchor frames, reuse elsewhere
    (extended for analytics per the paper).
  * BiSwift — our system (hybrid codec + 3 pipelines).

All four run on the same analytic accuracy backend and latency model as
the env, so benchmark deltas isolate the *policy*, exactly like the
paper's even-bandwidth-for-baselines protocol.  Per-frame edge costs:
inference 33 ms; SR ~80 ms/frame (the paper's motivation for avoiding
per-frame SR); reuse 6 ms.
"""
from __future__ import annotations


import numpy as np
import jax.numpy as jnp

from repro.codec.rate_model import QUALITY_LADDER, ladder_for_bandwidth
from repro.core.classification import classify_frames
from repro.sim.env import analytic_f1

f32 = np.float32

COST_INFER = 0.033
COST_SR = 0.080
COST_REUSE = 0.006
COST_TRANSFER = 0.010


def _features(frames):
    fd = np.abs(np.diff(frames, axis=0)).mean(axis=(1, 2)) / 255.0
    return np.concatenate([[0.0], fd])


def _video_bits(level: int, T: int, fps: float) -> float:
    return QUALITY_LADDER[level].bitrate_kbps * 1000.0 * (T / fps)


def _result(name, accs, t_comp, bits, bw_kbps, T, fps, n_infer,
            t_gpu=None):
    t_trans = bits / max(bw_kbps * 1000.0, 1e-6)
    return {"policy": name, "accuracy": float(np.mean(accs)),
            "latency": t_trans + t_comp, "t_trans": t_trans,
            "t_comp": t_comp, "bits": bits, "n_infer": n_infer,
            # GPU-side time only: the paper runs reuse + DRL on CPU (§VII)
            "t_gpu": t_comp if t_gpu is None else t_gpu,
            "utilization": min(bits / max(bw_kbps * 1000.0 * (T / fps),
                                          1e-6), 1.0)}



def _reuse_decay(since: float, speed: float) -> float:
    """Pipeline-3 decay (paper Fig. 8b): boxes shift by mean MV; accuracy
    degrades with motion and distance from the last inference."""
    return max(1.0 - 0.03 * speed * since, 0.3)

def run_biswift(frames, boxes, valid, bw_kbps, stream_cfg, *,
                tr1=0.05, tr2=0.10, fps=30.0):
    T = frames.shape[0]
    fd = _features(frames)
    rm = fd * 0.8 + 0.02
    types = np.asarray(classify_frames(jnp.asarray(fd), jnp.asarray(rm),
                                       tr1, tr2)[0]).copy()
    # adaptive split (paper §IV-A): anchors and video SHARE the stream's
    # allocation.  Charge actual anchor bits; if the agent requested more
    # anchors than the link affords, demote the excess to the transfer
    # pipeline (the accuracy-first policy keeps them sparse, 7-8%).
    chunk_s = T / fps
    budget_bits = bw_kbps * 1000.0 * chunk_s
    video_floor = QUALITY_LADDER[0].bitrate_kbps * 1000.0 * chunk_s
    afford = max(int((budget_bits - video_floor) / 45_000.0), 1)
    anchor_ids = np.nonzero(types == 1)[0]
    if len(anchor_ids) > afford:
        for i in anchor_ids[afford:]:
            types[i] = 2                     # demoted: transfer + infer
    n_anchors = int((types == 1).sum())
    anchor_kbps = n_anchors * 45.0 / chunk_s
    level = ladder_for_bandwidth(max(bw_kbps - anchor_kbps, 0.0))
    ql = QUALITY_LADDER[level]
    obj = float(boxes[0, :, 2:].mean())
    n = int(valid[0].sum())
    accs, since, last = [], 0.0, 0.0
    for ty in types:
        if ty != 3:
            since = 0.0
            scale = 1.0 if ty == 1 else ql.scale
            qual = 80.0 if ty == 1 else ql.quality
            last = analytic_f1(scale, qual, obj, n, int(ty), 0.0,
                               stream_cfg.speed)
            accs.append(last)
        else:
            since += 1.0
            accs.append(last * _reuse_decay(since, stream_cfg.speed))
    n1, n2, n3 = [(types == k).sum() for k in (1, 2, 3)]
    t_comp = n1 * COST_INFER + n2 * (COST_INFER + COST_TRANSFER) \
        + n3 * COST_REUSE
    bits = _video_bits(level, T, fps) + n1 * 45_000.0
    return _result("biswift", accs, t_comp, bits, bw_kbps, T, fps,
                   int(n1 + n2),
                   t_gpu=n1 * COST_INFER + n2 * (COST_INFER + COST_TRANSFER))


def run_accdecoder(frames, boxes, valid, bw_kbps, stream_cfg, *,
                   anchor_frac=0.26, fps=30.0):
    """LR video only; anchors SR-enhanced then inferred; rest reused."""
    T = frames.shape[0]
    level = ladder_for_bandwidth(bw_kbps)      # all bandwidth to video
    ql = QUALITY_LADDER[level]
    obj = float(boxes[0, :, 2:].mean())
    n = int(valid[0].sum())
    n_anchor = max(int(round(anchor_frac * T)), 1)
    anchor_every = max(T // n_anchor, 1)
    accs, since, last = [], 0.0, 0.0
    n_inf = 0
    for t in range(T):
        if t % anchor_every == 0:
            since = 0.0
            n_inf += 1
            # SR roughly doubles effective scale, capped at 1
            sr_scale = min(ql.scale * 2.0, 1.0) * 0.92  # SR artifacts
            last = analytic_f1(sr_scale, ql.quality, obj, n, 1, 0.0,
                               stream_cfg.speed)
            accs.append(last)
        else:
            since += 1.0
            accs.append(last * _reuse_decay(since, stream_cfg.speed))
    t_comp = n_inf * (COST_SR + COST_INFER) + (T - n_inf) * COST_REUSE
    bits = _video_bits(level, T, fps)
    return _result("accdecoder", accs, t_comp, bits, bw_kbps, T, fps,
                   n_inf, t_gpu=n_inf * (COST_SR + COST_INFER))


def run_reducto(frames, boxes, valid, bw_kbps, stream_cfg, *,
                diff_thresh=0.03, fps=30.0):
    """Camera-side filtering: frames below the diff threshold are dropped."""
    T = frames.shape[0]
    fd = _features(frames)
    sent = (fd > diff_thresh)
    sent[0] = True
    frac_sent = float(sent.mean())
    # rate control reacts with delay: the effective ladder boost from
    # dropping frames is capped (cannot assume perfect foresight)
    level = ladder_for_bandwidth(bw_kbps / max(frac_sent, 0.6))
    ql = QUALITY_LADDER[level]
    obj = float(boxes[0, :, 2:].mean())
    n = int(valid[0].sum())
    accs, since, last = [], 0.0, 0.0
    for t in range(T):
        if sent[t]:
            since = 0.0
            last = analytic_f1(ql.scale, ql.quality, obj, n, 1, 0.0,
                               stream_cfg.speed)
            accs.append(last)
        else:
            since += 1.0
            accs.append(last * _reuse_decay(since, stream_cfg.speed))
    n_inf = int(sent.sum())
    t_comp = n_inf * COST_INFER + (T - n_inf) * COST_REUSE
    bits = _video_bits(level, T, fps) * frac_sent
    return _result("reducto", accs, t_comp, bits, bw_kbps, T, fps, n_inf,
                   t_gpu=n_inf * COST_INFER)


def run_neuroscaler(frames, boxes, valid, bw_kbps, stream_cfg, *,
                    anchor_frac=0.26, fps=30.0):
    """Selective SR on anchors (QoE->analytics extension: infer anchors,
    reuse elsewhere)."""
    T = frames.shape[0]
    level = ladder_for_bandwidth(bw_kbps)
    ql = QUALITY_LADDER[level]
    obj = float(boxes[0, :, 2:].mean())
    n = int(valid[0].sum())
    n_anchor = max(int(round(anchor_frac * T)), 1)
    anchor_every = max(T // n_anchor, 1)
    accs, since, last = [], 0.0, 0.0
    n_inf = 0
    for t in range(T):
        if t % anchor_every == 0:
            since = 0.0
            n_inf += 1
            sr_scale = min(ql.scale * 2.0, 1.0) * 0.90
            last = analytic_f1(sr_scale, ql.quality, obj, n, 1, 0.0,
                               stream_cfg.speed)
            accs.append(last)
        else:
            since += 1.0
            accs.append(last * _reuse_decay(since, stream_cfg.speed))
    t_comp = n_inf * (COST_SR + COST_INFER) + (T - n_inf) * COST_REUSE
    bits = _video_bits(level, T, fps)
    return _result("neuroscaler*", accs, t_comp, bits, bw_kbps, T, fps,
                   n_inf, t_gpu=n_inf * (COST_SR + COST_INFER))


BASELINES = {
    "biswift": run_biswift,
    "accdecoder": run_accdecoder,
    "reducto": run_reducto,
    "neuroscaler*": run_neuroscaler,
}
