from repro.baselines.policies import (  # noqa: F401
    run_accdecoder, run_biswift, run_neuroscaler, run_reducto, BASELINES,
)
