"""Logical-axis sharding rules.

Every parameter / activation in the model zoo is annotated with *logical*
axis names ("batch", "fsdp", "model_q_heads", ...).  A rule table maps each
logical name onto zero or more *mesh* axes.  This keeps the model code
mesh-agnostic: single-pod (data, model) and multi-pod (pod, data, model)
meshes only differ in their rule tables.

Logical axes used across the zoo
--------------------------------
batch     activation batch dim                -> (pod, data)
fsdp      weight storage shard (ZeRO-3 style) -> (data,)
tensor    tensor-parallel weight dim          -> (model,)
seq_kv    decode KV-cache sequence dim        -> (model,)   (flash-decoding)
expert    MoE expert dim (EP hillclimb)       -> ()  baseline / ("model",) EP
stream    serving stream dim (one video feed) -> (data,)  /  (pod, data)
None      replicated

The "stream" axis is the serving-side analogue of "batch": the fused
chunk executor (`decode_execute_batched`) carries one independent video
stream per leading-axis element, so data-parallel placement over the mesh
is exact — no cross-stream collectives exist in the chunk computation.
`repro.distributed.stream_sharding.shard_streams` consumes these rules.
"""
from __future__ import annotations

import dataclasses
from typing import Mapping, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class AxisRules:
    """Maps logical axis name -> tuple of mesh axis names."""

    table: Mapping[str, tuple[str, ...]]

    def mesh_axes(self, logical: str | None) -> tuple[str, ...]:
        if logical is None:
            return ()
        return tuple(self.table.get(logical, ()))


SINGLE_POD_RULES = AxisRules(
    {
        "batch": ("data",),
        "fsdp": ("data",),
        "tensor": ("model",),
        "seq_kv": ("model",),
        "expert": (),
        "stream": ("data",),
    }
)

MULTI_POD_RULES = AxisRules(
    {
        "batch": ("pod", "data"),
        "fsdp": ("data",),
        "tensor": ("model",),
        "seq_kv": ("model",),
        "expert": (),
        "stream": ("pod", "data"),
    }
)

# Hillclimb variants ---------------------------------------------------------
# Expert-parallel MoE: expert dim over model axis (requires E % model == 0).
SINGLE_POD_RULES_EP = AxisRules(
    {**SINGLE_POD_RULES.table, "expert": ("model",), "tensor": ()}
)
MULTI_POD_RULES_EP = AxisRules(
    {**MULTI_POD_RULES.table, "expert": ("model",), "tensor": ()}
)
# FSDP over both pod and data (ZeRO across pods; trades collective locality).
MULTI_POD_RULES_FSDP_POD = AxisRules(
    {**MULTI_POD_RULES.table, "fsdp": ("pod", "data")}
)
# Decode: replicate the KV cache over the tensor axis (q heads stay
# sharded) — removes the per-layer softmax psum over sequence shards at the
# cost of ~tensor× cache replication (fits: caches are ~1 GB/dev).
SINGLE_POD_RULES_KVREP = AxisRules(
    {**SINGLE_POD_RULES.table, "seq_kv": ()}
)
MULTI_POD_RULES_KVREP = AxisRules(
    {**MULTI_POD_RULES.table, "seq_kv": ()}
)
# Vision: pure data parallelism — small convnets replicate weights and
# shard batch over every chip; TP for 25-100M-param models is overhead.
# Serving streams ride the same placement: the tiny edge detector is
# replicated, so streams can spread over the model axis too.
SINGLE_POD_RULES_DP = AxisRules(
    {"batch": ("data", "model"), "fsdp": (), "tensor": (), "seq_kv": (),
     "expert": (), "stream": ("data", "model")}
)
MULTI_POD_RULES_DP = AxisRules(
    {"batch": ("pod", "data", "model"), "fsdp": (), "tensor": (),
     "seq_kv": (), "expert": (), "stream": ("pod", "data", "model")}
)

_NAMED_RULES = {
    ("single", "baseline"): SINGLE_POD_RULES,
    ("multi", "baseline"): MULTI_POD_RULES,
    ("single", "ep"): SINGLE_POD_RULES_EP,
    ("multi", "ep"): MULTI_POD_RULES_EP,
    ("multi", "fsdp_pod"): MULTI_POD_RULES_FSDP_POD,
    ("single", "kvrep"): SINGLE_POD_RULES_KVREP,
    ("multi", "kvrep"): MULTI_POD_RULES_KVREP,
    ("single", "dp"): SINGLE_POD_RULES_DP,
    ("multi", "dp"): MULTI_POD_RULES_DP,
    # fast_train*: baseline rules + config overrides (bf16 grad accum,
    # capacity factor 1.0; fast_train4 also halves grad-accum microbatches)
    # applied in launch/dryrun.py
    ("single", "fast_train"): SINGLE_POD_RULES,
    ("multi", "fast_train"): MULTI_POD_RULES,
    ("single", "fast_train4"): SINGLE_POD_RULES,
    ("multi", "fast_train4"): MULTI_POD_RULES,
    # kvint8: baseline rules + int8 KV cache (config override in dryrun)
    ("single", "kvint8"): SINGLE_POD_RULES,
    ("multi", "kvint8"): MULTI_POD_RULES,
}


def make_axis_rules(multi_pod: bool, variant: str = "baseline") -> AxisRules:
    return _NAMED_RULES[("multi" if multi_pod else "single", variant)]


def logical_to_spec(
    logical_axes: Sequence[str | None],
    rules: AxisRules,
    shape: Sequence[int] | None = None,
) -> P:
    """Translate per-dim logical names into a PartitionSpec.

    If ``shape`` is given, any dim whose size is not divisible by the product
    of its mesh-axis sizes is demoted to replicated (guard for e.g. 60
    experts over a 16-way axis).  Mesh axis sizes are looked up lazily from
    the ambient mesh at spec-build time in :func:`named_sharding`.
    """
    parts = []
    for name in logical_axes:
        axes = rules.mesh_axes(name)
        if len(axes) == 0:
            parts.append(None)
        elif len(axes) == 1:
            parts.append(axes[0])
        else:
            parts.append(tuple(axes))
    # strip trailing Nones for a tidy spec
    while parts and parts[-1] is None:
        parts.pop()
    return P(*parts)


def _axis_size(mesh: Mesh, entry) -> int:
    if entry is None:
        return 1
    if isinstance(entry, tuple):
        n = 1
        for a in entry:
            n *= mesh.shape[a]
        return n
    return mesh.shape[entry]


def validated_spec(spec: P, shape: Sequence[int], mesh: Mesh) -> P:
    """Demote non-divisible dims to replicated so lowering never fails."""
    parts = list(spec) + [None] * (len(shape) - len(spec))
    out = []
    for dim, entry in zip(shape, parts):
        n = _axis_size(mesh, entry)
        out.append(entry if (n > 1 and dim % n == 0) or n == 1 else None)
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def named_sharding(
    mesh: Mesh, logical_axes: Sequence[str | None], rules: AxisRules,
    shape: Sequence[int] | None = None,
) -> NamedSharding:
    spec = logical_to_spec(logical_axes, rules)
    if shape is not None:
        spec = validated_spec(spec, shape, mesh)
    return NamedSharding(mesh, spec)


def tree_shardings(mesh: Mesh, specs_tree, rules: AxisRules):
    """Map a pytree of ParamSpec -> pytree of NamedSharding."""
    from repro.models.params import ParamSpec  # local import, avoid cycle

    def one(s: ParamSpec):
        return named_sharding(mesh, s.axes, rules, s.shape)

    return jax.tree.map(one, specs_tree,
                        is_leaf=lambda x: isinstance(x, ParamSpec))
