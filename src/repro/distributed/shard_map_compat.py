"""jax ``shard_map`` version compat, in ONE place.

Two renames happened across jax releases: the entry point moved from
``jax.experimental.shard_map`` to a top-level ``jax.shard_map`` export,
and the replication-checking kwarg went from ``check_rep`` to
``check_vma``.  Every call site (``distributed.stream_sharding``, the MoE
dispatch in ``models.layers``) goes through :func:`shard_map_compat` so
the next rename is a one-line fix.
"""
from __future__ import annotations

import inspect

try:                                   # jax >= 0.5 top-level export
    from jax import shard_map as _shard_map
except ImportError:                    # pragma: no cover - older jax
    from jax.experimental.shard_map import shard_map as _shard_map

CHECK_KW = ("check_vma" if "check_vma"
            in inspect.signature(_shard_map).parameters else "check_rep")


def shard_map_compat(body, mesh, in_specs, out_specs, check: bool = False):
    return _shard_map(body, mesh=mesh, in_specs=in_specs,
                      out_specs=out_specs, **{CHECK_KW: check})
