"""Ambient shard context.

Model code consults this at *trace* time to decide whether to emit explicit
``shard_map`` regions (MoE sorted dispatch must not argsort a globally
sharded token axis — that would force an all-gather of every token).
Launchers trace/lower inside ``with shard_ctx(mesh, rules): ...``; CPU smoke
tests trace with no context and take the purely local paths.
"""
from __future__ import annotations

import contextlib
import dataclasses

from jax.sharding import Mesh

from repro.distributed.sharding import AxisRules


@dataclasses.dataclass(frozen=True)
class ShardCtx:
    mesh: Mesh
    rules: AxisRules

    @property
    def batch_axes(self) -> tuple[str, ...]:
        return self.rules.mesh_axes("batch")

    @property
    def tensor_axes(self) -> tuple[str, ...]:
        return self.rules.mesh_axes("tensor")

    @property
    def stream_axes(self) -> tuple[str, ...]:
        """Mesh axes the serving stream dim shards over (axes named by the
        rule table but absent from this mesh are dropped)."""
        from repro.distributed.stream_sharding import stream_axis_names
        return stream_axis_names(self.mesh, self.rules)

    @property
    def stream_shards(self) -> int:
        """Stream-axis data-parallel extent of the ambient mesh."""
        return self.axis_size(self.stream_axes)

    def axis_size(self, axes: tuple[str, ...]) -> int:
        n = 1
        for a in axes:
            n *= self.mesh.shape[a]
        return n


_CTX: list[ShardCtx] = []


@contextlib.contextmanager
def shard_ctx(mesh: Mesh, rules: AxisRules):
    _CTX.append(ShardCtx(mesh, rules))
    try:
        yield _CTX[-1]
    finally:
        _CTX.pop()


def current_ctx() -> ShardCtx | None:
    return _CTX[-1] if _CTX else None
