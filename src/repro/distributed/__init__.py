from repro.distributed.sharding import (  # noqa: F401
    AxisRules,
    SINGLE_POD_RULES,
    MULTI_POD_RULES,
    logical_to_spec,
    make_axis_rules,
)
from repro.distributed.stream_sharding import (  # noqa: F401
    pad_stream_axis,
    shard_streams,
    stream_shard_count,
    stream_sharding,
)
