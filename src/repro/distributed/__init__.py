from repro.distributed.sharding import (  # noqa: F401
    AxisRules,
    SINGLE_POD_RULES,
    MULTI_POD_RULES,
    logical_to_spec,
    make_axis_rules,
)
