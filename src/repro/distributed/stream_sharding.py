"""Shard the fused batched stream runtime over a device mesh.

``decode_execute_batched`` treats its leading axis as independent video
streams — there are no cross-stream collectives anywhere in the chunk
computation — so data-parallel placement over the mesh's "stream" axes is
exact: each device runs the same fused vmap over its local slice of
streams and the results concatenate back bit-for-bit.

``shard_streams(mesh, rules)`` returns a callable with the same signature
as ``decode_execute_batched``:

  * the stream axis is zero-padded up to a multiple of the mesh's stream
    extent (non-divisible stream counts — e.g. 3 streams on 4 devices —
    stay legal; padded lanes are computed and dropped),
  * stream-leading operands enter a ``shard_map`` region split over the
    rule table's "stream" axes; detector params are replicated,
  * outputs are unpadded back to the caller's stream count.

The single-device vmap stays the oracle: ``tests/test_stream_sharding.py``
forces a 4-device CPU platform in a subprocess and asserts bit-exact
parity for divisible and non-divisible stream counts.

``shard_encode(mesh, rules, cfg=...)`` is the encoder-side twin: it wraps
``encode_chunk_batched``'s body the same way (streams are just as
independent on the encode path), so camera-side chunk encoding scales over
the same "stream" mesh axes as edge-side execution
(``tests/test_fused_encoder.py`` holds its parity matrix).

``shard_roundtrip(mesh, rules, cfg=...)`` shards the WHOLE fused
encode->decode round trip (``repro.core.roundtrip``): each device runs
source-frames->HD-detections for its local slice of streams in one
program.  Mixed bitrate-ladder rungs are legal — the shard_map body is the
post-downscale heterogeneous form, so per-stream extents/QPs travel as
data while the shape-changing per-rung downscale stays outside the
region.  Padded stream lanes carry FULL-canvas extents (not zeros) so
their masked means never divide by zero.  ``RoundtripConfig.anchor_search``
rides through unchanged: the masked quality-ladder sweep is per-stream
data-parallel like everything else, and the ``anchor_q`` output follows the
same stream-leading out_specs as the rest of the result dict.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.distributed.shard_map_compat import shard_map_compat
from repro.distributed.sharding import AxisRules

f32 = jnp.float32


def stream_axis_names(mesh: Mesh, rules: AxisRules) -> tuple[str, ...]:
    """The rule table's "stream" axes that actually exist in ``mesh``."""
    return tuple(a for a in rules.mesh_axes("stream") if a in mesh.shape)


def stream_shard_count(mesh: Mesh, rules: AxisRules) -> int:
    """How many ways the stream axis splits on this mesh."""
    n = 1
    for a in stream_axis_names(mesh, rules):
        n *= mesh.shape[a]
    return n


def stream_partition_spec(mesh: Mesh, rules: AxisRules) -> P:
    axes = stream_axis_names(mesh, rules)
    if not axes:
        return P()
    return P(axes[0] if len(axes) == 1 else axes)


def stream_sharding(mesh: Mesh, rules: AxisRules) -> NamedSharding:
    return NamedSharding(mesh, stream_partition_spec(mesh, rules))


def pad_stream_axis(tree, n_shards: int):
    """Zero-pad every leaf's leading (stream) axis to a multiple of
    ``n_shards``.  Zero lanes are safe: each stream's chunk computation is
    independent and guarded against degenerate inputs (bw floors at 1e-6,
    F1 on empty boxes is finite), and the wrapper drops them on exit."""
    def one(x):
        x = jnp.asarray(x)
        s = x.shape[0]
        s_pad = -(-s // n_shards) * n_shards
        if s_pad == s:
            return x
        pad = [(0, s_pad - s)] + [(0, 0)] * (x.ndim - 1)
        return jnp.pad(x, pad)

    return jax.tree.map(one, tree)


def shard_encode(mesh: Mesh, rules: AxisRules, *, cfg):
    """Build the mesh-sharded twin of ``encode_chunk_batched``.

    Returns ``run(frames)`` where frames is (S, T, H, W): the stream axis
    is zero-padded up to the mesh's stream extent, each device encodes its
    local slice of streams through the single-jit codec body, and outputs
    unpad back to S.  Zero-frame lanes are safe — the codec is total on
    constant frames (the all-ties motion search resolves first-wins) — and
    they are dropped on exit.  ``cfg`` (``VideoCodecConfig``) is bound at
    build time: it is a static jit argument."""
    from repro.codec.video_codec import _encode_batch

    spec = stream_partition_spec(mesh, rules)
    n_shards = stream_shard_count(mesh, rules)

    sharded = jax.jit(shard_map_compat(
        lambda f: _encode_batch(f, cfg), mesh=mesh,
        in_specs=(spec,), out_specs=spec,
    ))

    def run(frames):
        frames = jnp.asarray(frames)
        s = frames.shape[0]
        (padded,) = pad_stream_axis((frames,), n_shards)
        out = sharded(padded)
        return jax.tree.map(lambda x: x[:s], out)

    return run


def shard_roundtrip(mesh: Mesh, rules: AxisRules, *, cfg):
    """Build the mesh-sharded twin of ``roundtrip_batched`` /
    ``roundtrip_ladder_batched``.

    Returns ``run(raw, gt_boxes, gt_valid, detector_params, *, tr1, tr2,
    bw_kbps, queue_delay, levels=None)`` where raw is (S, T, H, W) source
    frames and the keyword scalars broadcast to (S,).  ``levels`` (host
    tuple, one ladder rung per stream) defaults to ``cfg.level`` for all
    streams; mixed rungs run through the padded heterogeneous encode, so
    one shard_map region serves the whole mixed-ladder stream set.  The
    stream axis is zero-padded to the mesh's stream extent; padded lanes
    get full-canvas extents (a zero extent would poison the masked means
    with 0/0) and are dropped on exit.  ``cfg`` (``RoundtripConfig``) is
    bound at build time — it is a static jit argument."""
    from repro.core.roundtrip import (_downscale_pad, _roundtrip_ladder_body,
                                      ladder_batch_arrays)

    spec = stream_partition_spec(mesh, rules)
    n_shards = stream_shard_count(mesh, rules)

    def body(raw, lr_pad, extents, qualities, gb, gv, params, t1, t2,
             bw, qd):
        return _roundtrip_ladder_body(raw, lr_pad, extents, qualities, gb,
                                      gv, params, t1, t2, bw, qd, cfg)

    sharded = jax.jit(shard_map_compat(
        body, mesh=mesh,
        in_specs=(spec, spec, spec, spec, spec, spec, P(), spec, spec,
                  spec, spec),
        out_specs=spec,
    ))

    def run(raw, gt_boxes, gt_valid, detector_params, *, tr1, tr2, bw_kbps,
            queue_delay=0.0, levels=None):
        raw = jnp.asarray(raw, f32)
        s = raw.shape[0]
        levels = tuple(levels) if levels is not None else (cfg.level,) * s
        lr_pad = _downscale_pad(raw, levels)
        extents, qualities = ladder_batch_arrays(levels, *raw.shape[2:])
        streamed = (raw, lr_pad, gt_boxes, gt_valid,
                    jnp.broadcast_to(jnp.asarray(tr1, f32), (s,)),
                    jnp.broadcast_to(jnp.asarray(tr2, f32), (s,)),
                    jnp.broadcast_to(jnp.asarray(bw_kbps, f32), (s,)),
                    jnp.broadcast_to(jnp.asarray(queue_delay, f32), (s,)))
        r, lp, gb, gv, t1, t2, bw, qd = pad_stream_axis(streamed, n_shards)
        pad = r.shape[0] - s
        if pad:
            # padded lanes: full canvas extent, nominal quality
            extents = jnp.concatenate(
                [extents, jnp.tile(jnp.asarray(lp.shape[2:], jnp.int32),
                                   (pad, 1))])
            qualities = jnp.concatenate([qualities, jnp.full((pad,), 50.0,
                                                             f32)])
        out = sharded(r, lp, extents, qualities, gb, gv, detector_params,
                      t1, t2, bw, qd)
        return jax.tree.map(lambda x: x[:s], out)

    return run


def shard_streams(mesh: Mesh, rules: AxisRules, *, det_cfg,
                  costs=None):
    """Build the mesh-sharded twin of ``decode_execute_batched``.

    Returns ``run(enc, types, anchor_hd, gt_boxes, gt_valid,
    detector_params, *, bw_kbps, queue_delay, total_bits)`` where every
    positional operand and the three keyword scalars carry a leading
    stream axis of identical extent S.  S need not divide the mesh's
    stream extent.  ``det_cfg``/``costs`` are bound at build time (they
    are static jit arguments)."""
    from repro.core.hybrid_decoder import PipelineCosts, _execute_batch

    costs = costs or PipelineCosts()
    spec = stream_partition_spec(mesh, rules)
    n_shards = stream_shard_count(mesh, rules)

    def body(e, ty, ah, gb, gv, params, bw, qd, tb):
        return _execute_batch(e, ty, ah, gb, gv, params, det_cfg,
                              bw, qd, tb, costs)

    sharded = jax.jit(shard_map_compat(
        body, mesh=mesh,
        in_specs=(spec, spec, spec, spec, spec, P(), spec, spec, spec),
        out_specs=spec,
    ))

    def run(enc, types, anchor_hd, gt_boxes, gt_valid, detector_params, *,
            bw_kbps, queue_delay, total_bits):
        types = jnp.asarray(types)
        s = types.shape[0]
        streamed = (enc, types, anchor_hd, gt_boxes, gt_valid,
                    jnp.broadcast_to(jnp.asarray(bw_kbps, f32), (s,)),
                    jnp.broadcast_to(jnp.asarray(queue_delay, f32), (s,)),
                    jnp.broadcast_to(jnp.asarray(total_bits, f32), (s,)))
        e, ty, ah, gb, gv, bw, qd, tb = pad_stream_axis(streamed, n_shards)
        out = sharded(e, ty, ah, gb, gv, detector_params, bw, qd, tb)
        return jax.tree.map(lambda x: x[:s], out)

    return run
