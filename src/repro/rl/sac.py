"""High-level Soft Actor-Critic bandwidth controller (paper §V-B, §VI-B).

Hyper-parameters from the paper: policy lr 0.001, value lr 0.003, Q lr
0.0003; target update tau 0.02; γ 0.9; replay 1e4; minibatch 128.  Policy
4×256 MLP, value/Q 3×256 MLPs.  The action is the per-stream bandwidth
proportion vector (softmax-normalized downstream).
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.models.params import init_params
from repro.rl import networks as N
from repro.train.optimizer import AdamWConfig, apply_updates, init_state

f32 = jnp.float32


@dataclasses.dataclass(frozen=True)
class SACConfig:
    state_dim: int
    action_dim: int
    lr_policy: float = 0.001
    lr_value: float = 0.003
    lr_q: float = 0.0003
    tau: float = 0.02
    gamma: float = 0.9
    alpha: float = 0.05          # entropy temperature
    buffer_size: int = 10_000
    minibatch: int = 128


def init(key, cfg: SACConfig):
    ks = jax.random.split(key, 4)
    actor = init_params(ks[0], N.high_actor_specs(cfg.state_dim,
                                                  cfg.action_dim))
    value = init_params(ks[1], N.high_value_specs(cfg.state_dim))
    q1 = init_params(ks[2], N.high_q_specs(cfg.state_dim, cfg.action_dim))
    q2 = init_params(ks[3], N.high_q_specs(cfg.state_dim, cfg.action_dim))
    return {
        "actor": actor, "value": value, "value_target": value,
        "q1": q1, "q2": q2,
        "opt_actor": init_state(actor), "opt_value": init_state(value),
        "opt_q1": init_state(q1), "opt_q2": init_state(q2),
    }


def _act(key, agent, state, explore: bool = True):
    mu, log_std = N.high_actor_apply(agent["actor"], state)
    return N.policy_action(key, mu, log_std, explore)


# jitted (fused-control-plane parity: both sides must see XLA's codegen;
# eager mode skips the fused multiply-adds jit emits) — see rl/a2c.py
act = partial(jax.jit, static_argnums=(3,))(_act)
act.__doc__ = "(C,) action in (0,1); normalized to proportions by caller."


def _update(key, agent, batch, cfg: SACConfig):
    s, a, r, s2, done = (batch["states"], batch["actions"],
                         batch["rewards"], batch["next_states"],
                         batch["dones"])
    k1, k2 = jax.random.split(key)

    # --- Q update: target r + γ V_target(s') --------------------------------
    vt = N.high_value_apply(agent["value_target"], s2)
    q_target = jax.lax.stop_gradient(r + cfg.gamma * vt * (1 - done))

    def q_loss(qp):
        q = N.high_q_apply(qp, s, a)
        return jnp.mean(jnp.square(q - q_target))

    ql1, gq1 = jax.value_and_grad(q_loss)(agent["q1"])
    ql2, gq2 = jax.value_and_grad(q_loss)(agent["q2"])

    # --- value update: target E[minQ(s, a~π) − α logπ] ----------------------
    mu, log_std = N.high_actor_apply(agent["actor"], s)
    a_new, logp = N.sample_squashed(k1, mu, log_std)
    qmin = jnp.minimum(N.high_q_apply(agent["q1"], s, a_new),
                       N.high_q_apply(agent["q2"], s, a_new))
    v_target = jax.lax.stop_gradient(qmin - cfg.alpha * logp)

    def v_loss(vp):
        v = N.high_value_apply(vp, s)
        return jnp.mean(jnp.square(v - v_target))

    vl, gv = jax.value_and_grad(v_loss)(agent["value"])

    # --- policy update ------------------------------------------------------
    def pi_loss(ap):
        mu, log_std = N.high_actor_apply(ap, s)
        a_s, logp_s = N.sample_squashed(k2, mu, log_std)
        q = jnp.minimum(N.high_q_apply(agent["q1"], s, a_s),
                        N.high_q_apply(agent["q2"], s, a_s))
        return jnp.mean(cfg.alpha * logp_s - q)

    pl, gp = jax.value_and_grad(pi_loss)(agent["actor"])

    oq = AdamWConfig(lr=cfg.lr_q, weight_decay=0.0, warmup_steps=0,
                     clip_norm=5.0)
    ov = AdamWConfig(lr=cfg.lr_value, weight_decay=0.0, warmup_steps=0,
                     clip_norm=5.0)
    op = AdamWConfig(lr=cfg.lr_policy, weight_decay=0.0, warmup_steps=0,
                     clip_norm=5.0)
    q1, oq1, _ = apply_updates(agent["q1"], gq1, agent["opt_q1"], oq)
    q2, oq2, _ = apply_updates(agent["q2"], gq2, agent["opt_q2"], oq)
    value, ov_, _ = apply_updates(agent["value"], gv, agent["opt_value"], ov)
    actor, oa_, _ = apply_updates(agent["actor"], gp, agent["opt_actor"], op)
    target = jax.tree.map(lambda t, o: (1 - cfg.tau) * t + cfg.tau * o,
                          agent["value_target"], value)
    new_agent = {"actor": actor, "value": value, "value_target": target,
                 "q1": q1, "q2": q2, "opt_actor": oa_, "opt_value": ov_,
                 "opt_q1": oq1, "opt_q2": oq2}
    return new_agent, {"q_loss": 0.5 * (ql1 + ql2), "v_loss": vl,
                       "pi_loss": pl}


update = partial(jax.jit, static_argnums=(3,))(_update)

