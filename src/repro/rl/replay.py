"""Fixed-size replay buffer (paper: size 1e4, minibatch 128)."""
from __future__ import annotations

import numpy as np


class ReplayBuffer:
    def __init__(self, capacity: int, state_dim: int, action_dim: int,
                 seed: int = 0):
        self.capacity = capacity
        self.s = np.zeros((capacity, state_dim), np.float32)
        self.a = np.zeros((capacity, action_dim), np.float32)
        self.r = np.zeros((capacity,), np.float32)
        self.s2 = np.zeros((capacity, state_dim), np.float32)
        self.d = np.zeros((capacity,), np.float32)
        self.ptr = 0
        self.full = False
        self.rng = np.random.default_rng(seed)

    def __len__(self):
        return self.capacity if self.full else self.ptr

    def add(self, s, a, r, s2, done):
        i = self.ptr
        self.s[i], self.a[i], self.r[i] = s, a, r
        self.s2[i], self.d[i] = s2, float(done)
        self.ptr = (self.ptr + 1) % self.capacity
        self.full = self.full or self.ptr == 0

    def sample(self, batch: int):
        n = len(self)
        idx = self.rng.integers(0, n, size=batch)
        return {"states": self.s[idx], "actions": self.a[idx],
                "rewards": self.r[idx], "next_states": self.s2[idx],
                "dones": self.d[idx]}


class StackedReplayBuffer:
    """C per-stream replay buffers as one (C, capacity, ...) array set.

    The bi-level control plane's low-level agents each keep their own
    experience; stacking the storage lets one ``sample`` call gather a
    (C, B, ...) batch for the single-dispatch ``a2c.update_stacked``.
    Per-stream write cursors and per-stream ``default_rng(seed + c)``
    streams make stream c's contents AND sampling order bit-identical to
    a standalone ``ReplayBuffer(capacity, state_dim, action_dim,
    seed=seed + c)`` fed the same transitions — the parity contract the
    loop oracle in ``repro.core.bilevel`` relies on
    (tests/test_rl_bilevel.py).
    """

    def __init__(self, capacity: int, n_streams: int, state_dim: int,
                 action_dim: int, seed: int = 0):
        self.capacity = capacity
        self.C = n_streams
        self.s = np.zeros((n_streams, capacity, state_dim), np.float32)
        self.a = np.zeros((n_streams, capacity, action_dim), np.float32)
        self.r = np.zeros((n_streams, capacity), np.float32)
        self.s2 = np.zeros((n_streams, capacity, state_dim), np.float32)
        self.d = np.zeros((n_streams, capacity), np.float32)
        self.ptr = np.zeros(n_streams, np.int64)
        self.full = np.zeros(n_streams, bool)
        self.rngs = [np.random.default_rng(seed + c)
                     for c in range(n_streams)]

    def lens(self) -> np.ndarray:
        return np.where(self.full, self.capacity, self.ptr)

    def __len__(self):
        """Min per-stream fill — the train-gating view (streams fill in
        lockstep in the bi-level trainer, so min == max there)."""
        return int(self.lens().min()) if self.C else 0

    def add_stream(self, c: int, s, a, r, s2, done):
        i = self.ptr[c]
        self.s[c, i], self.a[c, i], self.r[c, i] = s, a, r
        self.s2[c, i], self.d[c, i] = s2, float(done)
        self.ptr[c] = (i + 1) % self.capacity
        self.full[c] = self.full[c] or self.ptr[c] == 0

    def add_batch(self, s, a, r, s2, done):
        """One transition per stream: s (C, S), a (C, A), r (C,), s2
        (C, S), done (C,)."""
        for c in range(self.C):
            self.add_stream(c, s[c], a[c], r[c], s2[c], done[c])

    def sample_stream(self, c: int, batch: int):
        n = int(self.lens()[c])
        idx = self.rngs[c].integers(0, n, size=batch)
        return {"states": self.s[c, idx], "actions": self.a[c, idx],
                "rewards": self.r[c, idx], "next_states": self.s2[c, idx],
                "dones": self.d[c, idx]}

    def sample(self, batch: int):
        """(C, B, ...) batch stack; consumes each stream's rng exactly as
        ``sample_stream(c, batch)`` for c = 0..C-1 would."""
        per = [self.sample_stream(c, batch) for c in range(self.C)]
        return {k: np.stack([p[k] for p in per]) for k in per[0]}
