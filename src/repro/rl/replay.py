"""Fixed-size replay buffer (paper: size 1e4, minibatch 128)."""
from __future__ import annotations

import numpy as np


class ReplayBuffer:
    def __init__(self, capacity: int, state_dim: int, action_dim: int,
                 seed: int = 0):
        self.capacity = capacity
        self.s = np.zeros((capacity, state_dim), np.float32)
        self.a = np.zeros((capacity, action_dim), np.float32)
        self.r = np.zeros((capacity,), np.float32)
        self.s2 = np.zeros((capacity, state_dim), np.float32)
        self.d = np.zeros((capacity,), np.float32)
        self.ptr = 0
        self.full = False
        self.rng = np.random.default_rng(seed)

    def __len__(self):
        return self.capacity if self.full else self.ptr

    def add(self, s, a, r, s2, done):
        i = self.ptr
        self.s[i], self.a[i], self.r[i] = s, a, r
        self.s2[i], self.d[i] = s2, float(done)
        self.ptr = (self.ptr + 1) % self.capacity
        self.full = self.full or self.ptr == 0

    def sample(self, batch: int):
        n = len(self)
        idx = self.rng.integers(0, n, size=batch)
        return {"states": self.s[idx], "actions": self.a[idx],
                "rewards": self.r[idx], "next_states": self.s2[idx],
                "dones": self.d[idx]}
