"""DRL networks sized exactly per paper §VI-B.

Low level (per camera, actor-critic): policy + value both 2-layer MLPs with
128 units, ReLU.  High level (bandwidth controller, SAC): policy 4-layer
MLP 256 units; value/Q 3-layer MLPs 256 units, ReLU.

The dense layers deliberately avoid ``x @ w`` (``dot_general``): XLA's CPU
gemm picks a batch-count-dependent accumulation order (a degenerate C=1
batch is rewritten to a plain gemm with a different algorithm than the
C-batched kernel), which breaks the stacked-vs-loop bit-exactness contract
of the bi-level control plane (docs/bilevel.md).  The broadcast-multiply +
``sum(-2)`` form reduces each output element in the same order under
eager, jit, and ``vmap`` at ANY leading batch count — verified by
tests/test_rl_bilevel.py — and these control-plane MLPs are far too small
for the gemm to matter.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.params import spec

f32 = jnp.float32


def mlp_specs(sizes, name="mlp"):
    p = {}
    for i, (a, b) in enumerate(zip(sizes[:-1], sizes[1:])):
        p[f"w{i}"] = spec((a, b), (None, None), dtype=f32, init="fan_in")
        p[f"b{i}"] = spec((b,), (None,), dtype=f32, init="zeros")
    return p


def dense(x, w, b):
    """Batch-count-stable dense layer (see module docstring)."""
    return (x[..., :, None] * w).sum(-2) + b


def mlp_apply(params, x, n_layers: int, final_activation=None):
    for i in range(n_layers):
        x = dense(x, params[f"w{i}"], params[f"b{i}"])
        if i < n_layers - 1:
            x = jax.nn.relu(x)
    if final_activation is not None:
        x = final_activation(x)
    return x


# ---------------- low level (paper: 2x128) ----------------
def low_actor_specs(state_dim: int, action_dim: int = 2):
    # outputs mean and log_std per action dim
    return mlp_specs((state_dim, 128, 128, 2 * action_dim))


def low_critic_specs(state_dim: int):
    return mlp_specs((state_dim, 128, 128, 1))


def low_actor_apply(params, state):
    out = mlp_apply(params, state, 3)
    mu, log_std = jnp.split(out, 2, axis=-1)
    # bounded mean keeps the squashed policy off the tanh saturation
    # attractor (the density jacobian rewards extreme actions otherwise)
    return jnp.clip(mu, -3.0, 3.0), jnp.clip(log_std, -4.0, 1.0)


def low_critic_apply(params, state):
    return mlp_apply(params, state, 3)[..., 0]


# ---------------- high level (paper: SAC, 4x256 policy / 3x256 value) -----
def high_actor_specs(state_dim: int, action_dim: int):
    return mlp_specs((state_dim, 256, 256, 256, 256, 2 * action_dim))


def high_value_specs(state_dim: int):
    return mlp_specs((state_dim, 256, 256, 256, 1))


def high_q_specs(state_dim: int, action_dim: int):
    return mlp_specs((state_dim + action_dim, 256, 256, 256, 1))


def high_actor_apply(params, state):
    out = mlp_apply(params, state, 5)
    mu, log_std = jnp.split(out, 2, axis=-1)
    return mu, jnp.clip(log_std, -5.0, 2.0)


def high_value_apply(params, state):
    return mlp_apply(params, state, 4)[..., 0]


def high_q_apply(params, state, action):
    return mlp_apply(params, jnp.concatenate([state, action], -1), 4)[..., 0]


# ---------------- squashed-Gaussian helpers ----------------
def sample_squashed(key, mu, log_std):
    """tanh-squashed Gaussian -> action in (0,1), with log-prob."""
    std = jnp.exp(log_std)
    eps = jax.random.normal(key, mu.shape, f32)
    pre = mu + std * eps
    tanh = jnp.tanh(pre)
    a = 0.5 * (tanh + 1.0)
    logp = (-0.5 * (eps ** 2) - log_std - 0.5 * jnp.log(2 * jnp.pi)).sum(-1)
    # tanh + affine change of variables
    logp -= jnp.sum(jnp.log(0.5 * (1 - tanh ** 2) + 1e-6), axis=-1)
    return a, logp


def deterministic_action(mu):
    return 0.5 * (jnp.tanh(mu) + 1.0)


def policy_action(key, mu, log_std, explore: bool):
    """Squashed-Gaussian action in (0,1): sampled or deterministic.

    ``explore`` is a Python bool (static under jit) — both the A2C and SAC
    act paths route through here so the loop oracle and the fused
    ``bilevel_step`` trace the identical expression."""
    if explore:
        a, _ = sample_squashed(key, mu, log_std)
        return a
    return deterministic_action(mu)
