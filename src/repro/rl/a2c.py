"""Low-level actor-critic agent (paper §V-A, §VI-B).

Per-camera agent choosing the two classification thresholds (tr1, tr2) per
chunk.  Hyper-parameters from the paper: Adam lr 0.005 (actor) / 0.01
(critic), discount γ = 0.9, reward r = α1·acc − α2·latency-penalty with
α1 = α2 = 0.5, τ = 1 s.

Stacked layout (PR 5): the C per-stream agents of the bi-level control
plane live in ONE pytree whose leaves carry a leading stream axis
(``init_stacked``), so ``act``/``update`` vectorize over all streams in a
single dispatch (``act_stacked``/``update_stacked`` are the jitted vmap
forms; ``repro.core.bilevel.bilevel_step`` inlines the same ``_act`` /
``_update`` bodies into its own trace).  Parity contract: the vmapped
forms are bit-exact (f32) against the per-stream calls for any stream
count — this relies on ``networks.dense`` avoiding batch-count-dependent
gemm lowering, and on BOTH paths being jit-compiled (eager mode skips the
fused multiply-adds XLA emits under jit).  Locked down by
tests/test_rl_bilevel.py.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.models.params import init_params
from repro.rl import networks as N
from repro.train.optimizer import AdamWConfig, apply_updates, init_state

f32 = jnp.float32


@dataclasses.dataclass(frozen=True)
class A2CConfig:
    state_dim: int
    action_dim: int = 2
    lr_actor: float = 0.005
    lr_critic: float = 0.01
    gamma: float = 0.9
    alpha1: float = 0.5   # reward accuracy weight
    alpha2: float = 0.5   # reward latency-penalty weight
    tau_latency: float = 1.0
    entropy_coef: float = 1e-3


def reward(cfg: A2CConfig, mean_acc, latency):
    """Eq. 4: α1·acc − α2·P(latency>τ)."""
    penalty = (latency > cfg.tau_latency).astype(f32)
    return cfg.alpha1 * mean_acc - cfg.alpha2 * penalty


def init(key, cfg: A2CConfig):
    ka, kc = jax.random.split(key)
    actor = init_params(ka, N.low_actor_specs(cfg.state_dim, cfg.action_dim))
    critic = init_params(kc, N.low_critic_specs(cfg.state_dim))
    return {
        "actor": actor, "critic": critic,
        "opt_a": init_state(actor), "opt_c": init_state(critic),
    }


def init_stacked(keys, cfg: A2CConfig):
    """C agents as one pytree with a leading stream axis.

    ``keys`` is a (C,)-batched PRNG key array; leaf c of the result is
    bit-identical to ``init(keys[c], cfg)`` (built by stacking the
    per-key inits, so stacked-vs-loop parity starts from equal params).
    """
    agents = [init(k, cfg) for k in keys]
    return jax.tree.map(lambda *xs: jnp.stack(xs), *agents)


def slice_agent(stacked, c: int):
    """Agent ``c`` of a stacked pytree (a view fit for the per-stream
    ``act``/``update``; slicing is exact)."""
    return jax.tree.map(lambda x: x[c], stacked)


def set_agent(stacked, c: int, agent):
    """Write a per-stream agent back into the stack (oracle loop only)."""
    return jax.tree.map(lambda s, a: s.at[c].set(a), stacked, agent)


def n_stacked(stacked) -> int:
    return jax.tree.leaves(stacked)[0].shape[0]


def _act(key, agent, state, explore: bool = True):
    mu, log_std = N.low_actor_apply(agent["actor"], state)
    return N.policy_action(key, mu, log_std, explore)


# jitted: the fused control plane requires BOTH sides of the parity
# contract to see XLA's codegen (eager skips jit-only fma contractions)
act = partial(jax.jit, static_argnums=(3,))(_act)
act.__doc__ = "(action_dim,) action in (0,1): [tr1, tr2]."

# one dispatch for all C agents: (C,) keys, stacked agents, (C, S) states
act_stacked = partial(jax.jit, static_argnums=(3,))(
    jax.vmap(_act, in_axes=(0, 0, 0, None)))


def _update(agent, batch, cfg: A2CConfig):
    """On-policy update over a batch of transitions.

    batch: states (B, S), actions (B, A), rewards (B,), next_states (B, S),
    dones (B,).
    """
    s, a, r, s2, done = (batch["states"], batch["actions"],
                         batch["rewards"], batch["next_states"],
                         batch["dones"])
    v2 = N.low_critic_apply(agent["critic"], s2)
    target = r + cfg.gamma * v2 * (1.0 - done)
    target = jax.lax.stop_gradient(target)

    def critic_loss(cp):
        v = N.low_critic_apply(cp, s)
        return jnp.mean(jnp.square(v - target))

    cl, gc = jax.value_and_grad(critic_loss)(agent["critic"])
    adv = target - N.low_critic_apply(agent["critic"], s)
    # normalized advantages + clipped log-probs: the tanh-squash jacobian
    # explodes near the action bounds and destabilizes vanilla A2C
    adv = (adv - adv.mean()) / (adv.std() + 1e-6)
    adv = jax.lax.stop_gradient(adv)

    def actor_loss(ap):
        mu, log_std = N.low_actor_apply(ap, s)
        std = jnp.exp(log_std)
        # REINFORCE on the *pre-squash* Gaussian: the policy is a
        # distribution over pre-activations, the reward composes with the
        # squash — an unbiased estimator with no tanh-density saturation
        # attractor (the a-space jacobian term rewards extreme actions).
        pre = jnp.arctanh(jnp.clip(2 * a - 1, -0.995, 0.995))
        logp = (-0.5 * jnp.square(jnp.clip((pre - mu) / std, -6, 6))
                - log_std - 0.5 * jnp.log(2 * jnp.pi)).sum(-1)
        ent = log_std.sum(-1).mean()
        return -(logp * adv).mean() - cfg.entropy_coef * ent

    al, ga = jax.value_and_grad(actor_loss)(agent["actor"])
    oa = AdamWConfig(lr=cfg.lr_actor, weight_decay=0.0, warmup_steps=0,
                     clip_norm=5.0)
    oc = AdamWConfig(lr=cfg.lr_critic, weight_decay=0.0, warmup_steps=0,
                     clip_norm=5.0)
    new_actor, opt_a, _ = apply_updates(agent["actor"], ga, agent["opt_a"], oa)
    new_critic, opt_c, _ = apply_updates(agent["critic"], gc, agent["opt_c"], oc)
    return ({"actor": new_actor, "critic": new_critic,
             "opt_a": opt_a, "opt_c": opt_c},
            {"actor_loss": al, "critic_loss": cl,
             "mean_adv": adv.mean()})


update = partial(jax.jit, static_argnums=(2,))(_update)
update.__doc__ = _update.__doc__

# one dispatch updates all C agents from a (C, B, ...) batch stack
update_stacked = partial(jax.jit, static_argnums=(2,))(
    jax.vmap(_update, in_axes=(0, 0, None)))
