"""Jit'd wrapper for the blockdct kernel."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.codec import blockdct as B
from repro.kernels.blockdct.kernel import blockdct_tiles

f32 = jnp.float32


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@partial(jax.jit, static_argnames=("tile", "interpret"))
def blockdct_quantize(blocks, quality, *, tile: int = 256,
                      interpret: bool | None = None):
    """blocks: (nb, 8, 8) f32 -> (quantized, recon)."""
    if interpret is None:
        interpret = not on_tpu()
    dmat = jnp.asarray(B.dct_matrix(8), f32)
    qtab = jnp.maximum(B.JPEG_LUMA_Q50 * B.quality_scale(quality), 1.0)
    return blockdct_tiles(blocks.astype(f32), dmat, qtab, tile=tile,
                          interpret=interpret)
