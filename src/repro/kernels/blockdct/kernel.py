"""8×8 block DCT + quantize + dequant/IDCT kernel (Pallas TPU).

The JPEG/codec transform core: y = D·x·Dᵀ, q = round(y / qtab),
recon = Dᵀ·(q·qtab)·D.  Expressed as batched 8×8 matmuls over a VMEM tile
of TILE blocks — MXU-shaped by construction (the (TILE·8, 8)×(8, 8)
contractions keep the systolic array fed; the DCT matrix stays resident).

Grid: (nb / TILE,).  VMEM per step: TILE·8·8·4 bytes ·3 ≈ 196 KiB at
TILE = 256.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

f32 = jnp.float32


def _kernel(x_ref, d_ref, qt_ref, q_ref, rec_ref):
    x = x_ref[...]                     # (TILE, 8, 8)
    D = d_ref[...]                     # (8, 8)
    qt = qt_ref[...]                   # (8, 8)
    # y = D @ x @ D^T  via two batched contractions
    y = jax.lax.dot_general(x, D.T, (((2,), (0,)), ((), ())),
                            preferred_element_type=f32)     # x @ D^T
    y = jax.lax.dot_general(D, y, (((1,), (1,)), ((), ())),
                            preferred_element_type=f32)     # (8, TILE, 8)
    y = y.transpose(1, 0, 2)                                # (TILE, 8, 8)
    q = jnp.round(y / qt[None])
    q_ref[...] = q.astype(q_ref.dtype)
    deq = q * qt[None]
    r = jax.lax.dot_general(deq, D, (((2,), (0,)), ((), ())),
                            preferred_element_type=f32)     # deq @ D
    r = jax.lax.dot_general(D.T, r, (((1,), (1,)), ((), ())),
                            preferred_element_type=f32)
    rec_ref[...] = r.transpose(1, 0, 2).astype(rec_ref.dtype)


def blockdct_tiles(blocks, dmat, qtab, *, tile: int = 256,
                   interpret: bool = False):
    """blocks: (nb, 8, 8) f32 -> (quantized (nb, 8, 8), recon (nb, 8, 8))."""
    nb = blocks.shape[0]
    tile = min(tile, nb)
    pad = (-nb) % tile
    if pad:
        blocks = jnp.concatenate(
            [blocks, jnp.zeros((pad, 8, 8), blocks.dtype)], axis=0)
    n = blocks.shape[0]

    q, rec = pl.pallas_call(
        _kernel,
        grid=(n // tile,),
        in_specs=[
            pl.BlockSpec((tile, 8, 8), lambda i: (i, 0, 0)),
            pl.BlockSpec((8, 8), lambda i: (0, 0)),
            pl.BlockSpec((8, 8), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((tile, 8, 8), lambda i: (i, 0, 0)),
            pl.BlockSpec((tile, 8, 8), lambda i: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, 8, 8), blocks.dtype),
            jax.ShapeDtypeStruct((n, 8, 8), blocks.dtype),
        ],
        interpret=interpret,
    )(blocks, dmat, qtab)
    return q[:nb], rec[:nb]
