"""Pure-jnp oracle: repro.codec.blockdct composed round trip."""
from __future__ import annotations

import jax.numpy as jnp

from repro.codec import blockdct as B

f32 = jnp.float32


def blockdct_ref(blocks, quality):
    """blocks: (nb, 8, 8) -> (quantized coefs, recon blocks)."""
    coefs = B.dct2(blocks)
    q, qtab = B.quantize(coefs, quality)
    rec = B.idct2(B.dequantize(q, qtab))
    return q, rec
