from repro.kernels.blockdct.ops import blockdct_quantize  # noqa: F401
