"""Pallas TPU kernels for BiSwift's compute hot spots.

flash_attention — fused online-softmax attention (causal / sliding-window /
                  GQA) for the LM backbones; avoids materializing repeated
                  KV heads or S×S scores.
qtransfer       — quality transfer (paper Fig. 7): MV block gather from the
                  HD anchor plane + residual add, tiled 16×16 per macroblock
                  row with the anchor staged in VMEM.
blockdct        — 8×8 DCT + quantization (JPEG/codec core) as paired 8×8
                  matmuls over VMEM tiles (MXU-shaped by construction).
motion_sad      — full-search ±R block-motion SAD: every candidate offset
                  evaluated against a padded reference frame resident in
                  VMEM, one macroblock row per grid step; bit-exact MVs
                  vs the ``repro.codec.motion.block_sad_scan`` legacy
                  scan oracle (bf16 staging variant via ``dtype=``).

Each kernel package: kernel.py (pl.pallas_call + BlockSpec), ops.py (jit'd
wrapper, interpret=True on CPU), ref.py (pure-jnp oracle).
"""
