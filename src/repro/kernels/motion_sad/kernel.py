"""Block-motion SAD kernels (Pallas TPU): exhaustive full search and a
traced coarse-to-fine (diamond / three-step) search.

Tiling (reworked in the kernel speed pass): one grid step now produces
MULTIPLE macroblock rows of the MV field.  ``_rows_per_step`` picks the
largest row count whose resident working window — the row band plus its
±R halo against the padded reference — stays inside ``_WINDOW_BUDGET``
(512 KiB), which keeps the candidate loop L2-resident in interpret mode
and leaves headroom under the ~16 MiB/core VMEM budget on TPU (the
padded reference itself is staged whole via a constant index map:
720p f32 padded by R=8 is (736, 1296) ≈ 3.6 MiB; use bf16 at 1080p).
At small shapes (64×96) the whole frame is one grid step, so the
per-step staging that used to dominate is paid exactly once.

Candidate evaluation is a flat ``fori_loop`` over all (2R+1)² offsets,
but the per-candidate reduce runs in a two-stage row-major layout
(``(bh, nbx, MB).sum(-1)`` then ``(rows, MB, nbx).sum(1)``) that XLA:CPU
vectorizes far better than the oracle's two-strided-axis reduce — this,
not the loop structure, is where the kernel's speed over the scan oracle
comes from.  The two-stage sum can differ from the oracle's summation
order by float-rounding ULPs on non-integer inputs, so it is used for
*selection only*: after the loop each grid step recomputes the winning
candidate's SAD once in the oracle's per-block reduction order, making
the returned (mv, sad) bit-exact vs ``block_sad_scan``.  (On
integer-valued content ≤ 2²⁴ — i.e. real video pixels — every summation
order is exact, so even selection is provably order-independent there;
for continuous inputs a selection flip would need two distinct residual
patterns whose exact f32 sums collide in one order but not the other.)

Candidate order stays dy-major (idx = (dy+R)·(2R+1) + (dx+R)), identical
to ``repro.codec.motion._offsets``, with a strict ``<`` best-update, so
first-wins tie-breaking matches the scan oracle.

``search="diamond"`` selects the traced coarse-to-fine kernel: a static
step schedule (largest power of two ≤ R, halving to 1 — see
``repro.codec.motion.diamond_steps``) probes a 3×3 neighbourhood around
each macroblock's running best offset, clipped to ±R.  Every shape is
static (the step count is baked into the trace), so the variant is
jit-stable; it evaluates 1 + 9·len(steps) candidates per block instead of
(2R+1)² and matches the pure-jnp fallback ``block_sad_diamond``
bit-exactly on MVs.  Quality vs exhaustive is a documented tolerance
contract (docs/fused_encoder.md), not bit-exactness.

``dtype=jnp.bfloat16`` selects the bf16 storage variant on both kernels:
cur/ref bands are staged in VMEM as bf16 — halving the resident footprint
and doubling effective bandwidth at 1080p — while every SAD accumulates
in f32 inside the kernel.  The 16×W band blocks satisfy the bf16
(16, 128) minimum tile (sublane 16 = MB; lane W is a multiple of 128 at
ladder resolutions).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

MB = 16
f32 = jnp.float32

# per-grid-step resident working window budget: the (rows*MB + 2R) ×
# (W + 2R) reference band the candidate loop repeatedly re-reads.  512 KiB
# keeps it L2-resident on CPU interpret runs and is far under VMEM on TPU.
_WINDOW_BUDGET = 512 * 1024


def _rows_per_step(nby: int, width: int, radius: int, itemsize: int = 4,
                   max_rows: int = 8) -> int:
    """Largest macroblock-row count r ≤ max_rows dividing nby whose
    resident reference window (r*MB + 2R, W + 2R) fits the budget."""
    for r in range(min(nby, max_rows), 0, -1):
        if nby % r:
            continue
        window = (r * MB + 2 * radius) * (width + 2 * radius) * itemsize
        if window <= _WINDOW_BUDGET:
            return r
    return 1


def _gather_sad(band, curb, offy, offx, radius: int):
    """Per-block SAD at per-block offsets, in the oracle's per-block
    reduction order.  band: (bh + 2R, W + 2R) resident reference slab;
    curb: (rows, nbx, MB, MB); offy/offx: (rows, nbx) int32 in [-R, R]."""
    rows, nbx = curb.shape[:2]
    base_y = (jnp.arange(rows, dtype=jnp.int32) * MB)[:, None]
    base_x = (jnp.arange(nbx, dtype=jnp.int32) * MB)[None, :]
    ar = jnp.arange(MB, dtype=jnp.int32)
    ys = (base_y + offy + radius)[..., None] + ar     # (rows, nbx, MB)
    xs = (base_x + offx + radius)[..., None] + ar
    cand = band[ys[..., :, None], xs[..., None, :]]   # (rows, nbx, MB, MB)
    return jnp.abs(curb - cand).sum(axis=(2, 3))


def _kernel(cur_ref, refp_ref, sad_ref, idx_ref, *, radius: int, rows: int,
            nbx: int, width: int):
    i = pl.program_id(0)
    side = 2 * radius + 1
    bh = rows * MB
    cur = cur_ref[...].astype(f32)                        # (bh, W)

    def body(idx, carry):
        best_sad, best_idx = carry
        dy, dx = idx // side, idx % side
        win = refp_ref[pl.dslice(i * bh + dy, bh),
                       pl.dslice(dx, width)].astype(f32)
        d = jnp.abs(cur - win)
        # two-stage row-major reduce: contiguous 16-wide inner sum, then
        # the block-row sum — the layout XLA vectorizes.  Selection only;
        # the winner's SAD is recomputed in oracle order below.
        sad = d.reshape(bh, nbx, MB).sum(-1).reshape(rows, MB, nbx).sum(1)
        better = sad < best_sad
        return (jnp.where(better, sad, best_sad),
                jnp.where(better, idx.astype(jnp.int32), best_idx))

    init = (jnp.full((rows, nbx), jnp.inf, f32),
            jnp.zeros((rows, nbx), jnp.int32))
    best_sad, best_idx = lax.fori_loop(0, side * side, body, init)

    # one oracle-order evaluation of the winning candidate per block, so
    # the returned SAD is bit-exact vs block_sad_scan
    band = refp_ref[pl.dslice(i * bh, bh + 2 * radius),
                    pl.dslice(0, width + 2 * radius)].astype(f32)
    curb = cur.reshape(rows, MB, nbx, MB).transpose(0, 2, 1, 3)
    sad_ref[...] = _gather_sad(band, curb, best_idx // side - radius,
                               best_idx % side - radius, radius)
    idx_ref[...] = best_idx


def _diamond_kernel(cur_ref, refp_ref, sad_ref, mv_ref, *, radius: int,
                    rows: int, nbx: int, width: int, steps: tuple):
    i = pl.program_id(0)
    bh = rows * MB
    cur = cur_ref[...].astype(f32)                        # (bh, W)
    band = refp_ref[pl.dslice(i * bh, bh + 2 * radius),
                    pl.dslice(0, width + 2 * radius)].astype(f32)
    curb = cur.reshape(rows, MB, nbx, MB).transpose(0, 2, 1, 3)

    zero = jnp.zeros((rows, nbx), jnp.int32)
    best_y, best_x = zero, zero
    best_sad = _gather_sad(band, curb, zero, zero, radius)
    # static unroll: len(steps) rounds of 9 probes, dy-major, first-wins
    for s in steps:
        cy, cx = best_y, best_x
        for py in (-s, 0, s):
            for px in (-s, 0, s):
                oy = jnp.clip(cy + py, -radius, radius)
                ox = jnp.clip(cx + px, -radius, radius)
                sad = _gather_sad(band, curb, oy, ox, radius)
                better = sad < best_sad
                best_sad = jnp.where(better, sad, best_sad)
                best_y = jnp.where(better, oy, best_y)
                best_x = jnp.where(better, ox, best_x)
    sad_ref[...] = best_sad.astype(sad_ref.dtype)
    mv_ref[...] = jnp.stack([best_y, best_x], axis=-1)


def motion_sad_rows(cur, ref, *, radius: int = 8, interpret: bool = False,
                    dtype=None, search: str = "exhaustive"):
    """cur/ref: (H, W) with H, W multiples of 16.

    Returns (mv (nby, nbx, 2) int32, sad (nby, nbx) f32) — the codec
    convention pred(y) = ref(y + mv), matching ``repro.codec.motion``.
    ``dtype`` is the VMEM storage dtype of the staged operands (bf16
    halves the resident reference); SADs accumulate in f32 regardless.
    ``search`` picks exhaustive ±R full search (bit-exact vs the scan
    oracle) or the traced diamond search (subset of the candidate set,
    quality-contract semantics).
    """
    store = dtype or f32
    H, W = cur.shape
    nby, nbx = H // MB, W // MB
    rows = _rows_per_step(nby, W, radius, jnp.dtype(store).itemsize)
    refp = jnp.pad(ref.astype(store), radius, mode="edge")

    if search == "exhaustive":
        kernel = functools.partial(_kernel, radius=radius, rows=rows,
                                   nbx=nbx, width=W)
    elif search == "diamond":
        from repro.codec.motion import diamond_steps
        kernel = functools.partial(_diamond_kernel, radius=radius,
                                   rows=rows, nbx=nbx, width=W,
                                   steps=diamond_steps(radius))
    else:
        raise ValueError(f"unknown search strategy {search!r} "
                         "(expected 'exhaustive' or 'diamond')")

    out_specs = [pl.BlockSpec((rows, nbx), lambda i: (i, 0))]
    out_shape = [jax.ShapeDtypeStruct((nby, nbx), f32)]
    if search == "diamond":
        out_specs.append(pl.BlockSpec((rows, nbx, 2), lambda i: (i, 0, 0)))
        out_shape.append(jax.ShapeDtypeStruct((nby, nbx, 2), jnp.int32))
    else:
        out_specs.append(pl.BlockSpec((rows, nbx), lambda i: (i, 0)))
        out_shape.append(jax.ShapeDtypeStruct((nby, nbx), jnp.int32))

    sad, out = pl.pallas_call(
        kernel,
        grid=(nby // rows,),
        in_specs=[
            pl.BlockSpec((rows * MB, W), lambda i: (i, 0)),
            pl.BlockSpec((H + 2 * radius, W + 2 * radius), lambda i: (0, 0)),
        ],
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
    )(cur.astype(store), refp)

    if search == "diamond":
        return out, sad
    side = 2 * radius + 1
    mv = jnp.stack([out // side - radius, out % side - radius], axis=-1)
    return mv.astype(jnp.int32), sad
