"""Full-search block-motion SAD kernel (Pallas TPU).

One grid step produces one macroblock ROW of the MV field.  The padded
reference frame is staged *whole* in VMEM (constant index map — resident
across steps; 720p f32 padded by R=8 is (736, 1296) ≈ 3.6 MiB, inside the
~16 MiB/core budget) and the current frame arrives one 16×W band at a time.
Each of the (2R+1)² candidate offsets is evaluated against a 16×W band
sliced from the resident reference — a VMEM-local dynamic slice — instead
of the legacy ``lax.scan`` that materializes (2R+1)² whole-frame shifted
copies through HBM.

Candidate order is dy-major (idx = (dy+R)·(2R+1) + (dx+R)), identical to
``repro.codec.motion._offsets``; the strict ``<`` best-update gives the
same first-wins tie-breaking as the scan oracle, so MVs match bit-exactly.

``dtype=jnp.bfloat16`` selects the bf16 storage variant: cur/ref bands are
staged in VMEM as bf16 — halving the resident footprint and doubling
effective bandwidth at 1080p — while every SAD accumulates in f32 inside
the kernel.  The 16×W band blocks satisfy the bf16 (16, 128) minimum tile
(sublane 16 = MB; lane W is a multiple of 128 at ladder resolutions).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

MB = 16
f32 = jnp.float32


def _kernel(cur_ref, refp_ref, sad_ref, idx_ref, *, radius: int, nbx: int,
            width: int):
    i = pl.program_id(0)
    cur = cur_ref[...].astype(f32)                      # (MB, W)
    side = 2 * radius + 1

    def body(k, carry):
        best_sad, best_idx = carry
        dy = k // side - radius
        dx = k % side - radius
        band = refp_ref[pl.dslice(radius + i * MB + dy, MB),
                        pl.dslice(radius + dx, width)]  # (MB, W)
        diff = jnp.abs(cur - band.astype(f32))
        sad = diff.reshape(MB, nbx, MB).sum(axis=(0, 2))     # (nbx,)
        better = sad < best_sad
        return (jnp.where(better, sad, best_sad),
                jnp.where(better, k.astype(jnp.int32), best_idx))

    init = (jnp.full((nbx,), jnp.inf, f32), jnp.zeros((nbx,), jnp.int32))
    best_sad, best_idx = jax.lax.fori_loop(0, side * side, body, init)
    sad_ref[...] = best_sad[None].astype(sad_ref.dtype)
    idx_ref[...] = best_idx[None]


def motion_sad_rows(cur, ref, *, radius: int = 8, interpret: bool = False,
                    dtype=None):
    """cur/ref: (H, W) with H, W multiples of 16.

    Returns (mv (nby, nbx, 2) int32, sad (nby, nbx) f32) — the codec
    convention pred(y) = ref(y + mv), matching ``repro.codec.motion``.
    ``dtype`` is the VMEM storage dtype of the staged operands (bf16
    halves the resident reference); SADs accumulate in f32 regardless.
    """
    store = dtype or f32
    H, W = cur.shape
    nby, nbx = H // MB, W // MB
    refp = jnp.pad(ref.astype(store), radius, mode="edge")

    kernel = functools.partial(_kernel, radius=radius, nbx=nbx, width=W)
    sad, idx = pl.pallas_call(
        kernel,
        grid=(nby,),
        in_specs=[
            pl.BlockSpec((MB, W), lambda i: (i, 0)),
            pl.BlockSpec((H + 2 * radius, W + 2 * radius), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, nbx), lambda i: (i, 0)),
            pl.BlockSpec((1, nbx), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nby, nbx), f32),
            jax.ShapeDtypeStruct((nby, nbx), jnp.int32),
        ],
        interpret=interpret,
    )(cur.astype(store), refp)

    side = 2 * radius + 1
    mv = jnp.stack([idx // side - radius, idx % side - radius], axis=-1)
    return mv.astype(jnp.int32), sad
