"""Jit'd wrapper: per-frame and batched (vmap) motion-SAD search."""
from __future__ import annotations

from functools import partial

import jax

from repro.kernels.motion_sad.kernel import motion_sad_rows


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@partial(jax.jit, static_argnames=("radius", "interpret", "dtype", "search"))
def motion_sad(cur, ref, *, radius: int = 8, interpret: bool | None = None,
               dtype=None, search: str = "exhaustive"):
    """cur/ref: (H, W) or (T, H, W) -> (mv, sad).

    mv: (..., nby, nbx, 2) int32; sad: (..., nby, nbx) f32.  ``dtype``
    selects the VMEM storage variant (bf16 stages operands half-width;
    SADs still accumulate in f32).  ``search`` routes to the exhaustive
    ±R kernel (default, bit-exact vs the scan oracle) or the traced
    diamond-search kernel (static step schedule, subset of the candidate
    set — see ``repro.codec.motion.diamond_steps``).
    """
    if interpret is None:
        interpret = not on_tpu()
    fn = partial(motion_sad_rows, radius=radius, interpret=interpret,
                 dtype=dtype, search=search)
    if cur.ndim == 3:
        return jax.vmap(fn)(cur, ref)
    return fn(cur, ref)
