"""Oracle for the motion-SAD kernel: the scan-based full search in
``repro.codec.motion.block_sad`` (one whole-frame shifted SAD per candidate
offset).  The kernel must match its MVs bit-exactly, including first-wins
tie-breaking over the dy-major candidate order."""
from __future__ import annotations

from repro.codec.motion import block_sad


def motion_sad_ref(cur, ref, radius: int = 8):
    return block_sad(cur, ref, radius, use_kernel=False)
