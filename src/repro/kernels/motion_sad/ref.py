"""Oracle for the motion-SAD kernel: the LEGACY scan-based full search
``repro.codec.motion.block_sad_scan`` (one whole-frame shifted SAD per
candidate offset) — deliberately NOT the vmapped per-window fallback,
which shares the kernel's resident-window slicing design and could hide a
symmetric bug.  The kernel must match the scan's MVs bit-exactly,
including first-wins tie-breaking over the dy-major candidate order."""
from __future__ import annotations

from repro.codec.motion import block_sad_scan


def motion_sad_ref(cur, ref, radius: int = 8):
    return block_sad_scan(cur, ref, radius)
