"""Jit'd wrapper: per-frame and batched (vmap) quality transfer."""
from __future__ import annotations

from functools import partial

import jax

from repro.kernels.qtransfer.kernel import qtransfer_rows


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@partial(jax.jit, static_argnames=("radius", "interpret", "dtype"))
def qtransfer(anchor, mv, resid, *, radius: int = 16,
              interpret: bool | None = None, dtype=None):
    """anchor/resid: (H, W) or (T, H, W); mv: (..., nby, nbx, 2) int32.

    ``dtype`` selects the VMEM storage variant (bf16 stages the resident
    anchor plane and residual band half-width; the block gather + residual
    add accumulates in f32 inside the kernel, and the output comes back in
    the storage dtype).
    """
    if interpret is None:
        interpret = not on_tpu()
    if dtype is not None:
        anchor = anchor.astype(dtype)
        resid = resid.astype(dtype)
    fn = partial(qtransfer_rows, radius=radius, interpret=interpret)
    if anchor.ndim == 3:
        return jax.vmap(fn)(anchor, mv, resid)
    return fn(anchor, mv, resid)
