"""Pure-jnp oracle for the qtransfer kernel.

Same semantics as repro.codec.motion.warp_blocks + residual add, with the
kernel's clamping rules (vertical clamp to ±radius, horizontal clamp to
the frame border, edge padding).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

MB = 16
f32 = jnp.float32


def qtransfer_ref(anchor, mv, resid, *, radius: int = 16):
    H, W = anchor.shape
    nby, nbx = mv.shape[:2]
    ap = jnp.pad(anchor.astype(f32), ((radius, radius), (0, 0)), mode="edge")

    def one(by, bx):
        dy = jnp.clip(mv[by, bx, 0], -radius, radius)
        dx = mv[by, bx, 1]
        y0 = radius + by * MB + dy
        x0 = jnp.clip(bx * MB + dx, 0, W - MB)
        return lax.dynamic_slice(ap, (y0, x0), (MB, MB))

    rows = jax.vmap(lambda by: jax.vmap(lambda bx: one(by, bx))(
        jnp.arange(nbx)))(jnp.arange(nby))          # (nby, nbx, MB, MB)
    warped = rows.transpose(0, 2, 1, 3).reshape(H, W)
    return jnp.clip(warped + resid.astype(f32), 0.0, 255.0).astype(anchor.dtype)
