"""Quality-transfer kernel (Pallas TPU) — paper Fig. 7 adapted to TPU.

One grid step produces one macroblock ROW of the output frame.  The padded
HD anchor plane is staged *whole* in VMEM (constant index map — resident
across steps; 720p f32 = 3.7 MiB, 1080p bf16 = 4.2 MiB, inside the
~16 MiB/core budget); the kernel gathers each 16×16 block at its (dy, dx)
motion offset with dynamic slices in VMEM, adds the decoded residual band,
and writes the row band.

GPU implementations do this as per-pixel gathers; re-blocking to macroblock
granularity matches both the codec structure and the TPU (8, 128) vector
layout — a 16×W band is a dense contiguous tile.  MVs ride in SMEM.

The bf16 variant (``dtype=jnp.bfloat16`` on ``ops.qtransfer``) stages the
anchor plane and residual bands in bf16 — the 16×W bands satisfy the bf16
(16, 128) minimum tile (sublane 16 = MB) — while the gather + residual add
accumulates in f32 before casting back to the storage dtype.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

MB = 16
f32 = jnp.float32


def _kernel(mv_ref, anchor_ref, resid_ref, out_ref, *, radius: int,
            nbx: int, width: int, height: int):
    i = pl.program_id(0)

    def body(bx, _):
        dy = jnp.clip(mv_ref[0, bx, 0], -radius, radius)
        dx = mv_ref[0, bx, 1]
        y0 = radius + i * MB + dy                  # into padded anchor
        x0 = jnp.clip(bx * MB + dx, 0, width - MB)
        block = anchor_ref[pl.dslice(y0, MB), pl.dslice(x0, MB)]
        resid = resid_ref[:, pl.dslice(bx * MB, MB)]
        out = jnp.clip(block.astype(f32) + resid.astype(f32), 0.0, 255.0)
        out_ref[:, pl.dslice(bx * MB, MB)] = out.astype(out_ref.dtype)
        return 0

    jax.lax.fori_loop(0, nbx, body, 0)


def qtransfer_rows(anchor, mv, resid, *, radius: int = 16,
                   interpret: bool = False):
    """anchor/resid: (H, W) f32; mv: (nby, nbx, 2) int32 -> (H, W).

    Vertical offsets are clamped to ±radius; horizontal offsets clamp to
    the frame border — matching repro.codec.motion.warp_blocks (edge pad).
    """
    H, W = anchor.shape
    nby, nbx = H // MB, W // MB
    anchor_p = jnp.pad(anchor, ((radius, radius), (0, 0)), mode="edge")

    kernel = functools.partial(_kernel, radius=radius, nbx=nbx, width=W,
                               height=H)
    return pl.pallas_call(
        kernel,
        grid=(nby,),
        in_specs=[
            pl.BlockSpec((1, nbx, 2), lambda i: (i, 0, 0),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((H + 2 * radius, W), lambda i: (0, 0)),
            pl.BlockSpec((MB, W), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((MB, W), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((H, W), anchor.dtype),
        interpret=interpret,
    )(mv, anchor_p, resid)
