from repro.kernels.qtransfer.ops import qtransfer  # noqa: F401
