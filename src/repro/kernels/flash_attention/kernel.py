"""Flash attention forward kernel (Pallas TPU).

Grid: (B, H, nq, nk) with the kv dimension innermost; the online-softmax
state (m, l, acc) lives in VMEM scratch and survives across the nk steps of
one (b, h, i) cell.  GQA is handled in the k/v BlockSpec index maps
(kv_head = h * Hk // H) — the repeated KV heads are never materialized.
Causal and sliding-window masks are applied from global position iota.

Block shapes: q (1, 1, QB, D); k/v (1, 1, KB, D) — QB/KB default 128/128,
MXU-aligned for D ∈ {64, 128}.  VMEM per cell ≈ QB·D·4 + 2·KB·D·2 + scores
QB·KB·4 ≈ 160 KiB at defaults, far under the ~16 MiB/core budget, leaving
headroom for double buffering.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

f32 = jnp.float32
NEG_INF = -1e30


def _fwd_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                scale: float, causal: bool, window, q_blk: int, k_blk: int,
                nk: int, seq_q: int, seq_k: int):
    i = pl.program_id(2)
    j = pl.program_id(3)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.bfloat16)          # (QB, D)
    k = k_ref[0, 0].astype(jnp.bfloat16)          # (KB, D)
    v = v_ref[0, 0].astype(jnp.bfloat16)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=f32) * scale  # (QB, KB)

    q_pos = i * q_blk + jax.lax.broadcasted_iota(jnp.int32, (q_blk, k_blk), 0)
    k_pos = j * k_blk + jax.lax.broadcasted_iota(jnp.int32, (q_blk, k_blk), 1)
    mask = (k_pos < seq_k) & (q_pos < seq_q)
    if causal:
        mask &= k_pos <= q_pos
    if window is not None:
        mask &= (q_pos - k_pos) < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]
    l_prev = l_scr[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_new = l_prev * corr + p.sum(axis=1)
    pv = jax.lax.dot_general(p.astype(jnp.bfloat16), v,
                             (((1,), (0,)), ((), ())),
                             preferred_element_type=f32)
    acc_scr[...] = acc_scr[...] * corr[:, None] + pv
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(j == nk - 1)
    def _finalize():
        o_ref[0, 0] = (acc_scr[...] /
                       jnp.maximum(l_scr[...], 1e-30)[:, None]
                       ).astype(o_ref.dtype)


def flash_attention_fwd(q, k, v, *, causal: bool = True, window=None,
                        q_blk: int = 128, k_blk: int = 128,
                        interpret: bool = False):
    """q: (B, H, Sq, D); k/v: (B, Hk, Sk, D) -> (B, H, Sq, D)."""
    B, H, Sq, D = q.shape
    Hk, Sk = k.shape[1], k.shape[2]
    q_blk = min(q_blk, Sq)
    k_blk = min(k_blk, Sk)
    nq = pl.cdiv(Sq, q_blk)
    nk = pl.cdiv(Sk, k_blk)
    scale = 1.0 / math.sqrt(D)

    kernel = functools.partial(
        _fwd_kernel, scale=scale, causal=causal, window=window,
        q_blk=q_blk, k_blk=k_blk, nk=nk, seq_q=Sq, seq_k=Sk)

    return pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, q_blk, D), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, k_blk, D),
                         lambda b, h, i, j, Hk=Hk, H=H: (b, h * Hk // H, j, 0)),
            pl.BlockSpec((1, 1, k_blk, D),
                         lambda b, h, i, j, Hk=Hk, H=H: (b, h * Hk // H, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, q_blk, D),
                               lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((q_blk,), f32),
            pltpu.VMEM((q_blk,), f32),
            pltpu.VMEM((q_blk, D), f32),
        ],
        interpret=interpret,
    )(q, k, v)
