"""Jit'd public wrapper for the flash attention kernel.

Accepts model-layout tensors (B, S, H, D) and handles transposition,
GQA head mapping, and the CPU fallback (interpret mode executes the kernel
body in Python on CPU for correctness validation; real TPUs compile it).
"""
from __future__ import annotations

from functools import partial

import jax

from repro.kernels.flash_attention.kernel import flash_attention_fwd


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@partial(jax.jit, static_argnames=("causal", "window", "q_blk", "k_blk",
                                   "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window=None,
                    q_blk: int = 128, k_blk: int = 128,
                    interpret: bool | None = None):
    """q: (B, Sq, H, D); k/v: (B, Sk, Hk, D) -> (B, Sq, H, D)."""
    if interpret is None:
        interpret = not on_tpu()
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    o = flash_attention_fwd(qt, kt, vt, causal=causal, window=window,
                            q_blk=q_blk, k_blk=k_blk, interpret=interpret)
    return o.transpose(0, 2, 1, 3)
