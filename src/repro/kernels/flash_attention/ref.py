"""Pure-jnp oracle for the flash attention kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

f32 = jnp.float32


def attention_ref(q, k, v, *, causal: bool = True, window=None):
    """q: (B, H, Sq, D); k/v: (B, Hk, Sk, D) -> (B, H, Sq, D), fp32 math."""
    B, H, Sq, D = q.shape
    Hk, Sk = k.shape[1], k.shape[2]
    G = H // Hk
    k = jnp.repeat(k, G, axis=1)
    v = jnp.repeat(v, G, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(f32), k.astype(f32))
    s = s / jnp.sqrt(jnp.asarray(D, f32))
    q_pos = jnp.arange(Sq)[:, None]
    k_pos = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= k_pos <= q_pos
    if window is not None:
        mask &= (q_pos - k_pos) < window
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(f32)).astype(q.dtype)
