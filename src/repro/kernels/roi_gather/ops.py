"""Jit'd wrappers for the ROI patch gather: Pallas kernel + jnp oracle."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax import lax

from repro.kernels.roi_gather.kernel import roi_gather_patches


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@partial(jax.jit, static_argnames=("region_px", "halo"))
def roi_gather_ref(planes, ry, rx, *, region_px: int, halo: int):
    """Pure-jnp fallback oracle: per-lane ``dynamic_slice`` gather.

    planes: (T, Hp, Wp) halo-padded planes; ry/rx: (T, K) region indices.
    Returns (T, K, P, P), P = region_px + 2·halo — the parity baseline
    for the Pallas kernel (a gather is exact, so the contract is
    bit-exactness, like ``motion_sad`` vs ``block_sad_scan``).
    """
    P = region_px + 2 * halo

    def one(plane, y, x):
        return lax.dynamic_slice(plane, (y * region_px, x * region_px),
                                 (P, P))

    return jax.vmap(lambda pl_, ys, xs: jax.vmap(
        lambda y, x: one(pl_, y, x))(ys, xs))(planes, ry, rx)


@partial(jax.jit, static_argnames=("region_px", "halo", "interpret"))
def roi_gather(planes, ry, rx, *, region_px: int, halo: int,
               interpret: bool | None = None):
    """Pallas ROI gather (interpret mode on CPU): (T, Hp, Wp) + (T, K)
    region indices -> (T, K, P, P) packed patch batch, bit-exact vs
    ``roi_gather_ref``."""
    if interpret is None:
        interpret = not on_tpu()
    return roi_gather_patches(planes, ry, rx, region_px=region_px,
                              halo=halo, interpret=interpret)
