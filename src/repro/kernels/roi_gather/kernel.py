"""ROI patch-gather kernel (Pallas): pack top-K active regions densely.

One grid step per (frame, capacity-lane): the whole halo-padded frame is
staged via a constant index map (it is re-read K times per frame, so on
TPU it stays VMEM-resident across the K lanes of a frame), the lane's
region offset comes in as a (1, 1) scalar block, and the output block is
the lane's dense (P, P) patch, P = region_px + 2·halo.  The gather start
is dynamic (``pl.dslice`` from the offset refs) but every SHAPE is
static — the packed batch always has capacity-K lanes, so the detector
trace downstream never changes with scene content.

Invalid lanes (gate admitted fewer than K regions) still gather a patch
(the caller points them at region 0); their outputs are dropped at
scatter time.  A gather is exact regardless of dtype, so the kernel is
bit-exact vs the pure-jnp ``dynamic_slice`` fallback — the parity
contract ``tests/test_roi.py`` holds, mirroring ``motion_sad``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gather_kernel(ry_ref, rx_ref, x_ref, o_ref, *, region_px: int,
                   halo: int):
    P = region_px + 2 * halo
    # region (ry, rx) -> top-left corner in the halo-padded plane: the
    # padding shifts frame coords by +halo, so the patch spanning
    # [ry*R - halo, ry*R + R + halo) starts at padded row ry*R
    y0 = ry_ref[0, 0] * region_px
    x0 = rx_ref[0, 0] * region_px
    patch = pl.load(x_ref, (pl.dslice(0, 1), pl.dslice(y0, P),
                            pl.dslice(x0, P)))
    o_ref[0, 0] = patch[0]


def roi_gather_patches(planes, ry, rx, *, region_px: int, halo: int,
                       interpret: bool = True):
    """planes: (T, Hp, Wp) halo-padded planes; ry/rx: (T, K) int32 region
    indices -> (T, K, P, P) packed patches."""
    T, Hp, Wp = planes.shape
    K = ry.shape[1]
    P = region_px + 2 * halo
    kernel = functools.partial(_gather_kernel, region_px=region_px,
                               halo=halo)
    return pl.pallas_call(
        kernel,
        grid=(T, K),
        in_specs=[
            pl.BlockSpec((1, 1), lambda t, k: (t, k)),
            pl.BlockSpec((1, 1), lambda t, k: (t, k)),
            pl.BlockSpec((1, Hp, Wp), lambda t, k: (t, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, P, P), lambda t, k: (t, k, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((T, K, P, P), planes.dtype),
        interpret=interpret,
    )(ry.astype(jnp.int32), rx.astype(jnp.int32), planes)
