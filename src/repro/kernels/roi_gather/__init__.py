from repro.kernels.roi_gather.ops import roi_gather, roi_gather_ref

__all__ = ["roi_gather", "roi_gather_ref"]
