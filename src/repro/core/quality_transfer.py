"""Quality transfer (paper §IV-B, Fig. 7).

Enhances a non-anchor LR frame using high-quality content from the nearest
preceding HD anchor: 1) locate each macroblock's source block on the anchor
via the (accumulated) motion vectors, 2) gather the HD block, 3) add the
interpolated residual, 4) paste.  TPU adaptation: the whole operation is a
block-tiled gather+add over a (H/16 × W/16) grid — the Pallas kernel in
``repro.kernels.qtransfer`` executes it with anchor tiles staged in VMEM;
this module is the pure-jnp reference used on CPU and as the kernel oracle.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.codec import blockdct as B
from repro.codec.motion import warp_blocks

f32 = jnp.float32


def residual_to_pixels(residual_q, qtab, H: int, W: int):
    """Dequantize + inverse-transform a frame's residual coefficients."""
    return B.unblockify(B.idct2(B.dequantize(residual_q, qtab)), H, W)


def transfer_frame(anchor_hd, mv_acc, residual_px, blend: float = 1.0,
                   use_kernel: bool = False):
    """One frame of quality transfer.

    anchor_hd: (H, W) the decoded HD anchor; mv_acc: (nby, nbx, 2)
    anchor-relative motion vectors; residual_px: (H, W) decoded residual.
    ``use_kernel`` routes through the Pallas TPU kernel (interpret mode on
    CPU); the pure-jnp path is the oracle.  Returns the enhanced frame.
    """
    if use_kernel:
        from repro.kernels.qtransfer.ops import qtransfer
        return qtransfer(anchor_hd, jnp.clip(mv_acc, -16, 16),
                         blend * residual_px, radius=16)
    warped = warp_blocks(anchor_hd, mv_acc)
    return jnp.clip(warped + blend * residual_px, 0.0, 255.0)


def transfer_chunk(frames_lr_up, anchor_hd, anchor_idx, mvs, residual_q,
                   qtab, types):
    """Apply quality transfer to every type-2 frame of a chunk.

    frames_lr_up: (T, H, W) decoder-upscaled LR frames (fallback content);
    anchor_hd: (T, H, W) per-frame nearest-anchor HD plane (gathered by the
    decoder); anchor_idx: (T,) index of that anchor; mvs: (T, nby, nbx, 2)
    frame-to-previous MVs; types: (T,) pipeline assignment.

    Returns (T, H, W) frames routed to pipeline ② (others pass through).
    """
    T, H, W = frames_lr_up.shape
    # accumulate MVs from each frame's anchor: cumsum minus cumsum at anchor
    cum = jnp.cumsum(mvs, axis=0)                       # (T, nby, nbx, 2)
    cum_at_anchor = cum[anchor_idx]                     # (T, nby, nbx, 2)
    mv_rel = cum - cum_at_anchor

    def one(i):
        resid = residual_to_pixels(residual_q[i], qtab, H, W)
        enhanced = transfer_frame(anchor_hd[i], mv_rel[i], resid)
        return jnp.where(types[i] == 2, enhanced, frames_lr_up[i])

    return jax.vmap(one)(jnp.arange(T))


def transfer_gain_psnr(raw, lr_up, enhanced):
    """PSNR gain of transfer vs plain upscale (paper Fig. 8a)."""
    def p(a, b):
        mse = jnp.mean(jnp.square(a.astype(f32) - b.astype(f32)))
        return 10.0 * jnp.log10(255.0 ** 2 / jnp.maximum(mse, 1e-9))
    return p(raw, enhanced) - p(raw, lr_up)
