"""Fused encode->decode round-trip: one chunk, one jit (ISSUE 4 tentpole).

BiSwift's end-to-end claim is that the adaptive hybrid codec plus the
multi-level pipelines keep 9+ concurrent streams real-time on one edge
GPU.  In this reproduction that means the whole camera->edge loop —
ladder downscale, video encode, Eq. 3 frame classification, JPEG anchor
encode, the rate/latency model, and the 3-pipeline decode-execute with
the detector backend — should trace as ONE program instead of two
separately-jitted halves stitched together by host Python:

  * ``roundtrip_chunk``         — one stream, one chunk, one module-level
    jit: source HD frames in, HD detections + accuracy + latency out.
  * ``roundtrip_batched``       — vmap over a homogeneous-signature
    stream set (same HD shape, same ladder rung).
  * ``roundtrip_ladder_batched``— MIXED ladder rungs in one dispatch: the
    per-stream static rungs fix each LR shape, streams pad onto a common
    LR canvas, and the heterogeneous-ladder masked encode plus the
    extent-aware decode keep every lane bit-exact vs its own
    single-stream round trip.
  * ``roundtrip_oracle``        — the compose-the-two-jits reference
    (module-level ``encode_chunk`` jit + host glue + ``decode_execute_chunk``
    jit).  ``tests/test_roundtrip.py`` holds the f32 bit-exactness
    contract between the oracle and all three fused forms; the
    mesh-sharded twin is ``repro.distributed.stream_sharding.shard_roundtrip``.

Static vs traced: the ladder rung (it fixes the LR shapes) lives in
``RoundtripConfig`` and is a static jit argument; thresholds (tr1, tr2),
bandwidth and queue delay are traced scalars, so the controller can
sweep them without recompiling.  The anchor JPEG quality is EITHER
static (``anchor_search=False``: pinned to ``cfg.anchor_quality``,
byte-identical to the pre-search trace) OR traced
(``anchor_search=True``: every frame is encoded at every rung of
``ANCHOR_QUALITY_LADDER`` in one masked sweep with static shapes, bits
are charged per rung through ``entropy_bits``, and a traced argmax picks
the highest rung whose per-anchor share of the chunk's bandwidth budget
fits — so ``bw_kbps`` can vary chunk-to-chunk without retracing).

Semantics note vs ``hybrid_encoder.encode_hybrid``: the legacy host
encoder searches the JPEG quality ladder and demotes anchors when the
budget runs out — both data-dependent host decisions.  The fused round
trip keeps the pure Eq. 3 classification inside the trace; with
``anchor_search`` on, the quality search moves inside too (same budget
arithmetic as ``encode_hybrid``: ``bw_kbps * 1000 * T/fps`` minus video
bits, split evenly across anchors), leaving anchor demotion as the one
remaining host-side decision.  Anchor bits are charged through the same
``entropy_bits`` rate model either way.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.codec import blockdct as B
from repro.codec.image_codec import (ANCHOR_QUALITY_LADDER, budget_rung,
                                     jpeg_encode_decode, ladder_sweep,
                                     quality_for_budget)
from repro.codec.rate_model import QUALITY_LADDER, downscale, ladder_lr_shape
from repro.codec.video_codec import (VideoCodecConfig, _encode_chunk,
                                     _encode_ladder_batch, encode_chunk)
from repro.core.classification import classify_frames
from repro.core.hybrid_decoder import (PipelineCosts, _execute_chunk,
                                       decode_execute_chunk)
from repro.models.detection import TinyDetectorConfig

f32 = jnp.float32


@dataclasses.dataclass(frozen=True)
class RoundtripConfig:
    """Static (hashable) half of the round-trip signature.

    ``level`` is the bitrate-ladder rung (§VI-A): it decides the LR shape
    and the codec quality, so it must be static.  ``codec.quality`` is
    overridden by the rung's quality — set ``use_kernel``/``dtype`` there
    to pick the search variant.  ``roi`` (a ``repro.core.roi.RoiConfig``)
    turns on ROI-gated inference inside the fused trace: the detector
    runs only on the top-K packed region patches.  ``anchor_search``
    switches the anchor quality from the static ``anchor_quality`` pin to
    the in-trace budget search over ``ANCHOR_QUALITY_LADDER`` (the flag
    itself is static — off-mode traces are byte-identical to pre-search
    builds; ``anchor_quality`` remains as the off-mode pin)."""
    level: int = 2
    codec: VideoCodecConfig = VideoCodecConfig()
    anchor_quality: float = 70.0
    det_cfg: TinyDetectorConfig = TinyDetectorConfig()
    costs: PipelineCosts = PipelineCosts()
    fps: float = 30.0
    roi: object | None = None
    anchor_search: bool = False

    def codec_for(self, level: int | None = None) -> VideoCodecConfig:
        ql = QUALITY_LADDER[self.level if level is None else level]
        return dataclasses.replace(self.codec, quality=ql.quality)


def anchor_budget_bits(bw_kbps, video_bits, n_anchors, n_frames: int,
                       fps: float):
    """Per-anchor bit budget: the chunk's bandwidth allowance
    (``bw_kbps * 1000 * T/fps``, the ``encode_hybrid`` arithmetic) minus
    the video-layer bits, split evenly across the chunk's anchors.  All
    of bw_kbps / video_bits / n_anchors may be traced — this is the
    shared budget expression of the fused search and the host oracle, so
    the two agree bit-for-bit by construction."""
    chunk_bits = jnp.asarray(bw_kbps, f32) * 1000.0 * (n_frames / fps)
    spare = jnp.maximum(chunk_bits - jnp.asarray(video_bits, f32), 0.0)
    return spare / jnp.maximum(jnp.asarray(n_anchors, f32), 1.0)


def _roundtrip_execute(raw, enc, lr_extent, gt_boxes, gt_valid,
                       detector_params, tr1, tr2, bw_kbps, queue_delay,
                       cfg: RoundtripConfig) -> dict:
    """Post-encode half of the trace: classification, anchors, rate model,
    3-pipeline execution.  Shared by every fused form (``lr_extent`` is
    the valid LR extent for heterogeneous-ladder lanes, None otherwise).
    """
    # seq_sum everywhere a variable-length total feeds the rate model: the
    # oracle accumulates the same terms in the same left-to-right order,
    # so the fused and composed paths agree bit-for-bit
    video_bits = B.seq_sum(enc.bits)
    types, _, _ = classify_frames(enc.frame_diff / 255.0,
                                  enc.residual_mag / 255.0, tr1, tr2)
    is1 = types == 1
    T = raw.shape[0]
    if cfg.anchor_search:
        # masked ladder sweep: encode EVERY frame at EVERY rung (static
        # shapes — neither content nor budget retraces), charge bits per
        # rung, then a traced argmax picks each frame's highest rung that
        # fits its even share of the chunk's spare bandwidth
        sweep_rec, sweep_bits = jax.vmap(ladder_sweep)(raw)  # (T,Q,H,W),(T,Q)
        n_anchors = B.seq_sum(jnp.where(is1, 1.0, 0.0))
        per_anchor = anchor_budget_bits(bw_kbps, video_bits, n_anchors,
                                        T, cfg.fps)
        rung = budget_rung(sweep_bits, per_anchor)           # (T,)
        jrec = jnp.take_along_axis(
            sweep_rec, rung[:, None, None, None], axis=1)[:, 0]
        jbits = jnp.take_along_axis(sweep_bits, rung[:, None], axis=1)[:, 0]
        frame_q = jnp.asarray(ANCHOR_QUALITY_LADDER, f32)[rung]
    else:
        # JPEG-encode EVERY frame at the pinned anchor quality and mask to
        # the type-1 plane: data-independent shapes keep the anchor
        # pipeline inside the trace (the host path only encodes anchors)
        jrec, jbits = jax.vmap(
            lambda fr: jpeg_encode_decode(fr, cfg.anchor_quality))(raw)
        frame_q = jnp.full((T,), cfg.anchor_quality, f32)
    anchor_hd = jnp.where(is1[:, None, None], jrec, 0.0)
    anchor_bits = B.seq_sum(jnp.where(is1, jbits, 0.0))
    anchor_q = jnp.where(is1, frame_q, 0.0)
    total_bits = video_bits + anchor_bits

    out = _execute_chunk(enc, types, anchor_hd, gt_boxes, gt_valid,
                         detector_params, cfg.det_cfg, bw_kbps, queue_delay,
                         total_bits, cfg.costs, lr_extent=lr_extent,
                         roi=cfg.roi)
    out.update(types=types, video_bits=video_bits, anchor_bits=anchor_bits,
               total_bits=total_bits, anchor_q=anchor_q)
    return out


def _roundtrip_chunk(raw, gt_boxes, gt_valid, detector_params, tr1, tr2,
                     bw_kbps, queue_delay, cfg: RoundtripConfig) -> dict:
    """Traced single-stream body: raw (T, H, W) HD frames -> detections."""
    ql = QUALITY_LADDER[cfg.level]
    lr = downscale(jnp.asarray(raw, f32), ql.scale)
    enc = _encode_chunk(lr, cfg.codec_for())
    return _roundtrip_execute(raw, enc, None, gt_boxes, gt_valid,
                              detector_params, tr1, tr2, bw_kbps,
                              queue_delay, cfg)


@partial(jax.jit, static_argnames=("cfg",))
def roundtrip_chunk(raw, gt_boxes, gt_valid, detector_params, *, tr1, tr2,
                    bw_kbps, queue_delay=0.0,
                    cfg: RoundtripConfig = RoundtripConfig()) -> dict:
    """One chunk of one stream, source frames -> HD detections, ONE jit.

    raw: (T, H, W) [0..255]; gt_boxes/gt_valid: (T, N, 4)/(T, N);
    tr1/tr2/bw_kbps/queue_delay: traced scalars; cfg static.  Returns the
    ``decode_execute_chunk`` result dict plus types/video_bits/
    anchor_bits/total_bits.
    """
    return _roundtrip_chunk(raw, gt_boxes, gt_valid, detector_params,
                            tr1, tr2, bw_kbps, queue_delay, cfg)


def _roundtrip_batch(raw, gt_boxes, gt_valid, detector_params, tr1, tr2,
                     bw_kbps, queue_delay, cfg: RoundtripConfig) -> dict:
    """vmap-over-streams traced body (homogeneous signature + rung)."""
    return jax.vmap(
        lambda r, gb, gv, t1, t2, bw, qd: _roundtrip_chunk(
            r, gb, gv, detector_params, t1, t2, bw, qd, cfg)
    )(raw, gt_boxes, gt_valid, tr1, tr2, bw_kbps, queue_delay)


@partial(jax.jit, static_argnames=("cfg",))
def roundtrip_batched(raw, gt_boxes, gt_valid, detector_params, *, tr1, tr2,
                      bw_kbps, queue_delay,
                      cfg: RoundtripConfig = RoundtripConfig()) -> dict:
    """S streams of one signature group, one device dispatch.

    raw: (S, T, H, W); per-stream scalars are (S,) arrays; detector
    params shared.  Same stream-axis shape discipline as
    ``decode_execute_batched`` — the mesh-sharded twin is
    ``stream_sharding.shard_roundtrip``.
    """
    return _roundtrip_batch(raw, gt_boxes, gt_valid, detector_params,
                            tr1, tr2, bw_kbps, queue_delay, cfg)


def _roundtrip_ladder_body(raw, lr_pad, extents, qualities, gt_boxes,
                           gt_valid, detector_params, tr1, tr2, bw_kbps,
                           queue_delay, cfg: RoundtripConfig) -> dict:
    """Post-downscale mixed-ladder traced body: lr_pad (S, T, Hp, Wp) is
    the padded LR canvas, extents (S, 2) the per-stream valid (h, w),
    qualities (S,) the per-stream QP.  Shared by the single-device jit
    and ``shard_roundtrip`` (the shape-changing per-rung downscale happens
    OUTSIDE the shard_map region; everything here is uniform-shape)."""
    enc = _encode_ladder_batch(lr_pad, extents, qualities, cfg.codec)
    return jax.vmap(
        lambda r, e, ext, gb, gv, t1, t2, bw, qd: _roundtrip_execute(
            r, e, (ext[0], ext[1]), gb, gv, detector_params, t1, t2, bw,
            qd, cfg)
    )(raw, enc, extents, gt_boxes, gt_valid, tr1, tr2, bw_kbps, queue_delay)


def ladder_batch_arrays(levels, H: int, W: int):
    """Static per-rung LR shapes -> (extents (S, 2) int32, qualities (S,))
    for a mixed-ladder batch over an (H, W) HD source."""
    shapes = [ladder_lr_shape(level, H, W) for level in levels]
    extents = jnp.asarray(shapes, jnp.int32)
    qualities = jnp.asarray([QUALITY_LADDER[level].quality
                             for level in levels], f32)
    return extents, qualities


def _downscale_pad(raw, levels):
    """Per-stream static-rung downscale, padded onto one LR canvas."""
    S, T, H, W = raw.shape
    shapes = [ladder_lr_shape(level, H, W) for level in levels]
    hp = max(h for h, _ in shapes)
    wp = max(w for _, w in shapes)
    lanes = []
    for s, level in enumerate(levels):
        lr = downscale(raw[s], QUALITY_LADDER[level].scale)
        h, w = shapes[s]
        lanes.append(jnp.pad(lr, ((0, 0), (0, hp - h), (0, wp - w))))
    return jnp.stack(lanes)


def full_lr_canvas(H: int, W: int) -> tuple[int, int]:
    """The largest LR shape any ladder rung can produce for an (H, W)
    source — the fixed canvas of the shape-stable dispatch below."""
    from repro.codec.rate_model import lr_shape_for_scale
    return lr_shape_for_scale(1.0, H, W)


@partial(jax.jit, static_argnames=("cfg",))
def roundtrip_padded_batched(raw, lr_pad, extents, qualities, gt_boxes,
                             gt_valid, detector_params, *, tr1, tr2,
                             bw_kbps, queue_delay,
                             cfg: RoundtripConfig = RoundtripConfig()
                             ) -> dict:
    """Shape-stable mixed-ladder round trip: rungs travel as DATA.

    The caller downscales each stream to its rung eagerly and pads onto
    one fixed canvas (``full_lr_canvas``), passing extents (S, 2) and
    qualities (S,) as arrays — so a stream set of fixed size compiles ONE
    trace no matter how per-step bandwidth reallocation reshuffles the
    rungs.  (``roundtrip_ladder_batched`` below, with its static rung
    tuple, sizes the canvas to the batch's largest rung — less masked
    margin to encode, but one retrace per rung combination; the sim env
    uses THIS entry to bound compile churn at one trace per signature.)
    ``cfg.level`` is ignored.
    """
    return _roundtrip_ladder_body(jnp.asarray(raw, f32), lr_pad, extents,
                                  qualities, gt_boxes, gt_valid,
                                  detector_params, tr1, tr2, bw_kbps,
                                  queue_delay, cfg)


@partial(jax.jit, static_argnames=("levels", "cfg"))
def roundtrip_ladder_batched(raw, gt_boxes, gt_valid, detector_params, *,
                             tr1, tr2, bw_kbps, queue_delay,
                             levels: tuple,
                             cfg: RoundtripConfig = RoundtripConfig()
                             ) -> dict:
    """Mixed bitrate-ladder rungs, ONE padded dispatch, still one jit.

    ``levels`` (static tuple, one rung per stream) fixes each stream's LR
    shape; streams downscale to their own rung, pad onto the common LR
    canvas, and run the masked heterogeneous encode + extent-aware
    decode.  Lane s is bit-exact (f32) vs
    ``roundtrip_chunk(raw[s], ..., cfg=replace(cfg, level=levels[s]))``.
    ``cfg.level`` is ignored (the per-stream rungs win).
    """
    raw = jnp.asarray(raw, f32)
    S, T, H, W = raw.shape
    lr_pad = _downscale_pad(raw, levels)
    extents, qualities = ladder_batch_arrays(levels, H, W)
    return _roundtrip_ladder_body(raw, lr_pad, extents, qualities, gt_boxes,
                                  gt_valid, detector_params, tr1, tr2,
                                  bw_kbps, queue_delay, cfg)


# --------------------------------------------------------------------------
# Compose-the-two-jits oracle (host glue between the PR-3 jits)
# --------------------------------------------------------------------------
# module-level jit: re-wrapping per call would retrace the JPEG encode
# inside every oracle invocation and inflate the two-jit bench baseline
_jpeg = jax.jit(jpeg_encode_decode)
# static qualities so the probe's per-rung loop unrolls over the same
# constants the fused sweep bakes in
_q_for_budget = jax.jit(quality_for_budget, static_argnames=("qualities",))


def roundtrip_oracle(raw, gt_boxes, gt_valid, detector_params, *, tr1, tr2,
                     bw_kbps, queue_delay=0.0,
                     cfg: RoundtripConfig = RoundtripConfig()) -> dict:
    """The pre-tentpole execution: ``encode_chunk`` (jit #1), host-side
    classification + per-anchor JPEG loop + rate model, then
    ``decode_execute_chunk`` (jit #2).  The fused forms must reproduce
    this bit-for-bit in f32 — it is the parity baseline for
    ``tests/test_roundtrip.py`` and the "sequential two-jit" side of
    ``benchmarks/roundtrip.py``.
    """
    raw = jnp.asarray(raw, f32)
    ql = QUALITY_LADDER[cfg.level]
    lr = downscale(raw, ql.scale)
    enc = encode_chunk(lr, cfg.codec_for())                    # jit #1
    video_bits = B.seq_sum(enc.bits)
    types, _, _ = classify_frames(enc.frame_diff / 255.0,
                                  enc.residual_mag / 255.0, tr1, tr2)
    types_host = jax.device_get(types)
    anchors = np.flatnonzero(types_host == 1)
    T = raw.shape[0]
    anchor_hd = jnp.zeros_like(raw)
    anchor_bits = jnp.asarray(0.0, f32)
    anchor_q = jnp.zeros((T,), f32)
    if cfg.anchor_search:
        # host-side twin of the traced search: probe the ladder per anchor
        # with quality_for_budget against the same per-anchor budget share
        per_anchor = anchor_budget_bits(bw_kbps, video_bits,
                                        float(len(anchors)), T, cfg.fps)
        for i in anchors:
            q_i, _ = _q_for_budget(raw[i], per_anchor)
            rec, bits = _jpeg(raw[i], q_i)
            anchor_hd = anchor_hd.at[i].set(rec)
            anchor_bits = anchor_bits + bits
            anchor_q = anchor_q.at[i].set(q_i)
    else:
        for i in anchors:
            rec, bits = _jpeg(raw[i], cfg.anchor_quality)
            anchor_hd = anchor_hd.at[i].set(rec)
            anchor_bits = anchor_bits + bits
            anchor_q = anchor_q.at[i].set(cfg.anchor_quality)
    total_bits = video_bits + anchor_bits
    out = decode_execute_chunk(                                # jit #2
        enc, types, anchor_hd, gt_boxes, gt_valid, detector_params,
        cfg.det_cfg, bw_kbps=bw_kbps, queue_delay=queue_delay,
        total_bits=total_bits, costs=cfg.costs, roi=cfg.roi)
    out = dict(out)
    out.update(types=types, video_bits=video_bits, anchor_bits=anchor_bits,
               total_bits=total_bits, anchor_q=anchor_q)
    return out
