"""Analytics-aware bandwidth controller (paper §IV-C / §V-B).

Wraps the high-level SAC agent: observes S_high = (num, size, r, b_L, acc,
p), emits the per-stream bandwidth proportion vector every
``controller_interval`` chunks (10 s in the paper), and is trained with
reward r_high = min_c r_c (Eq. 6).  Baseline comparison: even allocation.

Two act paths share the same traced expression (bit-exact parity
contract, docs/bilevel.md): :meth:`proportions` dispatches the jitted
``act_proportions`` per reallocation (the loop oracle), while the fused
``repro.core.bilevel.bilevel_step`` inlines ``_act_proportions`` into its
single-jit trace and syncs the host-side cache back via :meth:`adopt`.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import numpy as np

from repro.rl import sac
from repro.rl.replay import ReplayBuffer

f32 = np.float32


def normalize_proportions(a):
    """Controller action -> bandwidth proportions (floor 1e-3, sum 1)."""
    p = a + 1e-3
    return p / p.sum()


def _act_proportions(key, agent, state, explore: bool = True):
    """(raw action, normalized proportions) — raw feeds the replay
    buffer, proportions feed allocation and every low-level state."""
    a = sac._act(key, agent, state, explore)
    return a, normalize_proportions(a)


act_proportions = partial(jax.jit, static_argnums=(3,))(_act_proportions)


@dataclasses.dataclass
class BandwidthController:
    agent: dict
    cfg: sac.SACConfig
    buffer: ReplayBuffer
    interval: int = 10
    _last_state: np.ndarray | None = None
    _last_action: np.ndarray | None = None
    _current: np.ndarray | None = None
    updates: int = 0

    @classmethod
    def create(cls, key, state_dim: int, n_streams: int, interval: int = 10):
        cfg = sac.SACConfig(state_dim=state_dim, action_dim=n_streams)
        agent = sac.init(key, cfg)
        buf = ReplayBuffer(cfg.buffer_size, state_dim, n_streams)
        return cls(agent=agent, cfg=cfg, buffer=buf, interval=interval)

    def needs_act(self, t: int) -> bool:
        return self._current is None or t % self.interval == 0

    def proportions(self, key, state: np.ndarray, t: int,
                    explore: bool = True) -> np.ndarray:
        """Controller action; recomputed every ``interval`` chunks."""
        if self.needs_act(t):
            a, p = act_proportions(key, self.agent, state, explore)
            self.adopt(np.asarray(a), np.asarray(p, f32), state)
        return self._current

    def adopt(self, raw_action: np.ndarray, props: np.ndarray,
              state: np.ndarray):
        """Install a freshly computed action (from :meth:`proportions` or
        from the fused bilevel_step's inlined act on recompute chunks)."""
        self._last_state = state
        self._last_action = raw_action
        self._current = props

    def record(self, reward: float, next_state: np.ndarray,
               done: bool = False):
        if self._last_state is not None:
            self.buffer.add(self._last_state, self._last_action, reward,
                            next_state, done)

    def ready(self) -> bool:
        return len(self.buffer) >= self.cfg.minibatch

    def train(self, key, n_updates: int = 1):
        logs = []
        for _ in range(n_updates):
            if not self.ready():
                break
            batch = self.buffer.sample(self.cfg.minibatch)
            self.agent, log = sac.update(key, self.agent, batch, self.cfg)
            self.updates += 1
            logs.append(log)
        return logs


def even_proportions(n_streams: int) -> np.ndarray:
    return np.full(n_streams, 1.0 / n_streams, f32)
