"""Analytics-aware bandwidth controller (paper §IV-C / §V-B).

Wraps the high-level SAC agent: observes S_high = (num, size, r, b_L, acc,
p), emits the per-stream bandwidth proportion vector every
``controller_interval`` chunks (10 s in the paper), and is trained with
reward r_high = min_c r_c (Eq. 6).  Baseline comparison: even allocation.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.rl import sac
from repro.rl.replay import ReplayBuffer

f32 = np.float32


@dataclasses.dataclass
class BandwidthController:
    agent: dict
    cfg: sac.SACConfig
    buffer: ReplayBuffer
    interval: int = 10
    _last_state: np.ndarray | None = None
    _last_action: np.ndarray | None = None
    _current: np.ndarray | None = None
    updates: int = 0

    @classmethod
    def create(cls, key, state_dim: int, n_streams: int, interval: int = 10):
        cfg = sac.SACConfig(state_dim=state_dim, action_dim=n_streams)
        agent = sac.init(key, cfg)
        buf = ReplayBuffer(cfg.buffer_size, state_dim, n_streams)
        return cls(agent=agent, cfg=cfg, buffer=buf, interval=interval)

    def proportions(self, key, state: np.ndarray, t: int,
                    explore: bool = True) -> np.ndarray:
        """Controller action; recomputed every ``interval`` chunks."""
        if self._current is None or t % self.interval == 0:
            a = np.asarray(sac.act(key, self.agent, state, explore))
            self._last_state = state
            self._last_action = a
            p = a + 1e-3
            self._current = (p / p.sum()).astype(f32)
        return self._current

    def record(self, reward: float, next_state: np.ndarray,
               done: bool = False):
        if self._last_state is not None:
            self.buffer.add(self._last_state, self._last_action, reward,
                            next_state, done)

    def train(self, key, n_updates: int = 1):
        logs = []
        for _ in range(n_updates):
            if len(self.buffer) < self.cfg.minibatch:
                break
            batch = self.buffer.sample(self.cfg.minibatch)
            self.agent, log = sac.update(key, self.agent, batch, self.cfg)
            self.updates += 1
            logs.append(log)
        return logs


def even_proportions(n_streams: int) -> np.ndarray:
    return np.full(n_streams, 1.0 / n_streams, f32)
