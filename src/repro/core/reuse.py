"""Inference-result reuse (paper §IV-B, pipeline ③).

1) take the last inference frame's detections, 2) mean the motion vectors
inside each bbox, 3) shift the bbox by that mean.  ~6 ms/frame in the
paper vs full inference — the source of the 7–18 frame/s acceleration
(Fig. 8b).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.codec.motion import MB

f32 = jnp.float32


def shift_boxes(boxes, scores, mv):
    """boxes: (N, 4) cxcywh px; mv: (nby, nbx, 2) codec motion vectors.

    Codec convention: pred(y) = ref(y + mv), i.e. mv points from the current
    block to its source in the reference frame — the object's displacement
    is therefore −mv, and each box shifts by −mean(mv) over its blocks.
    """
    nby, nbx = mv.shape[:2]
    cy = (jnp.arange(nby, dtype=f32)[:, None] + 0.5) * MB
    cx = (jnp.arange(nbx, dtype=f32)[None, :] + 0.5) * MB

    def one(box):
        inside = (jnp.abs(cy - box[0]) <= box[2] / 2 + MB / 2) & \
                 (jnp.abs(cx - box[1]) <= box[3] / 2 + MB / 2)
        w = inside.astype(f32)
        n = jnp.maximum(w.sum(), 1e-9)
        dy = (mv[..., 0] * w).sum() / n
        dx = (mv[..., 1] * w).sum() / n
        return box.at[0].add(-dy).at[1].add(-dx)

    return jax.vmap(one)(boxes), scores


def reuse_chunk(types, mvs, infer_boxes, infer_scores,
                init_boxes=None, init_scores=None):
    """Propagate detections through type-3 frames of a chunk.

    types: (T,); mvs: (T, nby, nbx, 2) frame-to-previous MVs;
    infer_boxes/scores: (T, N, 4)/(T, N) — valid at type-1/2 frames (others
    ignored).  ``init_boxes``/``init_scores`` seed the reuse carry — pass
    the previous chunk's last detections so type-3 frames at a chunk
    boundary keep tracking across chunks (defaults keep the historical
    within-chunk behavior).  Returns per-frame (boxes, scores).
    """
    T = types.shape[0]
    if init_boxes is None:
        init_boxes = infer_boxes[0]
    if init_scores is None:
        init_scores = infer_scores[0]

    def step(carry, i):
        boxes, scores = carry
        fresh = types[i] != 3
        # accumulate motion since the last inference frame
        shifted, sc = shift_boxes(boxes, scores, mvs[i])
        boxes = jnp.where(fresh, infer_boxes[i], shifted)
        scores = jnp.where(fresh, infer_scores[i], sc)
        return (boxes, scores), (boxes, scores)

    (_, _), (all_boxes, all_scores) = jax.lax.scan(
        step, (init_boxes, init_scores), jnp.arange(T))
    return all_boxes, all_scores
