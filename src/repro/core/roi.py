"""Hierarchical ROI-gated inference inside the fused trace (ISSUE 9).

BiSwift spends detector compute only where it matters: a cheap relevance
head built from statistics the codec ALREADY computed (macroblock motion
vectors + quantized residual energy) scores each ``region_px``-sized HD
region, ``lax.top_k`` packs the top-K active regions into a dense
fixed-capacity patch batch (static shapes — the detector trace never
changes with scene content), the detector convs run only on the packed
patches, and a scatter with a temporal carry covers gated-off regions
with their last computed raw head output (the pipeline-③ idea applied at
region granularity, below the frame-level reuse that still runs
downstream).

Bit-exactness contract (``tests/test_roi.py``): when the gate admits
every region (``threshold <= 0`` and ``capacity >= n_regions``) the
assembled raw map equals the full-frame ``detection.forward`` output
bit-for-bit, so the whole ROI-gated fused round trip reproduces the
ungated one exactly.  That works because each patch carries a ``halo``
wide enough to cover the conv stack's receptive field AND the patch
forward masks activations that fall outside the frame after every layer,
reproducing full-frame SAME-padding semantics at frame boundaries (zero
padding of the pre-normalized plane matches conv zero padding; interior
activations are unaffected by the mask).

Static vs traced: everything in :class:`RoiConfig` is static (it rides
inside ``RoundtripConfig``/``ServingConfig`` and the jit signatures);
region scores, the top-K selection and the gather starts are traced, so
scene content never retraces anything.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import detection as D

f32 = jnp.float32


@dataclasses.dataclass(frozen=True)
class RoiConfig:
    """Static half of the ROI gate.

    ``region_px`` — HD region side (must divide H and W and be a multiple
    of both 8 and the detector stride); ``halo`` — context margin per
    patch side (must cover the detector's receptive field and divide by
    the total downsampling, see ``validate_roi``); ``capacity`` — K, the
    fixed number of packed patch lanes per frame (the compute budget);
    ``threshold`` — minimum relevance score for a region to be eligible
    (``<= 0`` admits every region, leaving top-K as the only gate);
    ``w_motion``/``w_resid`` — relevance-head feature weights;
    ``use_kernel`` routes the patch gather through the Pallas kernel
    (``repro.kernels.roi_gather``, interpret mode on CPU)."""
    region_px: int = 32
    halo: int = 8
    capacity: int = 8
    threshold: float = 0.0
    w_motion: float = 1.0
    w_resid: float = 1.0
    use_kernel: bool = False


def region_grid(hd_hw, roi: RoiConfig) -> tuple[int, int]:
    """(n_region_rows, n_region_cols) of the HD region grid."""
    H, W = hd_hw
    if H % roi.region_px or W % roi.region_px:
        raise ValueError(
            f"RoiConfig.region_px={roi.region_px} must divide the HD "
            f"shape ({H}, {W})")
    return H // roi.region_px, W // roi.region_px


def required_halo(det_cfg) -> int:
    """Receptive-field radius of the conv stack at input resolution: a
    3×3 layer adds ±1 at its input's scale, and each downsampling layer
    doubles the scale of everything after it."""
    n_down = {2: 1, 4: 2, 8: 3}[det_cfg.stride]
    rf, grow = 0, 1
    for i in range(len(det_cfg.channels)):
        rf += grow
        if i < n_down:
            grow *= 2
    return rf


def validate_roi(roi: RoiConfig, det_cfg, hd_hw) -> None:
    """Static-shape sanity for one (roi, detector, HD shape) binding —
    raises ValueError at trace time, not deep inside a conv."""
    region_grid(hd_hw, roi)
    s = det_cfg.stride
    if roi.region_px % 8 or roi.region_px % s:
        raise ValueError(
            f"region_px={roi.region_px} must be a multiple of 8 and of "
            f"the detector stride {s}")
    if roi.halo % s:
        raise ValueError(
            f"halo={roi.halo} must be a multiple of the total "
            f"downsampling {s} (the interior crop happens on the "
            "stride-s output grid)")
    rf = required_halo(det_cfg)
    if roi.halo < rf:
        raise ValueError(
            f"halo={roi.halo} is smaller than the detector's receptive "
            f"field radius {rf}; patch outputs would diverge from the "
            "full-frame forward")
    if roi.capacity < 1:
        raise ValueError(f"capacity={roi.capacity} must be >= 1")


# --------------------------------------------------------------------------
# relevance head: codec statistics -> per-region scores
# --------------------------------------------------------------------------
def region_scores(mv, residual_q, lr_hw, hd_hw, roi: RoiConfig,
                  lr_extent=None):
    """Cheap traced relevance scores, (T, nry, nrx) f32.

    ``mv``: (T, nby, nbx, 2) LR macroblock motion vectors; ``residual_q``:
    (T, nblocks, 8, 8) quantized residual coefficients (row-major 8×8
    blocks over the LR canvas); ``lr_hw``: the (static) LR canvas shape
    those statistics were computed on; ``lr_extent``: traced valid (h, w)
    when the encode came from the heterogeneous-ladder padded path (the
    sample-point index maps then read only the valid region, like
    ``_upscale_mvs``).

    Each HD region is sampled on an 8-px sub-grid; every sample maps to
    its nearest LR macroblock (motion magnitude |dy|+|dx|) and nearest LR
    8×8 residual block (mean |coef|), and the region score is the max
    over samples of ``w_motion·motion + w_resid·residual``.  Scores only
    GATE — no bit-exactness contract — so nearest-index sampling is fine.
    """
    H, W = hd_hw
    h, w = lr_hw
    hv, wv = (h, w) if lr_extent is None else lr_extent
    hv = jnp.asarray(hv, jnp.int32)
    wv = jnp.asarray(wv, jnp.int32)
    nry, nrx = region_grid((H, W), roi)
    s = roi.region_px // 8                  # samples per region side
    T = mv.shape[0]

    # HD sample centers -> LR pixel coords (floor map over the valid
    # extent) -> macroblock / residual-block indices
    ys = jnp.arange(nry * s, dtype=jnp.int32) * 8 + 4
    xs = jnp.arange(nrx * s, dtype=jnp.int32) * 8 + 4
    ylr = jnp.clip(ys * hv // H, 0, hv - 1)
    xlr = jnp.clip(xs * wv // W, 0, wv - 1)
    mby = jnp.clip(ylr // 16, 0, jnp.maximum(hv // 16 - 1, 0))
    mbx = jnp.clip(xlr // 16, 0, jnp.maximum(wv // 16 - 1, 0))
    rby = jnp.clip(ylr // 8, 0, hv // 8 - 1)
    rbx = jnp.clip(xlr // 8, 0, wv // 8 - 1)

    motion = jnp.abs(mv.astype(f32)).sum(-1)          # (T, nby, nbx)
    motion_s = motion[:, mby][:, :, mbx]              # (T, nry*s, nrx*s)
    energy = jnp.abs(residual_q.astype(f32)).mean((-1, -2))  # (T, nblocks)
    rid = rby[:, None] * (w // 8) + rbx[None, :]      # (nry*s, nrx*s)
    energy_s = energy[:, rid]
    samples = roi.w_motion * motion_s + roi.w_resid * energy_s
    return samples.reshape(T, nry, s, nrx, s).max(axis=(2, 4))


def roi_select(scores, capacity: int, threshold: float):
    """Top-K active regions, fixed capacity, deterministic tie-break.

    ``scores``: (..., R) flat per-region scores.  Returns
    ``(idx (..., K) int32, valid (..., K) bool)``: the K highest-scoring
    regions with score >= threshold, descending score, ties broken by
    LOWER flat region index (``lax.top_k``'s documented stable order).
    Lanes beyond the number of admitted regions (threshold cuts, or
    capacity > R) come back with ``valid=False`` and a safe index 0.
    """
    R = scores.shape[-1]
    keyed = jnp.where(scores >= threshold, scores.astype(f32), -jnp.inf)
    k = min(capacity, R)
    top, idx = lax.top_k(keyed, k)
    valid = jnp.isfinite(top)
    if k < capacity:
        pad = capacity - k
        idx = jnp.concatenate(
            [idx, jnp.zeros(idx.shape[:-1] + (pad,), idx.dtype)], axis=-1)
        valid = jnp.concatenate(
            [valid, jnp.zeros(valid.shape[:-1] + (pad,), bool)], axis=-1)
    return jnp.where(valid, idx, 0).astype(jnp.int32), valid


# --------------------------------------------------------------------------
# packed patch batch: gather -> masked conv forward -> scatter
# --------------------------------------------------------------------------
def extract_patches(frames, ry, rx, roi: RoiConfig):
    """Normalize, halo-pad and gather: (T, H, W) [0..255] frames + (T, K)
    region coords -> (T, K, P, P) pre-normalized patches.

    Normalization happens BEFORE padding so the zero margin equals the
    conv stack's SAME zero padding (raw-pixel zeros would normalize to
    -0.5 and break boundary exactness)."""
    xn = frames.astype(f32) / 255.0 - 0.5
    xp = jnp.pad(xn, ((0, 0), (roi.halo, roi.halo), (roi.halo, roi.halo)))
    if roi.use_kernel:
        from repro.kernels.roi_gather.ops import roi_gather
        return roi_gather(xp, ry, rx, region_px=roi.region_px,
                          halo=roi.halo)
    from repro.kernels.roi_gather.ops import roi_gather_ref
    return roi_gather_ref(xp, ry, rx, region_px=roi.region_px,
                          halo=roi.halo)


def forward_patches(params, det_cfg, patches, ry, rx, hd_hw,
                    roi: RoiConfig):
    """Detector forward over the packed patch batch, (T, K, rc, rc, 5).

    All T·K patches run in ONE conv dispatch.  After every conv layer,
    activations whose global coordinate falls outside the frame are
    zeroed: an interior activation never reads them (halo >= receptive
    field), and a boundary activation then sees exactly the zero padding
    the full-frame SAME conv would have provided — which is what makes
    the interior crop bit-exact vs ``detection.forward`` for arbitrary
    params, including nonzero biases.  ``rc = region_px / stride`` output
    cells per patch side."""
    H, W = hd_hw
    T, K, P, _ = patches.shape
    x = patches.reshape(T * K, P, P)[..., None]
    ri = ry.reshape(-1)
    rj = rx.reshape(-1)
    n_down = {2: 1, 4: 2, 8: 3}[det_cfg.stride]
    halo_l, reg_l, Hl, Wl = roi.halo, roi.region_px, H, W
    for i, _c in enumerate(det_cfg.channels):
        stride = 2 if i < n_down else 1
        x = lax.conv_general_dilated(
            x, params[f"conv{i}"], window_strides=(stride, stride),
            padding="SAME", dimension_numbers=("NHWC", "HWIO", "NHWC"))
        x = jax.nn.relu(x + params[f"bias{i}"])
        halo_l //= stride
        reg_l //= stride
        Hl //= stride
        Wl //= stride
        gy = ri[:, None] * reg_l - halo_l \
            + jnp.arange(x.shape[1])[None, :]                # (TK, P_l)
        gx = rj[:, None] * reg_l - halo_l \
            + jnp.arange(x.shape[2])[None, :]
        m = ((gy >= 0) & (gy < Hl))[:, :, None] \
            & ((gx >= 0) & (gx < Wl))[:, None, :]
        x = jnp.where(m[..., None], x, 0.0)
    x = lax.conv_general_dilated(
        x, params["head"], window_strides=(1, 1), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC")) + params["head_b"]
    x = x[:, halo_l:halo_l + reg_l, halo_l:halo_l + reg_l, :]
    return x.reshape(T, K, reg_l, reg_l, x.shape[-1])


def roi_raw_maps(params, det_cfg, roi: RoiConfig, frames, idx, valid, *,
                 carry: bool = True):
    """Gather + forward + scatter: (T, H, W) frames and a (T, K)
    selection -> assembled (T, hc, wc, 5) raw head maps.

    ``carry=True`` (the fused chunk path): a ``lax.scan`` over frames
    keeps the per-region raw outputs as device state, so a region the
    gate skips at frame t retains its most recent computed raw — region-
    granular pipeline-③ reuse.  Regions never selected in the chunk stay
    at raw 0 (objectness sigmoid(0) = 0.5, below the strict > 0.5
    confidence cut).  ``carry=False`` (the serving batch path, where rows
    from different streams interleave): every row scatters into a fresh
    zero map.  Invalid lanes scatter out of bounds and are dropped."""
    T, H, W = frames.shape
    validate_roi(roi, det_cfg, (H, W))
    nry, nrx = region_grid((H, W), roi)
    R = nry * nrx
    stride = det_cfg.stride
    rc = roi.region_px // stride
    hc, wc = H // stride, W // stride
    ry = (idx // nrx).astype(jnp.int32)
    rx = (idx % nrx).astype(jnp.int32)
    patches = extract_patches(frames, ry, rx, roi)
    raws = forward_patches(params, det_cfg, patches, ry, rx, (H, W), roi)

    def scatter(regions, raws_t, idx_t, valid_t):
        safe = jnp.where(valid_t, idx_t, R)      # R is out of bounds
        return regions.at[safe].set(raws_t, mode="drop")

    def assemble(regions):
        return regions.reshape(nry, nrx, rc, rc, 5) \
            .transpose(0, 2, 1, 3, 4).reshape(hc, wc, 5)

    init = jnp.zeros((R, rc, rc, 5), raws.dtype)
    if carry:
        def step(regions, xs):
            regions = scatter(regions, *xs)
            return regions, assemble(regions)

        _, maps = lax.scan(step, init, (raws, idx, valid))
    else:
        maps = jax.vmap(
            lambda r, i, v: assemble(scatter(init, r, i, v)))(
            raws, idx, valid)
    return maps


# --------------------------------------------------------------------------
# entry points
# --------------------------------------------------------------------------
def roi_detect(params, det_cfg, roi: RoiConfig, frames, mv, residual_q,
               lr_hw, lr_extent=None):
    """ROI-gated replacement for the full-frame ``_detect``: score, pack,
    forward, scatter-with-carry, decode.  Same (boxes, scores) shapes as
    ``detection.decode_boxes`` on the full frame."""
    T, H, W = frames.shape
    nry, nrx = region_grid((H, W), roi)
    scores = region_scores(mv, residual_q, lr_hw, (H, W), roi,
                           lr_extent=lr_extent)
    idx, valid = roi_select(scores.reshape(T, nry * nrx), roi.capacity,
                            roi.threshold)
    maps = roi_raw_maps(params, det_cfg, roi, frames, idx, valid,
                        carry=True)
    return D.decode_boxes(maps, det_cfg)


def roi_infer(params, det_cfg, roi: RoiConfig, frames, scores):
    """Serving-plane batched path: gate each padded-batch row by its
    pre-staged region scores (``runtime._stage_chunk``), no temporal
    carry (rows from different streams interleave; the frame-level
    pipeline-③ carry still runs in ``_finish_chunk``).  Bit-exact vs the
    full-frame detector when the gate admits every region."""
    idx, valid = roi_select(scores, roi.capacity, roi.threshold)
    maps = roi_raw_maps(params, det_cfg, roi, frames, idx, valid,
                        carry=False)
    return D.decode_boxes(maps, det_cfg)
