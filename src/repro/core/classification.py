"""Frame classification — Eq. 3 of the paper.

Given the two agent thresholds (tr1, tr2) for a chunk, every frame is
assigned one of three pipelines:

  type 1 (anchor):   X_f > tr1            -> HD JPEG + full inference
  type 2 (transfer): X_f <= tr1, R_f > tr2 -> quality transfer + inference
  type 3 (reuse):    otherwise             -> MV-shift cached results

X_f is the difference feature between frame f and the last *inference*
frame before f; R_f is the residual accumulated since that frame.  Both
therefore reset at every type-1/2 frame, which makes the classification a
sequential scan (exactly as the decoder replays it).
"""
from __future__ import annotations

import jax.numpy as jnp
from jax import lax

f32 = jnp.float32


def classify_frames(frame_diff, residual_mag, tr1, tr2):
    """frame_diff/residual_mag: (T,) per-frame codec features (normalized).

    Returns (types (T,) int32 in {1,2,3}, X (T,), R (T,)) where X/R are the
    accumulated features actually compared against the thresholds.
    """
    T = frame_diff.shape[0]

    def step(carry, inp):
        accX, accR = carry
        fd, rm, idx = inp
        X = accX + fd
        R = accR + rm
        is1 = (X > tr1) | (idx == 0)   # chunk I-frame is always an anchor
        is2 = (~is1) & (R > tr2)
        t = jnp.where(is1, 1, jnp.where(is2, 2, 3))
        inferred = t != 3
        accX = jnp.where(inferred, 0.0, X)
        accR = jnp.where(inferred, 0.0, R)
        return (accX, accR), (t.astype(jnp.int32), X, R)

    (_, _), (types, X, R) = lax.scan(
        step, (jnp.asarray(0.0, f32), jnp.asarray(0.0, f32)),
        (frame_diff.astype(f32), residual_mag.astype(f32),
         jnp.arange(T, dtype=jnp.int32)))
    return types, X, R


def anchor_fraction(types):
    return jnp.mean((types == 1).astype(f32))


def pipeline_fractions(types):
    return jnp.stack([jnp.mean((types == k).astype(f32)) for k in (1, 2, 3)])
