"""Hybrid encoder (paper §IV-A, Fig. 5) — camera side.

Per chunk: 1) the *video encoder* picks a (bitrate, resolution) ladder
level from the allocated bandwidth (adaptive feedback control, §VI-A
5-level ladder); 2) the *agent*'s thresholds (tr1, tr2) classify frames
via codec features (Eq. 3); 3) the *image encoder* JPEG-encodes type-1
frames (anchors) at the highest quality that fits the remaining bandwidth
share.  Anchors and video share the stream's allocation.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.codec.image_codec import jpeg_encode_decode, jpeg_bits
from repro.codec.rate_model import (QUALITY_LADDER, downscale,
                                    ladder_for_bandwidth,
                                    video_bandwidth_share)
from repro.codec.video_codec import VideoCodecConfig, encode_chunk
from repro.core.classification import classify_frames

f32 = jnp.float32

ANCHOR_QUALITIES = (25.0, 40.0, 55.0, 70.0, 85.0)

# module-level jits: re-wrapping per encode_hybrid call would retrace the
# JPEG paths on every chunk (the same re-wrap defect PR 3 fixed for
# encode_chunk call sites)
_jpeg_bits = jax.jit(jpeg_bits)
_jpeg = jax.jit(jpeg_encode_decode)


@dataclasses.dataclass
class HybridPacket:
    """What the camera ships to the edge for one chunk."""
    types: np.ndarray           # (T,) 1/2/3 pipeline assignment
    ladder_level: int
    video: object               # EncodedChunk (LR)
    anchor_hd: np.ndarray       # (T, H, W) decoded-anchor plane (0 for non-anchors)
    anchor_quality: float
    video_bits: float
    anchor_bits: float
    lr_shape: tuple

    @property
    def total_bits(self) -> float:
        return float(self.video_bits + self.anchor_bits)


def _normalize_features(enc):
    """Codec features -> [0, ~1] classification features."""
    fd = enc.frame_diff / 255.0
    rm = enc.residual_mag / 255.0
    return fd, rm


def encode_hybrid(raw_frames, bw_kbps: float, tr1: float, tr2: float,
                  fps: float = 30.0, codec_overrides: dict | None = None,
                  level: int | None = None) -> HybridPacket:
    """raw_frames: (T, H, W) [0..255] numpy/jax array.

    Host-level orchestration (anchor count is data-dependent); all inner
    compute (codec, JPEG, classification) is jitted JAX.
    ``codec_overrides`` replaces VideoCodecConfig fields — e.g.
    ``{"use_kernel": True}`` routes the P-frame search through the Pallas
    kernel, ``{"dtype": "bfloat16"}`` selects the bf16 search variant.
    ``level`` pins the ladder rung instead of deriving it from bandwidth —
    the degradation ladder (``repro.serving.runtime``) uses this to demote
    a struggling stream below what its allocation would normally buy.
    """
    raw_frames = jnp.asarray(raw_frames, f32)
    T, H, W = raw_frames.shape
    budget_bits = bw_kbps * 1000.0 * (T / fps)

    # 1) ladder selection with headroom reserved for anchors (~35%)
    if level is None:
        level = ladder_for_bandwidth(video_bandwidth_share(bw_kbps))
    elif not 0 <= level < len(QUALITY_LADDER):
        raise ValueError(f"ladder level {level} outside "
                         f"[0, {len(QUALITY_LADDER)})")
    ql = QUALITY_LADDER[level]
    frames_lr = downscale(raw_frames, ql.scale)
    cfg = VideoCodecConfig(quality=ql.quality)
    if codec_overrides:
        cfg = dataclasses.replace(cfg, **codec_overrides)
    # encode_chunk is the module-level jit (config static) — calling it
    # directly shares one compile cache across every chunk and stream,
    # where the old per-call jax.jit(...) wrapper retraced every time
    enc = encode_chunk(frames_lr, cfg)
    video_bits = float(enc.bits.sum())

    # 2) frame classification from codec features
    fd, rm = _normalize_features(enc)
    types, _, _ = classify_frames(fd, rm, tr1, tr2)
    types = np.asarray(types)
    anchor_ids = np.nonzero(types == 1)[0]

    # 3) anchors: highest JPEG quality fitting the leftover budget
    anchor_budget = max(budget_bits - video_bits, 0.0)
    per_anchor = anchor_budget / max(len(anchor_ids), 1)
    quality = ANCHOR_QUALITIES[0]
    for q in ANCHOR_QUALITIES:
        bits = float(_jpeg_bits(raw_frames[anchor_ids[0]], q)) \
            if len(anchor_ids) else 0.0
        if bits <= per_anchor:
            quality = q
    anchor_hd = np.zeros((T, H, W), np.float32)
    anchor_bits = 0.0
    for i in anchor_ids:
        rec, bits = _jpeg(raw_frames[i], quality)
        anchor_hd[i] = np.asarray(rec)
        anchor_bits += float(bits)

    return HybridPacket(types=types, ladder_level=level, video=enc,
                        anchor_hd=anchor_hd, anchor_quality=float(quality),
                        video_bits=video_bits, anchor_bits=anchor_bits,
                        lr_shape=tuple(frames_lr.shape))
