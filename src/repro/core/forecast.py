"""Per-stream bandwidth/content forecasting for predictive control.

BiSwift's controller is reactive: the SAC bandwidth agent sees only the
CURRENT chunk's statistics, so it reallocates one controller interval
after a demand spike or a link collapse has already cost deadline
misses.  This module adds the predictive layer the ROADMAP asks for
(SiEVE motivates content-aware signals as forecast features; the
related traffic repo's ``/api/predict_traffic`` is the day-of-week/hour
analogue): a small EWMA forecast head over per-stream rate and content
history whose features

  * extend the SAC controller's state vector (``EnvConfig.forecast`` →
    ``high_state_dim`` grows by ``forecast_dim(C)`` and
    ``MultiStreamEnv.observe_high`` appends ``features()``), and
  * gate chunk admission in the serving soak (``run_soak(...,
    forecast=...)`` holds chunks the predicted link cannot deliver
    inside the deadline, leaning on pipeline-③ reuse instead of
    transmitting into a collapse).

Everything here is pure float32 numpy with NO randomness: state after N
updates is a deterministic function of the observation sequence, so
seeded soak replays are bit-identical (``tests/test_forecast.py``) and
``forecast=None`` (the default everywhere) leaves every existing path
untouched.
"""
from __future__ import annotations

import dataclasses

import numpy as np

f32 = np.float32

# features per stream: [ewma rate, rate dispersion, ewma demand, phase]
FEATURES_PER_STREAM = 4


@dataclasses.dataclass(frozen=True)
class ForecastConfig:
    """Hyper-parameters of the EWMA forecast head.

    ``alpha`` is the EWMA gain shared by the rate and demand trackers;
    ``period`` the chunk-count period of the periodic (diurnal-analogue)
    feature; ``rate_norm``/``bits_norm`` scale features to O(1) for the
    SAC state vector; ``floor_kbps`` bounds ``predict_bw`` away from
    zero so a post-outage prediction can never pin transmission off."""
    alpha: float = 0.4
    period: int = 8
    rate_norm: float = 5000.0
    bits_norm: float = 1e5
    floor_kbps: float = 1e-3


def forecast_dim(n_streams: int) -> int:
    """Width the forecast head adds to the high-level controller state."""
    return FEATURES_PER_STREAM * n_streams


class StreamForecaster:
    """EWMA rate/content tracker for C streams (deterministic, host-side).

    ``update`` folds one chunk's observations in; ``features`` exposes
    the normalized state for the controller; ``predict_bw`` is the
    serving-plane admission signal.  The EW variance uses the standard
    recurrence ``var' = (1 - a) * (var + a * delta^2)`` so dispersion is
    tracked without a second pass.  Prediction is the EWMA itself — NOT
    a lower confidence bound: subtracting k*std would keep the predicted
    rate pinned near zero for chunks after an outage (variance spikes
    exactly when the mean recovers), perpetuating holds and defeating
    recovery.
    """

    def __init__(self, cfg: ForecastConfig, n_streams: int):
        self.cfg = cfg
        self.n = int(n_streams)
        self.rate = np.zeros(self.n, f32)     # EWMA of observed kbps
        self.var = np.zeros(self.n, f32)      # EW variance of the rate
        self.demand = np.zeros(self.n, f32)   # EWMA of achieved bits/chunk
        self.t = 0
        self._warm = np.zeros(self.n, bool)   # has stream seen any obs?

    def update(self, bw_kbps, bits, mask=None) -> None:
        """Fold one chunk: bw_kbps (C,) observed rate, bits (C,) achieved
        transmission size (codec statistics the encoder already computed).
        ``mask`` (C,) bool marks streams that actually observed the link
        this chunk — unmasked streams keep their state untouched (a
        stalled camera learns nothing, and must not warm up on zeros)."""
        bw = np.asarray(bw_kbps, f32)
        bt = np.asarray(bits, f32)
        m = np.ones(self.n, bool) if mask is None else np.asarray(mask, bool)
        a = f32(self.cfg.alpha)
        first = ~self._warm
        delta = bw - self.rate
        new_rate = np.where(first, bw, self.rate + a * delta)
        new_var = np.where(first, f32(0.0),
                           (f32(1.0) - a) * (self.var + a * delta * delta))
        new_demand = np.where(first, bt,
                              self.demand + a * (bt - self.demand))
        self.rate = np.where(m, new_rate, self.rate).astype(f32)
        self.var = np.where(m, new_var, self.var).astype(f32)
        self.demand = np.where(m, new_demand, self.demand).astype(f32)
        self._warm = self._warm | (m & np.isfinite(bw))
        self.t += 1

    def predict_bw(self) -> np.ndarray:
        """(C,) predicted deliverable kbps for the NEXT chunk.  Cold
        streams predict +inf (no history — never hold on ignorance)."""
        floor = f32(self.cfg.floor_kbps)
        return np.where(self._warm, np.maximum(self.rate, floor),
                        np.inf).astype(f32)

    def features(self) -> np.ndarray:
        """(forecast_dim(C),) normalized state for the SAC controller:
        per-stream [rate, sqrt(var), demand] scaled to O(1) plus a shared
        periodic phase feature (the diurnal analogue at chunk scale)."""
        cfg = self.cfg
        phase = f32(np.sin(2.0 * np.pi * (self.t % cfg.period) / cfg.period))
        cols = np.stack([
            self.rate / f32(cfg.rate_norm),
            np.sqrt(self.var) / f32(cfg.rate_norm),
            self.demand / f32(cfg.bits_norm),
            np.full(self.n, phase, f32),
        ], axis=1)
        return cols.reshape(-1).astype(f32)

    def state(self) -> dict:
        """Copyable snapshot (replay-determinism assertions + reports)."""
        return {"rate": self.rate.copy(), "var": self.var.copy(),
                "demand": self.demand.copy(), "t": self.t,
                "warm": self._warm.copy()}
