"""BiSwift core: the paper's contribution as composable JAX modules.

hybrid_encoder  — camera side: ladder selection + frame classification +
                  JPEG anchor encoding under the allocated bandwidth
hybrid_decoder  — edge side: decode + 3 execution pipelines (infer /
                  quality-transfer+infer / MV-reuse)
quality_transfer— anchor-HD block transfer onto LR frames (Fig. 7)
reuse           — cached-detection MV shift (pipeline ③)
classification  — Eq. 3 threshold classifier
bandwidth_controller — high-level SAC allocation (Eq. 5/6)
bilevel         — joint low-level/high-level DRL training driver
"""
from repro.core.classification import classify_frames  # noqa: F401
from repro.core.fairness import min_reward_fairness, jain_index  # noqa: F401
