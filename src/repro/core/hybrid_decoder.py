"""Hybrid decoder + 3 execution pipelines (paper §IV-B, Fig. 6) — edge side.

Pipeline ①: decoded HD anchors -> DNN inference (results cached)
Pipeline ②: LR frame -> quality transfer from anchors -> DNN inference
Pipeline ③: no decode — cached detections shifted by mean MV (reuse)

Latency model (paper Fig. 13b): transmission = bits / allocated bandwidth,
queueing from the serving queues, compute from per-pipeline costs.

Two execution paths:

* ``decode_and_execute`` — the legacy host-orchestrated path: per-frame
  Python loops, eager op dispatch, ``np.asarray`` round trips.  Kept as the
  oracle for the fused path.
* ``decode_execute_chunk`` — ONE ``jax.jit`` end to end: vectorized
  anchor-index computation (``lax.cummax`` instead of the Python loop),
  fused upscale + quality transfer + detector forward + reuse + F1, and
  the latency model as traced scalar math.  ``decode_execute_batched`` is
  its vmap-over-streams entry point (one device dispatch for N streams).
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.codec.rate_model import upscale_nearest
from repro.core.hybrid_encoder import HybridPacket
from repro.core.reuse import reuse_chunk
from repro.models import detection as D

f32 = jnp.float32


@dataclasses.dataclass(frozen=True)
class PipelineCosts:
    """Per-frame edge compute costs (seconds) — calibrated to the paper's
    RTX-3070 numbers: full inference ~33 ms, transfer+infer ~43 ms, reuse
    ~6 ms, DRL <10 ms.  Used by the latency model (wall-clock cannot be
    measured on this CPU-only container; DESIGN.md §2)."""
    infer: float = 0.033
    transfer: float = 0.010     # on top of infer for pipeline ②
    reuse: float = 0.006
    decode_hd: float = 0.004
    decode_video: float = 0.002


def pipeline_cost(n1, n2, n3, costs: PipelineCosts = PipelineCosts()):
    """Per-chunk edge compute time for n1/n2/n3 frames on pipelines ①/②/③.

    The single source of truth for the per-pipeline cost formula — shared
    by the legacy path, the fused traced path, and the serving runtime.
    Works for host ints and traced scalars alike.
    """
    return (n1 * (costs.infer + costs.decode_hd)
            + n2 * (costs.infer + costs.transfer + costs.decode_video)
            + n3 * costs.reuse)


@dataclasses.dataclass
class ChunkResult:
    boxes: np.ndarray           # (T, N, 4)
    scores: np.ndarray          # (T, N)
    types: np.ndarray           # (T,)
    f1: np.ndarray              # (T,) accuracy vs GT
    mean_f1: float
    latency: float              # end-to-end chunk latency (s)
    t_trans: float
    t_queue: float
    t_comp: float


def _detect(detector_params, det_cfg, frames):
    raw = D.forward(detector_params, det_cfg, frames)
    boxes, scores = D.decode_boxes(raw, det_cfg)
    return boxes, scores


def decode_and_execute(packet: HybridPacket, detector_params, det_cfg,
                       gt_boxes, gt_valid, *, bw_kbps: float,
                       queue_delay: float = 0.0,
                       costs: PipelineCosts = PipelineCosts(),
                       fps: float = 30.0) -> ChunkResult:
    """Run the 3 pipelines for one chunk of one stream (host orchestration,
    jitted compute)."""
    enc = packet.video
    T = packet.types.shape[0]
    H, W = packet.anchor_hd.shape[1:]
    types = jnp.asarray(packet.types)

    # decode + upscale the LR video to analytics resolution
    lr_up = upscale_nearest(enc.recon, H, W)

    # per-frame nearest preceding anchor plane
    anchor_idx = np.zeros(T, np.int64)
    last = 0
    for i in range(T):
        if packet.types[i] == 1:
            last = i
        anchor_idx[i] = last
    anchor_plane = jnp.asarray(packet.anchor_hd[anchor_idx])

    # scale LR MVs/residuals up to analytics resolution
    mvs_hd = _upscale_mvs(enc.mv, (H, W))

    # pipeline ②: quality transfer (type-2 frames)
    residual_up = jax.vmap(lambda r: upscale_nearest(r[None], H, W)[0])(
        _residual_px(enc))
    frames_exec = jnp.where((types == 1)[:, None, None],
                            jnp.asarray(packet.anchor_hd), lr_up)
    qt = _transfer(anchor_plane, jnp.asarray(anchor_idx, jnp.int32),
                   mvs_hd, residual_up, frames_exec, types)

    # pipelines ① + ②: DNN inference on type-1/2 frames
    boxes_i, scores_i = _detect(detector_params, det_cfg, qt)

    # pipeline ③: reuse with MV shift
    boxes, scores = reuse_chunk(types, mvs_hd, boxes_i, scores_i)

    f1 = jax.vmap(lambda b, s, g, v: D.f1_score(b, s, g, v))(
        boxes, scores, jnp.asarray(gt_boxes), jnp.asarray(gt_valid))

    n1 = int((packet.types == 1).sum())
    n2 = int((packet.types == 2).sum())
    n3 = int((packet.types == 3).sum())
    t_comp = pipeline_cost(n1, n2, n3, costs)
    t_trans = packet.total_bits / max(bw_kbps * 1000.0, 1e-6)
    latency = t_trans + queue_delay + t_comp
    return ChunkResult(boxes=np.asarray(boxes), scores=np.asarray(scores),
                       types=packet.types, f1=np.asarray(f1),
                       mean_f1=float(f1.mean()), latency=float(latency),
                       t_trans=float(t_trans), t_queue=float(queue_delay),
                       t_comp=float(t_comp))


# --------------------------------------------------------------------------
# Fused path: the whole chunk as one jitted computation
# --------------------------------------------------------------------------
def anchor_index(types):
    """Vectorized nearest-preceding-anchor index: for each frame i, the
    largest j <= i with types[j] == 1 (frame 0 if none).  Replaces the
    legacy per-frame Python loop with a cumulative max over marked indices.
    """
    idx = jnp.arange(types.shape[0], dtype=jnp.int32)
    marked = jnp.where(types == 1, idx, -1)
    return jnp.maximum(lax.cummax(marked), 0)


def _execute_chunk(enc, types, anchor_hd, gt_boxes, gt_valid,
                   detector_params, det_cfg, bw_kbps, queue_delay,
                   total_bits, costs: PipelineCosts, lr_extent=None,
                   roi=None):
    """Traced body shared by ``decode_execute_chunk`` (single stream) and
    ``decode_execute_batched`` (vmap over streams).  Pure jnp: no host
    transfers, no Python loops over frames.

    ``lr_extent`` ((h, w), traced ints) is the valid LR extent when
    ``enc`` came out of the heterogeneous-ladder padded encode: the
    upscale/MV index maps then read only the valid region of the padded
    canvas, making the result bit-identical to decoding the stream's
    unpadded encode (the fused round-trip relies on this).

    ``roi`` (a static ``repro.core.roi.RoiConfig``) gates the detector:
    instead of the full-frame forward, a relevance head over the codec's
    macroblock statistics picks top-K regions, only their packed patches
    run the convs, and a scatter with a temporal carry covers gated-off
    regions (bit-exact vs the ungated path when the gate admits every
    region — ``tests/test_roi.py``)."""
    H, W = anchor_hd.shape[1:]

    lr_up = upscale_nearest(enc.recon, H, W, src_hw=lr_extent)
    aidx = anchor_index(types)
    anchor_plane = anchor_hd[aidx]
    mvs_hd = _upscale_mvs(enc.mv, (H, W), lr_hw=lr_extent)

    residual_up = jax.vmap(
        lambda r: upscale_nearest(r[None], H, W, src_hw=lr_extent)[0])(
        _residual_px(enc))
    frames_exec = jnp.where((types == 1)[:, None, None], anchor_hd, lr_up)
    qt = _transfer(anchor_plane, aidx, mvs_hd, residual_up, frames_exec,
                   types)

    # pipelines ① + ② fused into one detector forward over the whole chunk
    # (ROI-gated onto the top-K packed patch batch when cfg carries a roi)
    if roi is not None:
        from repro.core.roi import roi_detect
        boxes_i, scores_i = roi_detect(
            detector_params, det_cfg, roi, qt, enc.mv, enc.residual_q,
            enc.recon.shape[1:], lr_extent=lr_extent)
    else:
        boxes_i, scores_i = _detect(detector_params, det_cfg, qt)
    boxes, scores = reuse_chunk(types, mvs_hd, boxes_i, scores_i)

    f1 = jax.vmap(D.f1_score)(boxes, scores, gt_boxes, gt_valid)

    # latency model as traced scalar math (no host round trip)
    n1 = jnp.sum(types == 1).astype(f32)
    n2 = jnp.sum(types == 2).astype(f32)
    n3 = jnp.sum(types == 3).astype(f32)
    t_comp = pipeline_cost(n1, n2, n3, costs)
    t_trans = total_bits / jnp.maximum(bw_kbps * 1000.0, 1e-6)
    latency = t_trans + queue_delay + t_comp
    return {"boxes": boxes, "scores": scores, "f1": f1,
            "mean_f1": f1.mean(), "latency": latency, "t_trans": t_trans,
            "t_queue": queue_delay, "t_comp": t_comp}


@partial(jax.jit, static_argnames=("det_cfg", "costs", "roi"))
def decode_execute_chunk(enc, types, anchor_hd, gt_boxes, gt_valid,
                         detector_params, det_cfg, *, bw_kbps,
                         queue_delay=0.0, total_bits=0.0,
                         costs: PipelineCosts = PipelineCosts(),
                         roi=None):
    """One chunk of one stream as a SINGLE jitted computation.

    enc: EncodedChunk (pytree); types: (T,) int; anchor_hd: (T, H, W);
    gt_boxes/gt_valid: (T, N, 4)/(T, N); bw_kbps/queue_delay/total_bits:
    traced scalars; roi: optional static RoiConfig (detector gate).
    Returns a dict of device arrays (boxes, scores, f1, mean_f1, latency,
    t_trans, t_queue, t_comp).
    """
    return _execute_chunk(enc, types, anchor_hd, gt_boxes, gt_valid,
                          detector_params, det_cfg, bw_kbps, queue_delay,
                          total_bits, costs, roi=roi)


def _execute_batch(enc, types, anchor_hd, gt_boxes, gt_valid,
                   detector_params, det_cfg, bw_kbps, queue_delay,
                   total_bits, costs: PipelineCosts, roi=None):
    """vmap-over-streams traced body: every leading axis is the stream axis
    (S, ...); detector params are shared.  Shared by the single-device jit
    below and the mesh-sharded wrapper in
    ``repro.distributed.stream_sharding.shard_streams`` (which calls it
    inside a ``shard_map`` region with per-shard stream slices)."""
    fn = lambda e, ty, ah, gb, gv, bw, qd, tb: _execute_chunk(
        e, ty, ah, gb, gv, detector_params, det_cfg, bw, qd, tb, costs,
        roi=roi)
    return jax.vmap(fn)(enc, types, anchor_hd, gt_boxes, gt_valid,
                        bw_kbps, queue_delay, total_bits)


@partial(jax.jit, static_argnames=("det_cfg", "costs", "roi"))
def decode_execute_batched(enc, types, anchor_hd, gt_boxes, gt_valid,
                           detector_params, det_cfg, *, bw_kbps,
                           queue_delay, total_bits,
                           costs: PipelineCosts = PipelineCosts(),
                           roi=None):
    """vmap-over-streams fused execution — one device dispatch for the
    whole batch of chunks.  Single-device oracle for the sharded path."""
    return _execute_batch(enc, types, anchor_hd, gt_boxes, gt_valid,
                          detector_params, det_cfg, bw_kbps, queue_delay,
                          total_bits, costs, roi=roi)


def decode_and_execute_fused(packet: HybridPacket, detector_params, det_cfg,
                             gt_boxes, gt_valid, *, bw_kbps: float,
                             queue_delay: float = 0.0,
                             costs: PipelineCosts = PipelineCosts()
                             ) -> ChunkResult:
    """Host convenience wrapper: ``decode_execute_chunk`` with the same
    packet-in / ChunkResult-out contract as ``decode_and_execute``."""
    out = decode_execute_chunk(
        packet.video, jnp.asarray(packet.types), jnp.asarray(packet.anchor_hd),
        jnp.asarray(gt_boxes), jnp.asarray(gt_valid), detector_params,
        det_cfg, bw_kbps=bw_kbps, queue_delay=queue_delay,
        total_bits=packet.total_bits, costs=costs)
    return ChunkResult(boxes=np.asarray(out["boxes"]),
                       scores=np.asarray(out["scores"]), types=packet.types,
                       f1=np.asarray(out["f1"]),
                       mean_f1=float(out["mean_f1"]),
                       latency=float(out["latency"]),
                       t_trans=float(out["t_trans"]),
                       t_queue=float(out["t_queue"]),
                       t_comp=float(out["t_comp"]))


def _residual_px(enc):
    from repro.core.quality_transfer import residual_to_pixels
    h, w = enc.recon.shape[1:]
    return jax.vmap(lambda q: residual_to_pixels(q, enc.qtab, h, w))(
        enc.residual_q)


def _upscale_mvs(mv, hw, lr_hw=None):
    """LR MVs -> HD block grid + magnitude rescale (Fig. 7 step 2).

    ``lr_hw`` ((h, w), traced ints) overrides the LR extent when ``mv``
    carries padded macroblock rows/cols from the heterogeneous-ladder
    encode.  The scale factors are computed with f32 jnp ops in BOTH
    forms (constant-folded when static) so the padded path stays
    bit-identical to the unpadded one."""
    H, W = hw
    nby, nbx = H // 16, W // 16
    T, nby_p, nbx_p, _ = mv.shape
    nby_lr, nbx_lr = (nby_p, nbx_p) if lr_hw is None \
        else (lr_hw[0] // 16, lr_hw[1] // 16)
    yi = jnp.clip(jnp.arange(nby) * nby_lr // nby, 0, nby_lr - 1)
    xi = jnp.clip(jnp.arange(nbx) * nbx_lr // nbx, 0, nbx_lr - 1)
    mvu = mv[:, yi][:, :, xi].astype(f32)
    sy = jnp.asarray(H, f32) / (jnp.asarray(nby_lr, f32) * 16.0)
    sx = jnp.asarray(W, f32) / (jnp.asarray(nbx_lr, f32) * 16.0)
    return jnp.round(mvu * jnp.stack([sy, sx])).astype(jnp.int32)


def _transfer(anchor_plane, anchor_idx, mvs_hd, residual_up, frames, types):
    from repro.core.quality_transfer import transfer_frame
    cum = jnp.cumsum(mvs_hd, axis=0)
    cum_at_anchor = cum[anchor_idx]               # (T, nby, nbx, 2)
    mv_rel = (cum - cum_at_anchor).astype(jnp.int32)

    def one(i):
        enhanced = transfer_frame(anchor_plane[i], mv_rel[i], residual_up[i])
        return jnp.where(types[i] == 2, enhanced, frames[i])

    return jax.vmap(one)(jnp.arange(frames.shape[0]))
