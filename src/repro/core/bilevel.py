"""Bi-level joint training driver (paper §V, Fig. 9).

High level (bandwidth controller, SAC) and low level (per-camera frame
classification agents, A2C) are trained jointly: the controller's action
conditions every agent's state (allocations appear in S_c), and the
agents' decisions feed back into S_high (anchor proportions p, accuracy).
Experience flows every chunk; the controller acts every 10 chunks.

Fused control plane (PR 5): the C low-level agents live in ONE stacked
pytree (``a2c.init_stacked``) and the whole per-chunk RL sequence —
stacked A2C update, SAC update, controller proportions, low-level state
assembly, all C threshold actions, and the Eq. 6 fairness reduction —
runs as a single jit, :func:`bilevel_step`, instead of 2C+2 per-stream
dispatches.  Because the environment sits between act and train, the
fused step is shifted one chunk: the dispatch at chunk t first applies
the updates for chunk t-1's transitions (whose rewards the host observed
after the env step), then acts for chunk t.  Relative order of update and
act is exactly the loop's, so :meth:`BiLevelTrainer.run_chunk` is
bit-exact (f32) against the per-stream oracle
:meth:`BiLevelTrainer.run_chunk_loop` — actions, rewards, metrics and
(after :meth:`BiLevelTrainer.flush`) parameters.  See docs/bilevel.md for
the parity contract and jit-boundary rules.

Predictive extension (PR 10): when ``EnvConfig.forecast`` is set, the env
appends the :class:`repro.core.forecast.StreamForecaster` feature block
(EWMA rate/dispersion/demand + periodic phase, ``forecast_dim(C)`` wide)
to S_high, so the SAC controller conditions its allocations on forecast
state.  The forecaster updates only inside ``env.step()`` (never in
``observe_high``), so the widened state rides ``bilevel_step`` without
touching the stacked-vs-loop parity contract; ``forecast=None`` keeps the
state and every update bit-identical to pre-forecast builds.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.bandwidth_controller import BandwidthController, \
    _act_proportions
from repro.core.fairness import fairness_head, jain_index
from repro.rl import a2c, sac
from repro.rl.replay import StackedReplayBuffer
from repro.sim.env import EnvConfig, MultiStreamEnv, low_state_dim, \
    high_state_dim, low_alloc_offset

f32 = np.float32

# threshold actions in (0,1) scale into the feature range (~[0, 0.5])
THRESHOLD_SCALE = (0.5, 0.5)

_barrier = jax.lax.optimization_barrier


@partial(jax.jit, static_argnames=("low_cfg", "sac_cfg", "explore",
                                   "do_low", "do_high", "alloc_off"))
def bilevel_step(low_stack, sac_agent, k_hi, k_lo, k_tr, s_high,
                 cached_raw, cached_props, recompute, s_low_base,
                 prev_rewards, prev_accs, low_batch, sac_batch, *,
                 low_cfg: a2c.A2CConfig, sac_cfg: sac.SACConfig,
                 explore: bool, do_low: bool, do_high: bool,
                 alloc_off: int):
    """ONE dispatch for the whole bi-level control plane of a chunk.

    Order inside the trace mirrors the loop oracle's dispatch sequence:
    train on the previous chunk's transitions first (stacked A2C update +
    SAC update), then act (controller proportions -> low-level states ->
    all C thresholds).  ``optimization_barrier`` fences each component so
    XLA compiles it as the same fusion island as its standalone jit —
    that, plus ``networks.dense``'s batch-count-stable reduction, is what
    makes the fused step bit-exact against the per-stream loop.

    Static flags: ``do_low``/``do_high`` gate the update islands (they
    flip once, when the replay buffers first fill); ``recompute`` is
    traced (it flips every ``controller_interval`` chunks — retracing
    there would negate the fusion).
    """
    logs = {}
    # Eq. 6 / fairness reductions of the previous chunk's outcome — the
    # controller reward and the cross-stream dispersion diagnostics
    logs["fair"] = fairness_head(prev_rewards, prev_accs)

    # ---- train (previous chunk's transitions) -------------------------
    if do_low:
        low_stack, llog = jax.vmap(a2c._update, in_axes=(0, 0, None))(
            low_stack, low_batch, low_cfg)
        low_stack = _barrier(low_stack)
        logs["low"] = llog
    if do_high:
        sac_agent, hlog = sac._update(k_tr, sac_agent, sac_batch, sac_cfg)
        sac_agent = _barrier(sac_agent)
        logs["high"] = hlog

    # ---- controller proportions (recomputed every interval chunks) ----
    raw, fresh = _act_proportions(k_hi, sac_agent, s_high, explore)
    raw = jnp.where(recompute, raw, cached_raw)
    props = _barrier(jnp.where(recompute, fresh, cached_props))

    # ---- low-level states: host-built base + in-trace allocations -----
    C = props.shape[0]
    s_low = s_low_base.at[:, alloc_off:alloc_off + C].set(props)

    # ---- stacked act: all C thresholds in one island ------------------
    actions = _barrier(jax.vmap(a2c._act, in_axes=(0, 0, 0, None))(
        k_lo, low_stack, s_low, explore))
    thr = actions * jnp.asarray(THRESHOLD_SCALE, jnp.float32)
    return {"low_stack": low_stack, "sac_agent": sac_agent, "raw": raw,
            "props": props, "s_low": s_low, "actions": actions,
            "thr": thr, "logs": logs}


@dataclasses.dataclass
class BiLevelTrainer:
    env: MultiStreamEnv
    low_stack: dict
    low_cfg: a2c.A2CConfig
    controller: BandwidthController
    low_buffer: StackedReplayBuffer
    key: jax.Array
    low_batch: int = 32
    # deferred train work for the fused path: the update for chunk t's
    # transitions rides in chunk t+1's bilevel_step dispatch
    _pending: dict | None = None

    @classmethod
    def create(cls, cfg: EnvConfig, seed: int = 0, detector=None,
               low_batch: int = 32):
        env = MultiStreamEnv(cfg, detector=detector)
        key = jax.random.PRNGKey(seed)
        C = len(cfg.streams)
        sdim = low_state_dim(cfg)
        low_cfg = a2c.A2CConfig(state_dim=sdim, tau_latency=cfg.latency_tau)
        keys = jax.random.split(key, C + 2)
        low_stack = a2c.init_stacked(keys[:C], low_cfg)
        controller = BandwidthController.create(
            keys[C], high_state_dim(cfg), C, cfg.controller_interval)
        buf = StackedReplayBuffer(4096, C, sdim, 2)
        return cls(env=env, low_stack=low_stack, low_cfg=low_cfg,
                   controller=controller, low_buffer=buf, key=keys[C + 1],
                   low_batch=low_batch)

    # ------------------------------------------------------------------
    def _chunk_keys(self):
        """The per-chunk PRNG splits — shared verbatim by both paths so
        they consume the key stream identically."""
        self.key, k_hi, k_tr = jax.random.split(self.key, 3)
        klo = jax.random.split(self.key, self.env.C)
        return k_hi, k_tr, klo

    def _post_step(self, results, s_low, thresholds, props, k_tr, train):
        """Everything after the env step, identical in both paths:
        rewards, controller experience, low-level replay writes, and the
        book-keeping for the (fused path's) deferred update."""
        env, C = self.env, self.env.C
        rewards = np.asarray([r["reward"] for r in results], f32)
        r_high = float(rewards.min())                     # Eq. 6
        s_high2 = env.observe_high()
        self.controller.record(r_high, s_high2)
        s_low2 = env.observe_low_batched(props)
        self.low_buffer.add_batch(s_low, thresholds, rewards, s_low2,
                                  np.zeros(C, f32))
        self._pending = {
            "k_tr": k_tr,
            "do_low": bool(train and len(self.low_buffer) >= self.low_batch),
            "do_high": bool(train and self.controller.ready()),
            "rewards": rewards,
            "accs": np.asarray([r["accuracy"] for r in results], f32),
        }
        return rewards, r_high

    def _metrics(self, results, r_high):
        return {
            "mean_acc": float(np.mean([r["accuracy"] for r in results])),
            "min_acc": float(np.min([r["accuracy"] for r in results])),
            "mean_latency": float(np.mean([r["latency"] for r in results])),
            "reward_min": r_high,
            "jain": float(jain_index(np.asarray(
                [r["accuracy"] for r in results]))),
            "utilization": float(np.mean([r["utilization"]
                                          for r in results])),
            "anchor_frac": float(np.mean([r["n_anchor"] / len(r["types"])
                                          for r in results])),
        }

    # ------------------------------------------------------------------
    def run_chunk(self, explore: bool = True, train: bool = True):
        """Fused path: one ``bilevel_step`` dispatch per chunk (the
        deferred update for the previous chunk + all of this chunk's
        actions), then the env step.  Call :meth:`flush` after the final
        chunk to apply the last deferred update (the loop oracle trains
        inside every chunk, so parity of FINAL parameters needs it)."""
        env, C = self.env, self.env.C
        k_hi, k_tr, klo = self._chunk_keys()

        s_high = env.observe_high()
        s_low_base = env.observe_low_batched(None)
        recompute = self.controller.needs_act(env.t)
        pend = self._pending
        do_low = bool(pend and pend["do_low"])
        do_high = bool(pend and pend["do_high"])
        low_b = self.low_buffer.sample(self.low_batch) if do_low else None
        sac_b = self.controller.buffer.sample(
            self.controller.cfg.minibatch) if do_high else None
        zc = np.zeros(C, f32)
        cached_raw = self.controller._last_action \
            if self.controller._last_action is not None else zc
        cached_props = self.controller._current \
            if self.controller._current is not None else zc
        out = bilevel_step(
            self.low_stack, self.controller.agent, k_hi, klo,
            pend["k_tr"] if pend else k_tr, jnp.asarray(s_high),
            jnp.asarray(cached_raw), jnp.asarray(cached_props),
            jnp.asarray(recompute), jnp.asarray(s_low_base),
            jnp.asarray(pend["rewards"] if pend else zc),
            jnp.asarray(pend["accs"] if pend else zc),
            low_b, sac_b, low_cfg=self.low_cfg,
            sac_cfg=self.controller.cfg, explore=explore, do_low=do_low,
            do_high=do_high, alloc_off=low_alloc_offset(env.cfg))

        self.low_stack = out["low_stack"]
        if do_high:
            self.controller.agent = out["sac_agent"]
            self.controller.updates += 1
        props = np.asarray(out["props"], f32)
        if recompute:
            self.controller.adopt(np.asarray(out["raw"]), props, s_high)
        thresholds = np.asarray(out["actions"], f32)
        thr = np.asarray(out["thr"], f32)
        s_low = np.asarray(out["s_low"], f32)

        results, info = env.step(props, thr)
        _, r_high = self._post_step(results, s_low, thresholds, props,
                                    k_tr, train)
        logs = {}
        if pend:
            # the in-trace Eq. 6 / fairness reductions of the PREVIOUS
            # chunk's outcome (this dispatch applied that chunk's update)
            logs["fair_prev"] = {k: float(v) for k, v in
                                 out["logs"]["fair"].items()}
        if do_low:
            llog = out["logs"]["low"]
            for c in range(C):
                logs[f"low{c}"] = {k: float(v[c]) for k, v in llog.items()}
        if do_high:
            logs["high"] = {k: float(v) for k, v in
                            out["logs"]["high"].items()}
        return self._metrics(results, r_high), results, info, logs

    def flush(self):
        """Apply the deferred final update (fused path only; no-op when
        nothing is pending).  After ``run_chunk`` × n + ``flush()`` the
        parameters are bit-exact vs ``run_chunk_loop`` × n."""
        pend, self._pending = self._pending, None
        logs = {}
        if pend and pend["do_low"]:
            batch = self.low_buffer.sample(self.low_batch)
            self.low_stack, llog = a2c.update_stacked(
                self.low_stack, batch, self.low_cfg)
            for c in range(self.env.C):
                logs[f"low{c}"] = {k: float(v[c]) for k, v in llog.items()}
        if pend and pend["do_high"]:
            hlogs = self.controller.train(pend["k_tr"], n_updates=1)
            if hlogs:
                logs["high"] = {k: float(v) for k, v in hlogs[-1].items()}
        return logs

    # ------------------------------------------------------------------
    def run_chunk_loop(self, explore: bool = True, train: bool = True):
        """Per-stream loop ORACLE: 2C+2 small dispatches per chunk, kept
        as the bit-exactness baseline for the fused path (and as the
        reference implementation of the paper's Fig. 9 sequence)."""
        self.flush()    # mode mixing: apply any fused-path deferred update
        env, C = self.env, self.env.C
        k_hi, k_tr, klo = self._chunk_keys()

        s_high = env.observe_high()
        props = self.controller.proportions(k_hi, s_high, env.t, explore)
        s_low = np.stack([env.observe_low(c, props) for c in range(C)])
        thresholds = np.stack([
            np.asarray(a2c.act(klo[c], a2c.slice_agent(self.low_stack, c),
                               s_low[c], explore)) for c in range(C)])
        thr = thresholds * np.asarray(THRESHOLD_SCALE, f32)

        results, info = env.step(props, thr)
        rewards, r_high = self._post_step(results, s_low, thresholds,
                                          props, k_tr, train)
        self._pending = None        # the loop trains inside the chunk

        logs = {}
        if train:
            lens = self.low_buffer.lens()
            for c in range(C):
                if lens[c] >= self.low_batch:
                    batch = self.low_buffer.sample_stream(c, self.low_batch)
                    agent_c, llog = a2c.update(
                        a2c.slice_agent(self.low_stack, c), batch,
                        self.low_cfg)
                    self.low_stack = a2c.set_agent(self.low_stack, c,
                                                   agent_c)
                    logs[f"low{c}"] = {k: float(v) for k, v in llog.items()}
            hlogs = self.controller.train(k_tr, n_updates=1)
            if hlogs:
                logs["high"] = {k: float(v) for k, v in hlogs[-1].items()}
        return self._metrics(results, r_high), results, info, logs

    def train_steps(self, n: int, explore: bool = True):
        history = []
        for _ in range(n):
            metrics, _, _, _ = self.run_chunk(explore=explore, train=True)
            history.append(metrics)
        self.flush()
        return history
