"""Bi-level joint training driver (paper §V, Fig. 9).

High level (bandwidth controller, SAC) and low level (per-camera frame
classification agents, A2C) are trained jointly: the controller's action
conditions every agent's state (allocations appear in S_c), and the
agents' decisions feed back into S_high (anchor proportions p, accuracy).
Experience flows every chunk; the controller acts every 10 chunks.
"""
from __future__ import annotations

import dataclasses

import jax
import numpy as np

from repro.core.bandwidth_controller import BandwidthController
from repro.core.fairness import jain_index
from repro.rl import a2c
from repro.rl.replay import ReplayBuffer
from repro.sim.env import EnvConfig, MultiStreamEnv, low_state_dim, \
    high_state_dim

f32 = np.float32


@dataclasses.dataclass
class BiLevelTrainer:
    env: MultiStreamEnv
    low_agents: list
    low_cfg: a2c.A2CConfig
    controller: BandwidthController
    low_buffers: list
    key: jax.Array
    low_batch: int = 32

    @classmethod
    def create(cls, cfg: EnvConfig, seed: int = 0, detector=None):
        env = MultiStreamEnv(cfg, detector=detector)
        key = jax.random.PRNGKey(seed)
        C = len(cfg.streams)
        sdim = low_state_dim(cfg)
        low_cfg = a2c.A2CConfig(state_dim=sdim, tau_latency=cfg.latency_tau)
        keys = jax.random.split(key, C + 2)
        agents = [a2c.init(keys[i], low_cfg) for i in range(C)]
        controller = BandwidthController.create(
            keys[C], high_state_dim(cfg), C, cfg.controller_interval)
        bufs = [ReplayBuffer(4096, sdim, 2, seed=i) for i in range(C)]
        return cls(env=env, low_agents=agents, low_cfg=low_cfg,
                   controller=controller, low_buffers=bufs, key=keys[C + 1])

    # ------------------------------------------------------------------
    def run_chunk(self, explore: bool = True, train: bool = True):
        env, C = self.env, self.env.C
        self.key, k_hi, k_tr = jax.random.split(self.key, 3)
        klo = jax.random.split(self.key, C)

        s_high = env.observe_high()
        props = self.controller.proportions(k_hi, s_high, env.t, explore)
        s_low = [env.observe_low(c, props) for c in range(C)]
        thresholds = np.stack([
            np.asarray(a2c.act(klo[c], self.low_agents[c], s_low[c],
                               explore)) for c in range(C)])
        # scale thresholds into feature range (features are ~[0, 0.5])
        thr = thresholds * np.array([0.5, 0.5], f32)

        results, info = env.step(props, thr)

        rewards = np.asarray([r["reward"] for r in results], f32)
        r_high = float(rewards.min())                     # Eq. 6
        s_high2 = env.observe_high()
        self.controller.record(r_high, s_high2)
        s_low2 = [env.observe_low(c, props) for c in range(C)]
        for c in range(C):
            self.low_buffers[c].add(s_low[c], thresholds[c], rewards[c],
                                    s_low2[c], False)

        logs = {}
        if train:
            for c in range(C):
                if len(self.low_buffers[c]) >= self.low_batch:
                    batch = self.low_buffers[c].sample(self.low_batch)
                    self.low_agents[c], llog = a2c.update(
                        self.low_agents[c], batch, self.low_cfg)
                    logs[f"low{c}"] = {k: float(v) for k, v in llog.items()}
            hlogs = self.controller.train(k_tr, n_updates=1)
            if hlogs:
                logs["high"] = {k: float(v) for k, v in hlogs[-1].items()}

        metrics = {
            "mean_acc": float(np.mean([r["accuracy"] for r in results])),
            "min_acc": float(np.min([r["accuracy"] for r in results])),
            "mean_latency": float(np.mean([r["latency"] for r in results])),
            "reward_min": r_high,
            "jain": float(jain_index(np.asarray(
                [r["accuracy"] for r in results]))),
            "utilization": float(np.mean([r["utilization"]
                                          for r in results])),
            "anchor_frac": float(np.mean([r["n_anchor"] / len(r["types"])
                                          for r in results])),
        }
        return metrics, results, info, logs

    def train_steps(self, n: int, explore: bool = True):
        history = []
        for _ in range(n):
            metrics, _, _, _ = self.run_chunk(explore=explore, train=True)
            history.append(metrics)
        return history
