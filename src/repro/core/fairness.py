"""Fairness objectives for the bandwidth controller (paper Eq. 1, Eq. 6)."""
from __future__ import annotations

import jax.numpy as jnp

f32 = jnp.float32


def min_reward_fairness(rewards):
    """max-min fairness: the controller maximizes the worst stream (Eq. 6)."""
    return jnp.min(rewards)


def jain_index(values):
    """Jain's fairness index in [1/n, 1] — reported in EXPERIMENTS.md."""
    v = jnp.asarray(values, f32)
    return jnp.square(v.sum()) / jnp.maximum(v.shape[0] * (v * v).sum(), 1e-9)


def accuracy_spread(accs, lo: float = 0.5, hi: float = 0.75):
    """Percentile spread of per-stream accuracy (paper Fig. 12)."""
    v = jnp.sort(jnp.asarray(accs, f32))
    n = v.shape[0]
    return v[int(hi * (n - 1))] - v[int(lo * (n - 1))]


def fairness_head(rewards, accs):
    """The cross-stream reductions of the bi-level step, in one place so
    the fused ``bilevel_step`` trace and host-side logging agree on the
    definitions: controller reward r_high = min_c r_c (Eq. 6), Jain index
    and percentile spread over per-stream accuracy.  Pure jnp — traceable
    inside the single-jit scheduler step."""
    return {
        "r_high": min_reward_fairness(jnp.asarray(rewards, f32)),
        "jain": jain_index(accs),
        "spread": accuracy_spread(accs),
    }
