"""ResNet-v1.5 (bottleneck) — resnet-50 (3-4-6-3) and resnet-152 (3-8-36-3).

BatchNorm keeps running stats in a separate ``batch_stats`` collection; the
train step computes batch statistics (and returns updated running stats),
eval uses the running stats.  Stage blocks of equal geometry are stacked and
scanned to bound compile time (36-deep stage 3 of resnet-152 is one scan).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.params import spec

f32 = jnp.float32


@dataclasses.dataclass(frozen=True)
class ResNetConfig:
    name: str
    depths: tuple[int, int, int, int]
    width: int = 64
    n_classes: int = 1000
    dtype: str = "bfloat16"
    bn_momentum: float = 0.9

    def param_count(self) -> int:
        from repro.models.params import param_count
        return param_count(param_specs(self)["params"])


def _conv_spec(n, kh, kw, cin, cout, dt):
    return spec((n, kh, kw, cin, cout), (None, None, None, None, "tensor"),
                dtype=dt, init="fan_in")


def _bn_specs(n, c, dt):
    return {
        "scale": spec((n, c), (None, None), dtype=jnp.float32, init="ones"),
        "bias": spec((n, c), (None, None), dtype=jnp.float32, init="zeros"),
    }


def _bn_stats(n, c):
    return {
        "mean": spec((n, c), (None, None), dtype=jnp.float32, init="zeros"),
        "var": spec((n, c), (None, None), dtype=jnp.float32, init="ones"),
    }


def stage_channels(cfg: ResNetConfig):
    w = cfg.width
    return [(w * (2 ** i), w * (2 ** i) * 4) for i in range(4)]  # (mid, out)


def param_specs(cfg: ResNetConfig):
    dt = jnp.dtype(cfg.dtype)
    params = {
        "stem_conv": _conv_spec(1, 7, 7, 3, cfg.width, dt),
        "stem_bn": _bn_specs(1, cfg.width, dt),
        "head_w": spec((cfg.width * 32, cfg.n_classes), ("fsdp", "tensor"),
                       dtype=dt, init="fan_in"),
        "head_b": spec((cfg.n_classes,), ("tensor",), dtype=dt, init="zeros"),
    }
    stats = {"stem_bn": _bn_stats(1, cfg.width)}
    chans = stage_channels(cfg)
    in_c = cfg.width
    for si, (n_blocks, (mid, out)) in enumerate(zip(cfg.depths, chans)):
        # downsample/projection block (first of stage)
        params[f"s{si}_proj"] = {
            "conv0": _conv_spec(1, 1, 1, in_c, mid, dt),
            "bn0": _bn_specs(1, mid, dt),
            "conv1": _conv_spec(1, 3, 3, mid, mid, dt),
            "bn1": _bn_specs(1, mid, dt),
            "conv2": _conv_spec(1, 1, 1, mid, out, dt),
            "bn2": _bn_specs(1, out, dt),
            "convp": _conv_spec(1, 1, 1, in_c, out, dt),
            "bnp": _bn_specs(1, out, dt),
        }
        stats[f"s{si}_proj"] = {
            "bn0": _bn_stats(1, mid), "bn1": _bn_stats(1, mid),
            "bn2": _bn_stats(1, out), "bnp": _bn_stats(1, out),
        }
        # identity blocks (stacked, scanned)
        n_id = n_blocks - 1
        if n_id:
            params[f"s{si}_blocks"] = {
                "conv0": _conv_spec(n_id, 1, 1, out, mid, dt),
                "bn0": _bn_specs(n_id, mid, dt),
                "conv1": _conv_spec(n_id, 3, 3, mid, mid, dt),
                "bn1": _bn_specs(n_id, mid, dt),
                "conv2": _conv_spec(n_id, 1, 1, mid, out, dt),
                "bn2": _bn_specs(n_id, out, dt),
            }
            stats[f"s{si}_blocks"] = {
                "bn0": _bn_stats(n_id, mid), "bn1": _bn_stats(n_id, mid),
                "bn2": _bn_stats(n_id, out),
            }
        in_c = out
    return {"params": params, "batch_stats": stats}


def _conv(x, w, stride=1, padding="SAME"):
    return lax.conv_general_dilated(
        x, w.astype(x.dtype), window_strides=(stride, stride), padding=padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        ).astype(x.dtype)


def _bn(x, p, stats, train: bool, momentum: float):
    """Returns (y, new_stats)."""
    if train:
        xf = x.astype(f32)
        mean = xf.mean(axis=(0, 1, 2))
        var = xf.var(axis=(0, 1, 2))
        new = {
            "mean": momentum * stats["mean"] + (1 - momentum) * mean,
            "var": momentum * stats["var"] + (1 - momentum) * var,
        }
    else:
        mean, var = stats["mean"], stats["var"]
        new = stats
    y = (x.astype(f32) - mean) * lax.rsqrt(var + 1e-5)
    y = y * p["scale"] + p["bias"]
    return y.astype(x.dtype), new


def _bottleneck(x, p, st, train, momentum, stride=1, project=False):
    new_st = {}
    h, new_st["bn0"] = _bn(_conv(x, p["conv0"][0]), _tree0(p["bn0"]),
                           _tree0(st["bn0"]), train, momentum)
    h = jax.nn.relu(h)
    h, new_st["bn1"] = _bn(_conv(h, p["conv1"][0], stride=stride),
                           _tree0(p["bn1"]), _tree0(st["bn1"]), train, momentum)
    h = jax.nn.relu(h)
    h, new_st["bn2"] = _bn(_conv(h, p["conv2"][0]), _tree0(p["bn2"]),
                           _tree0(st["bn2"]), train, momentum)
    if project:
        sc, new_st["bnp"] = _bn(_conv(x, p["convp"][0], stride=stride),
                                _tree0(p["bnp"]), _tree0(st["bnp"]), train,
                                momentum)
    else:
        sc = x
    from repro.models import layers as L
    return L.constrain(jax.nn.relu(h + sc), "batch", None, None, None), new_st


def _tree0(t):
    return jax.tree.map(lambda a: a[0] if a.ndim >= 1 else a, t)


def _tree_expand(t):
    return jax.tree.map(lambda a: a[None], t)


def forward(variables, cfg: ResNetConfig, images, train: bool = False):
    """Returns (logits, new_batch_stats)."""
    p, st = variables["params"], variables["batch_stats"]
    mom = cfg.bn_momentum
    new_st = {}
    x = images.astype(cfg.dtype)
    x = _conv(x, p["stem_conv"][0], stride=2)
    x, s = _bn(x, _tree0(p["stem_bn"]), _tree0(st["stem_bn"]), train, mom)
    new_st["stem_bn"] = _tree_expand(s)
    x = jax.nn.relu(x)
    x = lax.reduce_window(x, -jnp.inf, lax.max, (1, 3, 3, 1), (1, 2, 2, 1),
                          "SAME")
    for si, n_blocks in enumerate(cfg.depths):
        stride = 1 if si == 0 else 2
        x, s = _bottleneck(x, p[f"s{si}_proj"], st[f"s{si}_proj"], train, mom,
                           stride=stride, project=True)
        new_st[f"s{si}_proj"] = _tree_expand(s)
        n_id = n_blocks - 1
        if n_id:
            bp, bs = p[f"s{si}_blocks"], st[f"s{si}_blocks"]

            def body(x, inp):
                pp, ss = inp
                y, ns = _bottleneck(x, _tree_expand(pp), _tree_expand(ss),
                                    train, mom)
                return y, ns  # scan stacks per-block stats back to (n_id, c)

            from repro.models import layers as L
            x, ns = lax.scan(jax.checkpoint(body), x, (bp, bs),
                             unroll=L.scan_unroll(n_id))
            new_st[f"s{si}_blocks"] = ns
    x = x.astype(f32).mean(axis=(1, 2)).astype(cfg.dtype)  # global avg pool
    logits = jnp.einsum("bd,dc->bc", x, p["head_w"],
                        preferred_element_type=f32) + p["head_b"].astype(f32)
    return logits, new_st


def loss_fn(variables, cfg: ResNetConfig, batch):
    logits, new_st = forward(variables, cfg, batch["images"], train=True)
    from repro.models.transformer_lm import softmax_xent
    return softmax_xent(logits, batch["labels"]), new_st
