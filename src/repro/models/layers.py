"""Shared neural-net layers for the backbone zoo (pure JAX, pjit-friendly).

Conventions
-----------
* activations: (batch, seq, d) or NHWC for vision.
* attention tensors: q (B, Sq, H, D); k/v (B, Sk, Hk, D) with GQA groups
  G = H // Hk.
* all matmuls accumulate in fp32 (``preferred_element_type``), softmax in
  fp32; outputs cast back to the activation dtype.
* attention is *chunked* (online softmax over KV blocks) so no S×S tensor is
  ever materialized — this is the XLA path; the Pallas flash kernel in
  ``repro.kernels.flash_attention`` is the TPU-optimized path.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax import lax

f32 = jnp.float32
NEG_INF = -1e30

# Dry-run cost-analysis mode: XLA's HLO cost analysis counts a while-loop
# body ONCE regardless of trip count, so the roofline dry-run fully unrolls
# every scan (layers, attention KV chunks, SWA q-blocks) to obtain exact
# FLOP/byte/collective counts.  Normal execution keeps rolled scans.
_DRYRUN_UNROLL = False


def set_dryrun_unroll(v: bool) -> None:
    global _DRYRUN_UNROLL
    _DRYRUN_UNROLL = v


def scan_unroll(length: int) -> int:
    return length if _DRYRUN_UNROLL else 1


def constrain(x, *logical_axes):
    """Activation sharding constraint from the ambient ShardCtx.

    No-op outside a ctx (CPU smoke tests) and inside shard_map bodies.
    Non-divisible dims demote to replicated automatically.
    """
    from repro.distributed.context import current_ctx
    from repro.distributed.sharding import named_sharding

    ctx = current_ctx()
    if ctx is None:
        return x
    sh = named_sharding(ctx.mesh, logical_axes, ctx.rules, x.shape)
    return jax.lax.with_sharding_constraint(x, sh)


# --------------------------------------------------------------------------
# Norms
# --------------------------------------------------------------------------
def rms_norm(x, weight, eps: float = 1e-6):
    xf = x.astype(f32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * lax.rsqrt(var + eps)
    return (y * weight.astype(f32)).astype(x.dtype)


def layer_norm(x, weight, bias, eps: float = 1e-6):
    xf = x.astype(f32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
    y = (xf - mu) * lax.rsqrt(var + eps)
    return (y * weight.astype(f32) + bias.astype(f32)).astype(x.dtype)


# --------------------------------------------------------------------------
# Rotary embeddings
# --------------------------------------------------------------------------
def rope_freqs(head_dim: int, fraction: float, theta: float) -> jnp.ndarray:
    """Inverse frequencies for the rotated sub-dimension."""
    rot = int(head_dim * fraction)
    rot -= rot % 2
    return 1.0 / (theta ** (jnp.arange(0, rot, 2, dtype=f32) / rot))


def apply_rope(x, positions, *, fraction: float = 1.0, theta: float = 10000.0):
    """x: (B, S, H, D); positions: (S,) or (B, S)."""
    D = x.shape[-1]
    rot = int(D * fraction)
    rot -= rot % 2
    inv = rope_freqs(D, fraction, theta)  # (rot/2,)
    pos = positions.astype(f32)
    if pos.ndim == 1:
        pos = pos[None, :]  # (1, S)
    ang = pos[..., None] * inv[None, None, :]           # (B?, S, rot/2)
    sin = jnp.sin(ang)[:, :, None, :]                   # (B?, S, 1, rot/2)
    cos = jnp.cos(ang)[:, :, None, :]
    xr, xp = x[..., :rot], x[..., rot:]
    x1, x2 = xr[..., ::2], xr[..., 1::2]
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    yr = jnp.stack([y1, y2], axis=-1).reshape(xr.shape)
    return jnp.concatenate([yr.astype(x.dtype), xp], axis=-1)


# --------------------------------------------------------------------------
# Attention — chunked online-softmax (full/causal) and SWA q-block paths
# --------------------------------------------------------------------------
def _repeat_kv(k, n_heads: int):
    """(B, S, Hk, D) -> (B, S, H, D) by repeating each kv head G times.

    Keeps the einsums flat over H so tensor-parallel head sharding works for
    any (Hk, TP) combination; per-device the repeat holds only the local
    slice, and the Pallas flash kernel avoids materializing it entirely.
    """
    B, S, Hk, D = k.shape
    G = n_heads // Hk
    if G == 1:
        return k
    return jnp.repeat(k, G, axis=2)


def chunked_attention(q, k, v, *, causal: bool, q_offset=0,
                      kv_positions=None, chunk: int = 1024):
    """Online-softmax attention scanning over KV chunks.

    q: (B, Sq, H, D); k, v: (B, Sk, Hk, D).  ``q_offset`` is the absolute
    position of q[0] (for causal masking during chunked prefill / decode).
    ``kv_positions``: (Sk,) absolute positions of cache slots (ring caches);
    defaults to arange.  Slots with position < 0 are masked out.
    """
    B, Sq, H, D = q.shape
    Sk = k.shape[1]
    q = constrain(q.astype(jnp.bfloat16), "batch", None, "tensor", None)
    k = constrain(_repeat_kv(k, H).astype(jnp.bfloat16),
                  "batch", None, "tensor", None)
    v = constrain(_repeat_kv(v, H).astype(jnp.bfloat16),
                  "batch", None, "tensor", None)
    if kv_positions is None:
        kv_positions = jnp.arange(Sk, dtype=jnp.int32)
    q_pos = q_offset + jnp.arange(Sq, dtype=jnp.int32)

    chunk = min(chunk, Sk)
    if Sk % chunk:
        chunk = Sk  # fallback: single chunk
    n_chunks = Sk // chunk
    kc = k.reshape(B, n_chunks, chunk, H, D)
    vc = v.reshape(B, n_chunks, chunk, H, D)
    pc = kv_positions.reshape(n_chunks, chunk)
    scale = D ** -0.5

    def step(carry, inp):
        m, l, acc = carry
        kb, vb, pb = inp  # (B, C, H, D), (C,)
        s = jnp.einsum("bqhd,bchd->bhqc", q, kb,
                       preferred_element_type=f32) * scale
        mask = pb[None, :] >= 0
        if causal:
            mask = mask & (pb[None, :] <= q_pos[:, None])
        s = jnp.where(mask[None, None], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        pv = jnp.einsum("bhqc,bchd->bhqd", p.astype(jnp.bfloat16), vb,
                        preferred_element_type=f32)
        acc_new = acc * corr[..., None] + pv
        m_new = constrain(m_new, "batch", "tensor", None)
        l_new = constrain(l_new, "batch", "tensor", None)
        acc_new = constrain(acc_new, "batch", "tensor", None, None)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, H, Sq), NEG_INF, f32)
    l0 = jnp.zeros((B, H, Sq), f32)
    a0 = jnp.zeros((B, H, Sq, D), f32)
    (m, l, acc), _ = lax.scan(
        step, (m0, l0, a0),
        (jnp.moveaxis(kc, 1, 0), jnp.moveaxis(vc, 1, 0), pc),
        unroll=scan_unroll(n_chunks))
    out = acc / jnp.maximum(l[..., None], 1e-30)
    out = jnp.moveaxis(out, 1, 2)                   # (B,Sq,H,D)
    return constrain(out.astype(jnp.bfloat16), "batch", None, "tensor", None)


def swa_attention(q, k, v, *, window: int, q_offset=0, q_block: int = 1024):
    """Sliding-window causal attention via q-block scan + KV dynamic slice.

    FLOPs scale as Sq×(window+q_block) instead of Sq×Sk — this is the
    sub-quadratic path used by mixtral configs.
    """
    B, Sq, H, D = q.shape
    Sk = k.shape[1]
    q = constrain(q.astype(jnp.bfloat16), "batch", None, "tensor", None)
    k = constrain(_repeat_kv(k, H).astype(jnp.bfloat16),
                  "batch", None, "tensor", None)
    v = constrain(_repeat_kv(v, H).astype(jnp.bfloat16),
                  "batch", None, "tensor", None)
    qb = min(q_block, Sq)
    if Sq % qb:
        qb = Sq
    nq = Sq // qb
    span = min(window + qb, Sk)
    scale = D ** -0.5
    qs = q.reshape(B, nq, qb, H, D)

    def step(i):
        qi = qs[:, i]                                          # (B,qb,H,D)
        q_pos = q_offset + i * qb + jnp.arange(qb, dtype=jnp.int32)
        ks_raw = q_offset + i * qb + qb - span                 # window start
        ks = jnp.clip(ks_raw, 0, Sk - span)
        kb = lax.dynamic_slice_in_dim(k, ks, span, axis=1)
        vb = lax.dynamic_slice_in_dim(v, ks, span, axis=1)
        k_pos = ks + jnp.arange(span, dtype=jnp.int32)
        s = jnp.einsum("bqhd,bchd->bhqc", qi, kb,
                       preferred_element_type=f32) * scale
        mask = (k_pos[None, :] <= q_pos[:, None]) & (
            q_pos[:, None] - k_pos[None, :] < window)
        s = jnp.where(mask[None, None], s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bhqc,bchd->bqhd", p.astype(jnp.bfloat16), vb,
                       preferred_element_type=f32)
        return constrain(o.astype(jnp.bfloat16),
                         "batch", None, "tensor", None)

    _, out = lax.scan(lambda c, i: (c, step(i)), None,
                      jnp.arange(nq), unroll=scan_unroll(nq))  # (nq,B,qb,H,D)
    out = jnp.moveaxis(out, 0, 1).reshape(B, Sq, H, D)
    return constrain(out, "batch", None, "tensor", None)


def decode_attention(q, k_cache, v_cache, *, cache_positions, pos,
                     window: int | None = None):
    """Single-token decode attention over a (possibly ring) KV cache.

    q: (B, 1, H, D); caches: (B, S, Hk, D); cache_positions: (S,) int32 with
    -1 for unwritten slots; pos: scalar current position.
    """
    B, _, H, D = q.shape
    Hk = k_cache.shape[2]
    qg = q.reshape(B, 1, Hk, H // Hk, D).astype(jnp.bfloat16)
    qg = constrain(qg, "batch", None, None, None, None)
    s = jnp.einsum("bqhgd,bshd->bhgqs", qg, k_cache.astype(jnp.bfloat16),
                   preferred_element_type=f32) * D ** -0.5
    s = constrain(s, "batch", None, None, None, "seq_kv")
    valid = (cache_positions >= 0) & (cache_positions <= pos)
    if window is not None:
        valid = valid & (pos - cache_positions < window)
    s = jnp.where(valid[None, None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqs,bshd->bhgqd", p.astype(jnp.bfloat16),
                   v_cache.astype(jnp.bfloat16), preferred_element_type=f32)
    o = jnp.moveaxis(o, 3, 1).reshape(B, 1, H, D)
    return constrain(o.astype(q.dtype), "batch", None, None, None)


# --------------------------------------------------------------------------
# MLPs
# --------------------------------------------------------------------------
def swiglu(x, w1, w3, w2):
    h = jnp.einsum("...d,df->...f", x, w1, preferred_element_type=f32)
    g = jnp.einsum("...d,df->...f", x, w3, preferred_element_type=f32)
    h = (jax.nn.silu(h) * g).astype(x.dtype)
    # bf16 output: the ff dim is tensor-sharded, so this matmul's partial
    # sums are all-reduced -- keep the wire payload in bf16.
    return jnp.einsum("...f,fd->...d", h, w2)


def gelu_mlp(x, w1, b1, w2, b2):
    h = jnp.einsum("...d,df->...f", x, w1, preferred_element_type=f32)
    h = jax.nn.gelu(h + b1.astype(f32)).astype(x.dtype)
    y = jnp.einsum("...f,fd->...d", h, w2)   # bf16 wire (see swiglu)
    return (y.astype(f32) + b2.astype(f32)).astype(x.dtype)


# --------------------------------------------------------------------------
# Mixture of Experts
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    capacity_factor: float = 1.25
    norm_topk: bool = True          # qwen renormalizes top-k probs


def router_topk(x, w_router, moe: MoEConfig):
    """Returns (expert_idx (T,k), weights (T,k), aux_loss scalar)."""
    logits = jnp.einsum("td,de->te", x.astype(f32), w_router.astype(f32))
    probs = jax.nn.softmax(logits, axis=-1)
    w, idx = lax.top_k(probs, moe.top_k)
    if moe.norm_topk:
        w = w / jnp.maximum(w.sum(-1, keepdims=True), 1e-9)
    # load-balance aux loss (Switch-style)
    me = probs.mean(0)
    ce = jnp.zeros(moe.n_experts).at[idx.reshape(-1)].add(
        1.0 / idx.size)
    aux = moe.n_experts * jnp.sum(me * ce)
    return idx, w.astype(x.dtype), aux


def moe_sorted_dispatch(x, w_router, w1, w3, w2, moe: MoEConfig):
    """Dropping MoE via sort-based dispatch into (E, C, d) capacity buffers.

    x: (T, d) tokens local to this shard.  Expert weights: w1/w3 (E, d, f),
    w2 (E, f, d).  FLOPs-honest: the only matmuls are the E×C×d×f expert
    GEMMs; dispatch/combine are gathers + scatters.
    """
    T, d = x.shape
    E, k = moe.n_experts, moe.top_k
    C = max(k, int(T * k * moe.capacity_factor / E + 0.999))
    C = min(C, T)
    idx, w, aux = router_topk(x, w_router, moe)
    eflat = idx.reshape(-1)                       # (T*k,)
    order = jnp.argsort(eflat, stable=True)
    sorted_e = eflat[order]
    counts = jnp.bincount(eflat, length=E)
    starts = jnp.cumsum(counts) - counts
    pos = jnp.arange(T * k, dtype=jnp.int32) - starts[sorted_e]
    tok = order // k
    buf = jnp.zeros((E, C, d), x.dtype)
    buf = buf.at[sorted_e, pos].set(x[tok], mode="drop")
    h = jnp.einsum("ecd,edf->ecf", buf, w1, preferred_element_type=f32)
    g = jnp.einsum("ecd,edf->ecf", buf, w3, preferred_element_type=f32)
    h = (jax.nn.silu(h) * g).astype(x.dtype)
    y = jnp.einsum("ecf,efd->ecd", h, w2,
                   preferred_element_type=f32).astype(x.dtype)
    contrib = y.at[sorted_e, pos].get(mode="fill", fill_value=0.0)
    contrib = contrib * w.reshape(-1)[order][:, None]
    out = jnp.zeros((T, d), x.dtype).at[tok].add(contrib)
    return out, aux


def moe_gathered_experts(x, w_router, w1, w3, w2, moe: MoEConfig):
    """Decode-shape MoE: per-token gather of its k experts' weights.

    FLOPs-honest (2·T·k·d·f per matmul); weight bytes are duplicated when
    T·k > E (noted in the roofline analysis).  Used when T is tiny.
    """
    T, d = x.shape
    idx, w, aux = router_topk(x, w_router, moe)   # (T,k)
    w1g = w1[idx]                                 # (T,k,d,f)
    w3g = w3[idx]
    w2g = w2[idx]                                 # (T,k,f,d)
    h = jnp.einsum("td,tkdf->tkf", x, w1g, preferred_element_type=f32)
    g = jnp.einsum("td,tkdf->tkf", x, w3g, preferred_element_type=f32)
    h = (jax.nn.silu(h) * g).astype(x.dtype)
    y = jnp.einsum("tkf,tkfd->tkd", h, w2g, preferred_element_type=f32)
    out = jnp.einsum("tkd,tk->td", y.astype(f32), w.astype(f32))
    return out.astype(x.dtype), aux


def _moe_local(xf, w_router, w1, w3, w2, moe: MoEConfig):
    """Dispatch-path choice for a *local* (unsharded) token block.

    sorted dispatch reads each expert's weights exactly once -> wins whenever
    T·k >= E; the gathered path reads only the k selected experts -> wins for
    tiny token counts (B=1 decode).
    """
    if xf.shape[0] * moe.top_k >= moe.n_experts:
        return moe_sorted_dispatch(xf, w_router, w1, w3, w2, moe)
    return moe_gathered_experts(xf, w_router, w1, w3, w2, moe)


def moe_block(x, w_router, w1, w3, w2, moe: MoEConfig):
    """x: (B, S, d) -> (B, S, d).

    With an ambient ShardCtx and a shardable batch, the dispatch runs inside
    an explicit ``shard_map`` over the batch axes so the argsort/scatter are
    *local* to each shard (a global argsort would all-gather every token).
    Expert weights enter the region all-gathered over fsdp but still sharded
    over the tensor axis (ff dim); the second GEMM's partial sums are
    reduced with one psum over the tensor axis after token combine.
    """
    from jax.sharding import PartitionSpec as P
    from repro.distributed.shard_map_compat import shard_map_compat
    from repro.distributed.context import current_ctx

    B, S, d = x.shape
    ctx = current_ctx()
    use_sm = (
        ctx is not None
        and len(ctx.batch_axes) > 0
        and B % ctx.axis_size(ctx.batch_axes) == 0
        and ctx.axis_size(ctx.tensor_axes) > 1
        and w1.shape[-1] % ctx.axis_size(ctx.tensor_axes) == 0
        # shard_map's in_specs force an all-gather of the FSDP-sharded
        # expert weights (~all params!) — only worth it when the token
        # batch is large enough that a global argsort would cost more.
        # Decode-sized batches stay on auto-SPMD, which keeps weights
        # sharded and psums the (tiny) activation partials instead.
        and B * S >= 4096
    )
    if not use_sm:
        out, aux = _moe_local(x.reshape(B * S, d), w_router, w1, w3, w2, moe)
        return out.reshape(B, S, d), aux

    batch = ctx.batch_axes if len(ctx.batch_axes) > 1 else ctx.batch_axes[0]
    tensor = ctx.tensor_axes[0]

    def body(xb, wr, a1, a3, a2):
        Bl = xb.shape[0]
        xf = xb.reshape(Bl * S, d)
        idx, w, aux = router_topk(xf, wr, moe)
        T, E, k = xf.shape[0], moe.n_experts, moe.top_k
        if T * k >= E:
            C = min(max(k, int(T * k * moe.capacity_factor / E + 0.999)), T)
            eflat = idx.reshape(-1)
            order = jnp.argsort(eflat, stable=True)
            sorted_e = eflat[order]
            counts = jnp.bincount(eflat, length=E)
            starts = jnp.cumsum(counts) - counts
            pos = jnp.arange(T * k, dtype=jnp.int32) - starts[sorted_e]
            tok = order // k
            buf = jnp.zeros((E, C, d), xf.dtype)
            buf = buf.at[sorted_e, pos].set(xf[tok], mode="drop")
            h = jnp.einsum("ecd,edf->ecf", buf, a1, preferred_element_type=f32)
            g = jnp.einsum("ecd,edf->ecf", buf, a3, preferred_element_type=f32)
            h = (jax.nn.silu(h) * g).astype(xf.dtype)
            y = jnp.einsum("ecf,efd->ecd", h, a2,
                           preferred_element_type=f32).astype(xf.dtype)
            contrib = y.at[sorted_e, pos].get(mode="fill", fill_value=0.0)
            contrib = contrib * w.reshape(-1)[order][:, None]
            out = jnp.zeros((T, d), xf.dtype).at[tok].add(contrib)
        else:
            h = jnp.einsum("td,tkdf->tkf", xf, a1[idx], preferred_element_type=f32)
            g = jnp.einsum("td,tkdf->tkf", xf, a3[idx], preferred_element_type=f32)
            h = (jax.nn.silu(h) * g).astype(xf.dtype)
            y = jnp.einsum("tkf,tkfd->tkd", h, a2[idx], preferred_element_type=f32)
            out = jnp.einsum("tkd,tk->td", y, w.astype(f32)).astype(xf.dtype)
        out = lax.psum(out, tensor)           # partial over ff shards
        aux = lax.pmean(aux, batch)
        return out.reshape(Bl, S, d), aux

    out, aux = shard_map_compat(
        body, mesh=ctx.mesh,
        in_specs=(P(batch), P(), P(None, None, tensor), P(None, None, tensor),
                  P(None, tensor, None)),
        out_specs=(P(batch), P()),
    )(x, w_router, w1, w3, w2)
    return out, aux
