"""Anchor-free detection head + tiny CPU-trainable detector + F1 metric.

The paper evaluates object detection (Faster R-CNN / YOLOv5, F1@IoU0.5).
Here the head is FCOS-style (per-cell objectness + center offset + size)
and attaches to any vision backbone from the zoo; ``TinyDetector`` is a
small convnet used by the end-to-end CPU examples and the serving sim.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.params import spec, init_params

f32 = jnp.float32


@dataclasses.dataclass(frozen=True)
class TinyDetectorConfig:
    channels: tuple[int, ...] = (16, 32, 64)
    stride: int = 8               # output cell size in px
    dtype: str = "float32"


def param_specs(cfg: TinyDetectorConfig):
    dt = jnp.dtype(cfg.dtype)
    p = {}
    cin = 1
    for i, c in enumerate(cfg.channels):
        p[f"conv{i}"] = spec((3, 3, cin, c), (None, None, None, "tensor"),
                             dtype=dt, init="fan_in")
        p[f"bias{i}"] = spec((c,), (None,), dtype=dt, init="zeros")
        cin = c
    p["head"] = spec((1, 1, cin, 5), (None, None, None, None), dtype=dt,
                     init="fan_in")
    p["head_b"] = spec((5,), (None,), dtype=dt, init="zeros")
    return p


def init(key, cfg: TinyDetectorConfig):
    return init_params(key, param_specs(cfg))


def forward(params, cfg: TinyDetectorConfig, frames):
    """frames: (B, H, W) [0..255] -> (B, H/s, W/s, 5) raw head output.

    Channels: [objectness logit, dy, dx, log h, log w].
    """
    x = (frames.astype(f32) / 255.0 - 0.5)[..., None]
    n_down = {2: 1, 4: 2, 8: 3}[cfg.stride]
    for i, c in enumerate(cfg.channels):
        stride = 2 if i < n_down else 1
        x = lax.conv_general_dilated(
            x, params[f"conv{i}"], window_strides=(stride, stride),
            padding="SAME", dimension_numbers=("NHWC", "HWIO", "NHWC"))
        x = jax.nn.relu(x + params[f"bias{i}"])
    x = lax.conv_general_dilated(
        x, params["head"], window_strides=(1, 1), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC")) + params["head_b"]
    return x


def decode_boxes(raw, cfg: TinyDetectorConfig, score_thresh: float = 0.5):
    """-> (boxes (B, Nc, 4) cxcywh px, scores (B, Nc)).  Nc = all cells."""
    B, hc, wc, _ = raw.shape
    s = cfg.stride
    obj = jax.nn.sigmoid(raw[..., 0])
    cy = (jnp.arange(hc, dtype=f32)[None, :, None] + 0.5 +
          jnp.tanh(raw[..., 1])) * s
    cx = (jnp.arange(wc, dtype=f32)[None, None, :] + 0.5 +
          jnp.tanh(raw[..., 2])) * s
    h = jnp.exp(jnp.clip(raw[..., 3], -3, 3)) * s
    w = jnp.exp(jnp.clip(raw[..., 4], -3, 3)) * s
    boxes = jnp.stack([jnp.broadcast_to(cy, obj.shape),
                       jnp.broadcast_to(cx, obj.shape), h, w], axis=-1)
    return boxes.reshape(B, -1, 4), obj.reshape(B, -1)


def _cell_targets(boxes, valid, hc: int, wc: int, stride: int):
    """Rasterize GT boxes onto the output grid.  boxes: (N,4) cxcywh."""
    cy = (jnp.arange(hc, dtype=f32)[:, None] + 0.5) * stride
    cx = (jnp.arange(wc, dtype=f32)[None, :] + 0.5) * stride
    d2 = (boxes[:, None, None, 0] - cy[None]) ** 2 \
        + (boxes[:, None, None, 1] - cx[None]) ** 2   # (N, hc, wc)
    d2 = jnp.where(valid[:, None, None], d2, jnp.inf)
    nearest = jnp.argmin(d2, axis=0)                   # (hc, wc)
    nearest_d2 = jnp.min(d2, axis=0)
    tgt = boxes[nearest]                               # (hc, wc, 4)
    # positive if cell center inside the matched box
    inside = (jnp.abs(cy - tgt[..., 0]) <= tgt[..., 2] / 2) & \
             (jnp.abs(cx - tgt[..., 1]) <= tgt[..., 3] / 2) & \
             jnp.isfinite(nearest_d2)
    return tgt, inside


def loss_fn(params, cfg: TinyDetectorConfig, frames, boxes, valid):
    """frames (B,H,W); boxes (B,N,4); valid (B,N)."""
    raw = forward(params, cfg, frames)
    B, hc, wc, _ = raw.shape
    s = cfg.stride
    tgt, pos = jax.vmap(lambda b, v: _cell_targets(b, v, hc, wc, s))(
        boxes, valid)
    obj_logit = raw[..., 0]
    obj_loss = jnp.mean(
        jnp.maximum(obj_logit, 0) - obj_logit * pos
        + jnp.log1p(jnp.exp(-jnp.abs(obj_logit))))
    cyc = (jnp.arange(hc, dtype=f32)[None, :, None] + 0.5) * s
    cxc = (jnp.arange(wc, dtype=f32)[None, None, :] + 0.5) * s
    t_dy = (tgt[..., 0] - cyc) / s
    t_dx = (tgt[..., 1] - cxc) / s
    t_lh = jnp.log(jnp.maximum(tgt[..., 2] / s, 1e-3))
    t_lw = jnp.log(jnp.maximum(tgt[..., 3] / s, 1e-3))
    reg = (jnp.tanh(raw[..., 1]) - jnp.clip(t_dy, -1, 1)) ** 2 \
        + (jnp.tanh(raw[..., 2]) - jnp.clip(t_dx, -1, 1)) ** 2 \
        + (jnp.clip(raw[..., 3], -3, 3) - jnp.clip(t_lh, -3, 3)) ** 2 \
        + (jnp.clip(raw[..., 4], -3, 3) - jnp.clip(t_lw, -3, 3)) ** 2
    reg_loss = jnp.sum(reg * pos) / jnp.maximum(pos.sum(), 1.0)
    return obj_loss + 0.5 * reg_loss


# --------------------------------------------------------------------------
# Metrics
# --------------------------------------------------------------------------
def iou_cxcywh(a, b):
    """a: (..., 4), b: (..., 4) -> IoU."""
    ay0, ay1 = a[..., 0] - a[..., 2] / 2, a[..., 0] + a[..., 2] / 2
    ax0, ax1 = a[..., 1] - a[..., 3] / 2, a[..., 1] + a[..., 3] / 2
    by0, by1 = b[..., 0] - b[..., 2] / 2, b[..., 0] + b[..., 2] / 2
    bx0, bx1 = b[..., 1] - b[..., 3] / 2, b[..., 1] + b[..., 3] / 2
    iy = jnp.maximum(jnp.minimum(ay1, by1) - jnp.maximum(ay0, by0), 0)
    ix = jnp.maximum(jnp.minimum(ax1, bx1) - jnp.maximum(ax0, bx0), 0)
    inter = iy * ix
    union = a[..., 2] * a[..., 3] + b[..., 2] * b[..., 3] - inter
    return inter / jnp.maximum(union, 1e-9)


def greedy_nms(boxes, scores, iou_thresh: float = 0.5, top_k: int = 32):
    """Simple greedy NMS over the top_k highest-scoring cells
    (jit-compatible: static shapes, mask-based suppression)."""
    k = min(top_k, scores.shape[0])
    sc, idx = lax.top_k(scores, k)
    bx = boxes[idx]
    rank = jnp.arange(k)

    def body(i, keep):
        ious = iou_cxcywh(bx[i][None], bx)[0]          # (k,)
        suppressed = jnp.any((ious > iou_thresh) & (rank < i) & (keep > 0))
        return keep.at[i].set(jnp.where(suppressed, 0.0, keep[i]))

    keep = jnp.ones((k,), f32)
    keep = lax.fori_loop(1, k, body, keep)
    return bx, sc * keep


def f1_score(pred_boxes, pred_scores, gt_boxes, gt_valid,
             iou_thresh: float = 0.5, score_thresh: float = 0.5):
    """Greedy matching F1@IoU for a single frame (jit-compatible)."""
    iou = iou_cxcywh(pred_boxes[:, None], gt_boxes[None])      # (P, G)
    conf = pred_scores > score_thresh
    iou = iou * conf[:, None] * gt_valid[None]

    def match_one(carry, _):
        iou_m, tp = carry
        flat = jnp.argmax(iou_m)
        pi, gi = flat // iou_m.shape[1], flat % iou_m.shape[1]
        best = iou_m[pi, gi]
        hit = best >= iou_thresh
        iou_m = jnp.where(hit, iou_m.at[pi, :].set(0.0).at[:, gi].set(0.0),
                          iou_m)
        return (iou_m, tp + hit.astype(f32)), None

    n = min(iou.shape[0], iou.shape[1])
    (iou_f, tp), _ = lax.scan(match_one, (iou, 0.0), None, length=n)
    n_pred = conf.sum()
    n_gt = gt_valid.sum()
    prec = tp / jnp.maximum(n_pred, 1e-9)
    rec = tp / jnp.maximum(n_gt, 1e-9)
    return jnp.where(n_gt > 0,
                     2 * prec * rec / jnp.maximum(prec + rec, 1e-9),
                     jnp.where(n_pred > 0, 0.0, 1.0))
