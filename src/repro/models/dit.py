"""Diffusion Transformer (DiT) with adaLN-Zero conditioning [arXiv:2212.09748].

Operates on VAE latents (img_res/8, 4 channels); the VAE frontend is a stub
per DESIGN.md §4 — ``input_specs`` provide latents directly.

train_step: noise-prediction MSE at a random timestep (t, noise supplied by
the data pipeline for determinism).  serve_step: one DDIM denoising step —
a steps-step sampler is ``steps`` calls to serve_step.
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import layers as L
from repro.models.params import spec

f32 = jnp.float32


@dataclasses.dataclass(frozen=True)
class DiTConfig:
    name: str
    img_res: int                  # pixel resolution of the *default* shape
    patch: int
    n_layers: int
    d_model: int
    n_heads: int
    n_classes: int = 1000
    latent_channels: int = 4
    vae_factor: int = 8
    dtype: str = "bfloat16"
    remat: bool = True
    max_latent: int = 128         # pos-emb sized for largest (1024/8)

    @property
    def mlp_ratio(self) -> int:
        return 4

    def latent_res(self, img_res: int) -> int:
        return img_res // self.vae_factor

    def n_tokens(self, img_res: int) -> int:
        return (self.latent_res(img_res) // self.patch) ** 2

    def param_count(self) -> int:
        from repro.models.params import param_count
        return param_count(param_specs(self))


def param_specs(cfg: DiTConfig):
    Ln, d, H = cfg.n_layers, cfg.d_model, cfg.n_heads
    Dh = d // H
    ff = d * cfg.mlp_ratio
    dt = jnp.dtype(cfg.dtype)
    in_dim = cfg.patch * cfg.patch * cfg.latent_channels
    max_tokens = (cfg.max_latent // cfg.patch) ** 2
    blk = {
        "adaln_w": spec((Ln, d, 6 * d), (None, "fsdp", "tensor"), dtype=dt,
                        init="zeros"),
        "adaln_b": spec((Ln, 6 * d), (None, "tensor"), dtype=dt, init="zeros"),
        "wq": spec((Ln, d, H, Dh), (None, "fsdp", "tensor", None), dtype=dt, init="fan_in"),
        "wk": spec((Ln, d, H, Dh), (None, "fsdp", "tensor", None), dtype=dt, init="fan_in"),
        "wv": spec((Ln, d, H, Dh), (None, "fsdp", "tensor", None), dtype=dt, init="fan_in"),
        "wo": spec((Ln, H, Dh, d), (None, "tensor", None, "fsdp"), dtype=dt, init="fan_in"),
        "w1": spec((Ln, d, ff), (None, "fsdp", "tensor"), dtype=dt, init="fan_in"),
        "b1": spec((Ln, ff), (None, "tensor"), dtype=dt, init="zeros"),
        "w2": spec((Ln, ff, d), (None, "tensor", "fsdp"), dtype=dt, init="fan_in"),
        "b2": spec((Ln, d), (None, None), dtype=dt, init="zeros"),
    }
    return {
        "patch_w": spec((in_dim, d), (None, "tensor"), dtype=dt, init="fan_in"),
        "patch_b": spec((d,), ("tensor",), dtype=dt, init="zeros"),
        "pos_embed": spec((max_tokens, d), (None, None), dtype=dt),
        "t_mlp1": spec((256, d), (None, "tensor"), dtype=dt, init="fan_in"),
        "t_mlp1_b": spec((d,), ("tensor",), dtype=dt, init="zeros"),
        "t_mlp2": spec((d, d), ("fsdp", "tensor"), dtype=dt, init="fan_in"),
        "t_mlp2_b": spec((d,), ("tensor",), dtype=dt, init="zeros"),
        "y_embed": spec((cfg.n_classes + 1, d), (None, "tensor"), dtype=dt),
        "blocks": blk,
        "final_adaln_w": spec((d, 2 * d), ("fsdp", "tensor"), dtype=dt, init="zeros"),
        "final_adaln_b": spec((2 * d,), ("tensor",), dtype=dt, init="zeros"),
        "final_ln_w": spec((d,), (None,), dtype=dt, init="ones"),
        "final_w": spec((d, in_dim), ("fsdp", None), dtype=dt, init="zeros"),
        "final_b": spec((in_dim,), (None,), dtype=dt, init="zeros"),
    }


def timestep_embedding(t, dim: int = 256):
    half = dim // 2
    freqs = jnp.exp(-math.log(10000.0) * jnp.arange(half, dtype=f32) / half)
    ang = t.astype(f32)[:, None] * freqs[None]
    return jnp.concatenate([jnp.cos(ang), jnp.sin(ang)], axis=-1)


def _modulate(x, shift, scale):
    return x * (1 + scale[:, None]) + shift[:, None]


def _block(cfg, p, x, c):
    """x: (B, S, d) tokens, c: (B, d) conditioning."""
    B, S, d = x.shape
    mod = jnp.einsum("bd,df->bf", c, p["adaln_w"],
                     preferred_element_type=f32) + p["adaln_b"].astype(f32)
    sh1, sc1, g1, sh2, sc2, g2 = jnp.split(mod, 6, axis=-1)
    ones = jnp.ones((d,), x.dtype)
    zeros = jnp.zeros((d,), x.dtype)
    h = L.layer_norm(x, ones, zeros).astype(f32)
    h = _modulate(h, sh1, sc1).astype(x.dtype)
    q = jnp.einsum("bsd,dhk->bshk", h, p["wq"], preferred_element_type=f32).astype(x.dtype)
    k = jnp.einsum("bsd,dhk->bshk", h, p["wk"], preferred_element_type=f32).astype(x.dtype)
    v = jnp.einsum("bsd,dhk->bshk", h, p["wv"], preferred_element_type=f32).astype(x.dtype)
    o = L.chunked_attention(q, k, v, causal=False, chunk=min(1024, S))
    o = jnp.einsum("bshk,hkd->bsd", o, p["wo"])     # bf16 wire for TP psum
    x = L.constrain(x + (g1[:, None] * o.astype(f32)).astype(x.dtype),
                    "batch", None, None)
    h = L.layer_norm(x, ones, zeros).astype(f32)
    h = _modulate(h, sh2, sc2).astype(x.dtype)
    h = L.gelu_mlp(h, p["w1"], p["b1"], p["w2"], p["b2"])
    x = L.constrain(x + (g2[:, None] * h.astype(f32)).astype(x.dtype),
                    "batch", None, None)
    return x


def patchify(latents, patch: int):
    B, Hh, Ww, C = latents.shape
    hp, wp = Hh // patch, Ww // patch
    x = latents.reshape(B, hp, patch, wp, patch, C)
    x = x.transpose(0, 1, 3, 2, 4, 5).reshape(B, hp * wp, patch * patch * C)
    return x, (hp, wp)


def unpatchify(x, hw, patch: int, channels: int):
    B = x.shape[0]
    hp, wp = hw
    x = x.reshape(B, hp, wp, patch, patch, channels)
    x = x.transpose(0, 1, 3, 2, 4, 5).reshape(B, hp * patch, wp * patch, channels)
    return x


def forward(params, cfg: DiTConfig, latents, t, y):
    """Noise prediction eps_theta(x_t, t, y).  latents: (B, h, w, C)."""
    x, hw = patchify(latents.astype(cfg.dtype), cfg.patch)
    S = x.shape[1]
    x = jnp.einsum("bsi,id->bsd", x, params["patch_w"],
                   preferred_element_type=f32) + params["patch_b"].astype(f32)
    x = x.astype(cfg.dtype) + params["pos_embed"][:S].astype(cfg.dtype)[None]
    temb = timestep_embedding(t)
    temb = jnp.einsum("bi,id->bd", temb, params["t_mlp1"].astype(f32)) + params["t_mlp1_b"].astype(f32)
    temb = jax.nn.silu(temb)
    temb = jnp.einsum("bi,id->bd", temb, params["t_mlp2"].astype(f32)) + params["t_mlp2_b"].astype(f32)
    yemb = params["y_embed"].at[y].get(mode="clip").astype(f32)
    c = (temb + yemb).astype(cfg.dtype)

    def body(x, p):
        return _block(cfg, p, x, c), None

    fn = jax.checkpoint(body) if cfg.remat else body
    x, _ = lax.scan(fn, x, params["blocks"],
                    unroll=L.scan_unroll(cfg.n_layers))
    mod = jnp.einsum("bd,df->bf", c, params["final_adaln_w"],
                     preferred_element_type=f32) + params["final_adaln_b"].astype(f32)
    sh, sc = jnp.split(mod, 2, axis=-1)
    ones = jnp.ones((cfg.d_model,), x.dtype)
    zeros = jnp.zeros((cfg.d_model,), x.dtype)
    x = _modulate(L.layer_norm(x, ones, zeros).astype(f32), sh, sc)
    x = jnp.einsum("bsd,di->bsi", x.astype(cfg.dtype), params["final_w"],
                   preferred_element_type=f32) + params["final_b"].astype(f32)
    return unpatchify(x.astype(f32), hw, cfg.patch, cfg.latent_channels)


# DDPM cosine schedule ------------------------------------------------------
def alpha_bar(t, T: int = 1000):
    s = 0.008
    tt = t.astype(f32) / T
    return jnp.cos((tt + s) / (1 + s) * jnp.pi / 2) ** 2


def loss_fn(params, cfg: DiTConfig, batch):
    """batch: latents (clean), t (B,), noise (B,h,w,C), labels (B,)."""
    x0, t, eps, y = (batch["latents"], batch["t"], batch["noise"],
                     batch["labels"])
    ab = alpha_bar(t)[:, None, None, None]
    xt = jnp.sqrt(ab) * x0.astype(f32) + jnp.sqrt(1 - ab) * eps.astype(f32)
    pred = forward(params, cfg, xt, t, y)
    return jnp.mean(jnp.square(pred - eps.astype(f32)))


def ddim_update(xt, eps, t, t_prev):
    """Deterministic DDIM update x_t -> x_{t_prev} given a noise estimate."""
    ab_t = alpha_bar(t)[:, None, None, None]
    ab_p = alpha_bar(t_prev)[:, None, None, None]
    x0 = (xt.astype(f32) - jnp.sqrt(1 - ab_t) * eps) / jnp.sqrt(ab_t)
    return jnp.sqrt(ab_p) * x0 + jnp.sqrt(1 - ab_p) * eps


def ddim_step(params, cfg: DiTConfig, xt, t, t_prev, y):
    """One DDIM step (fresh DNN forward)."""
    eps = forward(params, cfg, xt, t, y)
    return ddim_update(xt, eps, t, t_prev)


def sample_with_cache(params, cfg: DiTConfig, x, timesteps, y,
                      refresh_every: int = 2):
    """Step-cached sampling — BiSwift's reuse pipeline (③) mapped to
    diffusion serving (DESIGN.md §4): the noise estimate is refreshed by
    the DNN every ``refresh_every`` steps and *reused* in between
    (DeepCache-style), cutting sampler FLOPs by ~(1 − 1/refresh_every).

    timesteps: decreasing (n_steps+1,) int sequence; returns the final x.
    """
    eps = None
    fwd = jax.jit(lambda x, t: forward(params, cfg, x, t, y))
    for i in range(len(timesteps) - 1):
        t = jnp.full((x.shape[0],), int(timesteps[i]), jnp.int32)
        tp = jnp.full((x.shape[0],), int(timesteps[i + 1]), jnp.int32)
        if eps is None or i % refresh_every == 0:
            eps = fwd(x, t)
        x = ddim_update(x, eps, t, tp)
    return x
