"""Single-source parameter declaration.

Each model declares its parameters once, as a pytree of :class:`ParamSpec`
(shape + logical sharding axes + initializer).  From that single tree we
derive (a) real initialized parameters for smoke tests / training, and
(b) ShapeDtypeStructs carrying NamedShardings for the zero-allocation
multi-pod dry-run.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Sequence

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: tuple[int, ...]
    axes: tuple[str | None, ...]
    dtype: jnp.dtype = jnp.bfloat16
    init: str = "normal"  # normal | zeros | ones | fan_in
    scale: float = 0.02

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def _init_one(key, s: ParamSpec):
    if s.init == "zeros":
        return jnp.zeros(s.shape, s.dtype)
    if s.init == "ones":
        return jnp.ones(s.shape, s.dtype)
    if s.init == "fan_in":
        fan_in = s.shape[-2] if len(s.shape) >= 2 else s.shape[0]
        std = 1.0 / math.sqrt(fan_in)
        return (jax.random.normal(key, s.shape, jnp.float32) * std).astype(s.dtype)
    return (jax.random.normal(key, s.shape, jnp.float32) * s.scale).astype(s.dtype)


def init_params(key, specs_tree):
    leaves, treedef = jax.tree.flatten(specs_tree, is_leaf=is_spec)
    keys = jax.random.split(key, len(leaves))
    vals = [_init_one(k, s) for k, s in zip(keys, leaves)]
    return jax.tree.unflatten(treedef, vals)


def abstract_params(specs_tree, mesh=None, rules=None):
    """ShapeDtypeStruct pytree, optionally with NamedShardings attached."""
    from repro.distributed.sharding import named_sharding

    def one(s: ParamSpec):
        if mesh is None:
            return jax.ShapeDtypeStruct(s.shape, s.dtype)
        sh = named_sharding(mesh, s.axes, rules, s.shape)
        return jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh)

    return jax.tree.map(one, specs_tree, is_leaf=is_spec)


def param_count(specs_tree) -> int:
    leaves = jax.tree.leaves(specs_tree, is_leaf=is_spec)
    return sum(math.prod(s.shape) for s in leaves)


def param_bytes(specs_tree) -> int:
    leaves = jax.tree.leaves(specs_tree, is_leaf=is_spec)
    return sum(math.prod(s.shape) * jnp.dtype(s.dtype).itemsize for s in leaves)


def spec(shape: Sequence[int], axes: Sequence[str | None], **kw) -> ParamSpec:
    return ParamSpec(tuple(shape), tuple(axes), **kw)
