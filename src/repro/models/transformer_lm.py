"""Decoder-only LM family: llama3, chatglm3, qwen2-moe, mixtral.

One config dataclass covers all four assigned LM architectures:
  * GQA with arbitrary kv-head count (llama 8, chatglm 2, qwen 16, mixtral 8)
  * RoPE with a rotated fraction (chatglm "2d RoPE" rotates half the head dim)
  * optional sliding-window attention (mixtral)
  * optional MoE FFN with shared experts (qwen: 4 shared + 60 routed top-4;
    mixtral: 8 routed top-2)

Layers are stacked (L, ...) and scanned; remat is applied per layer.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import layers as L
from repro.models.params import spec

f32 = jnp.float32


@dataclasses.dataclass(frozen=True)
class LMConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0
    rope_fraction: float = 1.0
    rope_theta: float = 500000.0
    window: Optional[int] = None          # SWA window (mixtral)
    moe: Optional[L.MoEConfig] = None
    d_ff_shared: int = 0                  # qwen shared-expert width
    qkv_bias: bool = False                # qwen
    norm_eps: float = 1e-5
    dtype: str = "bfloat16"
    remat: bool = True
    attn_chunk: int = 1024
    q_block: int = 1024
    aux_loss_coef: float = 0.01
    attention_impl: str = "xla"           # xla | pallas (flash kernel)
    kv_cache_dtype: str = "bfloat16"      # bfloat16 | int8 (quantized cache)

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)

    @property
    def sub_quadratic(self) -> bool:
        return self.window is not None

    def param_count(self) -> int:
        from repro.models.params import param_count
        return param_count(param_specs(self))

    def active_param_count(self) -> int:
        """6·N_active·D convention: MoE counts only top-k + shared experts."""
        if self.moe is None:
            return self.param_count()
        c = self.param_count()
        per_expert = 3 * self.d_model * self.d_ff
        inactive = (self.moe.n_experts - self.moe.top_k) * per_expert
        return c - self.n_layers * inactive


# --------------------------------------------------------------------------
# Parameters
# --------------------------------------------------------------------------
def param_specs(cfg: LMConfig):
    Ln, d, H, Hk, Dh = (cfg.n_layers, cfg.d_model, cfg.n_heads,
                        cfg.n_kv_heads, cfg.head_dim)
    dt = jnp.dtype(cfg.dtype)
    blk = {
        "ln1": spec((Ln, d), (None, None), dtype=dt, init="ones"),
        "ln2": spec((Ln, d), (None, None), dtype=dt, init="ones"),
        "wq": spec((Ln, d, H, Dh), (None, "fsdp", "tensor", None), dtype=dt,
                   init="fan_in"),
        "wk": spec((Ln, d, Hk, Dh), (None, "fsdp", "tensor", None), dtype=dt,
                   init="fan_in"),
        "wv": spec((Ln, d, Hk, Dh), (None, "fsdp", "tensor", None), dtype=dt,
                   init="fan_in"),
        "wo": spec((Ln, H, Dh, d), (None, "tensor", None, "fsdp"), dtype=dt,
                   init="fan_in"),
    }
    if cfg.qkv_bias:
        blk["bq"] = spec((Ln, H, Dh), (None, "tensor", None), dtype=dt,
                         init="zeros")
        blk["bk"] = spec((Ln, Hk, Dh), (None, "tensor", None), dtype=dt,
                         init="zeros")
        blk["bv"] = spec((Ln, Hk, Dh), (None, "tensor", None), dtype=dt,
                         init="zeros")
    if cfg.moe is None:
        blk.update({
            "w1": spec((Ln, d, cfg.d_ff), (None, "fsdp", "tensor"), dtype=dt,
                       init="fan_in"),
            "w3": spec((Ln, d, cfg.d_ff), (None, "fsdp", "tensor"), dtype=dt,
                       init="fan_in"),
            "w2": spec((Ln, cfg.d_ff, d), (None, "tensor", "fsdp"), dtype=dt,
                       init="fan_in"),
        })
    else:
        E = cfg.moe.n_experts
        blk.update({
            "w_router": spec((Ln, d, E), (None, "fsdp", None), dtype=dt,
                             init="fan_in"),
            "we1": spec((Ln, E, d, cfg.d_ff), (None, "expert", "fsdp", "tensor"),
                        dtype=dt, init="fan_in"),
            "we3": spec((Ln, E, d, cfg.d_ff), (None, "expert", "fsdp", "tensor"),
                        dtype=dt, init="fan_in"),
            "we2": spec((Ln, E, cfg.d_ff, d), (None, "expert", "tensor", "fsdp"),
                        dtype=dt, init="fan_in"),
        })
        if cfg.d_ff_shared:
            blk.update({
                "ws1": spec((Ln, d, cfg.d_ff_shared), (None, "fsdp", "tensor"),
                            dtype=dt, init="fan_in"),
                "ws3": spec((Ln, d, cfg.d_ff_shared), (None, "fsdp", "tensor"),
                            dtype=dt, init="fan_in"),
                "ws2": spec((Ln, cfg.d_ff_shared, d), (None, "tensor", "fsdp"),
                            dtype=dt, init="fan_in"),
                "w_shared_gate": spec((Ln, d, 1), (None, "fsdp", None),
                                      dtype=dt, init="fan_in"),
            })
    return {
        # vocab on tensor axis only: a (V, d) table with d sharded would force
        # the token gather to reshard d per row (pathological under SPMD).
        "embed": spec((cfg.vocab, d), ("tensor", None), dtype=dt),
        "blocks": blk,
        "final_ln": spec((d,), (None,), dtype=dt, init="ones"),
    }


# --------------------------------------------------------------------------
# Forward
# --------------------------------------------------------------------------
def _ffn(cfg: LMConfig, p, x):
    """Per-layer FFN; p holds this layer's (un-stacked) weights."""
    if cfg.moe is None:
        return L.swiglu(x, p["w1"], p["w3"], p["w2"]), 0.0
    out, aux = L.moe_block(x, p["w_router"], p["we1"], p["we3"], p["we2"],
                           cfg.moe)
    if cfg.d_ff_shared:
        sh = L.swiglu(x, p["ws1"], p["ws3"], p["ws2"])
        gate = jax.nn.sigmoid(
            jnp.einsum("bsd,dk->bsk", x.astype(f32), p["w_shared_gate"].astype(f32)))
        out = out + (sh.astype(f32) * gate).astype(x.dtype)
    return out, aux


def _attn(cfg: LMConfig, p, x, positions, *, kv_override=None,
          cache_positions=None, decode_pos=None):
    """Returns (attn_out, (k, v)) for this layer."""
    B, S, d = x.shape
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"], preferred_element_type=f32)
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"], preferred_element_type=f32)
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"], preferred_element_type=f32)
    if cfg.qkv_bias:
        q = q + p["bq"].astype(f32)
        k = k + p["bk"].astype(f32)
        v = v + p["bv"].astype(f32)
    q, k, v = (L.constrain(t.astype(x.dtype), "batch", None, "tensor", None)
               for t in (q, k, v))
    q = L.apply_rope(q, positions, fraction=cfg.rope_fraction,
                     theta=cfg.rope_theta)
    k = L.apply_rope(k, positions, fraction=cfg.rope_fraction,
                     theta=cfg.rope_theta)
    if kv_override is not None:  # decode: attend over the cache
        kc, vc = kv_override
        o = L.decode_attention(q, kc, vc, cache_positions=cache_positions,
                               pos=decode_pos, window=cfg.window)
    elif cfg.attention_impl == "pallas":
        from repro.kernels.flash_attention.ops import flash_attention
        o = flash_attention(q, k, v, causal=True, window=cfg.window,
                            q_blk=min(128, S), k_blk=min(128, S))
    elif cfg.window is not None and S > cfg.q_block:
        o = L.swa_attention(q, k, v, window=cfg.window, q_block=cfg.q_block)
    else:
        o = L.chunked_attention(q, k, v, causal=True, chunk=cfg.attn_chunk)
    out = jnp.einsum("bshk,hkd->bsd", o, p["wo"])   # bf16 wire for TP psum
    return L.constrain(out.astype(x.dtype), "batch", None, None), (k, v)


def forward(params, cfg: LMConfig, tokens, *, collect_cache: bool = False):
    """Full-sequence forward (training / prefill).

    Returns (logits, aux_loss, cache_kv) where cache_kv is (k, v) stacked
    over layers if collect_cache else None.
    """
    B, S = tokens.shape
    positions = jnp.arange(S, dtype=jnp.int32)
    x = params["embed"].at[tokens].get(mode="clip").astype(cfg.dtype)
    x = L.constrain(x, "batch", None, None)

    def layer(carry, p):
        x, aux = carry
        h, kv = _attn(cfg, p, L.rms_norm(x, p["ln1"], cfg.norm_eps), positions)
        x = L.constrain(x + h, "batch", None, None)
        h, a = _ffn(cfg, p, L.rms_norm(x, p["ln2"], cfg.norm_eps))
        x = L.constrain(x + h, "batch", None, None)
        ys = kv if collect_cache else None
        return (x, aux + a), ys

    layer_fn = jax.checkpoint(layer) if cfg.remat else layer
    (x, aux), cache = lax.scan(layer_fn, (x, 0.0), params["blocks"],
                               unroll=L.scan_unroll(cfg.n_layers))
    x = L.rms_norm(x, params["final_ln"], cfg.norm_eps)
    logits = jnp.einsum("bsd,vd->bsv", x, params["embed"],
                        preferred_element_type=f32)
    logits = L.constrain(logits, "batch", None, "tensor")
    return logits, aux, cache


# --------------------------------------------------------------------------
# Loss / train step
# --------------------------------------------------------------------------
def softmax_xent(logits, labels):
    """Sharding-friendly CE: the gold logit is picked with a one-hot einsum
    (partial per vocab shard + psum) instead of take_along_axis, which would
    all-gather the full logits across the tensor axis."""
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    onehot = jax.nn.one_hot(labels, logits.shape[-1], dtype=logits.dtype)
    gold = jnp.einsum("...v,...v->...", logits, onehot)
    return (lse - gold).mean()


def loss_fn(params, cfg: LMConfig, batch):
    logits, aux, _ = forward(params, cfg, batch["tokens"])
    ce = softmax_xent(logits, batch["labels"])
    return ce + cfg.aux_loss_coef * aux / max(cfg.n_layers, 1)


# --------------------------------------------------------------------------
# Decode (serve_step)
# --------------------------------------------------------------------------
def cache_len(cfg: LMConfig, seq_len: int) -> int:
    """Ring-buffer caches for SWA archs are bounded by the window."""
    if cfg.window is not None:
        return min(cfg.window, seq_len)
    return seq_len


def init_cache_specs(cfg: LMConfig, batch: int, seq_len: int):
    Sc = cache_len(cfg, seq_len)
    quant = cfg.kv_cache_dtype == "int8"
    dt = jnp.int8 if quant else jnp.dtype(cfg.dtype)
    specs = {
        "k": spec((cfg.n_layers, batch, Sc, cfg.n_kv_heads, cfg.head_dim),
                  (None, "batch", "seq_kv", None, None), dtype=dt,
                  init="zeros"),
        "v": spec((cfg.n_layers, batch, Sc, cfg.n_kv_heads, cfg.head_dim),
                  (None, "batch", "seq_kv", None, None), dtype=dt,
                  init="zeros"),
        "slot_pos": spec((Sc,), (None,), dtype=jnp.int32, init="zeros"),
    }
    if quant:
        # per-(batch, slot, head) scales: +1/head_dim relative overhead
        for nm in ("k_scale", "v_scale"):
            specs[nm] = spec((cfg.n_layers, batch, Sc, cfg.n_kv_heads),
                             (None, "batch", "seq_kv", None),
                             dtype=jnp.float32, init="ones")
    return specs


def _quantize_kv(x):
    """(B, 1, Hk, D) -> (int8 values, (B, 1, Hk) scales)."""
    scale = jnp.maximum(jnp.abs(x.astype(f32)).max(axis=-1), 1e-6) / 127.0
    q = jnp.clip(jnp.round(x.astype(f32) / scale[..., None]), -127, 127)
    return q.astype(jnp.int8), scale


def _dequantize_kv(q, scale):
    return q.astype(jnp.bfloat16) * scale[..., None].astype(jnp.bfloat16)


def decode_step(params, cfg: LMConfig, cache, tokens, pos):
    """One-token decode.  tokens: (B, 1) int32; pos: scalar int32 position.

    Returns (logits (B, 1, V), new_cache).
    """
    Sc = cache["k"].shape[2]
    positions = jnp.reshape(jnp.asarray(pos, jnp.int32), (1,))
    x = params["embed"].at[tokens].get(mode="clip").astype(cfg.dtype)
    if cfg.window is not None:
        slot = positions[0] % Sc          # ring buffer
    else:
        slot = jnp.minimum(positions[0], Sc - 1)
    new_slot_pos = cache["slot_pos"].at[slot].set(positions[0])

    quant = cfg.kv_cache_dtype == "int8"

    # attention must see the *new* token's kv too -> write before attend.
    def layer_write_first(carry, inp):
        x, = carry
        if quant:
            p, kc, vc, ks, vs = inp
        else:
            p, kc, vc = inp
        xn = L.rms_norm(x, p["ln1"], cfg.norm_eps)
        q = jnp.einsum("bsd,dhk->bshk", xn, p["wq"], preferred_element_type=f32)
        k = jnp.einsum("bsd,dhk->bshk", xn, p["wk"], preferred_element_type=f32)
        v = jnp.einsum("bsd,dhk->bshk", xn, p["wv"], preferred_element_type=f32)
        if cfg.qkv_bias:
            q = q + p["bq"].astype(f32)
            k = k + p["bk"].astype(f32)
            v = v + p["bv"].astype(f32)
        q, k, v = (t.astype(x.dtype) for t in (q, k, v))
        q = L.apply_rope(q, positions, fraction=cfg.rope_fraction,
                         theta=cfg.rope_theta)
        k = L.apply_rope(k, positions, fraction=cfg.rope_fraction,
                         theta=cfg.rope_theta)
        if quant:
            kq, ksc = _quantize_kv(k)
            vq, vsc = _quantize_kv(v)
            kc = lax.dynamic_update_slice_in_dim(kc, kq, slot, axis=1)
            vc = lax.dynamic_update_slice_in_dim(vc, vq, slot, axis=1)
            ks = lax.dynamic_update_slice_in_dim(ks, ksc, slot, axis=1)
            vs = lax.dynamic_update_slice_in_dim(vs, vsc, slot, axis=1)
            k_full = _dequantize_kv(kc, ks)
            v_full = _dequantize_kv(vc, vs)
        else:
            kc = lax.dynamic_update_slice_in_dim(kc, k.astype(kc.dtype),
                                                 slot, axis=1)
            vc = lax.dynamic_update_slice_in_dim(vc, v.astype(vc.dtype),
                                                 slot, axis=1)
            k_full, v_full = kc, vc
        o = L.decode_attention(q, k_full, v_full,
                               cache_positions=new_slot_pos,
                               pos=positions[0], window=cfg.window)
        h = jnp.einsum("bshk,hkd->bsd", o, p["wo"],
                       preferred_element_type=f32).astype(x.dtype)
        x = x + h
        h, _ = _ffn(cfg, p, L.rms_norm(x, p["ln2"], cfg.norm_eps))
        x = x + h
        return (x,), ((kc, vc, ks, vs) if quant else (kc, vc))

    if quant:
        (x,), (k_all, v_all, ks_all, vs_all) = lax.scan(
            layer_write_first, (x,),
            (params["blocks"], cache["k"], cache["v"], cache["k_scale"],
             cache["v_scale"]), unroll=L.scan_unroll(cfg.n_layers))
    else:
        (x,), (k_all, v_all) = lax.scan(
            layer_write_first, (x,),
            (params["blocks"], cache["k"], cache["v"]),
            unroll=L.scan_unroll(cfg.n_layers))
    x = L.rms_norm(x, params["final_ln"], cfg.norm_eps)
    logits = jnp.einsum("bsd,vd->bsv", x, params["embed"],
                        preferred_element_type=f32)
    new_cache = {"k": k_all, "v": v_all, "slot_pos": new_slot_pos}
    if quant:
        new_cache["k_scale"] = ks_all
        new_cache["v_scale"] = vs_all
    return logits, new_cache


def prefill_step(params, cfg: LMConfig, tokens):
    """Inference prefill: returns (last-position logits, stacked kv cache)."""
    logits, _, cache = forward(params, cfg, tokens, collect_cache=True)
    return logits[:, -1:], cache
