"""EDSR-lite super-resolution (the neural-enhancement module used by the
AccDecoder / NeuroScaler* baselines; paper §II).

Conv -> N residual blocks -> nearest-upsample + conv refinement.  Small
enough to train on CPU in the examples; on the edge GPU the paper reports
~135 ms swap overhead per stream-specialized model — the motivation for
BiSwift's HD-anchor approach (Insight #2).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.params import spec, init_params

f32 = jnp.float32


@dataclasses.dataclass(frozen=True)
class EDSRConfig:
    channels: int = 16
    n_blocks: int = 4
    scale: int = 2


def param_specs(cfg: EDSRConfig):
    c = cfg.channels
    p = {
        "head": spec((3, 3, 1, c), (None, None, None, "tensor"), dtype=f32,
                     init="fan_in"),
        "tail": spec((3, 3, c, 1), (None, None, "tensor", None), dtype=f32,
                     init="fan_in"),
        "blocks": {
            "w1": spec((cfg.n_blocks, 3, 3, c, c),
                       (None, None, None, None, "tensor"), dtype=f32,
                       init="fan_in"),
            "w2": spec((cfg.n_blocks, 3, 3, c, c),
                       (None, None, None, "tensor", None), dtype=f32,
                       init="fan_in"),
        },
    }
    return p


def init(key, cfg: EDSRConfig):
    return init_params(key, param_specs(cfg))


def _conv(x, w):
    return lax.conv_general_dilated(
        x, w, window_strides=(1, 1), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))


def forward(params, cfg: EDSRConfig, frames):
    """frames: (B, h, w) [0..255] -> (B, h*scale, w*scale)."""
    x = (frames.astype(f32) / 255.0)[..., None]
    x = _conv(x, params["head"])

    def body(x, p):
        h = jax.nn.relu(_conv(x, p["w1"]))
        return x + 0.1 * _conv(h, p["w2"]), None

    x, _ = lax.scan(body, x, params["blocks"])
    s = cfg.scale
    B, h, w, c = x.shape
    x = jnp.repeat(jnp.repeat(x, s, axis=1), s, axis=2)   # nearest base
    x = _conv(x, params["tail"])[..., 0] + jnp.repeat(
        jnp.repeat(frames.astype(f32) / 255.0, s, axis=1), s, axis=2)
    return jnp.clip(x * 255.0, 0.0, 255.0)


def loss_fn(params, cfg: EDSRConfig, lr_frames, hd_frames):
    out = forward(params, cfg, lr_frames)
    return jnp.mean(jnp.square(out - hd_frames.astype(f32))) / (255.0 ** 2)
