"""ViT-B/16 style vision transformer (encoder-only classifier)."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import layers as L
from repro.models.params import spec

f32 = jnp.float32


@dataclasses.dataclass(frozen=True)
class ViTConfig:
    name: str
    img_res: int
    patch: int
    n_layers: int
    d_model: int
    n_heads: int
    d_ff: int
    n_classes: int = 1000
    dtype: str = "bfloat16"
    remat: bool = True
    max_res: int = 384        # pos-emb table sized for the largest shape

    @property
    def n_patches_max(self) -> int:
        return (self.max_res // self.patch) ** 2

    def param_count(self) -> int:
        from repro.models.params import param_count
        return param_count(param_specs(self))


def param_specs(cfg: ViTConfig):
    Ln, d, H, ff = cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.d_ff
    Dh = d // H
    dt = jnp.dtype(cfg.dtype)
    blk = {
        "ln1_w": spec((Ln, d), (None, None), dtype=dt, init="ones"),
        "ln1_b": spec((Ln, d), (None, None), dtype=dt, init="zeros"),
        "ln2_w": spec((Ln, d), (None, None), dtype=dt, init="ones"),
        "ln2_b": spec((Ln, d), (None, None), dtype=dt, init="zeros"),
        "wq": spec((Ln, d, H, Dh), (None, "fsdp", "tensor", None), dtype=dt, init="fan_in"),
        "wk": spec((Ln, d, H, Dh), (None, "fsdp", "tensor", None), dtype=dt, init="fan_in"),
        "wv": spec((Ln, d, H, Dh), (None, "fsdp", "tensor", None), dtype=dt, init="fan_in"),
        "bq": spec((Ln, H, Dh), (None, "tensor", None), dtype=dt, init="zeros"),
        "bk": spec((Ln, H, Dh), (None, "tensor", None), dtype=dt, init="zeros"),
        "bv": spec((Ln, H, Dh), (None, "tensor", None), dtype=dt, init="zeros"),
        "wo": spec((Ln, H, Dh, d), (None, "tensor", None, "fsdp"), dtype=dt, init="fan_in"),
        "bo": spec((Ln, d), (None, None), dtype=dt, init="zeros"),
        "w1": spec((Ln, d, ff), (None, "fsdp", "tensor"), dtype=dt, init="fan_in"),
        "b1": spec((Ln, ff), (None, "tensor"), dtype=dt, init="zeros"),
        "w2": spec((Ln, ff, d), (None, "tensor", "fsdp"), dtype=dt, init="fan_in"),
        "b2": spec((Ln, d), (None, None), dtype=dt, init="zeros"),
    }
    return {
        "patch_embed": spec((cfg.patch, cfg.patch, 3, d),
                            (None, None, None, "tensor"), dtype=dt, init="fan_in"),
        "patch_bias": spec((d,), ("tensor",), dtype=dt, init="zeros"),
        "cls_token": spec((1, 1, d), (None, None, None), dtype=dt),
        "pos_embed": spec((cfg.n_patches_max + 1, d), (None, None), dtype=dt),
        "blocks": blk,
        "ln_f_w": spec((d,), (None,), dtype=dt, init="ones"),
        "ln_f_b": spec((d,), (None,), dtype=dt, init="zeros"),
        "head_w": spec((d, cfg.n_classes), ("fsdp", "tensor"), dtype=dt, init="fan_in"),
        "head_b": spec((cfg.n_classes,), ("tensor",), dtype=dt, init="zeros"),
    }


def _block(cfg, p, x):
    B, S, d = x.shape
    H, Dh = cfg.n_heads, cfg.d_model // cfg.n_heads
    h = L.layer_norm(x, p["ln1_w"], p["ln1_b"])
    q = jnp.einsum("bsd,dhk->bshk", h, p["wq"], preferred_element_type=f32) + p["bq"].astype(f32)
    k = jnp.einsum("bsd,dhk->bshk", h, p["wk"], preferred_element_type=f32) + p["bk"].astype(f32)
    v = jnp.einsum("bsd,dhk->bshk", h, p["wv"], preferred_element_type=f32) + p["bv"].astype(f32)
    q, k, v = (L.constrain(t.astype(x.dtype), "batch", None, "tensor", None)
               for t in (q, k, v))
    o = L.chunked_attention(q, k, v, causal=False,
                            chunk=min(1024, S))
    h = jnp.einsum("bshk,hkd->bsd", o, p["wo"])     # bf16 wire for TP psum
    x = L.constrain(x + (h.astype(f32) + p["bo"].astype(f32)).astype(x.dtype),
                    "batch", None, None)
    h = L.layer_norm(x, p["ln2_w"], p["ln2_b"])
    x = L.constrain(x + L.gelu_mlp(h, p["w1"], p["b1"], p["w2"], p["b2"]),
                    "batch", None, None)
    return x


def forward(params, cfg: ViTConfig, images):
    """images: (B, H, W, 3) -> logits (B, n_classes)."""
    B, Hh, Ww, _ = images.shape
    d = cfg.d_model
    x = lax.conv_general_dilated(
        images.astype(cfg.dtype), params["patch_embed"].astype(cfg.dtype),
        window_strides=(cfg.patch, cfg.patch), padding="VALID",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    x = (x.astype(f32) + params["patch_bias"].astype(f32)).astype(cfg.dtype)
    S = x.shape[1] * x.shape[2]
    x = x.reshape(B, S, d)
    cls = jnp.broadcast_to(params["cls_token"].astype(cfg.dtype), (B, 1, d))
    x = jnp.concatenate([cls, x], axis=1)
    pos = params["pos_embed"][: S + 1].astype(cfg.dtype)
    x = x + pos[None]

    def body(x, p):
        return _block(cfg, p, x), None

    fn = jax.checkpoint(body) if cfg.remat else body
    x, _ = lax.scan(fn, x, params["blocks"],
                    unroll=L.scan_unroll(cfg.n_layers))
    x = L.layer_norm(x, params["ln_f_w"], params["ln_f_b"])
    cls_tok = x[:, 0]
    logits = jnp.einsum("bd,dc->bc", cls_tok, params["head_w"],
                        preferred_element_type=f32) + params["head_b"].astype(f32)
    return logits


def features(params, cfg: ViTConfig, images):
    """Patch-token feature map (B, H/p, W/p, d) for detection heads."""
    B, Hh, Ww, _ = images.shape
    d = cfg.d_model
    x = lax.conv_general_dilated(
        images.astype(cfg.dtype), params["patch_embed"].astype(cfg.dtype),
        window_strides=(cfg.patch, cfg.patch), padding="VALID",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        ).astype(cfg.dtype)
    hp, wp = x.shape[1], x.shape[2]
    S = hp * wp
    x = x.reshape(B, S, d)
    cls = jnp.broadcast_to(params["cls_token"].astype(cfg.dtype), (B, 1, d))
    x = jnp.concatenate([cls, x], axis=1) + params["pos_embed"][: S + 1].astype(cfg.dtype)[None]

    def body(x, p):
        return _block(cfg, p, x), None

    fn = jax.checkpoint(body) if cfg.remat else body
    x, _ = lax.scan(fn, x, params["blocks"],
                    unroll=L.scan_unroll(cfg.n_layers))
    x = L.layer_norm(x, params["ln_f_w"], params["ln_f_b"])
    return x[:, 1:].reshape(B, hp, wp, d)


def loss_fn(params, cfg: ViTConfig, batch):
    logits = forward(params, cfg, batch["images"])
    from repro.models.transformer_lm import softmax_xent
    return softmax_xent(logits, batch["labels"])
