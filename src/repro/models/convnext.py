"""ConvNeXt-B: depths 3-3-27-3, dims 128-256-512-1024 [arXiv:2201.03545].

Block: 7x7 depthwise conv -> LayerNorm -> 1x1 (4x expand) -> GELU -> 1x1 ->
LayerScale -> residual.  Blocks within a stage are stacked + scanned.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import layers as L
from repro.models.params import spec

f32 = jnp.float32


@dataclasses.dataclass(frozen=True)
class ConvNeXtConfig:
    name: str
    depths: tuple[int, int, int, int] = (3, 3, 27, 3)
    dims: tuple[int, int, int, int] = (128, 256, 512, 1024)
    n_classes: int = 1000
    dtype: str = "bfloat16"
    ls_init: float = 1e-6

    def param_count(self) -> int:
        from repro.models.params import param_count
        return param_count(param_specs(self))


def param_specs(cfg: ConvNeXtConfig):
    dt = jnp.dtype(cfg.dtype)
    p = {
        "stem_conv": spec((4, 4, 3, cfg.dims[0]), (None, None, None, "tensor"),
                          dtype=dt, init="fan_in"),
        "stem_ln_w": spec((cfg.dims[0],), (None,), dtype=dt, init="ones"),
        "stem_ln_b": spec((cfg.dims[0],), (None,), dtype=dt, init="zeros"),
        "head_w": spec((cfg.dims[-1], cfg.n_classes), ("fsdp", "tensor"),
                       dtype=dt, init="fan_in"),
        "head_b": spec((cfg.n_classes,), ("tensor",), dtype=dt, init="zeros"),
        "final_ln_w": spec((cfg.dims[-1],), (None,), dtype=dt, init="ones"),
        "final_ln_b": spec((cfg.dims[-1],), (None,), dtype=dt, init="zeros"),
    }
    for si, (n, d) in enumerate(zip(cfg.depths, cfg.dims)):
        if si > 0:
            p[f"down{si}_ln_w"] = spec((cfg.dims[si - 1],), (None,), dtype=dt, init="ones")
            p[f"down{si}_ln_b"] = spec((cfg.dims[si - 1],), (None,), dtype=dt, init="zeros")
            p[f"down{si}_conv"] = spec((2, 2, cfg.dims[si - 1], d),
                                       (None, None, None, "tensor"), dtype=dt,
                                       init="fan_in")
        p[f"s{si}"] = {
            "dw": spec((n, 7, 7, 1, d), (None, None, None, None, "tensor"),
                       dtype=dt, init="fan_in"),
            "ln_w": spec((n, d), (None, None), dtype=dt, init="ones"),
            "ln_b": spec((n, d), (None, None), dtype=dt, init="zeros"),
            "w1": spec((n, d, 4 * d), (None, "fsdp", "tensor"), dtype=dt, init="fan_in"),
            "b1": spec((n, 4 * d), (None, "tensor"), dtype=dt, init="zeros"),
            "w2": spec((n, 4 * d, d), (None, "tensor", "fsdp"), dtype=dt, init="fan_in"),
            "b2": spec((n, d), (None, None), dtype=dt, init="zeros"),
            "gamma": spec((n, d), (None, None), dtype=dt, init="ones",
                          scale=cfg.ls_init),
        }
    return p


def _block(x, p):
    d = x.shape[-1]
    h = lax.conv_general_dilated(
        x, p["dw"].astype(x.dtype), window_strides=(1, 1), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"), feature_group_count=d,
        ).astype(x.dtype)
    h = L.layer_norm(h, p["ln_w"], p["ln_b"])
    h = jnp.einsum("bhwc,cf->bhwf", h, p["w1"], preferred_element_type=f32)
    h = jax.nn.gelu(h + p["b1"].astype(f32)).astype(x.dtype)
    h = jnp.einsum("bhwf,fc->bhwc", h, p["w2"])     # bf16 wire for TP psum
    h = (h.astype(f32) + p["b2"].astype(f32)) * p["gamma"].astype(f32)
    return L.constrain(x + h.astype(x.dtype), "batch", None, None, None)


def forward(params, cfg: ConvNeXtConfig, images):
    x = images.astype(cfg.dtype)
    x = lax.conv_general_dilated(
        x, params["stem_conv"].astype(x.dtype), window_strides=(4, 4),
        padding="VALID", dimension_numbers=("NHWC", "HWIO", "NHWC"),
        ).astype(cfg.dtype)
    x = L.layer_norm(x, params["stem_ln_w"], params["stem_ln_b"])
    for si in range(4):
        if si > 0:
            x = L.layer_norm(x, params[f"down{si}_ln_w"], params[f"down{si}_ln_b"])
            x = lax.conv_general_dilated(
                x, params[f"down{si}_conv"].astype(x.dtype),
                window_strides=(2, 2), padding="VALID",
                dimension_numbers=("NHWC", "HWIO", "NHWC"),
                ).astype(cfg.dtype)

        def body(x, p):
            return _block(x, p), None

        x, _ = lax.scan(jax.checkpoint(body), x, params[f"s{si}"],
                        unroll=L.scan_unroll(int(cfg.depths[si])))
    x = x.astype(f32).mean(axis=(1, 2)).astype(cfg.dtype)
    x = L.layer_norm(x[:, None], params["final_ln_w"], params["final_ln_b"])[:, 0]
    logits = jnp.einsum("bd,dc->bc", x, params["head_w"],
                        preferred_element_type=f32) + params["head_b"].astype(f32)
    return logits


def loss_fn(params, cfg: ConvNeXtConfig, batch):
    logits = forward(params, cfg, batch["images"])
    from repro.models.transformer_lm import softmax_xent
    return softmax_xent(logits, batch["labels"])
