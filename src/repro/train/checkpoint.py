"""Checkpointing: atomic, versioned, async-capable, mesh-elastic.

Save: gather every leaf to host (numpy) and write one .npz + a JSON
manifest (step, pytree structure, config fingerprint).  Writes go to a tmp
dir renamed atomically; optional async via a background thread (the train
loop keeps stepping while the previous state is flushed).

Restore: load on ANY mesh — leaves are re-device_put with the *target*
shardings, so a checkpoint taken on a (16, 16) mesh restarts fine on
(8, 16) after losing a slice (elastic scaling).  Divisibility is
re-validated per leaf; non-divisible dims demote to replicated.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time

import jax
import numpy as np

_SEP = "/"


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        out[key] = np.asarray(leaf)
    return out, treedef


def save(ckpt_dir: str, step: int, tree, *, keep: int = 3,
         blocking: bool = True, extra: dict | None = None):
    """Returns the final checkpoint path (or a join handle if async)."""
    flat, _ = _flatten(tree)
    tmp = os.path.join(ckpt_dir, f".tmp_step_{step}")
    final = os.path.join(ckpt_dir, f"step_{step}")

    def _write():
        os.makedirs(tmp, exist_ok=True)
        np.savez(os.path.join(tmp, "arrays.npz"), **flat)
        manifest = {"step": step, "time": time.time(),
                    "keys": sorted(flat.keys()), "extra": extra or {}}
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        _gc(ckpt_dir, keep)

    if blocking:
        _write()
        return final
    t = threading.Thread(target=_write, daemon=True)
    t.start()
    return t


def _gc(ckpt_dir: str, keep: int):
    steps = sorted(all_steps(ckpt_dir))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s}"), ignore_errors=True)


def all_steps(ckpt_dir: str):
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for d in os.listdir(ckpt_dir):
        if d.startswith("step_"):
            try:
                out.append(int(d.split("_", 1)[1]))
            except ValueError:
                pass
    return sorted(out)


def latest_step(ckpt_dir: str):
    steps = all_steps(ckpt_dir)
    return steps[-1] if steps else None


def restore(ckpt_dir: str, step: int, like_tree, *, shardings=None):
    """Restore into the structure of ``like_tree``.

    ``shardings``: optional matching pytree of NamedSharding — leaves are
    device_put with them (cross-mesh elastic restore); otherwise arrays
    land on the default device.
    """
    path = os.path.join(ckpt_dir, f"step_{step}")
    data = np.load(os.path.join(path, "arrays.npz"))
    flat, treedef = _flatten(like_tree)
    missing = [k for k in flat if k not in data.files]
    if missing:
        raise KeyError(f"checkpoint {path} missing keys: {missing[:5]}")
    leaves = []
    paths_like = jax.tree_util.tree_flatten_with_path(like_tree)[0]
    shard_leaves = (jax.tree_util.tree_leaves(
        shardings, is_leaf=lambda x: hasattr(x, "mesh"))
        if shardings is not None else [None] * len(paths_like))
    for (path_k, leaf), sh in zip(paths_like, shard_leaves):
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path_k)
        arr = data[key]
        if sh is not None:
            leaves.append(jax.device_put(arr, sh))
        else:
            leaves.append(jax.device_put(arr))
    return jax.tree_util.tree_unflatten(treedef, leaves)
