"""Fault tolerance: supervised restarts + elastic re-meshing.

``supervise`` wraps train.loop.run: on failure (a lost node surfaces as an
exception in the runner) it restores the latest checkpoint and continues —
optionally on a *smaller* mesh (elastic downscale), re-device_putting every
leaf with the new shardings.  Checkpoints are the source of truth; at
1000+ node scale this is the standard preempt/resume discipline, and the
async checkpoint path bounds lost work to ``ckpt_every`` steps.
"""
from __future__ import annotations

import dataclasses
from typing import Callable


from repro.train import loop as LOOP


@dataclasses.dataclass
class SuperviseResult:
    state: object
    history: list
    restarts: int


def supervise(make_step_and_state: Callable, data_factory: Callable,
              cfg: LOOP.LoopConfig, *, max_restarts: int = 3,
              fail_injector=None, on_restart=None) -> SuperviseResult:
    """make_step_and_state(attempt) -> (step_fn, state, state_shardings).

    Re-invoked per attempt so the caller can rebuild on a smaller mesh
    (elastic): the restore inside loop.run() re-shards the checkpoint onto
    whatever shardings the new attempt provides.
    """
    restarts = 0
    history_all = []
    while True:
        step_fn, state, shardings = make_step_and_state(restarts)
        try:
            state, hist = LOOP.run(
                step_fn, state, data_factory(), cfg,
                state_shardings=shardings,
                fail_injector=fail_injector if restarts == 0 else None)
            history_all.extend(hist)
            return SuperviseResult(state=state, history=history_all,
                                   restarts=restarts)
        except RuntimeError:
            restarts += 1
            if restarts > max_restarts:
                raise
            if on_restart:
                on_restart(restarts)
