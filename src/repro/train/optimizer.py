"""Sharded AdamW with global-norm clipping (pure JAX, pytree-based).

Moments live in fp32 and inherit each parameter's NamedSharding (same
logical axes), so optimizer state is ZeRO-sharded exactly like the params.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

f32 = jnp.float32


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_ratio: float = 0.1


def init_state(params):
    return {
        "mu": jax.tree.map(lambda p: jnp.zeros(p.shape, f32), params),
        "nu": jax.tree.map(lambda p: jnp.zeros(p.shape, f32), params),
        "step": jnp.zeros((), jnp.int32),
    }


def abstract_state(param_sds):
    """ShapeDtypeStructs for the optimizer state (dry-run; keeps shardings)."""
    def f32_like(p):
        sh = getattr(p, "sharding", None)
        if sh is not None:
            return jax.ShapeDtypeStruct(p.shape, f32, sharding=sh)
        return jax.ShapeDtypeStruct(p.shape, f32)

    return {
        "mu": jax.tree.map(f32_like, param_sds),
        "nu": jax.tree.map(f32_like, param_sds),
        "step": jax.ShapeDtypeStruct((), jnp.int32),
    }


def lr_schedule(cfg: AdamWConfig, step):
    step = step.astype(f32)
    warm = jnp.minimum(step / max(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def global_norm(tree):
    sq = sum(jnp.sum(jnp.square(g.astype(f32))) for g in jax.tree.leaves(tree))
    return jnp.sqrt(sq)


def apply_updates(params, grads, opt_state, cfg: AdamWConfig):
    """Returns (new_params, new_opt_state, metrics)."""
    step = opt_state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
    lr = lr_schedule(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(f32)
    b2c = 1 - cfg.b2 ** step.astype(f32)

    def upd(p, g, mu, nu):
        g = g.astype(f32) * scale
        mu = cfg.b1 * mu + (1 - cfg.b1) * g
        nu = cfg.b2 * nu + (1 - cfg.b2) * g * g
        mhat = mu / b1c
        nhat = nu / b2c
        delta = mhat / (jnp.sqrt(nhat) + cfg.eps)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            delta = delta + cfg.weight_decay * p.astype(f32)
        return (p.astype(f32) - lr * delta).astype(p.dtype), mu, nu

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_mu = jax.tree.leaves(opt_state["mu"])
    flat_nu = jax.tree.leaves(opt_state["nu"])
    out = [upd(p, g, m, n) for p, g, m, n in
           zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_p = jax.tree.unflatten(tdef, [o[0] for o in out])
    new_mu = jax.tree.unflatten(tdef, [o[1] for o in out])
    new_nu = jax.tree.unflatten(tdef, [o[2] for o in out])
    new_state = {"mu": new_mu, "nu": new_nu, "step": step}
    return new_p, new_state, {"grad_norm": gnorm, "lr": lr}
