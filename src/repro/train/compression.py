"""Gradient compression for cross-pod data parallelism.

Two composable schemes with error feedback (memory), applied to the DP
gradient all-reduce — the dominant cross-pod collective:

  * top-k sparsification (keep the largest |g| fraction, accumulate the
    rest into the error buffer),
  * int8 quantization (per-tensor scale, stochastic-rounding-free
    deterministic variant; residual into the error buffer).

Both preserve the descent direction in expectation; see EXPERIMENTS.md
§Perf for the measured wire-byte reduction on the multi-pod mesh.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

f32 = jnp.float32


@dataclasses.dataclass(frozen=True)
class CompressionConfig:
    scheme: str = "none"        # none | topk | int8 | topk_int8
    topk_fraction: float = 0.05


def init_error(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, f32), params)


def _topk_mask(g, fraction: float):
    k = max(int(g.size * fraction), 1)
    flat = jnp.abs(g.reshape(-1))
    thresh = jax.lax.top_k(flat, k)[0][-1]
    return (jnp.abs(g) >= thresh).astype(g.dtype)


def compress(cfg: CompressionConfig, grads, error):
    """Returns (compressed_grads, new_error).  Call BEFORE the DP psum."""
    if cfg.scheme == "none":
        return grads, error

    def one(g, e):
        g = g.astype(f32) + e
        out = g
        if "topk" in cfg.scheme:
            mask = _topk_mask(g, cfg.topk_fraction)
            out = g * mask
        if "int8" in cfg.scheme:
            scale = jnp.maximum(jnp.abs(out).max(), 1e-12) / 127.0
            q = jnp.clip(jnp.round(out / scale), -127, 127)
            out = q * scale
        return out, g - out

    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(error)
    pairs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (jax.tree.unflatten(tdef, [p[0] for p in pairs]),
            jax.tree.unflatten(tdef, [p[1] for p in pairs]))


def compressed_bytes(cfg: CompressionConfig, grads) -> int:
    """Wire-byte estimate for EXPERIMENTS.md (values + indices for topk)."""
    total = 0
    for g in jax.tree.leaves(grads):
        if cfg.scheme == "none":
            total += g.size * 4
        elif cfg.scheme == "topk":
            k = max(int(g.size * cfg.topk_fraction), 1)
            total += k * (4 + 4)
        elif cfg.scheme == "int8":
            total += g.size * 1 + 4
        elif cfg.scheme == "topk_int8":
            k = max(int(g.size * cfg.topk_fraction), 1)
            total += k * (1 + 4) + 4
    return total
