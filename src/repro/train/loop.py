"""Generic training loop: data pipeline -> sharded step -> checkpoints.

Production behaviors: periodic + final checkpointing (async), metric
logging, preemption-safe resume (auto-restart from the latest step), and
optional gradient compression on the DP axis.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Iterator, Optional

import numpy as np

from repro.train import checkpoint as CKPT

f32 = np.float32


@dataclasses.dataclass
class LoopConfig:
    total_steps: int
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 100
    log_every: int = 10
    async_ckpt: bool = False
    keep: int = 3


def run(step_fn: Callable, state, data_iter: Iterator, cfg: LoopConfig,
        *, state_shardings=None, on_metrics=None, fail_injector=None):
    """Runs the loop; returns (final_state, history).

    ``fail_injector(step) -> bool`` lets the fault-tolerance tests simulate
    node failures mid-run; the loop raises, and the supervisor restarts
    from the latest checkpoint (see train/fault_tolerance.py).
    """
    start = 0
    if cfg.ckpt_dir:
        last = CKPT.latest_step(cfg.ckpt_dir)
        if last is not None:
            state = CKPT.restore(cfg.ckpt_dir, last, state,
                                 shardings=state_shardings)
            start = last
    history = []
    t0 = time.time()
    for step in range(start, cfg.total_steps):
        if fail_injector is not None and fail_injector(step):
            raise RuntimeError(f"injected failure at step {step}")
        batch = next(data_iter)
        state, metrics = step_fn(state, batch)
        if (step + 1) % cfg.log_every == 0 or step + 1 == cfg.total_steps:
            m = {k: float(v) for k, v in metrics.items()}
            m["step"] = step + 1
            m["steps_per_s"] = (step + 1 - start) / max(time.time() - t0,
                                                        1e-9)
            history.append(m)
            if on_metrics:
                on_metrics(m)
        if cfg.ckpt_dir and ((step + 1) % cfg.ckpt_every == 0
                             or step + 1 == cfg.total_steps):
            CKPT.save(cfg.ckpt_dir, step + 1, state, keep=cfg.keep,
                      blocking=not cfg.async_ckpt)
    return state, history
