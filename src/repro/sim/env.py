"""Multi-stream video-analytics environment (chunk-granular).

One env step = one chunk (paper: 1 s of video) across all C streams:

  controller proportions -> per-stream bandwidth -> hybrid encoder (ladder
  + Eq.3 classification + JPEG anchors) -> network transmission ->
  hybrid decoder 3-pipeline execution -> accuracy + latency -> rewards.

Two accuracy backends:
  * ``analytic``  — calibrated F1 model (paper Fig. 3d / Fig. 10 shape:
    small objects degrade sharply with resolution; reuse decays with
    motion).  Fast: used for DRL training loops and unit tests.
  * ``detector``  — the real TinyDetector + full codec path end-to-end,
    dispatched through the fused encode->decode round-trip jit
    (``repro.core.roundtrip``): one device dispatch per
    (batch-signature, ladder-rung) stream group, source frames to HD
    detections without leaving the trace.
Both expose the same observation/reward interface (paper §V states).
"""
from __future__ import annotations

import dataclasses

import numpy as np
import jax.numpy as jnp

from repro.codec.rate_model import QUALITY_LADDER
from repro.core.classification import classify_frames
from repro.sim.network import TraceConfig, allocate, generate_trace
from repro.sim.video_source import generate_chunk_batched, group_by_signature

f32 = np.float32


@dataclasses.dataclass(frozen=True)
class EnvConfig:
    streams: tuple                      # tuple[StreamConfig, ...]
    chunk_frames: int = 8               # frames per chunk (30 in paper; 8 for CPU)
    fps: float = 30.0
    trace: TraceConfig = TraceConfig()
    accuracy_backend: str = "analytic"  # analytic | detector
    gpu_capacity_fps: float = 120.0     # AGGREGATE edge DNN throughput (fps)
    latency_tau: float = 1.0
    controller_interval: int = 10       # chunks between reallocations (10 s)
    seed: int = 0
    # stream-axis mesh shards (repro.distributed.stream_sharding): streams
    # map round-robin to shards, each owning gpu_capacity_fps / n_shards;
    # queue delay is per-shard, so a hot shard only slows ITS streams
    n_shards: int = 1
    # detector backend: anchor JPEG quality pinned into the fused
    # round-trip jit (static; the off-mode pin when anchor_search is off)
    anchor_quality: float = 70.0
    # optional repro.core.roi.RoiConfig: gates the fused detector onto the
    # top-K active regions scored from the codec's macroblock statistics
    roi: object | None = None
    # in-trace anchor-quality budget search (RoundtripConfig.anchor_search):
    # the fused round trip picks each anchor's JPEG quality from the
    # discrete ladder against its traced bandwidth share
    anchor_search: bool = False
    # optional repro.core.forecast.ForecastConfig: per-stream EWMA
    # rate/content forecast features appended to the high-level state so
    # the SAC controller can allocate ahead of demand instead of reactively
    forecast: object | None = None


# ---------------------------------------------------------------------------
# analytic accuracy model — calibrated to the paper's observations
# ---------------------------------------------------------------------------
def analytic_f1(scale: float, quality: float, obj_size_px: float,
                n_objects: int, pipeline: int, frames_since_infer: float,
                speed: float) -> float:
    """F1 estimate for one frame.

    Shape constraints from the paper:  Fig. 3(b) HD JPEG quality 40-80 is
    high-accuracy; Fig. 3(d)/Fig. 10 dense-small streams degrade sharply
    with resolution; Fig. 8(b) reuse decays with motion.
    """
    if pipeline == 2:
        # quality transfer pastes HD anchor blocks onto the LR frame:
        # recovers ~70% of the resolution gap and floors the codec quality
        # at the anchor's (paper Fig. 8a / Fig. 13a: -16% without it).
        scale = scale + 0.7 * (1.0 - scale)
        quality = max(quality, 60.0)
    eff = scale * obj_size_px                 # visible object extent (px)
    base = 1.0 / (1.0 + np.exp(-(eff - 8.0) / 3.0))   # resolution term
    qual = 1.0 / (1.0 + np.exp(-(quality - 25.0) / 12.0))  # codec term
    dense_pen = 1.0 - 0.004 * min(n_objects, 40)
    f1 = 0.98 * base * qual * dense_pen
    if pipeline == 3:                        # reuse decays with motion
        decay = 0.03 * speed * frames_since_infer
        f1 = f1 * max(1.0 - decay, 0.3)
    return float(np.clip(f1, 0.0, 1.0))


# ---------------------------------------------------------------------------
@dataclasses.dataclass
class StreamObs:
    """Paper §V-A low-level state S_c."""
    content: np.ndarray        # κ: 128-d key-frame feature
    frame_diff: np.ndarray     # X: (T,) diff features
    bitrate: float
    resolution: float
    allocations: np.ndarray    # b: (C,)
    queues: np.ndarray         # q: (2,)

    def vector(self) -> np.ndarray:
        return np.concatenate([
            self.content, self.frame_diff,
            [self.bitrate / 5000.0, self.resolution],
            self.allocations, self.queues / 100.0]).astype(f32)


def low_state_dim(cfg: EnvConfig) -> int:
    return 128 + cfg.chunk_frames + 2 + len(cfg.streams) + 2


def low_alloc_offset(cfg: EnvConfig) -> int:
    """Column where the (C,) allocation block starts inside the low-level
    state vector — the fused ``bilevel_step`` writes the controller's
    in-trace proportions there (the only state component that depends on
    the controller action, so everything else batches host-side)."""
    return 128 + cfg.chunk_frames + 2


def high_state_dim(cfg: EnvConfig) -> int:
    C = len(cfg.streams)
    # num, size, residual, prev alloc, acc, anchor fraction  (paper §V-B),
    # plus the forecast head's features when predictive control is on
    base = 6 * C
    if cfg.forecast is not None:
        from repro.core.forecast import forecast_dim
        base += forecast_dim(C)
    return base


class MultiStreamEnv:
    def __init__(self, cfg: EnvConfig, detector=None, faults=None):
        """``faults`` (a ``repro.serving.faults.FaultSchedule``) arms the
        chaos plane: bandwidth collapses/outages scale the trace, and
        stream churn (leave/join) plus camera stalls mask streams out of
        each step — offline streams get placeholder results and zero
        allocation instead of silently consuming bandwidth."""
        self.cfg = cfg
        self.faults = faults
        self.C = len(cfg.streams)
        self.trace = generate_trace(cfg.trace, 100_000)
        self.t = 0
        # (n_shards, 2) ①/② backlogs per mesh shard; the observation keeps
        # the paper's 2-d aggregate view (sum over shards)
        self.shard_queues = np.zeros((max(cfg.n_shards, 1), 2), f32)
        self.prev_alloc = np.full(self.C, 1.0 / self.C, f32)
        self.prev_acc = np.full(self.C, 0.5, f32)
        self.prev_anchor_frac = np.full(self.C, 0.1, f32)
        self.detector = detector
        self._rng = np.random.default_rng(cfg.seed)
        self._chunk_cache = {}
        self._rt_cfg = None         # lazy RoundtripConfig (rungs are data)
        if cfg.forecast is not None:
            from repro.core.forecast import StreamForecaster
            self.forecaster = StreamForecaster(cfg.forecast, self.C)
        else:
            self.forecaster = None

    @property
    def queues(self) -> np.ndarray:
        """Aggregate (2,) ①/② depths — the paper's §V-A observation."""
        return self.shard_queues.sum(axis=0)

    def stream_shard(self, c: int) -> int:
        return c % self.shard_queues.shape[0]

    # ------------------------------------------------------------------
    def _chunks_for_step(self) -> dict:
        """All streams' chunks for the current step, produced in batched
        vmapped renders — one device dispatch per (H, W, N) signature
        group instead of one per stream.  Content is bit-identical to the
        per-stream ``generate_chunk`` (same seed-derived params)."""
        if self._chunk_cache.get("t") != self.t:
            t0 = self.t * self.cfg.chunk_frames
            groups = group_by_signature(self.cfg.streams)
            data = {}
            for ids in groups.values():
                fr, bx, vd = generate_chunk_batched(
                    [self.cfg.streams[c] for c in ids], t0,
                    self.cfg.chunk_frames)
                fr, bx, vd = np.asarray(fr), np.asarray(bx), np.asarray(vd)
                for i, c in enumerate(ids):
                    data[c] = (fr[i], bx[i], vd[i])
            self._chunk_cache = {"t": self.t, "data": data}
        return self._chunk_cache["data"]

    def _chunk(self, c: int):
        return self._chunks_for_step()[c]

    def total_bandwidth(self) -> float:
        bw = float(self.trace[self.t % len(self.trace)])
        if self.faults is not None:
            bw = max(bw * self.faults.bw_multiplier(self.t), 1.0)
        return bw

    # ------------------------------------------------------------------
    def _low_features(self, frames) -> tuple:
        """(content grid, frame-diff) features of one chunk — the
        allocation-independent part of S_c, shared by the per-stream and
        batched observers (identical numpy expressions, so the two paths
        are bit-identical)."""
        key_frame = frames[0]
        h, w = key_frame.shape
        grid = key_frame[: h // 8 * 8, : w // 16 * 16].reshape(
            8, h // 8, 16, w // 16).mean(axis=(1, 3)) / 255.0
        fd = np.abs(np.diff(frames, axis=0)).mean(axis=(1, 2)) / 255.0
        fd = np.concatenate([[0.0], fd])
        return grid.reshape(-1).astype(f32), fd.astype(f32)

    def observe_low(self, c: int, allocations) -> np.ndarray:
        frames, _, _ = self._chunk(c)
        content, fd = self._low_features(frames)
        level = QUALITY_LADDER[0]
        obs = StreamObs(content=content, frame_diff=fd,
                        bitrate=level.bitrate_kbps, resolution=level.scale,
                        allocations=np.asarray(allocations, f32),
                        queues=self.queues.copy())
        return obs.vector()

    def observe_low_batched(self, allocations=None) -> np.ndarray:
        """All C low-level states as one (C, sdim) array — the batched
        observation the stacked control plane consumes in a single call
        (bit-identical rows to :meth:`observe_low`).

        ``allocations=None`` zeroes the allocation block: the fused
        ``bilevel_step`` computes the controller proportions INSIDE its
        trace and writes them at ``low_alloc_offset`` itself.
        """
        C = self.C
        if allocations is None:
            allocations = np.zeros(C, f32)
        return np.stack([self.observe_low(c, allocations)
                         for c in range(C)])

    def observe_high(self) -> np.ndarray:
        """Paper §V-B state: num, size, residual, prev alloc, acc, anchors."""
        nums, sizes, resid = [], [], []
        for c in range(self.C):
            sc = self.cfg.streams[c]
            frames, boxes, valid = self._chunk(c)
            nums.append(valid[0].sum() / 40.0)
            sizes.append(boxes[0, :, 2:].mean() / sc.height)
            resid.append(np.abs(np.diff(frames, axis=0)).mean() / 255.0)
        parts = [nums, sizes, resid, self.prev_alloc, self.prev_acc,
                 self.prev_anchor_frac]
        if self.forecaster is not None:
            parts.append(self.forecaster.features())
        return np.concatenate(parts).astype(f32)

    # ------------------------------------------------------------------
    def step(self, proportions: np.ndarray, thresholds: np.ndarray):
        """One chunk for all streams.

        proportions: (C,) controller action; thresholds: (C, 2) per-stream
        low-level actions (tr1, tr2).  Returns per-stream dicts + info.
        """
        cfg = self.cfg
        total_bw = self.total_bandwidth()
        if self.faults is not None:
            live = self.faults.active_mask(self.t, self.C)
            stalled = np.asarray([self.faults.stalled(c, self.t)
                                  for c in range(self.C)], bool)
        else:
            live = np.ones(self.C, bool)
            stalled = np.zeros(self.C, bool)
        serve = live & ~stalled
        # offline streams surrender their bandwidth share (allocate floors
        # proportions at 1e-6, so their residual share is negligible)
        props = np.where(live, np.asarray(proportions, np.float64), 0.0)
        alloc = allocate(total_bw, props)
        if cfg.accuracy_backend == "detector" and self.detector is not None:
            results = self._run_streams_roundtrip(alloc, thresholds,
                                                  serve=serve)
        else:
            results = [None] * self.C
            for c in range(self.C):
                if not serve[c]:
                    continue
                frames, boxes, valid = self._chunk(c)
                tr1, tr2 = float(thresholds[c, 0]), float(thresholds[c, 1])
                results[c] = self._run_stream(c, frames, boxes, valid,
                                              alloc[c], tr1, tr2)
        for c in range(self.C):
            if results[c] is None:
                results[c] = self._offline_result(c, alloc[c],
                                                  bool(stalled[c]))

        # edge GPU queue dynamics, per mesh shard: each shard serves its
        # own slice of capacity, and a stream's queueing delay comes from
        # ITS shard only (identical to the legacy global queue at
        # n_shards=1 since the round-robin map is then the identity)
        n_sh = self.shard_queues.shape[0]
        dt = cfg.chunk_frames / cfg.fps
        served = cfg.gpu_capacity_fps / n_sh * dt
        arrivals = np.zeros((n_sh, 2), f32)
        for c, r in enumerate(results):
            arrivals[self.stream_shard(c), 0] += r["n_anchor"]
            arrivals[self.stream_shard(c), 1] += r["n_transfer"]
        self.shard_queues[:, 0] = np.maximum(
            self.shard_queues[:, 0] + arrivals[:, 0] - served * 0.6, 0.0)
        self.shard_queues[:, 1] = np.maximum(
            self.shard_queues[:, 1] + arrivals[:, 1] - served * 0.4, 0.0)
        shard_capacity = cfg.gpu_capacity_fps / n_sh
        queue_delay = float(self.queues.sum() / cfg.gpu_capacity_fps)
        for c, r in enumerate(results):
            r["queue_delay"] = float(
                self.shard_queues[self.stream_shard(c)].sum()
                / shard_capacity)
            r["latency"] += r["queue_delay"]
            r["reward"] = float(
                0.5 * r["accuracy"]
                - 0.5 * (r["latency"] > cfg.latency_tau))

        self.prev_alloc = np.asarray(proportions, f32)
        self.prev_acc = np.asarray([r["accuracy"] for r in results], f32)
        self.prev_anchor_frac = np.asarray(
            [r["n_anchor"] / cfg.chunk_frames for r in results], f32)
        if self.forecaster is not None:
            # fold this chunk's observed rate + achieved bits into the
            # forecast head (updates live in step, never in observe, so
            # observation is side-effect free on both control-plane paths)
            self.forecaster.update(
                np.asarray([r["bw_kbps"] for r in results], f32),
                np.asarray([r["bits"] for r in results], f32))
        self.t += 1
        info = {"total_bw": total_bw, "alloc": alloc,
                "queue_delay": queue_delay,
                "active_mask": live, "stalled_mask": stalled}
        return results, info

    def _offline_result(self, c: int, bw_kbps: float,
                        stalled: bool) -> dict:
        """Placeholder row for a stream that produced no chunk this step
        (left the pool, hasn't joined yet, or its camera stalled) — keeps
        results length C and makes absence explicit instead of silent."""
        types = np.zeros(self.cfg.chunk_frames, np.int64)
        return {"stream": c, "accuracy": 0.0, "latency": 0.0,
                "t_trans": 0.0, "t_comp": 0.0, "bits": 0.0, "types": types,
                "n_anchor": 0, "n_transfer": 0, "n_infer": 0,
                "bw_kbps": float(bw_kbps), "utilization": 0.0,
                "offline": not stalled, "stalled": stalled}

    # ------------------------------------------------------------------
    def _run_stream(self, c, frames, boxes, valid, bw_kbps, tr1, tr2):
        cfg = self.cfg
        sc = cfg.streams[c]
        # ---- analytic fast path: classification from raw frame features
        fd = np.abs(np.diff(frames, axis=0)).mean(axis=(1, 2)) / 255.0
        fd = np.concatenate([[0.0], fd])
        rm = fd * 0.8 + 0.02
        types, _, _ = classify_frames(jnp.asarray(fd), jnp.asarray(rm),
                                      tr1, tr2)
        types = np.asarray(types).copy()
        from repro.codec.rate_model import ladder_for_bandwidth
        chunk_s = cfg.chunk_frames / cfg.fps
        budget_bits = bw_kbps * 1000.0 * chunk_s
        video_floor = QUALITY_LADDER[0].bitrate_kbps * 1000.0 * chunk_s
        afford = max(int((budget_bits - video_floor) / 45_000.0), 1)
        anchor_ids = np.nonzero(types == 1)[0]
        if len(anchor_ids) > afford:
            for i in anchor_ids[afford:]:
                types[i] = 2
        n_anchors = int((types == 1).sum())
        level = ladder_for_bandwidth(
            max(bw_kbps - n_anchors * 45.0 / chunk_s, 0.0))
        ql = QUALITY_LADDER[level]
        obj_size = float(boxes[0, :, 2:].mean())
        n_obj = int(valid[0].sum())
        accs, since, last = [], 0.0, 0.0
        for t, ty in enumerate(types):
            if ty != 3:
                since = 0.0
                scale = 1.0 if ty == 1 else ql.scale
                qual = 80.0 if ty == 1 else ql.quality
                last = analytic_f1(scale, qual, obj_size, n_obj, int(ty),
                                   0.0, sc.speed)
                accs.append(last)
            else:
                since += 1.0
                accs.append(last * max(1.0 - 0.03 * sc.speed * since, 0.3))
        n1 = int((types == 1).sum())
        n2 = int((types == 2).sum())
        # bit model: ladder bitrate for video + JPEG anchors ~ 45 kbit each
        chunk_s = cfg.chunk_frames / cfg.fps
        bits = ql.bitrate_kbps * 1000.0 * chunk_s \
            + n1 * 45_000.0 * (sc.height * sc.width) / (96.0 * 160.0)
        t_trans = bits / max(bw_kbps * 1000.0, 1e-6)
        t_comp = n1 * 0.037 + n2 * 0.045 + int((types == 3).sum()) * 0.006
        return {"stream": c, "accuracy": float(np.mean(accs)),
                "latency": t_trans + t_comp, "t_trans": t_trans,
                "t_comp": t_comp, "bits": bits, "types": types,
                "n_anchor": n1, "n_transfer": n2, "n_infer": n1 + n2,
                "bw_kbps": float(bw_kbps),
                "utilization": min(bits / max(bw_kbps * 1000.0 * chunk_s,
                                              1e-6), 1.0)}

    def _roundtrip_cfg(self):
        """The env's RoundtripConfig (static jit argument; rungs travel
        as data through the shape-stable entry, so one config serves all
        ladder levels)."""
        if self._rt_cfg is None:
            from repro.core.roundtrip import RoundtripConfig
            _, det_cfg = self.detector
            self._rt_cfg = RoundtripConfig(
                det_cfg=det_cfg, anchor_quality=self.cfg.anchor_quality,
                fps=self.cfg.fps, roi=self.cfg.roi,
                anchor_search=self.cfg.anchor_search)
        return self._rt_cfg

    def _run_streams_roundtrip(self, alloc, thresholds,
                               serve=None) -> list:
        """Detector backend: ONE fused round-trip dispatch per
        batch-signature group — source frames to HD detections without
        leaving the trace (``repro.core.roundtrip``), instead of the
        legacy per-stream encode_hybrid + decode_and_execute_fused host
        loop.  Each stream's ladder rung rides along as DATA
        (``roundtrip_padded_batched``: eager per-rung downscale, fixed
        full-size LR canvas, per-stream extents/QPs), so per-step
        bandwidth reallocation never retraces — compile churn is bounded
        at one trace per signature, not per (rung-combination, size).
        """
        from repro.codec.rate_model import (QUALITY_LADDER, downscale,
                                            ladder_for_bandwidth,
                                            video_bandwidth_share)
        from repro.core.roundtrip import (full_lr_canvas,
                                          ladder_batch_arrays,
                                          roundtrip_padded_batched)
        det_params, _ = self.detector
        cfg = self.cfg
        chunks = self._chunks_for_step()
        # encode_hybrid's ladder selection: anchor headroom comes off first
        level = {c: ladder_for_bandwidth(video_bandwidth_share(alloc[c]))
                 for c in range(self.C)}

        chunk_s = cfg.chunk_frames / cfg.fps
        results = [None] * self.C
        # dispatch EVERY signature group before materializing any result:
        # JAX async dispatch lets group k+1's host-side staging overlap
        # group k's device computation; the np.asarray transfers below
        # only happen once all groups are in flight
        in_flight = []
        for sig, ids in group_by_signature(cfg.streams).items():
            if serve is not None:
                ids = [c for c in ids if serve[c]]
                if not ids:
                    continue
            H, W = sig[0], sig[1]
            hp, wp = full_lr_canvas(H, W)
            extents, quals = ladder_batch_arrays(
                [level[c] for c in ids], H, W)
            lr_pad = []
            for i, c in enumerate(ids):
                lr = downscale(jnp.asarray(chunks[c][0], f32),
                               QUALITY_LADDER[level[c]].scale)
                h, w = int(extents[i, 0]), int(extents[i, 1])
                lr_pad.append(jnp.pad(lr, ((0, 0), (0, hp - h),
                                           (0, wp - w))))
            raw = jnp.stack([jnp.asarray(chunks[c][0], f32) for c in ids])
            gtb = jnp.stack([jnp.asarray(chunks[c][1]) for c in ids])
            gtv = jnp.stack([jnp.asarray(chunks[c][2]) for c in ids])
            out = roundtrip_padded_batched(
                raw, jnp.stack(lr_pad), extents, quals, gtb, gtv,
                det_params,
                tr1=jnp.asarray([thresholds[c, 0] for c in ids], f32),
                tr2=jnp.asarray([thresholds[c, 1] for c in ids], f32),
                bw_kbps=jnp.asarray([alloc[c] for c in ids], f32),
                queue_delay=jnp.zeros((len(ids),), f32),
                cfg=self._roundtrip_cfg())
            in_flight.append((ids, out))
        for ids, out in in_flight:
            for i, c in enumerate(ids):
                types = np.asarray(out["types"][i])
                bits = float(out["total_bits"][i])
                bw = float(alloc[c])
                results[c] = {
                    "stream": c, "accuracy": float(out["mean_f1"][i]),
                    "latency": float(out["latency"][i]),
                    "t_trans": float(out["t_trans"][i]),
                    "t_comp": float(out["t_comp"][i]), "bits": bits,
                    "types": types,
                    "n_anchor": int((types == 1).sum()),
                    "n_transfer": int((types == 2).sum()),
                    "n_infer": int((types != 3).sum()),
                    "bw_kbps": bw,
                    "utilization": min(bits / max(bw * 1000.0 * chunk_s,
                                                  1e-6), 1.0)}
        return results
