"""Synthetic surveillance-style video sources.

Streams are parameterized by object count and size to reproduce the paper's
heterogeneity (§III Fig. 3d): stream 1 = few large objects (robust to low
resolution), stream 2 = many small objects (needs bandwidth).  Objects are
textured rectangles moving over a structured background; ground-truth boxes
are emitted per frame for F1 scoring.

The renderer is split from the per-stream parameter derivation so the
producer side can batch: ``generate_chunk`` renders one stream;
``generate_chunk_batched`` stacks the derived object parameters for
shape-compatible streams and renders them all in ONE vmapped jit — the
same leading "stream" axis discipline as ``encode_chunk_batched`` /
``decode_execute_batched`` downstream.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

f32 = jnp.float32


@dataclasses.dataclass(frozen=True)
class StreamConfig:
    name: str = "stream"
    height: int = 96
    width: int = 160
    n_objects: int = 4
    min_size: int = 12
    max_size: int = 28
    speed: float = 2.0            # px / frame
    texture_contrast: float = 90.0
    background_level: float = 110.0
    seed: int = 0

    @property
    def max_objects(self) -> int:
        return self.n_objects

    @property
    def batch_signature(self) -> tuple:
        """Streams with equal signatures render with identical shapes and
        can share one ``generate_chunk_batched`` dispatch."""
        return (self.height, self.width, self.n_objects)


# Paper-style heterogeneous stream mix: "stream 1" large+sparse,
# "stream 2" small+dense (Fig. 3d / Fig. 10).
def paper_stream_mix(n_streams: int, height: int = 96, width: int = 160):
    mix = []
    for i in range(n_streams):
        if i % 2 == 0:
            mix.append(StreamConfig(name=f"sparse_{i}", height=height,
                                    width=width, n_objects=3, min_size=20,
                                    max_size=32, speed=1.5, seed=100 + i))
        else:
            # dense-small: detectable at HD but fragile below ~2/3 scale
            # (paper Fig. 3d / Fig. 10's "stream 2" regime)
            mix.append(StreamConfig(name=f"dense_{i}", height=height,
                                    width=width, n_objects=12, min_size=10,
                                    max_size=16, speed=3.0, seed=200 + i))
    return mix


# Scenario presets for the ROI-gating benchmarks (fig. 14 style): named
# content regimes with very different active-region densities, so the
# relevance gate's win (sparse) and its saturation point (dense) are both
# exercised by the same harness.
def scenario_streams(scenario: str, n_streams: int = 1, height: int = 96,
                     width: int = 160) -> list[StreamConfig]:
    """Named content scenarios -> per-stream configs.

    ``sparse-highway``: a couple of large fast objects on a bright, mostly
    static background — most regions are idle, the ROI gate's best case.
    ``crowded-crossroad``: many small slow objects spread over the frame —
    activity everywhere, the gate's stress case.  ``day-night-mix``:
    alternating bright/low-light streams (contrast drops at night, so the
    residual term of the relevance head carries more of the signal).
    """
    if scenario == "sparse-highway":
        return [StreamConfig(name=f"highway_{i}", height=height,
                             width=width, n_objects=2, min_size=18,
                             max_size=30, speed=4.0, texture_contrast=80.0,
                             background_level=150.0, seed=300 + i)
                for i in range(n_streams)]
    if scenario == "crowded-crossroad":
        return [StreamConfig(name=f"crossroad_{i}", height=height,
                             width=width, n_objects=14, min_size=8,
                             max_size=14, speed=1.5, texture_contrast=70.0,
                             background_level=110.0, seed=400 + i)
                for i in range(n_streams)]
    if scenario == "day-night-mix":
        return [StreamConfig(
            name=f"{'day' if i % 2 == 0 else 'night'}_{i}", height=height,
            width=width, n_objects=6, min_size=10, max_size=20, speed=2.0,
            texture_contrast=90.0 if i % 2 == 0 else 40.0,
            background_level=120.0 if i % 2 == 0 else 35.0, seed=500 + i)
            for i in range(n_streams)]
    raise ValueError(
        f"unknown scenario {scenario!r} (expected 'sparse-highway', "
        "'crowded-crossroad' or 'day-night-mix')")


def _background(key, cfg: StreamConfig):
    H, W = cfg.height, cfg.width
    yy = jnp.linspace(0, 1, H)[:, None]
    xx = jnp.linspace(0, 1, W)[None, :]
    base = cfg.background_level + 25.0 * jnp.sin(6.28 * 2 * xx) \
        + 15.0 * yy * 40.0 / 40.0
    noise = jax.random.normal(key, (H, W), f32) * 4.0
    return base + noise


def _object_params(cfg: StreamConfig) -> dict:
    """Seed-derived per-stream object/background state (all arrays, so a
    list of these stacks into one batched pytree)."""
    H, W = cfg.height, cfg.width
    N = cfg.n_objects
    kobj = jax.random.PRNGKey(cfg.seed)
    k1, k2, k3, k4, kbg = jax.random.split(kobj, 5)
    return dict(
        pos0=jax.random.uniform(k1, (N, 2), f32) * jnp.array([H, W], f32),
        vel=(jax.random.uniform(k2, (N, 2), f32) - 0.5) * 2 * cfg.speed,
        size=jax.random.uniform(k3, (N, 2), f32)
        * (cfg.max_size - cfg.min_size) + cfg.min_size,
        tex_phase=jax.random.uniform(k4, (N,), f32) * 6.28,
        bg=_background(kbg, cfg),
        tex_contrast=jnp.asarray(cfg.texture_contrast, f32),
    )


def _render_chunk(params: dict, t0, n_frames: int, H: int, W: int):
    """Pure traced renderer shared by the single-stream path and the
    vmapped batched producer."""
    pos0, vel, size = params["pos0"], params["vel"], params["size"]
    tex_phase, bg = params["tex_phase"], params["bg"]

    t = t0 + jnp.arange(n_frames, dtype=f32)[:, None, None]     # (T,1,1)
    # positions bounce off walls via triangular wave
    span = jnp.array([H, W], f32) - size                        # (N,2)
    raw = pos0[None] + vel[None] * t                            # (T,N,2)
    period = 2 * jnp.maximum(span, 1.0)
    tri = jnp.abs(jnp.mod(raw, period[None]) - span[None])
    center = tri + size[None] / 2                               # (T,N,2) cy,cx

    yy = jnp.arange(H, dtype=f32)[None, None, :, None]
    xx = jnp.arange(W, dtype=f32)[None, None, None, :]
    cy = center[..., 0][:, :, None, None]
    cx = center[..., 1][:, :, None, None]
    hh = size[None, :, 0, None, None] / 2
    ww = size[None, :, 1, None, None] / 2
    inside = ((jnp.abs(yy - cy) <= hh) & (jnp.abs(xx - cx) <= ww))  # (T,N,H,W)
    tex = params["tex_contrast"] * jnp.sign(
        jnp.sin(0.8 * yy + tex_phase[None, :, None, None])
        * jnp.sin(0.8 * xx + tex_phase[None, :, None, None]))
    obj_pix = jnp.where(inside, 40.0 + jnp.abs(tex), 0.0)
    frames = jnp.clip(bg[None] + obj_pix.max(axis=1), 0.0, 255.0)

    boxes = jnp.concatenate([center, jnp.broadcast_to(
        size[None], center.shape)], axis=-1)                     # (T,N,4)
    valid = jnp.ones((n_frames, params["pos0"].shape[0]), bool)
    return frames, boxes, valid


def generate_chunk(key, cfg: StreamConfig, t0: int, n_frames: int):
    """Returns (frames (T,H,W) [0..255], boxes (T,N,4) cxcywh px, valid (T,N)).

    Deterministic in (cfg.seed, t0) so consecutive chunks are continuous.
    """
    return _render_chunk(_object_params(cfg), t0, n_frames,
                         cfg.height, cfg.width)


@partial(jax.jit, static_argnums=(2, 3, 4))
def _render_batched(params, t0, n_frames: int, H: int, W: int):
    return jax.vmap(lambda p: _render_chunk(p, t0, n_frames, H, W))(params)


def group_by_signature(cfgs) -> dict:
    """Stream indices grouped by ``batch_signature`` (insertion-ordered).

    The producer AND the fused round-trip dispatch batch per group: every
    stream in a group shares one padded shape, so one vmapped device
    dispatch serves the whole group (``repro.sim.env`` uses this for both
    ``generate_chunk_batched`` renders and ``roundtrip_batched`` calls).
    """
    groups: dict = {}
    for i, sc in enumerate(cfgs):
        groups.setdefault(sc.batch_signature, []).append(i)
    return groups


def generate_chunk_batched(cfgs, t0: int, n_frames: int):
    """Render S shape-compatible streams in one vmapped jit.

    cfgs: sequence of StreamConfig sharing one ``batch_signature``
    (height, width, n_objects) — heterogeneous mixes group by signature
    first (see ``repro.sim.env``).  Returns (frames (S,T,H,W),
    boxes (S,T,N,4), valid (S,T,N)), each stream bit-identical to its
    ``generate_chunk`` render.
    """
    sigs = {cfg.batch_signature for cfg in cfgs}
    if len(sigs) != 1:
        raise ValueError(
            f"generate_chunk_batched needs one shape signature, got {sigs}; "
            "group heterogeneous stream mixes by cfg.batch_signature")
    H, W, _ = next(iter(sigs))
    params = [_object_params(cfg) for cfg in cfgs]
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *params)
    return _render_batched(stacked, jnp.asarray(t0, f32), n_frames, H, W)
