"""Synthetic surveillance-style video sources.

Streams are parameterized by object count and size to reproduce the paper's
heterogeneity (§III Fig. 3d): stream 1 = few large objects (robust to low
resolution), stream 2 = many small objects (needs bandwidth).  Objects are
textured rectangles moving over a structured background; ground-truth boxes
are emitted per frame for F1 scoring.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

f32 = jnp.float32


@dataclasses.dataclass(frozen=True)
class StreamConfig:
    name: str = "stream"
    height: int = 96
    width: int = 160
    n_objects: int = 4
    min_size: int = 12
    max_size: int = 28
    speed: float = 2.0            # px / frame
    texture_contrast: float = 90.0
    background_level: float = 110.0
    seed: int = 0

    @property
    def max_objects(self) -> int:
        return self.n_objects


# Paper-style heterogeneous stream mix: "stream 1" large+sparse,
# "stream 2" small+dense (Fig. 3d / Fig. 10).
def paper_stream_mix(n_streams: int, height: int = 96, width: int = 160):
    mix = []
    for i in range(n_streams):
        if i % 2 == 0:
            mix.append(StreamConfig(name=f"sparse_{i}", height=height,
                                    width=width, n_objects=3, min_size=20,
                                    max_size=32, speed=1.5, seed=100 + i))
        else:
            # dense-small: detectable at HD but fragile below ~2/3 scale
            # (paper Fig. 3d / Fig. 10's "stream 2" regime)
            mix.append(StreamConfig(name=f"dense_{i}", height=height,
                                    width=width, n_objects=12, min_size=10,
                                    max_size=16, speed=3.0, seed=200 + i))
    return mix


def _background(key, cfg: StreamConfig):
    H, W = cfg.height, cfg.width
    yy = jnp.linspace(0, 1, H)[:, None]
    xx = jnp.linspace(0, 1, W)[None, :]
    base = cfg.background_level + 25.0 * jnp.sin(6.28 * 2 * xx) \
        + 15.0 * yy * 40.0 / 40.0
    noise = jax.random.normal(key, (H, W), f32) * 4.0
    return base + noise


def generate_chunk(key, cfg: StreamConfig, t0: int, n_frames: int):
    """Returns (frames (T,H,W) [0..255], boxes (T,N,4) cxcywh px, valid (T,N)).

    Deterministic in (cfg.seed, t0) so consecutive chunks are continuous.
    """
    H, W = cfg.height, cfg.width
    N = cfg.n_objects
    kobj = jax.random.PRNGKey(cfg.seed)
    k1, k2, k3, k4, kbg = jax.random.split(kobj, 5)
    pos0 = jax.random.uniform(k1, (N, 2), f32) * jnp.array([H, W], f32)
    vel = (jax.random.uniform(k2, (N, 2), f32) - 0.5) * 2 * cfg.speed
    size = jax.random.uniform(k3, (N, 2), f32) * (cfg.max_size - cfg.min_size) \
        + cfg.min_size
    tex_phase = jax.random.uniform(k4, (N,), f32) * 6.28
    bg = _background(kbg, cfg)

    t = t0 + jnp.arange(n_frames, dtype=f32)[:, None, None]     # (T,1,1)
    # positions bounce off walls via triangular wave
    span = jnp.array([H, W], f32) - size                        # (N,2)
    raw = pos0[None] + vel[None] * t                            # (T,N,2)
    period = 2 * jnp.maximum(span, 1.0)
    tri = jnp.abs(jnp.mod(raw, period[None]) - span[None])
    center = tri + size[None] / 2                               # (T,N,2) cy,cx

    yy = jnp.arange(H, dtype=f32)[None, None, :, None]
    xx = jnp.arange(W, dtype=f32)[None, None, None, :]
    cy = center[..., 0][:, :, None, None]
    cx = center[..., 1][:, :, None, None]
    hh = size[None, :, 0, None, None] / 2
    ww = size[None, :, 1, None, None] / 2
    inside = ((jnp.abs(yy - cy) <= hh) & (jnp.abs(xx - cx) <= ww))  # (T,N,H,W)
    tex = cfg.texture_contrast * jnp.sign(
        jnp.sin(0.8 * yy + tex_phase[None, :, None, None])
        * jnp.sin(0.8 * xx + tex_phase[None, :, None, None]))
    obj_pix = jnp.where(inside, 40.0 + jnp.abs(tex), 0.0)
    frames = jnp.clip(bg[None] + obj_pix.max(axis=1), 0.0, 255.0)

    boxes = jnp.concatenate([center, jnp.broadcast_to(
        size[None], center.shape)], axis=-1)                     # (T,N,4)
    valid = jnp.ones((n_frames, N), bool)
    return frames, boxes, valid
