"""FCC-broadband-style bandwidth traces + shared-uplink simulation.

The paper drives the total available bandwidth from an FCC trace (§VI-A)
and shapes per-camera links with WonderShaper.  Here: a stochastic trace
generator whose marginals mimic FCC fixed-broadband uplink measurements
(log-normal levels, AR(1) temporal correlation, occasional drops), plus a
shared-uplink splitter applying the controller's allocation vector, plus
the chaos-harness hook (:func:`apply_fault_profile`) that composes a
fault schedule's per-chunk multipliers — bandwidth collapses, correlated
outage bursts (``repro.serving.faults``) — onto a clean trace.

``generate_trace`` is vectorized (the AR(1) recurrence in blocked
cumulative form) so 100k-step soak traces cost milliseconds instead of a
Python loop; ``generate_trace_loop`` keeps the step-by-step recurrence as
the reference implementation.  Both draw randomness identically (one
batched normal draw + one batched uniform draw), so they agree to fp
rounding of the recurrence itself — the documented tolerance contract in
``tests/test_faults.py``.  NOTE: the pre-chaos-PR generator interleaved
its RNG draws per step, so traces for a given seed differ from that
version (same marginal distribution).
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class TraceConfig:
    mean_kbps: float = 16000.0   # paper evaluates 8/16 Mbps uplinks
    std_log: float = 0.25
    ar: float = 0.9              # AR(1) coefficient
    drop_prob: float = 0.02      # transient dips
    drop_factor: float = 0.3
    floor_kbps: float = 1000.0
    seed: int = 0


def _draws(cfg: TraceConfig, n_steps: int) -> tuple[np.ndarray, np.ndarray]:
    """The (normals, uniforms) both trace generators consume — drawn in
    one batch each so the vectorized and loop paths see identical
    randomness (a per-step ``rng.normal`` consumes a data-dependent number
    of raw draws, so interleaved ordering could never be replicated)."""
    rng = np.random.default_rng(cfg.seed)
    eps = rng.normal(0.0, cfg.std_log, n_steps)
    u = rng.random(n_steps)
    return eps, u


def _ar1_path(eps: np.ndarray, ar: float) -> np.ndarray:
    """x_t = ar·x_{t-1} + eps_t with x_{-1} = 0, vectorized.

    Blocked cumulative form: within a block of size B,
    ``x_{s+j} = ar^{j+1}·x_{s-1} + ar^j · cumsum(eps_{s+i} / ar^i)``.
    B is chosen so ``ar^{-(B-1)}`` stays comfortably inside float64 range
    (|ar| near 0 forces small blocks; |ar| near 1 allows thousands), which
    also keeps the reordered accumulation within fp rounding of the
    sequential recurrence: terms older than the representable dynamic
    range are exactly the ones the contraction has already damped away.
    """
    n = eps.size
    if n == 0:
        return eps.astype(np.float64)
    if not -1.0 < ar < 1.0:
        raise ValueError(f"AR(1) coefficient must satisfy |ar| < 1, got {ar}")
    if ar == 0.0:
        return eps.astype(np.float64)
    B = int(np.clip(-600.0 / np.log(abs(ar)), 1, 4096))
    out = np.empty(n, np.float64)
    carry = 0.0
    for s in range(0, n, B):
        e = eps[s:s + B].astype(np.float64)
        j = np.arange(e.size)
        p = ar ** j                               # ar^0 .. ar^(m-1)
        y = p * np.cumsum(e / p)                  # Σ_i ar^(j-i) eps_i
        blk = y + carry * ar * p                  # + ar^(j+1) x_{s-1}
        out[s:s + e.size] = blk
        carry = blk[-1]
    return out


def generate_trace(cfg: TraceConfig, n_steps: int) -> np.ndarray:
    """Per-chunk total available bandwidth (kbps), vectorized."""
    eps, u = _draws(cfg, n_steps)
    x = _ar1_path(eps * np.sqrt(1.0 - cfg.ar ** 2), cfg.ar)
    bw = cfg.mean_kbps * np.exp(x - cfg.std_log ** 2 / 2)
    bw = np.where(u < cfg.drop_prob, bw * cfg.drop_factor, bw)
    return np.maximum(bw, cfg.floor_kbps)


def generate_trace_loop(cfg: TraceConfig, n_steps: int) -> np.ndarray:
    """Step-by-step AR(1) reference (same draws as :func:`generate_trace`;
    agreement is fp-rounding-tight — the tolerance test's oracle)."""
    eps, u = _draws(cfg, n_steps)
    scale = np.sqrt(1.0 - cfg.ar ** 2)
    x = 0.0
    out = np.empty(n_steps, np.float64)
    for t in range(n_steps):
        x = cfg.ar * x + scale * eps[t]
        bw = cfg.mean_kbps * np.exp(x - cfg.std_log ** 2 / 2)
        if u[t] < cfg.drop_prob:
            bw *= cfg.drop_factor
        out[t] = max(bw, cfg.floor_kbps)
    return out


def apply_fault_profile(trace: np.ndarray, multipliers: np.ndarray,
                        floor_kbps: float = 1.0) -> np.ndarray:
    """Compose a chaos schedule's per-chunk bandwidth multipliers onto a
    clean trace (``repro.serving.faults.FaultSchedule.bw_multiplier``).

    An outage multiplier (≈0) deliberately punches BELOW the trace
    generator's ``floor_kbps`` — collapses are the whole point — but a
    1 kbps trickle remains so downstream latency models never divide by
    zero.
    """
    t = np.asarray(trace, np.float64)
    m = np.asarray(multipliers, np.float64)
    if t.shape != m.shape:
        raise ValueError(
            f"trace/multiplier length mismatch: {t.shape} vs {m.shape}")
    if np.any(m < 0.0):
        raise ValueError("bandwidth multipliers must be >= 0")
    return np.maximum(t * m, floor_kbps)


def allocate(total_kbps: float, proportions: np.ndarray) -> np.ndarray:
    """Split the shared uplink by the controller's proportion vector."""
    p = np.asarray(proportions, np.float64)
    p = np.maximum(p, 1e-6)
    p = p / p.sum()
    return total_kbps * p


def even_allocation(total_kbps: float, n_streams: int) -> np.ndarray:
    return np.full(n_streams, total_kbps / n_streams)
