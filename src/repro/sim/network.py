"""FCC-broadband-style bandwidth traces + shared-uplink simulation.

The paper drives the total available bandwidth from an FCC trace (§VI-A)
and shapes per-camera links with WonderShaper.  Here: a stochastic trace
generator whose marginals mimic FCC fixed-broadband uplink measurements
(log-normal levels, AR(1) temporal correlation, occasional drops), plus a
shared-uplink splitter applying the controller's allocation vector.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class TraceConfig:
    mean_kbps: float = 16000.0   # paper evaluates 8/16 Mbps uplinks
    std_log: float = 0.25
    ar: float = 0.9              # AR(1) coefficient
    drop_prob: float = 0.02      # transient dips
    drop_factor: float = 0.3
    floor_kbps: float = 1000.0
    seed: int = 0


def generate_trace(cfg: TraceConfig, n_steps: int) -> np.ndarray:
    """Per-chunk total available bandwidth (kbps)."""
    rng = np.random.default_rng(cfg.seed)
    x = 0.0
    out = np.empty(n_steps, np.float64)
    for t in range(n_steps):
        x = cfg.ar * x + np.sqrt(1 - cfg.ar ** 2) * rng.normal(0, cfg.std_log)
        bw = cfg.mean_kbps * np.exp(x - cfg.std_log ** 2 / 2)
        if rng.random() < cfg.drop_prob:
            bw *= cfg.drop_factor
        out[t] = max(bw, cfg.floor_kbps)
    return out


def allocate(total_kbps: float, proportions: np.ndarray) -> np.ndarray:
    """Split the shared uplink by the controller's proportion vector."""
    p = np.asarray(proportions, np.float64)
    p = np.maximum(p, 1e-6)
    p = p / p.sum()
    return total_kbps * p


def even_allocation(total_kbps: float, n_streams: int) -> np.ndarray:
    return np.full(n_streams, total_kbps / n_streams)
