"""Production mesh builders.

Functions, not module-level constants, so importing this module never
touches jax device state.  The dry-run process sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` *before* any jax
import (see dryrun.py); smoke tests and benchmarks see the real (1-device)
platform.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False, degraded: bool = False):
    """degraded=True builds the (8, 16) elastic-continuation mesh: the
    shape the fleet re-forms after losing a data-axis slice (half the
    pod's rows); checkpoints restore onto it via train/checkpoint.py."""
    if degraded:
        return jax.make_mesh((8, 16), ("data", "model"))
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(n_data: int | None = None, n_model: int = 1):
    """Small mesh over whatever devices exist (tests / CPU demos)."""
    n = len(jax.devices())
    if n_data is None:
        n_data = n // n_model
    return jax.make_mesh((n_data, n_model), ("data", "model"))


# v5e hardware constants for the roofline analysis (per chip).
PEAK_FLOPS_BF16 = 197e12      # FLOP/s
HBM_BW = 819e9                # B/s
ICI_BW = 50e9                 # B/s per link
