"""Serving launcher: the BiSwift multi-stream edge runtime.

``python -m repro.launch.serve --streams 4 --chunks 10`` runs the full
loop: synthetic cameras -> hybrid encoder -> (simulated) shared uplink ->
edge runtime (3 pipelines, batched detector, admission control) ->
bandwidth controller feedback.  This is deliverable (b)'s end-to-end
serving driver; benchmarks/ reuse the same plumbing.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.core.bandwidth_controller import BandwidthController, \
    even_proportions
from repro.core.hybrid_encoder import encode_hybrid
from repro.models import detection as D
from repro.serving.runtime import EdgeRuntime
from repro.serving.scheduler import ServingConfig
from repro.sim.env import EnvConfig, high_state_dim, MultiStreamEnv
from repro.sim.network import TraceConfig, allocate, generate_trace
from repro.sim.video_source import paper_stream_mix, generate_chunk


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--streams", type=int, default=4)
    ap.add_argument("--chunks", type=int, default=6)
    ap.add_argument("--chunk-frames", type=int, default=4)
    ap.add_argument("--height", type=int, default=64)
    ap.add_argument("--width", type=int, default=96)
    ap.add_argument("--bw-mean-kbps", type=float, default=16000.0)
    ap.add_argument("--controller", choices=["even", "sac"], default="even")
    ap.add_argument("--detector-ckpt", default=None)
    ap.add_argument("--quick-train", type=int, default=150,
                    help="inline detector fit steps when no ckpt (0=off)")
    args = ap.parse_args(argv)

    streams = paper_stream_mix(args.streams, args.height, args.width)
    det_cfg = D.TinyDetectorConfig()
    params = D.init(jax.random.PRNGKey(1), det_cfg)
    if args.detector_ckpt:
        from repro.train import checkpoint as CKPT
        step = CKPT.latest_step(args.detector_ckpt)
        params = CKPT.restore(args.detector_ckpt, step, params)
    elif args.quick_train:
        # make the demo self-sufficient: a short detector fit on the
        # stream mix (use examples/train_detector.py + --detector-ckpt
        # for a properly trained model)
        from repro.train.optimizer import AdamWConfig, apply_updates, \
            init_state
        opt = init_state(params)
        ocfg = AdamWConfig(lr=3e-3, weight_decay=0.0, warmup_steps=10,
                           total_steps=args.quick_train)

        @jax.jit
        def _fit(params, opt, frames, boxes, valid):
            loss, g = jax.value_and_grad(lambda p: D.loss_fn(
                p, det_cfg, frames, boxes, valid))(params)
            params, opt, _ = apply_updates(params, g, opt, ocfg)
            return params, opt, loss

        print(f"quick-training detector ({args.quick_train} steps)...")
        kq = jax.random.PRNGKey(3)
        for i in range(args.quick_train):
            sc = streams[i % len(streams)]
            fr, bx, vl = generate_chunk(kq, sc, i * 4, 4)
            params, opt, loss = _fit(params, opt, fr, bx, vl)
        print(f"  final det loss {float(loss):.3f}")

    runtime = EdgeRuntime(ServingConfig(n_streams=args.streams), params,
                          det_cfg)
    trace = generate_trace(TraceConfig(mean_kbps=args.bw_mean_kbps),
                           args.chunks)
    env_cfg = EnvConfig(streams=tuple(streams),
                        chunk_frames=args.chunk_frames)
    controller = None
    env = MultiStreamEnv(env_cfg)
    if args.controller == "sac":
        controller = BandwidthController.create(
            jax.random.PRNGKey(2), high_state_dim(env_cfg), args.streams)

    key = jax.random.PRNGKey(0)
    f1_all, lat_all = [], []
    t_start = time.time()
    for t in range(args.chunks):
        env.t = t
        if controller is not None:
            props = controller.proportions(key, env.observe_high(), t,
                                           explore=False)
        else:
            props = even_proportions(args.streams)
        alloc = allocate(trace[t], props)
        for c, sc in enumerate(streams):
            frames, boxes, valid = generate_chunk(
                key, sc, t * args.chunk_frames, args.chunk_frames)
            packet = encode_hybrid(np.asarray(frames), alloc[c],
                                   tr1=0.05, tr2=0.10)
            b, s, types = runtime.process_chunk(c, t, packet)
            lat = runtime.compute_latency(types, packet.total_bits, alloc[c],
                                          stream=c)
            nms = jax.jit(lambda bb, ss: D.greedy_nms(bb, ss,
                                                      iou_thresh=0.4,
                                                      top_k=16))
            f1 = np.mean([float(D.f1_score(
                *nms(jax.numpy.asarray(b[i]), jax.numpy.asarray(s[i])),
                jax.numpy.asarray(boxes[i]), jax.numpy.asarray(valid[i])))
                for i in range(frames.shape[0])])
            f1_all.append(f1)
            lat_all.append(lat["total"])
            print(f"chunk {t} stream {c}: bw={alloc[c]:7.0f}kbps "
                  f"types={types.tolist()} f1={f1:.3f} "
                  f"lat={lat['total'] * 1e3:6.1f}ms")
    wall = time.time() - t_start
    fps = args.streams * args.chunks * args.chunk_frames / wall
    print(f"\nmean F1 {np.mean(f1_all):.3f} | mean latency "
          f"{np.mean(lat_all) * 1e3:.1f} ms | deferred chunks "
          f"{runtime.deferred} | wall {wall:.1f}s ({fps:.1f} fps incl. "
          f"encode sim)")


if __name__ == "__main__":
    main()
