"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

The FIRST two lines below must run before any other import (jax locks the
device count on first init).  Each invocation handles one cell in a fresh
process; a driver loops cells:

    PYTHONPATH=src python -m repro.launch.dryrun --arch llama3_2_1b \
        --shape train_4k --mesh single --out experiments/dryrun

Cost-analysis methodology
-------------------------
XLA's HLO cost analysis counts a while-loop body ONCE regardless of trip
count, so rolled ``lax.scan`` layers would undercount FLOPs by ~n_layers.
We therefore do THREE compiles per cell:

  * the real config with rolled scans -> memory_analysis (the deployable
    artifact: per-device argument/temp bytes prove the cell fits HBM);
  * two probes at n_layers = 2 and 4 with every scan fully unrolled ->
    exact per-layer FLOPs/bytes/collective deltas;
  * extrapolation: cost(L) = cost(2) + (L-2)/2 * (cost(4) - cost(2)).

Conv families (ResNet/ConvNeXt) have heterogeneous stages, so they compile
once fully unrolled (cheap: conv bodies are small) and use direct costs.

Wire bytes use ring-algorithm estimates with group sizes parsed from each
collective's ``replica_groups``.
"""
import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))

import argparse      # noqa: E402
import dataclasses   # noqa: E402
import json          # noqa: E402
import re            # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402

import jax           # noqa: E402

from repro.configs import get_arch, all_cells, ALIASES        # noqa: E402
from repro.distributed.context import shard_ctx               # noqa: E402
from repro.distributed.sharding import make_axis_rules        # noqa: E402
from repro.launch.mesh import make_production_mesh            # noqa: E402
from repro.launch.steps import build_cell                     # noqa: E402
from repro.models import layers as model_layers               # noqa: E402

_DTYPE_BYTES = {
    "f64": 8, "s64": 8, "u64": 8, "c64": 8, "c128": 16,
    "f32": 4, "s32": 4, "u32": 4,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1,
}
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_IOTA_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_EXPLICIT_GROUPS_RE = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_bytes(dtype: str, dims: str) -> int:
    bpe = _DTYPE_BYTES.get(dtype)
    if bpe is None:
        return 0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * bpe


def _group_size(line: str, n_devices: int) -> int:
    m = _IOTA_GROUPS_RE.search(line)
    if m:
        return int(m.group(2))
    m = _EXPLICIT_GROUPS_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return n_devices


def parse_collectives(hlo: str, n_devices: int) -> dict:
    """Per-opcode: count, per-device output bytes, ring wire-byte estimate.

    Counts '-start' async forms once; skips '-done'.
    """
    out: dict[str, dict] = {}
    wire = 0.0
    for line in hlo.splitlines():
        for op in _COLLECTIVES:
            if f" {op}(" not in line and f" {op}-start(" not in line:
                continue
            pos = line.find(f" {op}")
            lhs = line[:pos]
            out_b = sum(_shape_bytes(d, s) for d, s in _SHAPE_RE.findall(lhs))
            g = _group_size(line, n_devices)
            if op == "all-reduce":
                w = 2.0 * out_b * (g - 1) / max(g, 1)
            elif op == "all-gather":
                w = out_b * (g - 1) / max(g, 1)
            elif op == "reduce-scatter":
                w = out_b * (g - 1)
            elif op == "all-to-all":
                w = out_b * (g - 1) / max(g, 1)
            else:  # collective-permute
                w = float(out_b)
            rec = out.setdefault(op, {"count": 0, "output_bytes": 0,
                                      "wire_bytes": 0.0})
            rec["count"] += 1
            rec["output_bytes"] += out_b
            rec["wire_bytes"] += w
            wire += w
            break
    out["_total_wire_bytes"] = wire
    return out


def _with_layers(arch, n: int):
    """Probe config with n (scanned) layers; transformer families only."""
    cfg = dataclasses.replace(arch.cfg, n_layers=n)
    return dataclasses.replace(arch, cfg=cfg)


def _apply_variant_overrides(arch, variant: str):
    """Config-level hillclimb knobs (rules-level ones live in sharding.py)."""
    from repro.launch import steps as steps_mod
    import jax.numpy as jnp
    if variant == "kvint8":
        if arch.family == "lm":
            arch = dataclasses.replace(
                arch, cfg=dataclasses.replace(arch.cfg,
                                              kv_cache_dtype="int8"))
        steps_mod.set_grad_accum_dtype(jnp.float32)
    elif variant.startswith("fast_train"):
        steps_mod.set_grad_accum_dtype(jnp.bfloat16)
        if arch.family == "lm" and arch.cfg.moe is not None:
            moe = dataclasses.replace(arch.cfg.moe, capacity_factor=1.0)
            arch = dataclasses.replace(
                arch, cfg=dataclasses.replace(arch.cfg, moe=moe))
        if variant == "fast_train4":
            # halve the microbatch count: halves per-step FSDP weight
            # gathers + gradient reductions, costs 2x activation memory
            shapes = {k: (dataclasses.replace(v, grad_accum=4)
                          if v.kind == "train" and v.grad_accum > 4 else v)
                      for k, v in arch.shapes.items()}
            arch = dataclasses.replace(arch, shapes=shapes)
    else:
        steps_mod.set_grad_accum_dtype(jnp.float32)
    return arch


def _costs(compiled, n_devices):
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    rec = {k: float(cost.get(k, 0.0)) for k in
           ("flops", "bytes accessed", "transcendentals")}
    rec["collectives"] = parse_collectives(compiled.as_text(), n_devices)
    rec["wire_bytes"] = rec["collectives"].pop("_total_wire_bytes")
    return rec


def _compile_cell(arch, case, mesh, rules, unroll: bool):
    model_layers.set_dryrun_unroll(unroll)
    try:
        with mesh, shard_ctx(mesh, rules):
            cell = build_cell(arch, case, mesh, rules)
            jitted = jax.jit(cell.fn, donate_argnums=cell.donate)
            lowered = jitted.lower(*cell.args)
            compiled = lowered.compile()
        return compiled
    finally:
        model_layers.set_dryrun_unroll(False)


def run_cell(arch_id: str, shape: str, mesh_kind: str, variant: str,
             out_dir: str | None):
    arch = get_arch(arch_id)
    case = arch.shapes[shape]
    rec = {"arch": ALIASES.get(arch_id, arch_id), "shape": shape,
           "mesh": mesh_kind, "variant": variant}
    if case.skip:
        rec["status"] = "skipped"
        rec["reason"] = case.skip
        _dump(rec, out_dir)
        return rec

    multi = mesh_kind == "multi"
    mesh = make_production_mesh(multi_pod=multi,
                                degraded=(mesh_kind == "degraded"))
    rules = make_axis_rules(multi, variant)
    arch = _apply_variant_overrides(arch, variant)
    case = arch.shapes[shape]          # re-fetch: overrides may change it
    rec["mesh_shape"] = dict(mesh.shape)
    rec["n_devices"] = mesh.size
    nd = mesh.size

    # 1) real config, rolled scans -> deployable memory picture
    t0 = time.time()
    compiled = _compile_cell(arch, case, mesh, rules, unroll=False)
    rec["compile_s"] = round(time.time() - t0, 2)
    mem = compiled.memory_analysis()
    rec["memory"] = {
        k: int(getattr(mem, k))
        for k in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "alias_size_in_bytes",
                  "generated_code_size_in_bytes")
        if hasattr(mem, k)
    }

    # 2) exact per-device costs
    fam = arch.family
    homogeneous = fam in ("lm", "diffusion") or \
        arch.cfg.__class__.__name__ == "ViTConfig"
    if homogeneous and arch.cfg.n_layers > 4:
        # probes at 2 and 4 layers: even counts keep the partitioner on the
        # same strategy; delta/2 = exact per-layer cost.
        c1 = _costs(_compile_cell(_with_layers(arch, 2), case, mesh, rules,
                                  unroll=True), nd)
        c2 = _costs(_compile_cell(_with_layers(arch, 4), case, mesh, rules,
                                  unroll=True), nd)
        L = arch.cfg.n_layers
        cost = {}
        for k in ("flops", "bytes accessed", "transcendentals",
                  "wire_bytes"):
            per_layer = (c2[k] - c1[k]) / 2.0
            cost[k] = max(c1[k] + (L - 2) * per_layer, 0.0)
        colls = {}
        for op in set(c1["collectives"]) | set(c2["collectives"]):
            a = c1["collectives"].get(op, {"count": 0, "output_bytes": 0,
                                           "wire_bytes": 0.0})
            b = c2["collectives"].get(op, {"count": 0, "output_bytes": 0,
                                           "wire_bytes": 0.0})
            colls[op] = {k2: max(a[k2] + (L - 2) * (b[k2] - a[k2]) / 2.0, 0)
                         for k2 in a}
        rec["cost_method"] = "probe_extrapolation(L=2,4 unrolled)"
        rec["cost"] = cost
        rec["collectives"] = colls
    else:
        c = _costs(_compile_cell(arch, case, mesh, rules, unroll=True), nd)
        rec["cost_method"] = "full_unroll"
        rec["cost"] = {k: c[k] for k in ("flops", "bytes accessed",
                                         "transcendentals", "wire_bytes")}
        rec["collectives"] = c["collectives"]
    rec["total_s"] = round(time.time() - t0, 2)
    rec["status"] = "ok"
    _dump(rec, out_dir)
    return rec


def _dump(rec, out_dir):
    if not out_dir:
        return
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(
        out_dir, f"{rec['arch']}__{rec['shape']}__{rec['mesh']}__"
                 f"{rec['variant']}.json".replace("/", "_"))
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", choices=["single", "multi", "degraded"],
                    default="single")
    ap.add_argument("--variant", default="baseline")
    ap.add_argument("--out", default=None)
    ap.add_argument("--list", action="store_true")
    args = ap.parse_args()
    if args.list:
        for a, s, skip in all_cells():
            print(f"{a}\t{s}\t{'SKIP:' + skip if skip else 'run'}")
        return
    try:
        rec = run_cell(args.arch, args.shape, args.mesh, args.variant,
                       args.out)
        print(json.dumps(rec, indent=1))
    except Exception:
        rec = {"arch": args.arch, "shape": args.shape, "mesh": args.mesh,
               "variant": args.variant, "status": "error",
               "error": traceback.format_exc()}
        _dump(rec, args.out)
        print(json.dumps(rec, indent=1))
        raise SystemExit(1)


if __name__ == "__main__":
    main()
