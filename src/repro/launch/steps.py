"""Step builders + input specs for every (architecture × shape) cell.

``build_cell(arch_spec, shape_case, mesh, rules)`` returns a :class:`Cell`
whose ``fn`` is the jit-able step and whose ``args`` are ShapeDtypeStructs
carrying NamedShardings — zero allocation, ready for
``jax.jit(fn, donate_argnums=...).lower(*args).compile()``.

``materialize(key, arch_spec, shape_case)`` produces real (small) arrays for
smoke tests; callers use the *reduced* configs there.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

from repro.configs import ArchSpec, ShapeCase
from repro.distributed.sharding import AxisRules, named_sharding
from repro.models import params as PM
from repro.train import optimizer as OPT

i32 = jnp.int32
f32 = jnp.float32
bf16 = jnp.bfloat16

ADAMW = OPT.AdamWConfig()

# fast_train hillclimb knob: accumulate microbatch grads in bf16 (halves
# the per-microbatch gradient all-reduce payload; EXPERIMENTS.md §Perf).
GRAD_ACCUM_DTYPE = f32


def set_grad_accum_dtype(dt):
    global GRAD_ACCUM_DTYPE
    GRAD_ACCUM_DTYPE = dt


@dataclasses.dataclass
class Cell:
    name: str
    fn: Callable
    args: tuple
    donate: tuple[int, ...]
    kind: str


def _sds(shape, dtype, axes, mesh, rules):
    if mesh is None:
        return jax.ShapeDtypeStruct(shape, dtype)
    return jax.ShapeDtypeStruct(
        shape, dtype, sharding=named_sharding(mesh, axes, rules, shape))


def _family(arch: ArchSpec):
    return arch.family


def _specs_tree(arch: ArchSpec):
    if arch.family == "lm":
        from repro.models import transformer_lm as M
        return M.param_specs(arch.cfg)
    if arch.family == "diffusion":
        from repro.models import dit as M
        return M.param_specs(arch.cfg)
    if arch.cfg.__class__.__name__ == "ResNetConfig":
        from repro.models import resnet as M
        return M.param_specs(arch.cfg)
    if arch.cfg.__class__.__name__ == "ConvNeXtConfig":
        from repro.models import convnext as M
        return M.param_specs(arch.cfg)
    from repro.models import vit as M
    return M.param_specs(arch.cfg)


def _loss_and_new_stats(arch: ArchSpec):
    """Returns loss_fn(params_or_vars, batch) -> (loss, aux_stats|None)."""
    cfg = arch.cfg
    if arch.family == "lm":
        from repro.models import transformer_lm as M
        return lambda p, b: (M.loss_fn(p, cfg, b), None), False
    if arch.family == "diffusion":
        from repro.models import dit as M
        return lambda p, b: (M.loss_fn(p, cfg, b), None), False
    name = cfg.__class__.__name__
    if name == "ResNetConfig":
        from repro.models import resnet as M
        return lambda v, b: M.loss_fn(v, cfg, b), True   # (loss, new_stats)
    if name == "ConvNeXtConfig":
        from repro.models import convnext as M
        return lambda p, b: (M.loss_fn(p, cfg, b), None), False
    from repro.models import vit as M
    return lambda p, b: (M.loss_fn(p, cfg, b), None), False


# --------------------------------------------------------------------------
# batch specs per family/kind
# --------------------------------------------------------------------------
def batch_specs(arch: ArchSpec, case: ShapeCase, mesh, rules):
    cfg = arch.cfg
    B = case.batch
    if arch.family == "lm":
        if case.kind == "train":
            return {
                "tokens": _sds((B, case.seq_len), i32, ("batch", None), mesh, rules),
                "labels": _sds((B, case.seq_len), i32, ("batch", None), mesh, rules),
            }
        if case.kind == "prefill":
            return {"tokens": _sds((B, case.seq_len), i32, ("batch", None),
                                   mesh, rules)}
        if case.kind == "decode":
            return {
                "tokens": _sds((B, 1), i32, ("batch", None), mesh, rules),
                "pos": jax.ShapeDtypeStruct((), i32),
            }
    if arch.family == "diffusion":
        lr = cfg.latent_res(case.img_res)
        C = cfg.latent_channels
        if case.kind == "train":
            return {
                "latents": _sds((B, lr, lr, C), f32, ("batch", None, None, None), mesh, rules),
                "noise": _sds((B, lr, lr, C), f32, ("batch", None, None, None), mesh, rules),
                "t": _sds((B,), i32, ("batch",), mesh, rules),
                "labels": _sds((B,), i32, ("batch",), mesh, rules),
            }
        return {  # sample: one DDIM step
            "xt": _sds((B, lr, lr, C), f32, ("batch", None, None, None), mesh, rules),
            "t": _sds((B,), i32, ("batch",), mesh, rules),
            "t_prev": _sds((B,), i32, ("batch",), mesh, rules),
            "y": _sds((B,), i32, ("batch",), mesh, rules),
        }
    # vision
    r = case.img_res
    if case.kind == "train":
        return {
            "images": _sds((B, r, r, 3), bf16, ("batch", None, None, None), mesh, rules),
            "labels": _sds((B,), i32, ("batch",), mesh, rules),
        }
    return {"images": _sds((B, r, r, 3), bf16, ("batch", None, None, None),
                           mesh, rules)}


# --------------------------------------------------------------------------
# step functions
# --------------------------------------------------------------------------
def make_train_fn(arch: ArchSpec, grad_accum: int = 1):
    lf, has_stats = _loss_and_new_stats(arch)

    if not has_stats:
        def grads_of(params, batch):
            return jax.value_and_grad(lambda p: lf(p, batch)[0])(params)

        def train_step(state, batch):
            if grad_accum == 1:
                loss, grads = grads_of(state["params"], batch)
            else:
                # microbatch scan with fp32 grad accumulators (sharded like
                # the params): bounds activation memory at paper-scale batch.
                from repro.models.layers import constrain, scan_unroll

                def split(x):
                    y = x.reshape(grad_accum, x.shape[0] // grad_accum,
                                  *x.shape[1:])
                    return constrain(y, None, "batch",
                                     *([None] * (y.ndim - 2)))

                mb = jax.tree.map(split, batch)
                acc_dt = GRAD_ACCUM_DTYPE
                g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, acc_dt),
                                  state["params"])

                def body(acc, b):
                    gsum, lsum = acc
                    loss, g = grads_of(state["params"], b)
                    gsum = jax.tree.map(lambda a, x: a + x.astype(acc_dt),
                                        gsum, g)
                    return (gsum, lsum + loss), None

                (gsum, lsum), _ = jax.lax.scan(
                    body, (g0, 0.0), mb, unroll=scan_unroll(grad_accum))
                grads = jax.tree.map(lambda g: (g / grad_accum), gsum)
                loss = lsum / grad_accum
            new_p, new_opt, metrics = OPT.apply_updates(
                state["params"], grads, state["opt"], ADAMW)
            return ({"params": new_p, "opt": new_opt},
                    {"loss": loss, **metrics})
        return train_step

    def train_step(state, batch):
        def inner(p):
            loss, new_st = lf({"params": p,
                               "batch_stats": state["batch_stats"]}, batch)
            return loss, new_st

        (loss, new_st), grads = jax.value_and_grad(inner, has_aux=True)(
            state["params"])
        new_p, new_opt, metrics = OPT.apply_updates(
            state["params"], grads, state["opt"], ADAMW)
        return ({"params": new_p, "opt": new_opt, "batch_stats": new_st},
                {"loss": loss, **metrics})
    return train_step


def make_infer_fn(arch: ArchSpec, case: ShapeCase):
    cfg = arch.cfg
    if arch.family == "lm":
        from repro.models import transformer_lm as M
        if case.kind == "prefill":
            return lambda params, batch: M.prefill_step(params, cfg,
                                                        batch["tokens"])
        if case.kind == "decode":
            return lambda params, cache, batch: M.decode_step(
                params, cfg, cache, batch["tokens"], batch["pos"])
    if arch.family == "diffusion":
        from repro.models import dit as M
        return lambda params, batch: M.ddim_step(
            params, cfg, batch["xt"], batch["t"], batch["t_prev"], batch["y"])
    name = cfg.__class__.__name__
    if name == "ResNetConfig":
        from repro.models import resnet as M
        return lambda variables, batch: M.forward(variables, cfg,
                                                  batch["images"],
                                                  train=False)[0]
    if name == "ConvNeXtConfig":
        from repro.models import convnext as M
        return lambda params, batch: M.forward(params, cfg, batch["images"])
    from repro.models import vit as M
    return lambda params, batch: M.forward(params, cfg, batch["images"])


# --------------------------------------------------------------------------
# cell assembly
# --------------------------------------------------------------------------
def build_cell(arch: ArchSpec, case: ShapeCase, mesh=None,
               rules: AxisRules | None = None) -> Cell:
    specs = _specs_tree(arch)
    is_resnet = arch.family == "vision" and \
        arch.cfg.__class__.__name__ == "ResNetConfig"
    if is_resnet:
        params_sds = PM.abstract_params(specs["params"], mesh, rules)
        stats_sds = PM.abstract_params(specs["batch_stats"], mesh, rules)
    else:
        params_sds = PM.abstract_params(specs, mesh, rules)
        stats_sds = None
    batch = batch_specs(arch, case, mesh, rules)

    if case.kind == "train":
        state = {"params": params_sds,
                 "opt": OPT.abstract_state(params_sds)}
        if is_resnet:
            state["batch_stats"] = stats_sds
        fn = make_train_fn(arch, grad_accum=case.grad_accum)
        return Cell(f"{arch.arch_id}:{case.name}", fn, (state, batch),
                    donate=(0,), kind="train")

    fn = make_infer_fn(arch, case)
    if arch.family == "lm" and case.kind == "decode":
        from repro.models import transformer_lm as M
        cache_specs = M.init_cache_specs(arch.cfg, case.batch, case.seq_len)
        cache_sds = PM.abstract_params(cache_specs, mesh, rules)
        return Cell(f"{arch.arch_id}:{case.name}", fn,
                    (params_sds, cache_sds, batch), donate=(1,),
                    kind="decode")
    args0 = {"params": params_sds, "batch_stats": stats_sds} if is_resnet \
        else params_sds
    return Cell(f"{arch.arch_id}:{case.name}", fn, (args0, batch),
                donate=(), kind=case.kind)


# --------------------------------------------------------------------------
# real arrays (reduced configs; smoke tests + examples)
# --------------------------------------------------------------------------
def materialize(key, arch: ArchSpec, case: ShapeCase):
    """Small real inputs matching build_cell's structure (no shardings)."""
    specs = _specs_tree(arch)
    is_resnet = arch.family == "vision" and \
        arch.cfg.__class__.__name__ == "ResNetConfig"
    kp, kb = jax.random.split(key)
    if is_resnet:
        params = PM.init_params(kp, specs["params"])
        stats = PM.init_params(kp, specs["batch_stats"])
    else:
        params = PM.init_params(kp, specs)
        stats = None

    cfg = arch.cfg
    B = case.batch
    if arch.family == "lm":
        V = cfg.vocab
        if case.kind in ("train", "prefill"):
            toks = jax.random.randint(kb, (B, case.seq_len), 0, V, i32)
            batch = {"tokens": toks}
            if case.kind == "train":
                batch["labels"] = jnp.roll(toks, -1, axis=1)
        else:
            batch = {"tokens": jax.random.randint(kb, (B, 1), 0, V, i32),
                     "pos": jnp.array(min(7, case.seq_len - 1), i32)}
    elif arch.family == "diffusion":
        lr = cfg.latent_res(case.img_res)
        C = cfg.latent_channels
        x = jax.random.normal(kb, (B, lr, lr, C), f32)
        if case.kind == "train":
            batch = {"latents": x, "noise": jax.random.normal(kp, x.shape, f32),
                     "t": jnp.full((B,), 500, i32),
                     "labels": jnp.zeros((B,), i32)}
        else:
            batch = {"xt": x, "t": jnp.full((B,), 500, i32),
                     "t_prev": jnp.full((B,), 480, i32),
                     "y": jnp.zeros((B,), i32)}
    else:
        r = case.img_res
        batch = {"images": jax.random.normal(kb, (B, r, r, 3), bf16)}
        if case.kind == "train":
            batch["labels"] = jnp.zeros((B,), i32)

    if case.kind == "train":
        state = {"params": params, "opt": OPT.init_state(params)}
        if is_resnet:
            state["batch_stats"] = stats
        return (state, batch)
    if arch.family == "lm" and case.kind == "decode":
        from repro.models import transformer_lm as M
        cache_specs = M.init_cache_specs(cfg, B, case.seq_len)
        cache = PM.init_params(kp, cache_specs)
        cache["slot_pos"] = jnp.full_like(cache["slot_pos"], -1)
        return (params, cache, batch)
    args0 = {"params": params, "batch_stats": stats} if is_resnet else params
    return (args0, batch)
