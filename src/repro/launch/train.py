"""Training launcher: ``python -m repro.launch.train --arch <id> ...``.

Runs real training steps for the selected architecture on the local
devices (reduced configs on CPU; the full configs target the production
mesh — see dryrun.py for the zero-allocation compile proof).  Supports
checkpoint/restore, preemption-safe resume, and supervised restarts.
"""
from __future__ import annotations

import argparse

import jax

from repro.configs import get_arch, ShapeCase
from repro.launch.steps import build_cell, materialize
from repro.train import loop as LOOP


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--img-res", type=int, default=32)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--log-every", type=int, default=5)
    args = ap.parse_args()

    arch = get_arch(args.arch, reduced=args.reduced)
    case = ShapeCase("cli_train", "train", batch=args.batch,
                     seq_len=args.seq_len, img_res=args.img_res)
    cell = build_cell(arch, case)
    key = jax.random.PRNGKey(0)
    state, batch0 = materialize(key, arch, case)
    step_fn = jax.jit(cell.fn, donate_argnums=(0,))

    def gen():
        k = key
        while True:
            k, kk = jax.random.split(k)
            yield materialize(kk, arch, case)[1]

    cfg = LOOP.LoopConfig(total_steps=args.steps, ckpt_dir=args.ckpt_dir,
                          ckpt_every=max(args.steps // 2, 1),
                          log_every=args.log_every)
    state, hist = LOOP.run(step_fn, state, gen(), cfg,
                           on_metrics=lambda m: print(
                               {k: round(v, 4) for k, v in m.items()}))
    print(f"done: {len(hist)} log points; final loss "
          f"{hist[-1]['loss']:.4f}" if hist else "done")


if __name__ == "__main__":
    main()
