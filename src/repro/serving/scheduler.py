"""Multi-stream serving scheduler — BiSwift's edge runtime control plane.

Chunk-granular event loop over C streams:
  * admission control: streams whose queue exceeds the latency budget are
    deferred (their packets fall back to pipeline ③ reuse — cheap),
  * pipeline queues: ①(infer) and ②(transfer+infer) feed the batched DNN
    executor; ③ bypasses the DNN (paper Fig. 6),
  * batching: inference requests across streams are batched to the DNN's
    preferred batch (amortizes dispatch; the DNN itself is pjit'd),
  * the bandwidth controller is invoked every ``controller_interval``
    chunks with the global S_high state (paper: 10 s).
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Callable, Optional

import numpy as np

f32 = np.float32


@dataclasses.dataclass
class ServingConfig:
    n_streams: int
    batch_size: int = 8              # DNN executor batch
    gpu_capacity_fps: float = 120.0
    latency_budget: float = 1.0
    controller_interval: int = 10


@dataclasses.dataclass
class InferRequest:
    stream: int
    chunk_t: int
    frame_idx: int
    pipeline: int                    # 1 or 2
    frame: np.ndarray


class PipelineQueues:
    """Queues for pipelines ① and ② + shared batched execution."""

    def __init__(self, cfg: ServingConfig, infer_fn: Callable):
        self.cfg = cfg
        self.q1: deque = deque()
        self.q2: deque = deque()
        self.infer_fn = infer_fn

    def submit(self, req: InferRequest):
        (self.q1 if req.pipeline == 1 else self.q2).append(req)

    @property
    def depths(self) -> np.ndarray:
        return np.asarray([len(self.q1), len(self.q2)], f32)

    def drain_fused(self, pad_multiple: Optional[int] = None):
        """Execute ALL queued requests (① before ②) as ONE padded
        invocation of ``infer_fn`` — one device dispatch per chunk.

        The stacked batch is zero-padded up to the next multiple of
        ``pad_multiple`` (default: the configured batch size) so the
        detector sees a small, fixed set of shapes and its jit cache stays
        warm across chunks with different type mixes.
        """
        batch = list(self.q1) + list(self.q2)
        self.q1.clear()
        self.q2.clear()
        if not batch:
            return []
        pad = max(pad_multiple or self.cfg.batch_size, 1)
        n = len(batch)
        n_pad = -(-n // pad) * pad
        frames = np.stack([r.frame for r in batch]
                          + [np.zeros_like(batch[0].frame)] * (n_pad - n))
        outs = self.infer_fn(frames)[:n]
        return list(zip(batch, outs))

    def drain(self, max_frames: Optional[int] = None):
        """Execute queued requests in batches (priority: ① then ②)."""
        done = []
        budget = max_frames if max_frames is not None else 1 << 30
        while budget > 0 and (self.q1 or self.q2):
            batch = []
            while len(batch) < min(self.cfg.batch_size, budget) and \
                    (self.q1 or self.q2):
                batch.append(self.q1.popleft() if self.q1
                             else self.q2.popleft())
            frames = np.stack([r.frame for r in batch])
            outs = self.infer_fn(frames)
            for r, o in zip(batch, outs):
                done.append((r, o))
            budget -= len(batch)
        return done


class AdmissionController:
    """Defers streams whose backlog would blow the latency budget."""

    def __init__(self, cfg: ServingConfig):
        self.cfg = cfg

    def admit(self, queue_depths: np.ndarray, n_new_infer: int) -> bool:
        backlog = float(queue_depths.sum()) + n_new_infer
        est_delay = backlog / self.cfg.gpu_capacity_fps
        return est_delay <= self.cfg.latency_budget
