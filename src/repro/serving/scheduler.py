"""Multi-stream serving scheduler — BiSwift's edge runtime control plane.

Chunk-granular event loop over C streams:
  * admission control: streams whose queue exceeds the latency budget are
    deferred (their packets fall back to pipeline ③ reuse — cheap),
  * pipeline queues: ①(infer) and ②(transfer+infer) feed the batched DNN
    executor; ③ bypasses the DNN (paper Fig. 6),
  * batching: inference requests across streams are batched to the DNN's
    preferred batch (amortizes dispatch; the DNN itself is pjit'd),
  * the bandwidth controller is invoked every ``controller_interval``
    chunks with the global S_high state (paper: 10 s).
"""
from __future__ import annotations

import dataclasses
import inspect
from collections import deque
from typing import Callable, Optional

import numpy as np

f32 = np.float32


@dataclasses.dataclass
class ServingConfig:
    n_streams: int
    batch_size: int = 8              # DNN executor batch
    gpu_capacity_fps: float = 120.0  # AGGREGATE edge DNN throughput
    latency_budget: float = 1.0
    controller_interval: int = 10
    # how many ways the stream axis is sharded over the device mesh
    # (repro.distributed.stream_sharding).  Streams map to shards
    # round-robin (stream % n_shards); each shard owns an equal slice of
    # gpu_capacity_fps and admits against its OWN queue depth, so a hot
    # shard defers its streams to pipeline-③ reuse instead of stalling
    # the global batch.
    n_shards: int = 1
    # double-buffered chunk slots: how many dispatched detector batches
    # may be outstanding per shard before the runtime retires the oldest
    # (EdgeRuntime.flush) — 2 overlaps host scheduling of the next batch
    # with the device computing the current one
    max_inflight: int = 2
    # optional repro.core.roi.RoiConfig: the detector dispatch gates each
    # batch row onto its top-K active regions (scored at stage time from
    # the codec's macroblock statistics).  None = full-frame inference.
    roi: object | None = None
    # in-trace anchor-quality budget search: when True the async stage
    # step additionally stages the per-rung anchor bit planes
    # (EdgeRuntime._stage_chunk) so a downstream budget pick needs no
    # extra host round trip — submit stays non-blocking either way
    anchor_search: bool = False

    @property
    def shard_capacity_fps(self) -> float:
        return self.gpu_capacity_fps / max(self.n_shards, 1)


@dataclasses.dataclass
class InferRequest:
    stream: int
    chunk_t: int
    frame_idx: int
    pipeline: int                    # 1 or 2
    # the frame payload, or None for a LIGHTWEIGHT request whose frames
    # are already staged on device (EdgeRuntime.submit_chunk): the queue
    # entry then carries only the accounting/routing state (depths,
    # admission, shard remap) and the owner gathers the staged plane at
    # dispatch time.  ``drain``/``drain_fused`` require real frames.
    frame: Optional[np.ndarray]
    shard: int = 0                   # owning mesh shard (stream % n_shards)


class PipelineQueues:
    """Queues for pipelines ① and ② + shared batched execution."""

    def __init__(self, cfg: ServingConfig, infer_fn: Callable):
        self.cfg = cfg
        self.q1: deque = deque()
        self.q2: deque = deque()
        self.infer_fn = infer_fn
        # shard-aware executors (EdgeRuntime in sharded mode) take the
        # drained shard so the dispatch lands on that shard's device;
        # plain ``f(frames)`` executors keep working unchanged.  A
        # ``**kwargs`` wrapper around a shard-aware executor counts too.
        try:
            params = inspect.signature(infer_fn).parameters.values()
            self._infer_takes_shard = any(
                p.name == "shard" or p.kind is p.VAR_KEYWORD
                for p in params)
        except (TypeError, ValueError):
            self._infer_takes_shard = False

    def submit(self, req: InferRequest):
        (self.q1 if req.pipeline == 1 else self.q2).append(req)

    @property
    def depths(self) -> np.ndarray:
        return np.asarray([len(self.q1), len(self.q2)], f32)

    @property
    def shard_depths(self) -> np.ndarray:
        """(n_shards, 2) queued-request counts per mesh shard.  Row i is
        the backlog in front of device shard i only — the admission signal
        when the stream axis is sharded (a hot shard must defer ITS
        streams without penalizing streams placed on idle shards)."""
        d = np.zeros((max(self.cfg.n_shards, 1), 2), f32)
        for req in self.q1:
            d[req.shard, 0] += 1.0
        for req in self.q2:
            d[req.shard, 1] += 1.0
        return d

    def drain_fused(self, pad_multiple: Optional[int] = None,
                    shard: Optional[int] = None):
        """Execute queued requests (① before ②) as ONE padded invocation
        of ``infer_fn`` — one device dispatch per chunk.

        ``shard`` restricts the drain to that mesh shard's requests (the
        per-shard detector dispatch of the sharded runtime); other shards'
        backlogs stay queued.  The stacked batch is zero-padded up to the
        next multiple of ``pad_multiple`` (default: the configured batch
        size) so the detector sees a small, fixed set of shapes and its
        jit cache stays warm across chunks with different type mixes.
        """
        if shard is None:
            batch = list(self.q1) + list(self.q2)
            self.q1.clear()
            self.q2.clear()
        else:
            batch = [r for r in self.q1 if r.shard == shard] \
                + [r for r in self.q2 if r.shard == shard]
            self.q1 = deque(r for r in self.q1 if r.shard != shard)
            self.q2 = deque(r for r in self.q2 if r.shard != shard)
        if not batch:
            return []
        pad = max(pad_multiple or self.cfg.batch_size, 1)
        n = len(batch)
        n_pad = -(-n // pad) * pad
        frames = np.stack([r.frame for r in batch]
                          + [np.zeros_like(batch[0].frame)] * (n_pad - n))
        if self._infer_takes_shard:
            outs = self.infer_fn(frames, shard=shard)[:n]
        else:
            outs = self.infer_fn(frames)[:n]
        return list(zip(batch, outs))

    def take(self, reqs) -> int:
        """Remove specific queued requests (by identity) WITHOUT executing
        them — the async dispatcher gathers their staged device frames
        itself (``EdgeRuntime._dispatch_group``) and only needs the queue
        to forget them.  Requests not queued here are ignored.  Returns
        the number removed."""
        ids = {id(r) for r in reqs}
        n0 = len(self.q1) + len(self.q2)
        self.q1 = deque(r for r in self.q1 if id(r) not in ids)
        self.q2 = deque(r for r in self.q2 if id(r) not in ids)
        return n0 - len(self.q1) - len(self.q2)

    def remap_shards(self, mapper: Callable[[int], int]) -> int:
        """Rewrite every queued request's owning shard via
        ``mapper(stream) -> shard``.  Called after a shard eviction so
        in-flight requests follow their streams onto the survivor shards
        instead of waiting on a device that will never drain them.
        Returns the number of requests whose shard changed."""
        moved = 0
        for q in (self.q1, self.q2):
            for req in q:
                new = int(mapper(req.stream))
                if new != req.shard:
                    req.shard = new
                    moved += 1
        return moved

    def drain(self, max_frames: Optional[int] = None):
        """Execute queued requests in batches (priority: ① then ②)."""
        done = []
        budget = max_frames if max_frames is not None else 1 << 30
        while budget > 0 and (self.q1 or self.q2):
            batch = []
            while len(batch) < min(self.cfg.batch_size, budget) and \
                    (self.q1 or self.q2):
                batch.append(self.q1.popleft() if self.q1
                             else self.q2.popleft())
            frames = np.stack([r.frame for r in batch])
            outs = self.infer_fn(frames)
            for r, o in zip(batch, outs):
                done.append((r, o))
            budget -= len(batch)
        return done


class AdmissionController:
    """Defers streams whose backlog would blow the latency budget."""

    def __init__(self, cfg: ServingConfig):
        self.cfg = cfg

    def admit(self, queue_depths: np.ndarray, n_new_infer: int) -> bool:
        """Global admission: total backlog vs aggregate capacity."""
        backlog = float(queue_depths.sum()) + n_new_infer
        est_delay = backlog / self.cfg.gpu_capacity_fps
        return est_delay <= self.cfg.latency_budget

    def admit_shard(self, shard_depths: np.ndarray, shard: int,
                    n_new_infer: int) -> bool:
        """Per-shard admission: the stream's OWN shard backlog vs that
        shard's slice of capacity.  Identical to :meth:`admit` when
        n_shards == 1; with a sharded mesh, a stream lands on pipeline-③
        reuse exactly when ITS device is hot — idle shards keep admitting
        regardless of the global backlog."""
        backlog = float(np.asarray(shard_depths)[shard].sum()) + n_new_infer
        est_delay = backlog / self.cfg.shard_capacity_fps
        return est_delay <= self.cfg.latency_budget
