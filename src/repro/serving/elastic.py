"""Elastic scaling of the serving plane.

``ElasticPool`` tracks healthy device groups; on failure/eviction it
rebuilds the mesh from survivors and re-shards the model (restore path in
train/checkpoint.py does the same for training).  On CPU we exercise the
logic with host-platform fake devices in tests.
"""
from __future__ import annotations

import dataclasses

import jax
import numpy as np

from repro.distributed.sharding import make_axis_rules


@dataclasses.dataclass
class ElasticPool:
    n_groups: int                     # replica groups (e.g. data-axis rows)
    healthy: np.ndarray = None

    def __post_init__(self):
        if self.healthy is None:
            self.healthy = np.ones(self.n_groups, bool)

    def fail(self, group: int):
        self.healthy[group] = False

    def recover(self, group: int):
        self.healthy[group] = True

    @property
    def n_healthy(self) -> int:
        return int(self.healthy.sum())

    def usable_power_of_two(self) -> int:
        """Largest power-of-two group count <= healthy (mesh axes like
        powers of two; spares idle until enough recover)."""
        n = self.n_healthy
        p = 1
        while p * 2 <= n:
            p *= 2
        return p


def remesh(pool: ElasticPool, n_model: int = 1):
    """Build the largest viable (data, model) mesh from healthy groups."""
    n_devices = len(jax.devices())
    n_data = min(pool.usable_power_of_two(), n_devices // n_model)
    mesh = jax.make_mesh((n_data, n_model), ("data", "model"))
    return mesh


def reshard_params(params, specs_tree, mesh, multi_pod: bool = False):
    """Re-device_put params for a new mesh (post-failure continuation)."""
    from repro.distributed.sharding import tree_shardings
    rules = make_axis_rules(multi_pod)
    shardings = tree_shardings(mesh, specs_tree, rules)
    return jax.tree.map(jax.device_put, params, shardings)
