"""Elastic scaling of the serving plane.

``ElasticPool`` tracks healthy device groups; on failure/eviction it
rebuilds the mesh from survivors and re-shards the model (restore path in
train/checkpoint.py does the same for training).  On CPU we exercise the
logic with host-platform fake devices in tests; the forced-4-device child
proves the evict → remesh → re-dispatch path bit-exact for surviving
streams (``tests/test_chaos.py``).

Contract with the async dispatch plane (``serving/runtime.py``): an
eviction re-homes both the evicted shard's QUEUED requests and its
pending (submitted-but-unflushed) tickets onto survivor shards; batches
already dispatched to the evicted device are NOT cancelled — they retire
normally at the next double-buffer rotation or at ``poll``, so in-flight
results are never dropped mid-eviction.
"""
from __future__ import annotations

import dataclasses

import jax
import numpy as np

from repro.distributed.sharding import make_axis_rules


@dataclasses.dataclass
class ElasticPool:
    """Health bitmap over replica groups (e.g. data-axis rows).

    ``healthy`` defaults to all-True; a caller-provided array is coerced
    to a bool copy (so external mutation can't corrupt the pool) and must
    have exactly ``n_groups`` entries.
    """
    n_groups: int
    healthy: np.ndarray | None = None

    def __post_init__(self):
        if self.n_groups < 1:
            raise ValueError(f"n_groups must be >= 1, got {self.n_groups}")
        if self.healthy is None:
            self.healthy = np.ones(self.n_groups, bool)
        else:
            h = np.asarray(self.healthy)
            if h.shape != (self.n_groups,):
                raise ValueError(
                    f"healthy must have shape ({self.n_groups},), "
                    f"got {h.shape}")
            self.healthy = h.astype(bool, copy=True)

    def _check(self, group: int):
        if not 0 <= group < self.n_groups:
            raise IndexError(
                f"group {group} outside pool of {self.n_groups}")

    def fail(self, group: int):
        self._check(group)
        self.healthy[group] = False

    def recover(self, group: int):
        self._check(group)
        self.healthy[group] = True

    @property
    def n_healthy(self) -> int:
        return int(self.healthy.sum())

    def healthy_groups(self) -> list[int]:
        return [int(g) for g in np.nonzero(self.healthy)[0]]

    def usable_power_of_two(self) -> int:
        """Largest power-of-two group count <= healthy (mesh axes like
        powers of two; spares idle until enough recover).  0 when no
        group is healthy."""
        n = self.n_healthy
        if n == 0:
            return 0
        p = 1
        while p * 2 <= n:
            p *= 2
        return p


def remesh(pool: ElasticPool, n_model: int = 1):
    """Build the largest viable (data, model) mesh from healthy groups.

    When the process's devices split evenly across the pool's groups,
    the mesh is built from the surviving groups' devices specifically
    (an evicted group's device really leaves the mesh); otherwise the
    groups are logical and the mesh just shrinks its data axis.

    Raises ``RuntimeError`` instead of silently producing a 0-sized mesh
    when too few healthy groups remain to place even one model replica.
    """
    if n_model < 1:
        raise ValueError(f"n_model must be >= 1, got {n_model}")
    devices = jax.devices()
    usable = pool.usable_power_of_two()
    if usable == 0:
        raise RuntimeError(
            f"cannot remesh: 0 of {pool.n_groups} groups healthy")
    if len(devices) % pool.n_groups == 0 and pool.n_healthy < pool.n_groups:
        per = len(devices) // pool.n_groups
        sel = [d for g in pool.healthy_groups()
               for d in devices[g * per:(g + 1) * per]]
    else:
        sel = list(devices)
    n_data = min(usable, len(sel) // n_model)
    if n_data < 1:
        raise RuntimeError(
            f"cannot remesh: {len(sel)} usable device(s) across "
            f"{pool.n_healthy}/{pool.n_groups} healthy groups cannot "
            f"host n_model={n_model}")
    sel = np.asarray(sel[:n_data * n_model], dtype=object)
    return jax.sharding.Mesh(sel.reshape(n_data, n_model),
                             ("data", "model"))


def reshard_params(params, specs_tree, mesh, multi_pod: bool = False):
    """Re-device_put params for a new mesh (post-failure continuation)."""
    from repro.distributed.sharding import tree_shardings
    rules = make_axis_rules(multi_pod)
    shardings = tree_shardings(mesh, specs_tree, rules)
    return jax.tree.map(jax.device_put, params, shardings)
