"""Chaos harness for the serving plane — seeded, deterministic fault
schedules + the closed-loop soak driver.

BiSwift's premise is sustained accuracy under a hostile environment (FCC
bandwidth collapses, 9+ competing streams, a small edge GPU), so the
runtime must be exercised against failure, not just sunshine.  This
module is the single source of injected misbehaviour:

  * :class:`FaultSchedule` — a list of :class:`FaultEvent` windows plus a
    seed.  Every query (``chunk_lost``, ``shard_slowdown``, ...) is a pure
    function of (seed, event list, query args): two schedules built the
    same way answer identically, so chaos soaks are replayable and CI can
    assert exact recovery behaviour.
  * preset schedules (:func:`preset_schedule`) — the named fault mixes the
    acceptance tests and ``benchmarks/chaos.py`` run.
  * :func:`run_soak` — the closed-loop driver: N chunks of C streams
    through an :class:`~repro.serving.runtime.EdgeRuntime` under a
    schedule, producing per-chunk fps series, per-stream degradation
    stats, and the accounting/recovery report the chaos tests assert on.

Fault kinds
-----------
``bw_collapse``
    total uplink bandwidth × ``magnitude`` over ``[t0, t1)``.
``outage``
    correlated outage burst: bandwidth × ``magnitude`` (≈0) over the
    window — composes multiplicatively with collapses.
``stall``
    camera stall: stream ``target`` produces no chunks in the window
    (bandwidth allocated to it is wasted; no frames enter accounting).
``leave`` / ``join``
    stream churn.  ``leave`` removes stream ``target`` over ``[t0, t1)``
    (it rejoins at ``t1``); ``join`` keeps the stream offline UNTIL
    ``t0`` (a late-joining camera).
``chunk_loss``
    the chunk a stream offloads is lost in transit with probability
    ``magnitude`` per chunk (``target == -1``: every stream).
    Retransmissions face the same per-try loss probability.
``chunk_corrupt``
    the chunk arrives but fails its checksum with probability
    ``magnitude`` — the payload is untrusted, so after detection it is
    handled exactly like a loss (retry ladder), counted separately.
``shard_slow``
    device shard ``target`` runs ``magnitude``× slower (straggler);
    ``magnitude`` ≫ 1 models a hung device.  Feeds the runtime's
    simulated step timings, so ``StragglerDetector`` eviction fires
    deterministically.
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np

FAULT_KINDS = ("bw_collapse", "outage", "stall", "leave", "join",
               "chunk_loss", "chunk_corrupt", "shard_slow")

# kinds that dent throughput — the recovery analysis measures steady-state
# fps against the union of these windows
DISRUPTIVE_KINDS = frozenset(FAULT_KINDS) - {"join"}

_KIND_CODE = {k: i for i, k in enumerate(FAULT_KINDS)}


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One fault window ``[t0, t1)`` (chunk indices)."""
    kind: str
    t0: int
    t1: int
    target: int = -1          # stream / shard id; -1 = every target
    magnitude: float = 1.0

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(
                f"unknown fault kind {self.kind!r}; expected one of "
                f"{FAULT_KINDS}")
        if self.t1 < self.t0:
            raise ValueError(f"fault window ends before it starts: "
                             f"[{self.t0}, {self.t1})")
        if self.magnitude < 0.0:
            raise ValueError(f"fault magnitude must be >= 0, "
                             f"got {self.magnitude}")

    def active(self, t: int) -> bool:
        return self.t0 <= t < self.t1


class FaultSchedule:
    """Deterministic fault oracle over a list of :class:`FaultEvent`.

    Randomized outcomes (a chunk-loss coin, a retry outcome) are drawn
    from a generator seeded by ``(seed, kind, target, t, ...)`` — never
    from shared mutable RNG state — so query order cannot change any
    answer and replays are exact.
    """

    def __init__(self, events, *, seed: int = 0):
        self.events = tuple(events)
        self.seed = int(seed)

    # -------------------------------------------------------------- coins
    def _coin(self, *ids: int) -> float:
        # mask to uint32 words: SeedSequence rejects negative entropy
        words = [self.seed & 0xFFFFFFFF] + [int(i) & 0xFFFFFFFF
                                            for i in ids]
        return float(np.random.default_rng(words).random())

    def _active(self, kind: str, t: int):
        return [e for e in self.events if e.kind == kind and e.active(t)]

    # ---------------------------------------------------------- bandwidth
    def bw_multiplier(self, t: int) -> float:
        """Product of active collapse/outage magnitudes (1.0 = clean)."""
        m = 1.0
        for e in self._active("bw_collapse", t) + self._active("outage", t):
            m *= e.magnitude
        return m

    def bw_multipliers(self, n_steps: int) -> np.ndarray:
        """(n_steps,) profile for :func:`repro.sim.network.apply_fault_profile`."""
        return np.asarray([self.bw_multiplier(t) for t in range(n_steps)])

    # --------------------------------------------------------------- churn
    def stalled(self, stream: int, t: int) -> bool:
        return any(e.target in (-1, stream)
                   for e in self._active("stall", t))

    def stream_active(self, stream: int, t: int) -> bool:
        """False while a ``leave`` window covers t, or before a ``join``
        event's start for that stream."""
        for e in self.events:
            if e.kind == "leave" and e.target in (-1, stream) \
                    and e.active(t):
                return False
            if e.kind == "join" and e.target == stream and t < e.t0:
                return False
        return True

    def active_mask(self, t: int, n_streams: int) -> np.ndarray:
        return np.asarray([self.stream_active(c, t)
                           for c in range(n_streams)], bool)

    # ------------------------------------------------------ loss/corruption
    def _event_prob(self, kind: str, stream: int, t: int) -> float:
        probs = [e.magnitude for e in self._active(kind, t)
                 if e.target in (-1, stream)]
        return min(max(probs, default=0.0), 1.0)

    def chunk_lost(self, stream: int, t: int) -> bool:
        p = self._event_prob("chunk_loss", stream, t)
        return p > 0.0 and self._coin(_KIND_CODE["chunk_loss"],
                                      stream, t) < p

    def chunk_corrupt(self, stream: int, t: int) -> bool:
        p = self._event_prob("chunk_corrupt", stream, t)
        return p > 0.0 and self._coin(_KIND_CODE["chunk_corrupt"],
                                      stream, t) < p

    def retry_succeeds(self, stream: int, t: int, attempt: int) -> bool:
        """A retransmission of a lost/corrupt chunk traverses the same
        degraded link: per-try success probability is 1 − loss prob."""
        p = max(self._event_prob("chunk_loss", stream, t),
                self._event_prob("chunk_corrupt", stream, t))
        return self._coin(_KIND_CODE["chunk_loss"], stream, t,
                          1000 + attempt) >= p

    # -------------------------------------------------------------- shards
    def shard_slowdown(self, shard: int, t: int) -> float:
        """≥ 1.0 step-time multiplier for a device shard (1.0 = healthy)."""
        mags = [e.magnitude for e in self._active("shard_slow", t)
                if e.target in (-1, shard)]
        return max(max(mags, default=1.0), 1.0)

    # ------------------------------------------------------------ analysis
    def horizon(self) -> int:
        return max((e.t1 for e in self.events), default=0)

    def disruption_mask(self, n_steps: int) -> np.ndarray:
        """(n_steps,) bool — True where ANY throughput-denting fault is
        active.  Contiguous True runs are the 'fault regions' whose
        clearing the recovery analysis measures from."""
        m = np.zeros(n_steps, bool)
        for e in self.events:
            if e.kind in DISRUPTIVE_KINDS:
                m[max(e.t0, 0):max(min(e.t1, n_steps), 0)] = True
        return m


# ---------------------------------------------------------------------------
# preset schedules — the named fault mixes CI asserts on
# ---------------------------------------------------------------------------
PRESETS = ("bw-collapse", "loss-burst", "stream-churn", "shard-chaos")


def preset_schedule(name: str, *, n_chunks: int, n_streams: int = 3,
                    n_shards: int = 1, seed: int = 0) -> FaultSchedule:
    """Named deterministic schedules sized to an ``n_chunks`` soak.

    Each preset front-loads a clean warmup (steady-state baseline), puts
    its faults in the middle, and leaves a clean tail longer than the
    degradation ladder's recovery patience, so the ≥90 %-recovery
    assertion has room to hold.
    """
    P = int(n_chunks)
    if P < 12:
        raise ValueError(f"presets need n_chunks >= 12, got {P}")
    q = P // 4
    if name == "bw-collapse":
        events = [
            # magnitudes are deep because the soak's chunks are tiny
            # (a few kbit): 0.01x of an 8 Mbps uplink is what makes
            # transmission latency actually threaten the deadline
            FaultEvent("bw_collapse", q, q + max(P // 8, 1),
                       magnitude=0.01),
            FaultEvent("outage", 2 * q, 2 * q + max(P // 10, 2),
                       magnitude=0.001),
        ]
    elif name == "loss-burst":
        events = [
            # loss before any carry exists -> rung 4 (frame-skip)
            FaultEvent("chunk_loss", 0, 1, target=0, magnitude=1.0),
            # hard loss burst: every retry fails -> reuse-fallback rung
            FaultEvent("chunk_loss", q, q + 2, target=-1, magnitude=1.0),
            # flaky window on stream 0: retries usually recover the chunk
            FaultEvent("chunk_loss", 2 * q, 2 * q + max(P // 8, 2),
                       target=0, magnitude=0.5),
            FaultEvent("chunk_corrupt", 2 * q, 2 * q + max(P // 8, 2),
                       target=min(1, n_streams - 1), magnitude=0.7),
        ]
    elif name == "stream-churn":
        last = n_streams - 1
        events = [
            FaultEvent("join", 2, P, target=last),
            FaultEvent("leave", q, 2 * q, target=min(1, last)),
            FaultEvent("stall", 2 * q + 1, 2 * q + 3, target=0),
        ]
    elif name == "shard-chaos":
        events = [
            FaultEvent("shard_slow", q, 2 * q, target=n_shards - 1,
                       magnitude=8.0),
            FaultEvent("bw_collapse", 2 * q + 2, 2 * q + 2 + max(P // 10, 1),
                       magnitude=0.3),
        ]
    else:
        raise KeyError(f"unknown preset {name!r}; have {PRESETS}")
    return FaultSchedule(events, seed=seed)


def churn_schedule(n_chunks: int, n_streams: int, *, seed: int = 0,
                   join_frac: float = 0.25, leave_frac: float = 0.2,
                   stall_frac: float = 0.05,
                   loss_window: bool = True) -> FaultSchedule:
    """Many-stream churn generator for O(100)-stream soaks.

    Deterministic in ``seed``: the last ``join_frac`` of the streams join
    staggered over the first half of the horizon (late-arriving cameras),
    ``leave_frac`` of the early streams each take one leave window,
    ``stall_frac`` stall for a chunk mid-run, and (optionally) a global
    flaky-loss window exercises the retry ladder while the pool is at its
    churn peak.  Unlike the 3-stream presets, windows are drawn per
    stream, so at 64+ streams every chunk sees a different live set.
    """
    if n_chunks < 4:
        raise ValueError(f"churn needs n_chunks >= 4, got {n_chunks}")
    rng = np.random.default_rng(seed)
    events = []
    n_join = int(n_streams * join_frac)
    for c in range(n_streams - n_join, n_streams):
        t0 = int(rng.integers(1, max(n_chunks // 2, 2)))
        events.append(FaultEvent("join", t0, n_chunks, target=c))
    early = max(n_streams - n_join, 1)
    n_leave = min(int(n_streams * leave_frac), early)
    for c in rng.choice(early, size=n_leave, replace=False):
        a = int(rng.integers(1, max(n_chunks - 2, 2)))
        b = min(a + 1 + int(rng.integers(1, max(n_chunks // 3, 2))),
                n_chunks - 1)
        events.append(FaultEvent("leave", a, b, target=int(c)))
    for c in rng.choice(early, size=min(max(int(n_streams * stall_frac),
                                            1), early), replace=False):
        a = int(rng.integers(1, n_chunks - 1))
        events.append(FaultEvent("stall", a, a + 1, target=int(c)))
    if loss_window:
        mid = n_chunks // 2
        events.append(FaultEvent("chunk_loss", mid, mid + 2, target=-1,
                                 magnitude=0.3))
    return FaultSchedule(events, seed=seed)


# ---------------------------------------------------------------------------
# closed-loop chaos soak
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class SoakConfig:
    n_streams: int = 3
    n_chunks: int = 24
    chunk_frames: int = 4
    height: int = 32
    width: int = 48
    fps: float = 30.0
    n_shards: int = 1
    gpu_capacity_fps: float = 480.0
    latency_budget: float = 1.0
    mean_kbps: float = 8000.0
    recovery_chunks: int = 6          # K: post-fault chunks to recover in
    recovery_frac: float = 0.9        # ...to >= this fraction of baseline
    tr1: float = 0.05
    tr2: float = 0.1
    seed: int = 0
    # shared-content pools for many-stream soaks: stream c renders the
    # frames of group ``c % content_groups`` (None = per-stream content,
    # the historical behavior).  64 streams over 8 pools keep the encode
    # cache small while every stream still runs its own control ladder.
    content_groups: int | None = None


def _recovery_report(fps_norm: np.ndarray, disrupted: np.ndarray,
                     cfg: SoakConfig) -> list[dict]:
    """Per fault-region recovery verdicts.

    For each maximal contiguous disrupted run ``[a, b)``: baseline = mean
    normalized fps over the clean chunks immediately preceding ``a``
    (after the previous region's own K-chunk recovery allowance); the
    region recovers if some chunk in ``[b, b+K]`` reaches
    ``recovery_frac × baseline``.  Regions without a clean pre-window or
    without post-fault room are reported unchecked (``baseline=None``).
    """
    n = fps_norm.size
    K = cfg.recovery_chunks
    regions = []
    a = None
    for t in range(n):
        if disrupted[t] and a is None:
            a = t
        elif not disrupted[t] and a is not None:
            regions.append((a, t))
            a = None
    if a is not None:
        regions.append((a, n))
    out = []
    prev_end = 0
    for a, b in regions:
        # clean window preceding the region, skipping the previous
        # region's own K-chunk recovery allowance when there is room
        pre_lo = min(prev_end + K, a)
        if pre_lo >= a:
            pre_lo = prev_end
        pre = fps_norm[pre_lo:a]
        entry = {"t0": int(a), "t1": int(b), "baseline": None,
                 "recovered_at": None, "recovered_in": None, "ok": None}
        if pre.size and b + 1 <= n:
            base = float(pre.mean())
            entry["baseline"] = base
            hi = min(b + K + 1, n)
            hit = [t for t in range(b, hi)
                   if fps_norm[t] >= cfg.recovery_frac * base]
            if hit:
                entry["recovered_at"] = int(hit[0])
                entry["recovered_in"] = int(hit[0] - b)
                entry["ok"] = True
            else:
                entry["ok"] = False
        prev_end = b
        out.append(entry)
    return out


def run_soak(cfg: SoakConfig, schedule: FaultSchedule, *,
             degrade=None, detector=None, batch_submit: bool = False,
             forecast=None) -> dict:
    """Drive an :class:`EdgeRuntime` through ``n_chunks`` of churning,
    faulty streams and report accounting + recovery.

    Per chunk: the schedule decides which streams are live/stalled, the
    (faulted) trace splits evenly across live streams, each live stream
    encodes at the runtime's suggested (possibly demoted) ladder rung and
    offers its chunk to ``process_chunk``; modeled chunk latency feeds the
    deadline ladder, and ``poll_faults`` runs straggler eviction/recovery
    once per chunk.  Content per stream is a fixed seeded chunk re-offered
    every step (encodes are cached per (content group, rung)) — the soak
    exercises the CONTROL plane, not content diversity.

    ``batch_submit=True`` drives the continuous-batching path: every live
    stream's chunk is SUBMITTED first (``submit_chunk``), then the whole
    round is flushed as cross-stream padded batches and polled — the mode
    that scales the soak to O(100) concurrent streams.  The default keeps
    the chunk-sequential PR-6 behavior bit-for-bit.

    ``forecast`` (a ``repro.core.forecast.ForecastConfig``) arms
    PREDICTIVE admission: an EWMA forecaster tracks each stream's
    observed rate, and a chunk whose modeled transmission time at
    ``min(allocated, predicted)`` kbps would blow the deadline is
    withheld (``EdgeRuntime.hold_chunk`` — pipeline-③ hold on the carry)
    instead of transmitted into the collapse.  The reactive default
    (``forecast=None``) transmits and discovers the miss after the fact
    — behavior is byte-identical to pre-forecast builds.

    Everything that influences a decision is simulated/seeded, so two
    calls with the same inputs produce identical reports (minus wall
    time).
    """
    import jax

    from repro.codec.rate_model import (ladder_for_bandwidth,
                                        video_bandwidth_share)
    from repro.core.hybrid_encoder import encode_hybrid
    from repro.models import detection as D
    from repro.serving.runtime import DegradeConfig, EdgeRuntime
    from repro.serving.scheduler import ServingConfig
    from repro.sim.network import (TraceConfig, apply_fault_profile,
                                   generate_trace)
    from repro.sim.video_source import StreamConfig, generate_chunk

    C, T = cfg.n_streams, cfg.chunk_frames
    det_cfg = D.TinyDetectorConfig()
    params = detector if detector is not None else \
        D.init(jax.random.PRNGKey(cfg.seed + 1), det_cfg)
    scfg = ServingConfig(n_streams=C, n_shards=cfg.n_shards,
                         gpu_capacity_fps=cfg.gpu_capacity_fps,
                         latency_budget=cfg.latency_budget)
    degrade = degrade or DegradeConfig(deadline_s=cfg.latency_budget)
    from repro.serving.straggler import DetectorConfig
    rt = EdgeRuntime(scfg, params, det_cfg, faults=schedule,
                     degrade=degrade,
                     # tight window/patience: the soak is short, so the
                     # detector must converge within a preset's window
                     straggler_cfg=DetectorConfig(patience=3, window=6))

    trace = generate_trace(TraceConfig(mean_kbps=cfg.mean_kbps,
                                       seed=cfg.seed), cfg.n_chunks)
    trace = apply_fault_profile(trace, schedule.bw_multipliers(cfg.n_chunks))

    forecaster = None
    if forecast is not None:
        from repro.core.forecast import StreamForecaster
        forecaster = StreamForecaster(forecast, C)
    forecast_holds = 0

    def _group(c: int) -> int:
        return c % cfg.content_groups if cfg.content_groups else c

    frames = {g: np.asarray(generate_chunk(
        None, StreamConfig(height=cfg.height, width=cfg.width,
                           n_objects=2, seed=cfg.seed * 101 + g), 0, T)[0])
        for g in sorted({_group(c) for c in range(C)})}
    packets: dict = {}

    def packet_for(c: int, level: int, bw: float):
        g = _group(c)
        if (g, level) not in packets:
            packets[(g, level)] = encode_hybrid(
                frames[g], bw, cfg.tr1, cfg.tr2, fps=cfg.fps, level=level)
        return packets[(g, level)]

    delivered_fps = np.zeros(cfg.n_chunks)
    infer_fps = np.zeros(cfg.n_chunks)
    fps_norm = np.zeros(cfg.n_chunks)         # per-live-stream delivered
    infer_norm = np.zeros(cfg.n_chunks)       # per-live-stream inferred
    queue_leaks = []
    wall0 = time.perf_counter()
    for t in range(cfg.n_chunks):
        live = [c for c in range(C) if schedule.stream_active(c, t)]
        n_live = max(len(live), 1)
        alloc = float(trace[t]) / n_live
        delivered = inferred = 0
        round_ = []                    # (stream, ticket-or-types, packet)
        for c in live:
            if schedule.stalled(c, t):
                rt.note_stall(c, t)
                continue
            base = ladder_for_bandwidth(video_bandwidth_share(alloc))
            level = rt.suggest_level(c, base)
            pkt = packet_for(c, level, alloc)
            if forecaster is not None:
                # predictive admission: hold the chunk if the modeled
                # transmission at min(allocated, EWMA-predicted) kbps
                # would blow the deadline — don't transmit into a collapse
                pred_kbps = min(alloc, float(forecaster.predict_bw()[c]))
                t_tx = pkt.total_bits / max(pred_kbps * 1000.0, 1e-6)
                if t_tx > degrade.deadline_s:
                    tk = rt.hold_chunk(c, t, pkt)
                    forecast_holds += 1
                    round_.append(
                        (c, tk if batch_submit else rt.poll(tk)[2], pkt))
                    continue
            if batch_submit:
                round_.append((c, rt.submit_chunk(c, t, pkt), pkt))
            else:
                round_.append((c, rt.process_chunk(c, t, pkt)[2], pkt))
        if batch_submit:
            rt.flush()
        obs_bits = np.zeros(C, np.float32)
        obs_mask = np.zeros(C, bool)
        for c, item, pkt in round_:
            types = rt.poll(item)[2] if batch_submit else item
            st = rt.stats[c]
            bits = pkt.total_bits if st.last_transmitted else 0.0
            lat = rt.compute_latency(types, bits, alloc, stream=c)["total"] \
                + st.last_penalty_s
            rt.note_chunk_latency(c, t, lat)
            delivered += st.last_delivered
            inferred += st.last_inferred
            obs_bits[c] = bits
            obs_mask[c] = True
        if forecaster is not None:
            # every participating stream observed its announced allocation
            # (held ones too — the allocation is control-plane knowledge,
            # and a frozen EWMA would never see the link recover)
            forecaster.update(np.full(C, alloc, np.float32), obs_bits,
                              mask=obs_mask)
        rt.poll_faults(t)
        depth = float(rt.queues.depths.sum())
        if depth:
            queue_leaks.append((t, depth))
        delivered_fps[t] = delivered * cfg.fps / T
        infer_fps[t] = inferred * cfg.fps / T
        fps_norm[t] = delivered_fps[t] / n_live
        infer_norm[t] = infer_fps[t] / n_live
    wall = time.perf_counter() - wall0
    rt.close()                        # retire in-flight work, stop hedge pool

    stats = {c: rt.stats[c].as_dict() for c in sorted(rt.stats)}
    accounting_ok = all(
        s["frames_in"] == s["frames_inferred"] + s["frames_reused"]
        + s["frames_skipped"] for s in stats.values())
    disrupted = schedule.disruption_mask(cfg.n_chunks)
    return {
        "config": dataclasses.asdict(cfg),
        "n_chunks": cfg.n_chunks,
        "delivered_fps": delivered_fps,
        "infer_fps": infer_fps,
        "fps_norm": fps_norm,
        "infer_norm": infer_norm,
        "stream_stats": stats,
        "accounting_ok": accounting_ok,
        "queue_leaks": queue_leaks,
        "recovery": _recovery_report(fps_norm, disrupted, cfg),
        "recovery_infer": _recovery_report(infer_norm, disrupted, cfg),
        "fault_log": list(rt.fault_log),
        "active_shards_final": list(rt.active_shards),
        "hedged_dispatches": rt.hedged_dispatches,
        "forecast_holds": forecast_holds,
        "forecast_state": None if forecaster is None else forecaster.state(),
        "wall_s": wall,
    }
