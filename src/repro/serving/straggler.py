"""Straggler mitigation for the distributed serving/training planes.

At pod scale, slow replicas dominate tail latency.  Two mechanisms:

  * ``HedgedExecutor`` — speculative re-issue: if a shard's result hasn't
    arrived within quantile-based deadline t_q, the request is re-issued to
    a backup replica; first result wins.  (Serving plane.)
  * ``StragglerDetector`` — per-step timing stats; replicas slower than
    median × threshold for ``patience`` consecutive steps are flagged for
    eviction, which triggers the elastic re-mesh path
    (``repro.serving.runtime.EdgeRuntime.poll_faults`` in serving,
    train/fault_tolerance.py in training).
"""
from __future__ import annotations

import concurrent.futures
import dataclasses
import time
from collections import defaultdict, deque
from typing import Callable

import numpy as np


@dataclasses.dataclass
class HedgeConfig:
    quantile: float = 0.95
    min_history: int = 20
    max_hedges: int = 1


class HedgedExecutor:
    """First-result-wins speculative execution over interchangeable
    replicas.

    Two paths share the deadline/accounting logic:

      * simulated (``simulate_latency`` given) — replica latency is the
        callable's answer; fully deterministic, used by tests and the
        chaos soak.
      * wall clock — the primary runs on a worker thread; if it misses
        the quantile deadline, the backup is issued on the caller's
        thread and whichever finishes first (by timestamp) wins.  The
        primary is never cancelled (JAX dispatches aren't interruptible);
        a hedge costs duplicated work, not correctness.
    """

    def __init__(self, cfg: HedgeConfig, replicas: list[Callable]):
        self.cfg = cfg
        self.replicas = replicas
        self.lat: deque = deque(maxlen=500)
        self.hedges = 0
        self.rr = 0
        self._pool = None    # lazy: most runs never hedge on wall clock

    def _deadline(self) -> float:
        if len(self.lat) < self.cfg.min_history:
            return float("inf")
        return float(np.quantile(np.asarray(self.lat), self.cfg.quantile))

    def _run_wall(self, payload, primary: int, deadline: float):
        can_hedge = (len(self.replicas) > 1 and self.cfg.max_hedges >= 1
                     and np.isfinite(deadline))
        t0 = time.perf_counter()
        if not can_hedge:
            out = self.replicas[primary](payload)
            self.lat.append(time.perf_counter() - t0)
            return out, primary
        if self._pool is None:
            self._pool = concurrent.futures.ThreadPoolExecutor(
                max_workers=2, thread_name_prefix="hedge")

        def timed(idx):
            r = self.replicas[idx](payload)
            return r, time.perf_counter()

        fut = self._pool.submit(timed, primary)
        try:
            out, _ = fut.result(timeout=deadline)
            self.lat.append(time.perf_counter() - t0)
            return out, primary
        except concurrent.futures.TimeoutError:
            pass
        # primary missed its deadline: issue the backup here, then take
        # whichever actually finished first
        self.hedges += 1
        backup = (primary + 1) % len(self.replicas)
        out_b, t_b = timed(backup)
        if fut.done() and not fut.exception():
            out_p, t_p = fut.result()
            if t_p <= t_b:
                self.lat.append(t_p - t0)
                return out_p, primary
        self.lat.append(t_b - t0)
        return out_b, backup

    def run(self, payload, *, simulate_latency: Callable | None = None,
            primary: int | None = None):
        """Returns ``(result, winning_replica)``.

        ``simulate_latency(replica_idx)`` supplies deterministic latencies
        (tests / chaos soak); wall clock otherwise.  ``primary`` pins the
        first-choice replica (stream-affinity routing); round-robin when
        omitted.
        """
        if primary is None:
            primary = self.rr % len(self.replicas)
            self.rr += 1
        deadline = self._deadline()
        if simulate_latency is not None:
            lat = simulate_latency(primary)
            if lat > deadline and len(self.replicas) > 1 \
                    and self.cfg.max_hedges >= 1:
                self.hedges += 1
                backup = (primary + 1) % len(self.replicas)
                lat2 = simulate_latency(backup)
                winner = backup if lat2 < lat else primary
                self.lat.append(min(lat, lat2))
                return self.replicas[winner](payload), winner
            self.lat.append(lat)
            return self.replicas[primary](payload), primary
        return self._run_wall(payload, primary, deadline)

    def close(self):
        """Shut the lazy hedge thread pool down.  Idempotent — safe to
        call on an executor that never hedged on wall clock.  Without
        this, the 2 worker threads outlive the executor (they leaked
        across EdgeRuntime lifecycles and test runs before the runtime
        teardown path called it)."""
        if self._pool is not None:
            self._pool.shutdown(wait=True)
            self._pool = None

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False


@dataclasses.dataclass
class DetectorConfig:
    threshold: float = 1.5          # × median
    patience: int = 5
    # sliding per-replica timing window the medians come from: small
    # windows react to a fresh slowdown within a few steps, large ones
    # smooth over transients
    window: int = 100


class StragglerDetector:
    def __init__(self, cfg: DetectorConfig, n_replicas: int):
        self.cfg = cfg
        self.n = n_replicas
        self.strikes = np.zeros(n_replicas, np.int64)
        self.history = defaultdict(lambda: deque(maxlen=cfg.window))

    def record(self, replica: int, step_time: float):
        self.history[replica].append(step_time)

    def reset(self, replica: int):
        """Forget a replica's record — used when a recovered device
        rejoins the pool so stale slow samples can't re-flag it."""
        self.strikes[replica] = 0
        self.history[replica].clear()

    def flagged(self) -> list[int]:
        medians = [np.median(self.history[i]) if self.history[i] else 0.0
                   for i in range(self.n)]
        global_med = np.median([m for m in medians if m > 0] or [0.0])
        out = []
        for i in range(self.n):
            if medians[i] > self.cfg.threshold * max(global_med, 1e-12):
                self.strikes[i] += 1
            else:
                self.strikes[i] = 0
            if self.strikes[i] >= self.cfg.patience:
                out.append(i)
        return out
