"""Straggler mitigation for the distributed serving/training planes.

At pod scale, slow replicas dominate tail latency.  Two mechanisms:

  * ``HedgedExecutor`` — speculative re-issue: if a shard's result hasn't
    arrived within quantile-based deadline t_q, the request is re-issued to
    a backup replica; first result wins.  (Serving plane.)
  * ``StragglerDetector`` — per-step timing stats; replicas slower than
    median × threshold for ``patience`` consecutive steps are flagged for
    eviction, which triggers the elastic re-mesh path in
    train/fault_tolerance.py.  (Training plane.)
"""
from __future__ import annotations

import dataclasses
import time
from collections import defaultdict, deque
from typing import Callable

import numpy as np


@dataclasses.dataclass
class HedgeConfig:
    quantile: float = 0.95
    min_history: int = 20
    max_hedges: int = 1


class HedgedExecutor:
    def __init__(self, cfg: HedgeConfig, replicas: list[Callable]):
        self.cfg = cfg
        self.replicas = replicas
        self.lat: deque = deque(maxlen=500)
        self.hedges = 0
        self.rr = 0

    def _deadline(self) -> float:
        if len(self.lat) < self.cfg.min_history:
            return float("inf")
        return float(np.quantile(np.asarray(self.lat), self.cfg.quantile))

    def run(self, payload, *, simulate_latency: Callable | None = None):
        """Synchronous simulation: replica latency comes from
        ``simulate_latency(replica_idx)`` in tests; wall clock otherwise."""
        primary = self.rr % len(self.replicas)
        self.rr += 1
        deadline = self._deadline()
        t0 = time.perf_counter()
        if simulate_latency is not None:
            lat = simulate_latency(primary)
            if lat > deadline and len(self.replicas) > 1:
                self.hedges += 1
                backup = (primary + 1) % len(self.replicas)
                lat2 = simulate_latency(backup)
                winner = backup if lat2 < lat else primary
                self.lat.append(min(lat, lat2))
                return self.replicas[winner](payload), winner
            self.lat.append(lat)
            return self.replicas[primary](payload), primary
        out = self.replicas[primary](payload)
        self.lat.append(time.perf_counter() - t0)
        return out, primary


@dataclasses.dataclass
class DetectorConfig:
    threshold: float = 1.5          # × median
    patience: int = 5


class StragglerDetector:
    def __init__(self, cfg: DetectorConfig, n_replicas: int):
        self.cfg = cfg
        self.n = n_replicas
        self.strikes = np.zeros(n_replicas, np.int64)
        self.history = defaultdict(lambda: deque(maxlen=100))

    def record(self, replica: int, step_time: float):
        self.history[replica].append(step_time)

    def flagged(self) -> list[int]:
        medians = [np.median(self.history[i]) if self.history[i] else 0.0
                   for i in range(self.n)]
        global_med = np.median([m for m in medians if m > 0] or [0.0])
        out = []
        for i in range(self.n):
            if medians[i] > self.cfg.threshold * max(global_med, 1e-12):
                self.strikes[i] += 1
            else:
                self.strikes[i] = 0
            if self.strikes[i] >= self.cfg.patience:
                out.append(i)
        return out
