"""BiSwift edge serving runtime: decoder -> pipelines -> results.

Binds the hybrid decoder's three pipelines to the scheduler's queues and a
(pjit-able) detector, per chunk per stream.  This is the deployable analog
of the paper's Fig. 4 right half; benchmarks/throughput.py drives it with
1..N concurrent streams to reproduce Fig. 11(a).
"""
from __future__ import annotations

import dataclasses

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.hybrid_encoder import HybridPacket
from repro.core.hybrid_decoder import (PipelineCosts, _upscale_mvs,
                                       pipeline_cost)
from repro.codec.rate_model import upscale_nearest
from repro.core.reuse import reuse_chunk
from repro.models import detection as D
from repro.serving.scheduler import (AdmissionController, InferRequest,
                                     PipelineQueues, ServingConfig)

f32 = np.float32


@dataclasses.dataclass
class StreamState:
    last_boxes: np.ndarray
    last_scores: np.ndarray


class EdgeRuntime:
    def __init__(self, cfg: ServingConfig, detector_params, det_cfg,
                 costs: PipelineCosts = PipelineCosts(), *,
                 mesh=None, rules=None):
        """``mesh``/``rules`` (jax Mesh + AxisRules with a "stream" entry)
        switch the runtime to sharded mode: n_shards is derived from the
        mesh's stream extent, streams map to shards round-robin, each
        chunk's detector dispatch drains only its own shard's queues, and
        shard i's detector (params replicated per shard) is COMMITTED to
        mesh device i — the per-shard capacity slice corresponds to a real
        device, not an accounting fiction."""
        if (mesh is None) != (rules is None):
            raise ValueError("sharded mode needs BOTH mesh= and rules= "
                             "(got only one)")
        self._shard_infer = None
        if mesh is not None:
            from repro.distributed.stream_sharding import stream_shard_count
            cfg = dataclasses.replace(
                cfg, n_shards=stream_shard_count(mesh, rules))
        self.cfg = cfg
        self.n_shards = max(cfg.n_shards, 1)
        self.det_cfg = det_cfg
        self.costs = costs

        # params enter the jit as an ARGUMENT (closure capture would embed
        # them as constants and the computation would ignore their device)
        infer_jit = jax.jit(lambda p, frames: D.decode_boxes(
            D.forward(p, det_cfg, frames), det_cfg))

        def make_infer(params):
            return lambda frames: infer_jit(params, frames)

        self._infer = make_infer(detector_params)
        if mesh is not None and self.n_shards > 1:
            devs = list(mesh.devices.flat)
            self._shard_infer = [
                make_infer(jax.device_put(detector_params,
                                          devs[i % len(devs)]))
                for i in range(self.n_shards)]
        self.queues = PipelineQueues(cfg, self._infer_batch)
        self.admission = AdmissionController(cfg)
        self.streams: dict[int, StreamState] = {}
        self.deferred = 0
        self.deferred_by_shard = np.zeros(self.n_shards, np.int64)
        # pipeline-③ fallback accounting: frames demoted ②->③ under
        # overload, and whole chunks forced onto reuse (deep overload)
        self.demoted_frames = np.zeros(self.n_shards, np.int64)
        self.reuse_fallback_chunks = np.zeros(self.n_shards, np.int64)

    def stream_shard(self, stream: int) -> int:
        return stream % self.n_shards

    def _infer_batch(self, frames, shard=None):
        """Shard-aware detector dispatch: in sharded mode the batch runs
        on the shard's own committed device (jit follows the committed
        params); otherwise on the single default-device detector."""
        fn = self._infer if (shard is None or self._shard_infer is None) \
            else self._shard_infer[shard]
        boxes, scores = fn(jnp.asarray(frames))
        return list(zip(np.asarray(boxes), np.asarray(scores)))

    # ------------------------------------------------------------------
    def process_chunk(self, stream: int, t: int, packet: HybridPacket):
        """Returns per-frame (boxes, scores, types) for one chunk.

        All pipeline-①/② frames of the chunk go through ONE padded detector
        invocation (``PipelineQueues.drain_fused``) on the stream's OWN
        mesh shard instead of one dispatch per frame; admission reads that
        shard's queue depths before the chunk is enqueued (a hot shard
        defers its streams to pipeline-③ reuse without stalling the other
        shards), and pipeline ③ carries the previous chunk's last
        detections across the chunk boundary.
        """
        enc = packet.video
        T = packet.types.shape[0]
        H, W = packet.anchor_hd.shape[1:]
        types = packet.types.copy()
        prev = self.streams.get(stream)
        shard = self.stream_shard(stream)

        n_infer = int((types != 3).sum())
        if not self.admission.admit_shard(self.queues.shard_depths, shard,
                                          n_infer):
            # overload: demote transfer frames to reuse, keep chunk anchors
            self.demoted_frames[shard] += int((types == 2).sum())
            types = np.where(types == 2, 3, types)
            self.deferred += 1
            self.deferred_by_shard[shard] += 1
            # deep overload: if even anchors-only blows the budget AND we
            # have carried detections to reuse, the whole chunk runs on
            # pipeline ③ (the previous chunk's boxes keep tracking via MVs)
            if prev is not None and \
                    not self.admission.admit_shard(self.queues.shard_depths,
                                                   shard,
                                                   int((types != 3).sum())):
                self.demoted_frames[shard] += int((types != 3).sum())
                types = np.full_like(types, 3)
                self.reuse_fallback_chunks[shard] += 1

        mvs_hd = np.asarray(_upscale_mvs(enc.mv, (H, W)))

        # submit pipeline ①/② frames; one fused padded dispatch for all.
        # lr_up is computed lazily: when overload demoted every type-2
        # frame, the shed-load path skips the whole-chunk upscale entirely
        lr_up = None
        for i in range(T):
            if types[i] == 1:
                self.queues.submit(InferRequest(stream, t, i, 1,
                                                packet.anchor_hd[i],
                                                shard=shard))
            elif types[i] == 2:
                if lr_up is None:
                    lr_up = np.asarray(upscale_nearest(enc.recon, H, W))
                self.queues.submit(InferRequest(stream, t, i, 2, lr_up[i],
                                                shard=shard))
        done = self.queues.drain_fused(shard=shard)

        # collect per-frame detections; pipeline ③ reuse fills the gaps
        n_cells = (H // self.det_cfg.stride) * (W // self.det_cfg.stride)
        boxes_t = np.zeros((T, n_cells, 4), f32)
        scores_t = np.zeros((T, n_cells), f32)
        for req, (b, s) in done:
            if req.stream == stream and req.chunk_t == t:
                boxes_t[req.frame_idx] = b
                scores_t[req.frame_idx] = s

        # pipeline-③ carry: seed reuse with the previous chunk's last boxes
        init_b = jnp.asarray(prev.last_boxes) if prev is not None else None
        init_s = jnp.asarray(prev.last_scores) if prev is not None else None
        boxes, scores = reuse_chunk(jnp.asarray(types), jnp.asarray(mvs_hd),
                                    jnp.asarray(boxes_t),
                                    jnp.asarray(scores_t),
                                    init_boxes=init_b, init_scores=init_s)
        self.streams[stream] = StreamState(last_boxes=np.asarray(boxes[-1]),
                                           last_scores=np.asarray(scores[-1]))
        return np.asarray(boxes), np.asarray(scores), types

    # ------------------------------------------------------------------
    def compute_latency(self, types: np.ndarray, bits: float,
                        bw_kbps: float, stream: int | None = None) -> dict:
        """Latency model for one chunk.  With ``stream`` given, queueing
        delay comes from that stream's shard backlog against the shard's
        capacity slice (identical to the global estimate at n_shards=1)."""
        n1 = int((types == 1).sum())
        n2 = int((types == 2).sum())
        n3 = int((types == 3).sum())
        t_comp = pipeline_cost(n1, n2, n3, self.costs)
        if stream is None:
            t_queue = float(self.queues.depths.sum()) \
                / self.cfg.gpu_capacity_fps
        else:
            shard = self.stream_shard(stream)
            t_queue = float(self.queues.shard_depths[shard].sum()) \
                / self.cfg.shard_capacity_fps
        t_trans = bits / max(bw_kbps * 1000.0, 1e-6)
        return {"t_trans": t_trans, "t_queue": t_queue, "t_comp": t_comp,
                "total": t_trans + t_queue + t_comp}
