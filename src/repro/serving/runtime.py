"""BiSwift edge serving runtime: decoder -> pipelines -> results.

Binds the hybrid decoder's three pipelines to the scheduler's queues and a
(pjit-able) detector, per chunk per stream.  This is the deployable analog
of the paper's Fig. 4 right half; benchmarks/run.py drives it with
1..N concurrent streams to reproduce Fig. 11(a).

Async continuous-batching plane (ISSUE 7): the runtime is a
submit/flush/poll dispatcher in the style of LLM serving —

  * ``submit_chunk`` runs ONLY host-side control (delivery ladder,
    admission, demotion, queue accounting) and stages the chunk's frames
    / motion vectors on device with a single jit (``_stage_chunk``); it
    returns a :class:`ChunkTicket` immediately, without waiting for any
    device work.
  * ``flush`` groups pending tickets by (shard, T, H, W) batch signature,
    gathers each group's pipeline-①/② frames into one padded detector
    batch (power-of-two bucketed so the jit cache stays warm), dispatches
    it asynchronously, and finishes every ticket with one fused
    scatter+reuse jit (``_finish_chunk``).  At most
    ``ServingConfig.max_inflight`` dispatched batches are outstanding per
    shard (double-buffered chunk slots): dispatching past the cap first
    retires the oldest with ``block_until_ready``, so host scheduling of
    the NEXT batch overlaps the device computing the current one.
  * ``poll`` materializes a ticket's results with a single device->host
    transfer at the poll boundary — no intermediate ``np.asarray`` syncs
    anywhere on the chunk path.

``process_chunk`` is now literally ``poll(submit_chunk(...))``, so every
legacy call site keeps its synchronous semantics (admission sees drained
queues, one dispatch per chunk) while sharing the async machinery.

Robustness plane (chaos PR): when constructed with ``faults=`` (a
``repro.serving.faults.FaultSchedule``) the runtime additionally runs

  * a per-stream deadline-driven **degradation ladder** replacing silent
    deferral — lost/corrupt chunks retry with exponential backoff; streams
    that keep missing their deadline are demoted down the bitrate ladder
    (``suggest_level``), then forced onto pipeline-③ reuse, then
    frame-skipped with explicit accounting (types == 0).  Every decision
    lands in ``stats[stream]`` (a :class:`StreamStats`).
  * **straggler eviction + elastic recovery** — per-dispatch shard
    timings feed a ``StragglerDetector``; ``poll_faults`` evicts flagged
    shards from ``active_shards`` (re-homing queued requests AND pending
    tickets onto survivors via ``PipelineQueues.remap_shards``) and
    re-admits them when the schedule says the device is healthy again.
    Dispatches hedge across active shards through a ``HedgedExecutor``;
    already-dispatched batches complete on their original device.

The accounting invariant every chaos test asserts —
``frames_in == frames_inferred + frames_reused + frames_skipped`` — is
established at SUBMIT time (types are decided by host control), so it
holds for every stream even while its chunk is still in flight.
"""
from __future__ import annotations

import dataclasses
from collections import defaultdict, deque
from functools import partial

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.hybrid_encoder import HybridPacket
from repro.core.hybrid_decoder import (PipelineCosts, _upscale_mvs,
                                       pipeline_cost)
from repro.codec.rate_model import QUALITY_LADDER, upscale_nearest
from repro.core.reuse import reuse_chunk
from repro.models import detection as D
from repro.serving.elastic import ElasticPool
from repro.serving.scheduler import (AdmissionController, InferRequest,
                                     PipelineQueues, ServingConfig)
from repro.serving.straggler import (DetectorConfig, HedgeConfig,
                                     HedgedExecutor, StragglerDetector)

f32 = np.float32


# ---------------------------------------------------------------------------
# module-level jits — one trace per batch signature, shared by every runtime
# ---------------------------------------------------------------------------
@partial(jax.jit, static_argnames=("hd_hw", "roi", "anchor_search"))
def _stage_chunk(types, anchor_hd, recon, mv, residual_q, *, hd_hw,
                 roi=None, anchor_search=False):
    """Stage one chunk on device: upscale the LR video to analytics
    resolution, select each frame's execution plane (decoded HD anchor for
    type-1, upscaled LR for the rest), and upscale the motion vectors —
    one async dispatch, nothing touches the host.  With ``roi`` set (a
    static ``repro.core.roi.RoiConfig``) the relevance head also scores
    each HD region from the codec's macroblock statistics; the (T, R)
    flat scores ride the ticket so the detector dispatch can gate its
    rows without re-deriving anything.  With ``anchor_search`` on the
    per-rung anchor bit planes (``ladder_bits`` over the HD anchor plane,
    (T, Q)) ride along too — staged in this same async dispatch so a
    downstream in-trace budget pick costs no extra host round trip."""
    H, W = hd_hw
    lr_up = upscale_nearest(recon, H, W)
    frames = jnp.where((types == 1)[:, None, None], anchor_hd, lr_up)
    mvs = _upscale_mvs(mv, (H, W))
    rung_bits = None
    if anchor_search:
        from repro.codec.image_codec import ladder_bits
        rung_bits = jax.vmap(ladder_bits)(anchor_hd)
    if roi is None:
        return frames, mvs, None, rung_bits
    from repro.core.roi import region_grid, region_scores
    nry, nrx = region_grid(hd_hw, roi)
    scores = region_scores(mv, residual_q, recon.shape[1:], hd_hw, roi)
    return (frames, mvs, scores.reshape(types.shape[0], nry * nrx),
            rung_bits)


@jax.jit
def _gather_batch(frames_seq, flat_idx, valid):
    """Pack per-ticket staged frames into one padded detector batch.

    ``frames_seq``: tuple of (T, H, W) staged planes (one per ticket slot,
    padded to a power-of-two count so the trace cache stays bounded);
    ``flat_idx``: (n_pad,) row ``slot * T + frame_idx`` per batch entry;
    ``valid``: (n_pad,) mask — padding rows come out exactly zero, matching
    the legacy ``np.zeros_like`` padding semantics bit-for-bit."""
    stacked = jnp.stack(frames_seq)
    flat = stacked.reshape((-1,) + stacked.shape[2:])
    batch = jnp.take(flat, jnp.clip(flat_idx, 0, flat.shape[0] - 1), axis=0)
    return jnp.where(valid[:, None, None], batch, 0.0)


@jax.jit
def _gather_rows(rows_seq, flat_idx, valid):
    """ROI-mode companion to ``_gather_batch``: pack per-ticket staged
    (T, R) region-score rows into the batch order.  Padding rows score 0
    everywhere — their gated patches run on the zero frames
    ``_gather_batch`` produced and are dropped at scatter time."""
    stacked = jnp.stack(rows_seq)
    flat = stacked.reshape((-1,) + stacked.shape[2:])
    rows = jnp.take(flat, jnp.clip(flat_idx, 0, flat.shape[0] - 1), axis=0)
    return jnp.where(valid[:, None], rows, 0.0)


@partial(jax.jit, static_argnames=("has_init",))
def _finish_chunk(types, pos, mvs, batch_boxes, batch_scores,
                  init_b, init_s, *, has_init):
    """Scatter one ticket's rows out of the batched detector output and
    run pipeline-③ reuse — fused, so the carry slice (``boxes[-1]``)
    never leaves the device between chunks."""
    mask = pos >= 0
    idx = jnp.clip(pos, 0, batch_boxes.shape[0] - 1)
    boxes_t = jnp.where(mask[:, None, None], batch_boxes[idx], 0.0)
    scores_t = jnp.where(mask[:, None], batch_scores[idx], 0.0)
    boxes, scores = reuse_chunk(
        types, mvs, boxes_t, scores_t,
        init_boxes=init_b if has_init else None,
        init_scores=init_s if has_init else None)
    return boxes, scores, boxes[-1], scores[-1]


@partial(jax.jit, static_argnames=("T",))
def _hold_chunk(last_b, last_s, *, T):
    """Zero-motion pipeline-③ hold for an undeliverable chunk with a
    carry: the previous detections repeated across the chunk."""
    return (jnp.broadcast_to(last_b[None], (T,) + last_b.shape),
            jnp.broadcast_to(last_s[None], (T,) + last_s.shape))


def _pad_bucket(n: int, base: int) -> int:
    """Smallest ``base * 2**k >= n`` — power-of-two bucketed padding keeps
    the detector's jit cache small while capping padding waste at 2x."""
    m = max(int(base), 1)
    while m < n:
        m *= 2
    return m


@dataclasses.dataclass
class StreamState:
    """Pipeline-③ carry across chunks.  DEVICE arrays: the carry feeds the
    next chunk's ``_finish_chunk`` without a host round trip."""
    last_boxes: jax.Array
    last_scores: jax.Array


@dataclasses.dataclass
class ChunkTicket:
    """Handle for one submitted chunk.  ``done`` flips when the device
    graph is built (dispatch + finish); ``poll`` materializes the result
    with one transfer and caches it."""
    stream: int
    chunk_t: int
    shard: int
    types: np.ndarray
    hw: tuple
    reqs: list = dataclasses.field(default_factory=list)
    frames_dev: jax.Array | None = None
    mvs_dev: jax.Array | None = None
    rscores_dev: jax.Array | None = None   # (T, R) ROI scores (roi mode)
    # (T, Q) per-rung anchor bit planes (anchor_search mode) — small, so
    # kept past dispatch for budget audits after poll
    rung_bits_dev: jax.Array | None = None
    init_b: jax.Array | None = None
    init_s: jax.Array | None = None
    n_cells: int = 0
    done: bool = False
    _dev_out: tuple | None = None      # (boxes, scores) on device
    _host: tuple | None = None         # cached poll result


@dataclasses.dataclass(frozen=True)
class DegradeConfig:
    """Deadline ladder knobs (rungs in escalation order).

    1. retry-with-backoff — a lost/corrupt chunk is retransmitted up to
       ``max_retries`` times, backoff doubling from ``retry_backoff_s``,
       while the accumulated penalty still fits ``deadline_s``;
    2. rung demotion — ``demote_patience`` consecutive deadline misses
       drop the stream one bitrate-ladder rung (down to ``max_demotion``
       below its bandwidth-derived rung);
    3. pipeline-③ fallback — misses at the bottom rung force whole chunks
       onto motion-vector reuse (no inference);
    4. frame-skip — an undeliverable chunk with no carried detections is
       dropped with explicit accounting (types == 0).

    ``promote_patience`` consecutive on-deadline chunks walk the stream
    back up one step (reuse → inference, then rung by rung).
    """
    deadline_s: float = 1.0
    max_retries: int = 3
    retry_backoff_s: float = 0.05
    demote_patience: int = 2
    promote_patience: int = 3
    max_demotion: int = len(QUALITY_LADDER) - 1


@dataclasses.dataclass
class StreamStats:
    """Per-stream degradation accounting — every ladder decision is
    surfaced here, nothing is silent."""
    stream: int
    frames_in: int = 0
    frames_inferred: int = 0          # pipelines ① and ② (through the DNN)
    frames_reused: int = 0            # pipeline ③
    frames_skipped: int = 0           # rung 4: explicitly dropped
    chunks: int = 0
    chunks_lost: int = 0
    chunks_corrupt: int = 0
    chunks_stalled: int = 0
    retries: int = 0
    deadline_misses: int = 0
    rung_demotion: int = 0            # current ladder demotion (0 = none)
    demote_events: int = 0
    promote_events: int = 0
    reuse_fallback_chunks: int = 0
    force_reuse: bool = False         # rung 3 engaged
    events: list = dataclasses.field(default_factory=list)
    # transient per-chunk fields (the soak reads them right after a chunk)
    last_penalty_s: float = 0.0
    last_transmitted: bool = True
    last_delivered: int = 0
    last_inferred: int = 0
    last_skipped: int = 0
    _miss_streak: int = 0
    _ok_streak: int = 0

    def note(self, t: int, action: str, detail: str = ""):
        self.events.append((int(t), action, detail))

    def as_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["events"] = [list(e) for e in d["events"]]
        return {k: v for k, v in d.items() if not k.startswith("_")}


class EdgeRuntime:
    def __init__(self, cfg: ServingConfig, detector_params, det_cfg,
                 costs: PipelineCosts = PipelineCosts(), *,
                 mesh=None, rules=None, faults=None,
                 degrade: DegradeConfig | None = None,
                 hedge: HedgeConfig | None = None,
                 straggler_cfg: DetectorConfig | None = None):
        """``mesh``/``rules`` (jax Mesh + AxisRules with a "stream" entry)
        switch the runtime to sharded mode: n_shards is derived from the
        mesh's stream extent, streams map to shards round-robin, each
        chunk's detector dispatch drains only its own shard's queues, and
        shard i's detector (params replicated per shard) is COMMITTED to
        mesh device i — the per-shard capacity slice corresponds to a real
        device, not an accounting fiction.

        ``faults`` (a ``FaultSchedule``) arms the chaos plane: the
        degradation ladder (``degrade``), hedged dispatch (``hedge``) and
        straggler eviction (``straggler_cfg``) all activate; without it
        the runtime behaves exactly as before (stats still collected)."""
        if (mesh is None) != (rules is None):
            raise ValueError("sharded mode needs BOTH mesh= and rules= "
                             "(got only one)")
        self._shard_infer = None
        if mesh is not None:
            from repro.distributed.stream_sharding import stream_shard_count
            cfg = dataclasses.replace(
                cfg, n_shards=stream_shard_count(mesh, rules))
        self.cfg = cfg
        self.n_shards = max(cfg.n_shards, 1)
        self.det_cfg = det_cfg
        self.costs = costs

        # params enter the jit as an ARGUMENT (closure capture would embed
        # them as constants and the computation would ignore their device)
        # In ROI mode the dispatch payload is (frames, region_scores) and
        # each row runs only its top-K gated region patches.
        roi = getattr(cfg, "roi", None)
        if roi is None:
            infer_jit = jax.jit(lambda p, frames: D.decode_boxes(
                D.forward(p, det_cfg, frames), det_cfg))
        else:
            from repro.core.roi import roi_infer
            infer_jit = jax.jit(lambda p, payload: roi_infer(
                p, det_cfg, roi, payload[0], payload[1]))
        self.roi = roi
        self.anchor_search = bool(getattr(cfg, "anchor_search", False))

        def make_infer(params, dev=None):
            # staged batches are COMMITTED (jit outputs); an explicit
            # device_put routes them onto this replica's device — required
            # for both the per-shard dispatch and the hedge backup, whose
            # input may sit on the primary's device
            if dev is None:
                return lambda frames: infer_jit(params, frames)
            return lambda frames: infer_jit(params,
                                            jax.device_put(frames, dev))

        self._infer = make_infer(detector_params)
        if mesh is not None and self.n_shards > 1:
            devs = list(mesh.devices.flat)
            self._shard_infer = [
                make_infer(jax.device_put(detector_params,
                                          devs[i % len(devs)]),
                           devs[i % len(devs)])
                for i in range(self.n_shards)]
        self.queues = PipelineQueues(cfg, self._infer_batch)
        self.admission = AdmissionController(cfg)
        self.streams: dict[int, StreamState] = {}
        self.deferred = 0
        self.deferred_by_shard = np.zeros(self.n_shards, np.int64)
        # pipeline-③ fallback accounting: frames demoted ②->③ under
        # overload, and whole chunks forced onto reuse (deep overload)
        self.demoted_frames = np.zeros(self.n_shards, np.int64)
        self.reuse_fallback_chunks = np.zeros(self.n_shards, np.int64)

        # ------------------------------------------ async dispatch plane
        self.max_inflight = max(int(getattr(cfg, "max_inflight", 2)), 1)
        self._pending: list[ChunkTicket] = []     # submitted, undispatched
        self._open: dict[int, ChunkTicket] = {}   # stream -> pending ticket
        self._inflight: dict[int, deque] = defaultdict(deque)

        # ---------------------------------------------- robustness plane
        self.faults = faults
        self.degrade = degrade or DegradeConfig(
            deadline_s=cfg.latency_budget)
        self.stats: dict[int, StreamStats] = {}
        self.active_shards: list[int] = list(range(self.n_shards))
        self.pool = ElasticPool(self.n_shards)
        self.straggler = StragglerDetector(
            straggler_cfg or DetectorConfig(), self.n_shards)
        self._hedge_cfg = hedge or HedgeConfig()
        self._hedge: HedgedExecutor | None = None
        if self.n_shards > 1 and (faults is not None or hedge is not None):
            self._rebuild_hedge()
        self.fault_log: list[tuple[int, str, str]] = []
        self._t = 0

    # ------------------------------------------------------------------
    def stream_shard(self, stream: int) -> int:
        """Owning shard for a stream — round-robin over the CURRENTLY
        active shards, so eviction re-homes streams onto survivors."""
        return self.active_shards[stream % len(self.active_shards)]

    def _shard_fn(self, shard: int):
        return self._infer if self._shard_infer is None \
            else self._shard_infer[shard]

    def _rebuild_hedge(self):
        old = self._hedge
        self._hedge = HedgedExecutor(
            self._hedge_cfg,
            [self._shard_fn(s) for s in self.active_shards])
        if old is not None:
            self._hedge.lat.extend(old.lat)
            self._hedge.hedges = old.hedges
            old.close()

    @property
    def hedged_dispatches(self) -> int:
        return 0 if self._hedge is None else self._hedge.hedges

    def _infer_batch_dev(self, frames, shard=None):
        """Shard-aware detector dispatch returning DEVICE arrays
        ``(boxes, scores)`` — nothing here blocks on the computation.
        In sharded mode the batch runs on the shard's own committed
        device (jit follows the committed params); otherwise on the
        single default-device detector.  With a fault schedule armed, the
        dispatch's simulated step time (base cost × the schedule's shard
        slowdown) feeds the straggler detector, and the call hedges
        across active shards when the primary would blow the
        latency-quantile deadline."""
        if shard is not None and self.faults is not None:
            n_rows = frames[0].shape[0] if isinstance(frames, tuple) \
                else frames.shape[0]
            base = n_rows / max(self.cfg.shard_capacity_fps, 1e-6)
            slow = self.faults.shard_slowdown(shard, self._t)
            self.straggler.record(shard, base * slow)
            if self._hedge is not None and len(self.active_shards) > 1 \
                    and shard in self.active_shards:
                idx = self.active_shards.index(shard)

                def sim(i):
                    return base * self.faults.shard_slowdown(
                        self.active_shards[i], self._t)

                out, _ = self._hedge.run(frames,
                                         simulate_latency=sim, primary=idx)
                return out
        fn = self._infer if (shard is None or self._shard_infer is None) \
            else self._shard_infer[shard]
        return fn(frames)

    def _infer_batch(self, frames, shard=None):
        """Legacy host-facing executor (``PipelineQueues.drain_fused``):
        the device dispatch plus an immediate transfer per row."""
        if self.roi is not None:
            raise RuntimeError(
                "the legacy frame-payload drain cannot run in ROI mode — "
                "region scores are staged per ticket; use "
                "submit_chunk/flush/poll (process_chunk)")
        boxes, scores = self._infer_batch_dev(jnp.asarray(frames), shard)
        return list(zip(np.asarray(boxes), np.asarray(scores)))

    # ------------------------------------------------- degradation ladder
    def _stats(self, stream: int) -> StreamStats:
        if stream not in self.stats:
            self.stats[stream] = StreamStats(stream)
        return self.stats[stream]

    def suggest_level(self, stream: int, base_level: int) -> int:
        """Ladder rung the stream should encode at: its bandwidth-derived
        rung minus any deadline-driven demotion (rung 2)."""
        st = self._stats(stream)
        return max(int(base_level) - st.rung_demotion, 0)

    def note_stall(self, stream: int, t: int):
        st = self._stats(stream)
        st.chunks_stalled += 1
        st.note(t, "stall", "camera produced no chunk")

    def note_chunk_latency(self, stream: int, t: int, latency_s: float):
        """Feed one chunk's end-to-end latency into the ladder controller:
        consecutive deadline misses demote (rung 2) then force reuse
        (rung 3); consecutive on-deadline chunks walk back up."""
        st = self._stats(stream)
        d = self.degrade
        if latency_s > d.deadline_s:
            st.deadline_misses += 1
            st._miss_streak += 1
            st._ok_streak = 0
            if st._miss_streak >= d.demote_patience:
                st._miss_streak = 0
                if st.rung_demotion < d.max_demotion:
                    st.rung_demotion += 1
                    st.demote_events += 1
                    st.note(t, "demote",
                            f"latency {latency_s:.3f}s > deadline; "
                            f"rung -{st.rung_demotion}")
                elif not st.force_reuse:
                    st.force_reuse = True
                    st.note(t, "force_reuse",
                            "bottom rung still missing deadline")
        else:
            st._ok_streak += 1
            st._miss_streak = 0
            if st._ok_streak >= d.promote_patience:
                st._ok_streak = 0
                if st.force_reuse:
                    st.force_reuse = False
                    st.note(t, "resume_infer", "deadline met; leaving "
                            "pipeline-3 fallback")
                elif st.rung_demotion > 0:
                    st.rung_demotion -= 1
                    st.promote_events += 1
                    st.note(t, "promote", f"rung -{st.rung_demotion}")

    def _deliver(self, stream: int, t: int) -> bool:
        """Rung 1: was the chunk's payload delivered (possibly after
        retries)?  Retransmissions traverse the same degraded link and
        each backoff eats deadline budget; accumulated backoff is charged
        to the chunk via ``last_penalty_s``."""
        st = self.stats[stream]
        f, d = self.faults, self.degrade
        lost = f.chunk_lost(stream, t)
        corrupt = f.chunk_corrupt(stream, t)
        if not (lost or corrupt):
            return True
        if lost:
            st.chunks_lost += 1
        if corrupt:
            st.chunks_corrupt += 1
        penalty = 0.0
        for attempt in range(d.max_retries):
            backoff = d.retry_backoff_s * (2 ** attempt)
            if penalty + backoff > d.deadline_s:
                break
            penalty += backoff
            st.retries += 1
            if f.retry_succeeds(stream, t, attempt):
                st.last_penalty_s = penalty
                st.note(t, "retry_ok",
                        f"attempt {attempt + 1}, +{penalty:.3f}s")
                return True
        st.last_penalty_s = penalty
        st.note(t, "retry_exhausted",
                f"{'lost' if lost else 'corrupt'} chunk undeliverable")
        return False

    def _skip_chunk(self, stream: int, t: int,
                    packet: HybridPacket) -> ChunkTicket:
        """Rungs 3/4 for an undeliverable chunk: hold the previous
        detections (zero-motion pipeline-③) when a carry exists, else
        drop the chunk with explicit accounting (types == 0).  The carry
        stays on device; the hold is a broadcast, not a transfer."""
        st = self.stats[stream]
        T = packet.types.shape[0]
        H, W = packet.anchor_hd.shape[1:]
        n_cells = (H // self.det_cfg.stride) * (W // self.det_cfg.stride)
        prev = self.streams.get(stream)
        tk = ChunkTicket(stream, t, self.stream_shard(stream),
                         np.zeros(T, packet.types.dtype), (H, W),
                         n_cells=n_cells, done=True)
        if prev is not None and prev.last_boxes.shape[0] == n_cells:
            tk.types = np.full(T, 3, packet.types.dtype)
            tk._dev_out = _hold_chunk(prev.last_boxes, prev.last_scores,
                                      T=T)
            st.frames_reused += T
            st.reuse_fallback_chunks += 1
            st.last_delivered = T
            st.note(t, "reuse_hold",
                    f"{T} frames held on carried detections")
            return tk
        st.frames_skipped += T
        st.last_skipped = T
        st.note(t, "frame_skip", f"{T} frames dropped (no carry)")
        tk._host = (np.zeros((T, n_cells, 4), f32),
                    np.zeros((T, n_cells), f32), tk.types)
        return tk

    def hold_chunk(self, stream: int, t: int,
                   packet: HybridPacket) -> ChunkTicket:
        """Predictive admission: withhold a chunk the forecast says the
        link cannot deliver inside the deadline, BEFORE transmitting it.
        Same degradation semantics as an undeliverable chunk (pipeline-③
        hold on the carried detections, frame-skip without a carry), but
        entered proactively by the caller's bandwidth forecast rather
        than reactively after a miss — no bits are charged and no
        deadline penalty accrues.  Accounting mirrors ``submit_chunk``
        (frames_in grows; the invariant frames_in == inferred + reused +
        skipped holds)."""
        self._t = t
        st = self._stats(stream)
        T = packet.types.shape[0]
        st.chunks += 1
        st.frames_in += T
        st.last_penalty_s = 0.0
        st.last_transmitted = False
        st.last_delivered = st.last_inferred = st.last_skipped = 0
        st.note(t, "forecast_hold",
                "predicted bandwidth below deadline; chunk withheld")
        return self._skip_chunk(stream, t, packet)

    # --------------------------------------------------- submit/flush/poll
    def submit_chunk(self, stream: int, t: int,
                     packet: HybridPacket) -> ChunkTicket:
        """Non-blocking admission of one chunk: run the host-side control
        ladder (delivery retries, forced reuse, admission/demotion), stage
        the chunk's execution planes on device, enqueue its pipeline-①/②
        requests, and return a :class:`ChunkTicket`.  No device work is
        waited on; the detector dispatch happens at ``flush`` and results
        cross to the host only at ``poll``.

        Per-stream ordering: submitting a stream's next chunk while its
        previous ticket is still pending first flushes the pipeline, so
        the pipeline-③ carry chain stays ordered (on device)."""
        self._t = t
        prev_tk = self._open.get(stream)
        if prev_tk is not None and not prev_tk.done:
            self.flush()

        st = self._stats(stream)
        T = packet.types.shape[0]
        st.chunks += 1
        st.frames_in += T
        st.last_penalty_s = 0.0
        st.last_transmitted = True
        st.last_delivered = st.last_inferred = st.last_skipped = 0

        if self.faults is not None and not self._deliver(stream, t):
            st.last_transmitted = False
            return self._skip_chunk(stream, t, packet)

        enc = packet.video
        H, W = packet.anchor_hd.shape[1:]
        types = packet.types.copy()
        prev = self.streams.get(stream)
        shard = self.stream_shard(stream)

        if st.force_reuse and prev is not None:
            # rung 3: ladder floor exhausted — whole chunk on pipeline ③
            # with the packet's REAL motion vectors (payload did arrive)
            types = np.full_like(types, 3)
            st.reuse_fallback_chunks += 1
            self.reuse_fallback_chunks[shard] += 1
            st.note(t, "reuse_chunk", "forced pipeline-3 chunk")

        n_infer = int((types != 3).sum())
        if n_infer and not self.admission.admit_shard(
                self.queues.shard_depths, shard, n_infer):
            # overload: demote transfer frames to reuse, keep chunk anchors
            self.demoted_frames[shard] += int((types == 2).sum())
            types = np.where(types == 2, 3, types)
            self.deferred += 1
            self.deferred_by_shard[shard] += 1
            st.note(t, "defer", "shard overloaded; type-2 frames demoted")
            # deep overload: if even anchors-only blows the budget AND we
            # have carried detections to reuse, the whole chunk runs on
            # pipeline ③ (the previous chunk's boxes keep tracking via MVs)
            if prev is not None and \
                    not self.admission.admit_shard(self.queues.shard_depths,
                                                   shard,
                                                   int((types != 3).sum())):
                self.demoted_frames[shard] += int((types != 3).sum())
                types = np.full_like(types, 3)
                self.reuse_fallback_chunks[shard] += 1
                st.reuse_fallback_chunks += 1
                st.note(t, "reuse_chunk", "deep overload")

        # one async dispatch stages the whole chunk on device; values stay
        # there until the poll boundary (anchor_search additionally stages
        # the per-rung bit planes in the SAME dispatch)
        frames_dev, mvs_dev, rscores_dev, rung_bits_dev = _stage_chunk(
            jnp.asarray(types), jnp.asarray(packet.anchor_hd),
            jnp.asarray(enc.recon), jnp.asarray(enc.mv),
            jnp.asarray(enc.residual_q), hd_hw=(H, W), roi=self.roi,
            anchor_search=self.anchor_search)

        n_cells = (H // self.det_cfg.stride) * (W // self.det_cfg.stride)
        tk = ChunkTicket(stream, t, shard, types, (H, W),
                         frames_dev=frames_dev, mvs_dev=mvs_dev,
                         rscores_dev=rscores_dev,
                         rung_bits_dev=rung_bits_dev,
                         init_b=None if prev is None else prev.last_boxes,
                         init_s=None if prev is None else prev.last_scores,
                         n_cells=n_cells)
        for i in range(T):
            if types[i] in (1, 2):
                req = InferRequest(stream, t, int(i), int(types[i]),
                                   None, shard=shard)
                self.queues.submit(req)
                tk.reqs.append(req)

        n_inf = int(((types == 1) | (types == 2)).sum())
        st.frames_inferred += n_inf
        st.frames_reused += int((types == 3).sum())
        st.last_inferred = n_inf
        st.last_delivered = T
        self._pending.append(tk)
        self._open[stream] = tk
        return tk

    def _dispatch_group(self, shard: int, tickets: list[ChunkTicket]):
        """Dispatch one (shard, T, H, W) signature group: gather every
        ticket's pipeline-①/② frames into one padded batch (① rows before
        ②, submit order within each, matching the legacy drain), run the
        detector asynchronously under the double-buffer cap, and finish
        each ticket's scatter+reuse on device."""
        T = int(tickets[0].types.shape[0])
        by_stream = {tk.stream: tk for tk in tickets}
        slot = {id(tk): i for i, tk in enumerate(tickets)}
        reqs = [r for tk in tickets for r in tk.reqs if r.pipeline == 1] \
            + [r for tk in tickets for r in tk.reqs if r.pipeline == 2]
        self.queues.take(reqs)

        bb = bs = None
        if reqs:
            n = len(reqs)
            n_pad = _pad_bucket(n, self.cfg.batch_size)
            flat_idx = np.zeros(n_pad, np.int32)
            valid = np.zeros(n_pad, bool)
            for j, r in enumerate(reqs):
                flat_idx[j] = slot[id(by_stream[r.stream])] * T \
                    + r.frame_idx
                valid[j] = True
            k_pad = _pad_bucket(len(tickets), 1)
            planes = tuple(tk.frames_dev for tk in tickets) \
                + (tickets[0].frames_dev,) * (k_pad - len(tickets))
            batch = _gather_batch(planes, jnp.asarray(flat_idx),
                                  jnp.asarray(valid))
            if self.roi is not None:
                rows = tuple(tk.rscores_dev for tk in tickets) \
                    + (tickets[0].rscores_dev,) * (k_pad - len(tickets))
                batch = (batch, _gather_rows(rows, jnp.asarray(flat_idx),
                                             jnp.asarray(valid)))
            q = self._inflight[shard]
            while len(q) >= self.max_inflight:
                jax.block_until_ready(q.popleft())
            bb, bs = self._infer_batch_dev(batch, shard=shard)
            if self._shard_infer is not None:
                # finish on the staging device: the carry must live on ONE
                # device regardless of which shard ran the batch
                home = next(iter(tickets[0].mvs_dev.devices()))
                bb, bs = jax.device_put((bb, bs), home)
            q.append((bb, bs))

        for tk in tickets:
            pos = np.full(T, -1, np.int32)
            for j, r in enumerate(reqs):
                if r.stream == tk.stream:
                    pos[r.frame_idx] = j
            if bb is None:
                dbb = jnp.zeros((1, tk.n_cells, 4), jnp.float32)
                dbs = jnp.zeros((1, tk.n_cells), jnp.float32)
            else:
                dbb, dbs = bb, bs
            has_init = tk.init_b is not None
            zb = jnp.zeros((tk.n_cells, 4), jnp.float32)
            zs = jnp.zeros((tk.n_cells,), jnp.float32)
            boxes, scores, last_b, last_s = _finish_chunk(
                jnp.asarray(tk.types), jnp.asarray(pos), tk.mvs_dev,
                dbb, dbs,
                tk.init_b if has_init else zb,
                tk.init_s if has_init else zs, has_init=has_init)
            self.streams[tk.stream] = StreamState(last_b, last_s)
            tk._dev_out = (boxes, scores)
            tk.done = True
            tk.frames_dev = tk.mvs_dev = tk.init_b = tk.init_s = None
            tk.rscores_dev = None
            if self._open.get(tk.stream) is tk:
                del self._open[tk.stream]

    def flush(self, shard: int | None = None):
        """Dispatch every pending ticket (optionally one shard's) —
        continuous batching: tickets submitted since the last flush form
        the NEXT padded batch-signature groups while earlier batches are
        still computing on device."""
        todo = [tk for tk in self._pending
                if not tk.done and (shard is None or tk.shard == shard)]
        groups: dict[tuple, list[ChunkTicket]] = {}
        for tk in todo:
            key = (tk.shard, int(tk.types.shape[0]), *tk.hw)
            groups.setdefault(key, []).append(tk)
        for key in sorted(groups):
            self._dispatch_group(key[0], groups[key])
        self._pending = [tk for tk in self._pending if not tk.done]

    def poll(self, ticket: ChunkTicket):
        """Block until the ticket's chunk is finished and return per-frame
        ``(boxes, scores, types)`` as host arrays — the ONE device->host
        transfer on the chunk path."""
        if ticket._host is None:
            if not ticket.done:
                self.flush()
            boxes, scores = ticket._dev_out
            ticket._host = (np.asarray(boxes), np.asarray(scores),
                            ticket.types)
            ticket._dev_out = None
        return ticket._host

    def poll_all(self, tickets):
        """Flush once, then materialize every ticket."""
        self.flush()
        return [self.poll(tk) for tk in tickets]

    # ------------------------------------------------------------------
    def process_chunk(self, stream: int, t: int, packet: HybridPacket):
        """Synchronous convenience wrapper: submit + flush + poll one
        chunk.  Returns per-frame (boxes, scores, types).

        All pipeline-①/② frames of the chunk go through ONE padded detector
        invocation on the stream's OWN mesh shard instead of one dispatch
        per frame; admission reads that shard's queue depths before the
        chunk is enqueued (a hot shard defers its streams to pipeline-③
        reuse without stalling the other shards), and pipeline ③ carries
        the previous chunk's last detections across the chunk boundary.

        With a fault schedule armed, the chunk first runs the delivery
        ladder (loss/corruption → retries → reuse-hold/frame-skip) and a
        stream in forced-reuse state routes the whole delivered chunk to
        pipeline ③.  Returned ``types`` may then contain 0 (explicitly
        skipped frames) alongside the usual 1/2/3.
        """
        return self.poll(self.submit_chunk(stream, t, packet))

    def close(self):
        """Tear down the dispatch plane: retire in-flight batches and shut
        the hedge executor's thread pool.  Idempotent."""
        for q in self._inflight.values():
            while q:
                jax.block_until_ready(q.popleft())
        if self._hedge is not None:
            self._hedge.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False

    # -------------------------------------------- eviction and recovery
    def evict_shard(self, shard: int, t: int, reason: str = "straggler"):
        """Remove a shard from service: queued requests AND pending
        (undispatched) tickets re-home onto survivor shards; future
        ``stream_shard`` routing skips it.  Batches already dispatched to
        the evicted device are kept — their results are in flight and
        identical, so admitted streams never lose work.  The LAST shard is
        never evicted (the plane degrades, it does not abandon admitted
        streams)."""
        if shard not in self.active_shards or len(self.active_shards) <= 1:
            return False
        self.pool.fail(shard)
        self.active_shards.remove(shard)
        moved = self.queues.remap_shards(self.stream_shard)
        for tk in self._pending:
            if not tk.done:
                tk.shard = self.stream_shard(tk.stream)
        self.straggler.reset(shard)
        if self._hedge is not None:
            self._rebuild_hedge()
        self.fault_log.append(
            (int(t), "evict",
             f"shard {shard} ({reason}); {moved} queued requests re-homed; "
             f"survivors {self.active_shards}"))
        return True

    def recover_shard(self, shard: int, t: int):
        if shard in self.active_shards or not 0 <= shard < self.n_shards:
            return False
        self.pool.recover(shard)
        self.active_shards = sorted(self.active_shards + [shard])
        self.straggler.reset(shard)
        if self._hedge is not None:
            self._rebuild_hedge()
        self.fault_log.append(
            (int(t), "recover",
             f"shard {shard} re-admitted; active {self.active_shards}"))
        return True

    def poll_faults(self, t: int):
        """Once-per-chunk control step: evict shards the straggler
        detector flags; re-admit evicted shards once the fault schedule
        reports them healthy (slowdown back to 1.0)."""
        self._t = t
        for shard in self.straggler.flagged():
            self.evict_shard(shard, t)
        if self.faults is not None:
            for g in range(self.n_shards):
                if g not in self.active_shards and \
                        self.faults.shard_slowdown(g, t) <= 1.0:
                    self.recover_shard(g, t)

    # ------------------------------------------------------------------
    def compute_latency(self, types: np.ndarray, bits: float,
                        bw_kbps: float, stream: int | None = None) -> dict:
        """Latency model for one chunk.  With ``stream`` given, queueing
        delay comes from that stream's shard backlog against the shard's
        capacity slice (identical to the global estimate at n_shards=1)."""
        n1 = int((types == 1).sum())
        n2 = int((types == 2).sum())
        n3 = int((types == 3).sum())
        t_comp = pipeline_cost(n1, n2, n3, self.costs)
        if stream is None:
            t_queue = float(self.queues.depths.sum()) \
                / self.cfg.gpu_capacity_fps
        else:
            shard = self.stream_shard(stream)
            t_queue = float(self.queues.shard_depths[shard].sum()) \
                / self.cfg.shard_capacity_fps
        t_trans = bits / max(bw_kbps * 1000.0, 1e-6)
        return {"t_trans": t_trans, "t_queue": t_queue, "t_comp": t_comp,
                "total": t_trans + t_queue + t_comp}
