"""BiSwift edge serving runtime: decoder -> pipelines -> results.

Binds the hybrid decoder's three pipelines to the scheduler's queues and a
(pjit-able) detector, per chunk per stream.  This is the deployable analog
of the paper's Fig. 4 right half; benchmarks/throughput.py drives it with
1..N concurrent streams to reproduce Fig. 11(a).

Robustness plane (chaos PR): when constructed with ``faults=`` (a
``repro.serving.faults.FaultSchedule``) the runtime additionally runs

  * a per-stream deadline-driven **degradation ladder** replacing silent
    deferral — lost/corrupt chunks retry with exponential backoff; streams
    that keep missing their deadline are demoted down the bitrate ladder
    (``suggest_level``), then forced onto pipeline-③ reuse, then
    frame-skipped with explicit accounting (types == 0).  Every decision
    lands in ``stats[stream]`` (a :class:`StreamStats`).
  * **straggler eviction + elastic recovery** — per-dispatch shard
    timings feed a ``StragglerDetector``; ``poll_faults`` evicts flagged
    shards from ``active_shards`` (re-homing queued requests onto
    survivors via ``PipelineQueues.remap_shards``) and re-admits them when
    the schedule says the device is healthy again.  Dispatches hedge
    across active shards through a ``HedgedExecutor``.

The accounting invariant every chaos test asserts:
``frames_in == frames_inferred + frames_reused + frames_skipped``.
"""
from __future__ import annotations

import dataclasses

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.hybrid_encoder import HybridPacket
from repro.core.hybrid_decoder import (PipelineCosts, _upscale_mvs,
                                       pipeline_cost)
from repro.codec.rate_model import QUALITY_LADDER, upscale_nearest
from repro.core.reuse import reuse_chunk
from repro.models import detection as D
from repro.serving.elastic import ElasticPool
from repro.serving.scheduler import (AdmissionController, InferRequest,
                                     PipelineQueues, ServingConfig)
from repro.serving.straggler import (DetectorConfig, HedgeConfig,
                                     HedgedExecutor, StragglerDetector)

f32 = np.float32


@dataclasses.dataclass
class StreamState:
    last_boxes: np.ndarray
    last_scores: np.ndarray


@dataclasses.dataclass(frozen=True)
class DegradeConfig:
    """Deadline ladder knobs (rungs in escalation order).

    1. retry-with-backoff — a lost/corrupt chunk is retransmitted up to
       ``max_retries`` times, backoff doubling from ``retry_backoff_s``,
       while the accumulated penalty still fits ``deadline_s``;
    2. rung demotion — ``demote_patience`` consecutive deadline misses
       drop the stream one bitrate-ladder rung (down to ``max_demotion``
       below its bandwidth-derived rung);
    3. pipeline-③ fallback — misses at the bottom rung force whole chunks
       onto motion-vector reuse (no inference);
    4. frame-skip — an undeliverable chunk with no carried detections is
       dropped with explicit accounting (types == 0).

    ``promote_patience`` consecutive on-deadline chunks walk the stream
    back up one step (reuse → inference, then rung by rung).
    """
    deadline_s: float = 1.0
    max_retries: int = 3
    retry_backoff_s: float = 0.05
    demote_patience: int = 2
    promote_patience: int = 3
    max_demotion: int = len(QUALITY_LADDER) - 1


@dataclasses.dataclass
class StreamStats:
    """Per-stream degradation accounting — every ladder decision is
    surfaced here, nothing is silent."""
    stream: int
    frames_in: int = 0
    frames_inferred: int = 0          # pipelines ① and ② (through the DNN)
    frames_reused: int = 0            # pipeline ③
    frames_skipped: int = 0           # rung 4: explicitly dropped
    chunks: int = 0
    chunks_lost: int = 0
    chunks_corrupt: int = 0
    chunks_stalled: int = 0
    retries: int = 0
    deadline_misses: int = 0
    rung_demotion: int = 0            # current ladder demotion (0 = none)
    demote_events: int = 0
    promote_events: int = 0
    reuse_fallback_chunks: int = 0
    force_reuse: bool = False         # rung 3 engaged
    events: list = dataclasses.field(default_factory=list)
    # transient per-chunk fields (the soak reads them right after a chunk)
    last_penalty_s: float = 0.0
    last_transmitted: bool = True
    last_delivered: int = 0
    last_inferred: int = 0
    last_skipped: int = 0
    _miss_streak: int = 0
    _ok_streak: int = 0

    def note(self, t: int, action: str, detail: str = ""):
        self.events.append((int(t), action, detail))

    def as_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["events"] = [list(e) for e in d["events"]]
        return {k: v for k, v in d.items() if not k.startswith("_")}


class EdgeRuntime:
    def __init__(self, cfg: ServingConfig, detector_params, det_cfg,
                 costs: PipelineCosts = PipelineCosts(), *,
                 mesh=None, rules=None, faults=None,
                 degrade: DegradeConfig | None = None,
                 hedge: HedgeConfig | None = None,
                 straggler_cfg: DetectorConfig | None = None):
        """``mesh``/``rules`` (jax Mesh + AxisRules with a "stream" entry)
        switch the runtime to sharded mode: n_shards is derived from the
        mesh's stream extent, streams map to shards round-robin, each
        chunk's detector dispatch drains only its own shard's queues, and
        shard i's detector (params replicated per shard) is COMMITTED to
        mesh device i — the per-shard capacity slice corresponds to a real
        device, not an accounting fiction.

        ``faults`` (a ``FaultSchedule``) arms the chaos plane: the
        degradation ladder (``degrade``), hedged dispatch (``hedge``) and
        straggler eviction (``straggler_cfg``) all activate; without it
        the runtime behaves exactly as before (stats still collected)."""
        if (mesh is None) != (rules is None):
            raise ValueError("sharded mode needs BOTH mesh= and rules= "
                             "(got only one)")
        self._shard_infer = None
        if mesh is not None:
            from repro.distributed.stream_sharding import stream_shard_count
            cfg = dataclasses.replace(
                cfg, n_shards=stream_shard_count(mesh, rules))
        self.cfg = cfg
        self.n_shards = max(cfg.n_shards, 1)
        self.det_cfg = det_cfg
        self.costs = costs

        # params enter the jit as an ARGUMENT (closure capture would embed
        # them as constants and the computation would ignore their device)
        infer_jit = jax.jit(lambda p, frames: D.decode_boxes(
            D.forward(p, det_cfg, frames), det_cfg))

        def make_infer(params):
            return lambda frames: infer_jit(params, frames)

        self._infer = make_infer(detector_params)
        if mesh is not None and self.n_shards > 1:
            devs = list(mesh.devices.flat)
            self._shard_infer = [
                make_infer(jax.device_put(detector_params,
                                          devs[i % len(devs)]))
                for i in range(self.n_shards)]
        self.queues = PipelineQueues(cfg, self._infer_batch)
        self.admission = AdmissionController(cfg)
        self.streams: dict[int, StreamState] = {}
        self.deferred = 0
        self.deferred_by_shard = np.zeros(self.n_shards, np.int64)
        # pipeline-③ fallback accounting: frames demoted ②->③ under
        # overload, and whole chunks forced onto reuse (deep overload)
        self.demoted_frames = np.zeros(self.n_shards, np.int64)
        self.reuse_fallback_chunks = np.zeros(self.n_shards, np.int64)

        # ---------------------------------------------- robustness plane
        self.faults = faults
        self.degrade = degrade or DegradeConfig(
            deadline_s=cfg.latency_budget)
        self.stats: dict[int, StreamStats] = {}
        self.active_shards: list[int] = list(range(self.n_shards))
        self.pool = ElasticPool(self.n_shards)
        self.straggler = StragglerDetector(
            straggler_cfg or DetectorConfig(), self.n_shards)
        self._hedge_cfg = hedge or HedgeConfig()
        self._hedge: HedgedExecutor | None = None
        if self.n_shards > 1 and (faults is not None or hedge is not None):
            self._rebuild_hedge()
        self.fault_log: list[tuple[int, str, str]] = []
        self._t = 0

    # ------------------------------------------------------------------
    def stream_shard(self, stream: int) -> int:
        """Owning shard for a stream — round-robin over the CURRENTLY
        active shards, so eviction re-homes streams onto survivors."""
        return self.active_shards[stream % len(self.active_shards)]

    def _shard_fn(self, shard: int):
        return self._infer if self._shard_infer is None \
            else self._shard_infer[shard]

    def _rebuild_hedge(self):
        old = self._hedge
        self._hedge = HedgedExecutor(
            self._hedge_cfg,
            [self._shard_fn(s) for s in self.active_shards])
        if old is not None:
            self._hedge.lat.extend(old.lat)
            self._hedge.hedges = old.hedges
            old.close()

    @property
    def hedged_dispatches(self) -> int:
        return 0 if self._hedge is None else self._hedge.hedges

    def _infer_batch(self, frames, shard=None):
        """Shard-aware detector dispatch: in sharded mode the batch runs
        on the shard's own committed device (jit follows the committed
        params); otherwise on the single default-device detector.  With a
        fault schedule armed, the dispatch's simulated step time (base
        cost × the schedule's shard slowdown) feeds the straggler
        detector, and the call hedges across active shards when the
        primary would blow the latency-quantile deadline."""
        if shard is not None and self.faults is not None:
            base = len(frames) / max(self.cfg.shard_capacity_fps, 1e-6)
            slow = self.faults.shard_slowdown(shard, self._t)
            self.straggler.record(shard, base * slow)
            if self._hedge is not None and len(self.active_shards) > 1 \
                    and shard in self.active_shards:
                idx = self.active_shards.index(shard)

                def sim(i):
                    return base * self.faults.shard_slowdown(
                        self.active_shards[i], self._t)

                out, _ = self._hedge.run(jnp.asarray(frames),
                                         simulate_latency=sim, primary=idx)
                boxes, scores = out
                return list(zip(np.asarray(boxes), np.asarray(scores)))
        fn = self._infer if (shard is None or self._shard_infer is None) \
            else self._shard_infer[shard]
        boxes, scores = fn(jnp.asarray(frames))
        return list(zip(np.asarray(boxes), np.asarray(scores)))

    # ------------------------------------------------- degradation ladder
    def _stats(self, stream: int) -> StreamStats:
        if stream not in self.stats:
            self.stats[stream] = StreamStats(stream)
        return self.stats[stream]

    def suggest_level(self, stream: int, base_level: int) -> int:
        """Ladder rung the stream should encode at: its bandwidth-derived
        rung minus any deadline-driven demotion (rung 2)."""
        st = self._stats(stream)
        return max(int(base_level) - st.rung_demotion, 0)

    def note_stall(self, stream: int, t: int):
        st = self._stats(stream)
        st.chunks_stalled += 1
        st.note(t, "stall", "camera produced no chunk")

    def note_chunk_latency(self, stream: int, t: int, latency_s: float):
        """Feed one chunk's end-to-end latency into the ladder controller:
        consecutive deadline misses demote (rung 2) then force reuse
        (rung 3); consecutive on-deadline chunks walk back up."""
        st = self._stats(stream)
        d = self.degrade
        if latency_s > d.deadline_s:
            st.deadline_misses += 1
            st._miss_streak += 1
            st._ok_streak = 0
            if st._miss_streak >= d.demote_patience:
                st._miss_streak = 0
                if st.rung_demotion < d.max_demotion:
                    st.rung_demotion += 1
                    st.demote_events += 1
                    st.note(t, "demote",
                            f"latency {latency_s:.3f}s > deadline; "
                            f"rung -{st.rung_demotion}")
                elif not st.force_reuse:
                    st.force_reuse = True
                    st.note(t, "force_reuse",
                            "bottom rung still missing deadline")
        else:
            st._ok_streak += 1
            st._miss_streak = 0
            if st._ok_streak >= d.promote_patience:
                st._ok_streak = 0
                if st.force_reuse:
                    st.force_reuse = False
                    st.note(t, "resume_infer", "deadline met; leaving "
                            "pipeline-3 fallback")
                elif st.rung_demotion > 0:
                    st.rung_demotion -= 1
                    st.promote_events += 1
                    st.note(t, "promote", f"rung -{st.rung_demotion}")

    def _deliver(self, stream: int, t: int) -> bool:
        """Rung 1: was the chunk's payload delivered (possibly after
        retries)?  Retransmissions traverse the same degraded link and
        each backoff eats deadline budget; accumulated backoff is charged
        to the chunk via ``last_penalty_s``."""
        st = self.stats[stream]
        f, d = self.faults, self.degrade
        lost = f.chunk_lost(stream, t)
        corrupt = f.chunk_corrupt(stream, t)
        if not (lost or corrupt):
            return True
        if lost:
            st.chunks_lost += 1
        if corrupt:
            st.chunks_corrupt += 1
        penalty = 0.0
        for attempt in range(d.max_retries):
            backoff = d.retry_backoff_s * (2 ** attempt)
            if penalty + backoff > d.deadline_s:
                break
            penalty += backoff
            st.retries += 1
            if f.retry_succeeds(stream, t, attempt):
                st.last_penalty_s = penalty
                st.note(t, "retry_ok",
                        f"attempt {attempt + 1}, +{penalty:.3f}s")
                return True
        st.last_penalty_s = penalty
        st.note(t, "retry_exhausted",
                f"{'lost' if lost else 'corrupt'} chunk undeliverable")
        return False

    def _skip_chunk(self, stream: int, t: int, packet: HybridPacket):
        """Rungs 3/4 for an undeliverable chunk: hold the previous
        detections (zero-motion pipeline-③) when a carry exists, else
        drop the chunk with explicit accounting (types == 0)."""
        st = self.stats[stream]
        T = packet.types.shape[0]
        H, W = packet.anchor_hd.shape[1:]
        n_cells = (H // self.det_cfg.stride) * (W // self.det_cfg.stride)
        prev = self.streams.get(stream)
        if prev is not None and prev.last_boxes.shape[0] == n_cells:
            types = np.full(T, 3, packet.types.dtype)
            boxes = np.repeat(prev.last_boxes[None], T, axis=0)
            scores = np.repeat(prev.last_scores[None], T, axis=0)
            st.frames_reused += T
            st.reuse_fallback_chunks += 1
            st.last_delivered = T
            st.note(t, "reuse_hold",
                    f"{T} frames held on carried detections")
            return boxes.astype(f32), scores.astype(f32), types
        types = np.zeros(T, packet.types.dtype)
        st.frames_skipped += T
        st.last_skipped = T
        st.note(t, "frame_skip", f"{T} frames dropped (no carry)")
        return (np.zeros((T, n_cells, 4), f32),
                np.zeros((T, n_cells), f32), types)

    # ------------------------------------------------------------------
    def process_chunk(self, stream: int, t: int, packet: HybridPacket):
        """Returns per-frame (boxes, scores, types) for one chunk.

        All pipeline-①/② frames of the chunk go through ONE padded detector
        invocation (``PipelineQueues.drain_fused``) on the stream's OWN
        mesh shard instead of one dispatch per frame; admission reads that
        shard's queue depths before the chunk is enqueued (a hot shard
        defers its streams to pipeline-③ reuse without stalling the other
        shards), and pipeline ③ carries the previous chunk's last
        detections across the chunk boundary.

        With a fault schedule armed, the chunk first runs the delivery
        ladder (loss/corruption → retries → reuse-hold/frame-skip) and a
        stream in forced-reuse state routes the whole delivered chunk to
        pipeline ③.  Returned ``types`` may then contain 0 (explicitly
        skipped frames) alongside the usual 1/2/3.
        """
        self._t = t
        st = self._stats(stream)
        T = packet.types.shape[0]
        st.chunks += 1
        st.frames_in += T
        st.last_penalty_s = 0.0
        st.last_transmitted = True
        st.last_delivered = st.last_inferred = st.last_skipped = 0

        if self.faults is not None and not self._deliver(stream, t):
            st.last_transmitted = False
            return self._skip_chunk(stream, t, packet)

        enc = packet.video
        H, W = packet.anchor_hd.shape[1:]
        types = packet.types.copy()
        prev = self.streams.get(stream)
        shard = self.stream_shard(stream)

        if st.force_reuse and prev is not None:
            # rung 3: ladder floor exhausted — whole chunk on pipeline ③
            # with the packet's REAL motion vectors (payload did arrive)
            types = np.full_like(types, 3)
            st.reuse_fallback_chunks += 1
            self.reuse_fallback_chunks[shard] += 1
            st.note(t, "reuse_chunk", "forced pipeline-3 chunk")

        n_infer = int((types != 3).sum())
        if n_infer and not self.admission.admit_shard(
                self.queues.shard_depths, shard, n_infer):
            # overload: demote transfer frames to reuse, keep chunk anchors
            self.demoted_frames[shard] += int((types == 2).sum())
            types = np.where(types == 2, 3, types)
            self.deferred += 1
            self.deferred_by_shard[shard] += 1
            st.note(t, "defer", "shard overloaded; type-2 frames demoted")
            # deep overload: if even anchors-only blows the budget AND we
            # have carried detections to reuse, the whole chunk runs on
            # pipeline ③ (the previous chunk's boxes keep tracking via MVs)
            if prev is not None and \
                    not self.admission.admit_shard(self.queues.shard_depths,
                                                   shard,
                                                   int((types != 3).sum())):
                self.demoted_frames[shard] += int((types != 3).sum())
                types = np.full_like(types, 3)
                self.reuse_fallback_chunks[shard] += 1
                st.reuse_fallback_chunks += 1
                st.note(t, "reuse_chunk", "deep overload")

        mvs_hd = np.asarray(_upscale_mvs(enc.mv, (H, W)))

        # submit pipeline ①/② frames; one fused padded dispatch for all.
        # lr_up is computed lazily: when overload demoted every type-2
        # frame, the shed-load path skips the whole-chunk upscale entirely
        lr_up = None
        for i in range(T):
            if types[i] == 1:
                self.queues.submit(InferRequest(stream, t, i, 1,
                                                packet.anchor_hd[i],
                                                shard=shard))
            elif types[i] == 2:
                if lr_up is None:
                    lr_up = np.asarray(upscale_nearest(enc.recon, H, W))
                self.queues.submit(InferRequest(stream, t, i, 2, lr_up[i],
                                                shard=shard))
        done = self.queues.drain_fused(shard=shard)

        # collect per-frame detections; pipeline ③ reuse fills the gaps
        n_cells = (H // self.det_cfg.stride) * (W // self.det_cfg.stride)
        boxes_t = np.zeros((T, n_cells, 4), f32)
        scores_t = np.zeros((T, n_cells), f32)
        for req, (b, s) in done:
            if req.stream == stream and req.chunk_t == t:
                boxes_t[req.frame_idx] = b
                scores_t[req.frame_idx] = s

        # pipeline-③ carry: seed reuse with the previous chunk's last boxes
        init_b = jnp.asarray(prev.last_boxes) if prev is not None else None
        init_s = jnp.asarray(prev.last_scores) if prev is not None else None
        boxes, scores = reuse_chunk(jnp.asarray(types), jnp.asarray(mvs_hd),
                                    jnp.asarray(boxes_t),
                                    jnp.asarray(scores_t),
                                    init_boxes=init_b, init_scores=init_s)
        self.streams[stream] = StreamState(last_boxes=np.asarray(boxes[-1]),
                                           last_scores=np.asarray(scores[-1]))
        n_inf = int(((types == 1) | (types == 2)).sum())
        st.frames_inferred += n_inf
        st.frames_reused += int((types == 3).sum())
        st.last_inferred = n_inf
        st.last_delivered = T
        return np.asarray(boxes), np.asarray(scores), types

    # -------------------------------------------- eviction and recovery
    def evict_shard(self, shard: int, t: int, reason: str = "straggler"):
        """Remove a shard from service: queued requests re-home onto
        survivor shards and future ``stream_shard`` routing skips it.
        The LAST shard is never evicted (the plane degrades, it does not
        abandon admitted streams)."""
        if shard not in self.active_shards or len(self.active_shards) <= 1:
            return False
        self.pool.fail(shard)
        self.active_shards.remove(shard)
        moved = self.queues.remap_shards(self.stream_shard)
        self.straggler.reset(shard)
        if self._hedge is not None:
            self._rebuild_hedge()
        self.fault_log.append(
            (int(t), "evict",
             f"shard {shard} ({reason}); {moved} queued requests re-homed; "
             f"survivors {self.active_shards}"))
        return True

    def recover_shard(self, shard: int, t: int):
        if shard in self.active_shards or not 0 <= shard < self.n_shards:
            return False
        self.pool.recover(shard)
        self.active_shards = sorted(self.active_shards + [shard])
        self.straggler.reset(shard)
        if self._hedge is not None:
            self._rebuild_hedge()
        self.fault_log.append(
            (int(t), "recover",
             f"shard {shard} re-admitted; active {self.active_shards}"))
        return True

    def poll_faults(self, t: int):
        """Once-per-chunk control step: evict shards the straggler
        detector flags; re-admit evicted shards once the fault schedule
        reports them healthy (slowdown back to 1.0)."""
        self._t = t
        for shard in self.straggler.flagged():
            self.evict_shard(shard, t)
        if self.faults is not None:
            for g in range(self.n_shards):
                if g not in self.active_shards and \
                        self.faults.shard_slowdown(g, t) <= 1.0:
                    self.recover_shard(g, t)

    # ------------------------------------------------------------------
    def compute_latency(self, types: np.ndarray, bits: float,
                        bw_kbps: float, stream: int | None = None) -> dict:
        """Latency model for one chunk.  With ``stream`` given, queueing
        delay comes from that stream's shard backlog against the shard's
        capacity slice (identical to the global estimate at n_shards=1)."""
        n1 = int((types == 1).sum())
        n2 = int((types == 2).sum())
        n3 = int((types == 3).sum())
        t_comp = pipeline_cost(n1, n2, n3, self.costs)
        if stream is None:
            t_queue = float(self.queues.depths.sum()) \
                / self.cfg.gpu_capacity_fps
        else:
            shard = self.stream_shard(stream)
            t_queue = float(self.queues.shard_depths[shard].sum()) \
                / self.cfg.shard_capacity_fps
        t_trans = bits / max(bw_kbps * 1000.0, 1e-6)
        return {"t_trans": t_trans, "t_queue": t_queue, "t_comp": t_comp,
                "total": t_trans + t_queue + t_comp}
