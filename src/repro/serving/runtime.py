"""BiSwift edge serving runtime: decoder -> pipelines -> results.

Binds the hybrid decoder's three pipelines to the scheduler's queues and a
(pjit-able) detector, per chunk per stream.  This is the deployable analog
of the paper's Fig. 4 right half; benchmarks/throughput.py drives it with
1..N concurrent streams to reproduce Fig. 11(a).
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.hybrid_encoder import HybridPacket
from repro.core.hybrid_decoder import PipelineCosts, _upscale_mvs
from repro.codec.rate_model import upscale_nearest
from repro.core.reuse import reuse_chunk
from repro.models import detection as D
from repro.serving.scheduler import (AdmissionController, InferRequest,
                                     PipelineQueues, ServingConfig)

f32 = np.float32


@dataclasses.dataclass
class StreamState:
    last_boxes: np.ndarray
    last_scores: np.ndarray


class EdgeRuntime:
    def __init__(self, cfg: ServingConfig, detector_params, det_cfg,
                 costs: PipelineCosts = PipelineCosts()):
        self.cfg = cfg
        self.det_cfg = det_cfg
        self.costs = costs
        self._infer = jax.jit(
            lambda frames: D.decode_boxes(
                D.forward(detector_params, det_cfg, frames), det_cfg))
        self.queues = PipelineQueues(cfg, self._infer_batch)
        self.admission = AdmissionController(cfg)
        self.streams: dict[int, StreamState] = {}
        self.deferred = 0

    def _infer_batch(self, frames):
        boxes, scores = self._infer(jnp.asarray(frames))
        return list(zip(np.asarray(boxes), np.asarray(scores)))

    # ------------------------------------------------------------------
    def process_chunk(self, stream: int, t: int, packet: HybridPacket):
        """Returns per-frame (boxes, scores, types) for one chunk."""
        enc = packet.video
        T = packet.types.shape[0]
        H, W = packet.anchor_hd.shape[1:]
        types = packet.types.copy()

        n_infer = int((types != 3).sum())
        if not self.admission.admit(self.queues.depths, n_infer):
            # overload: demote transfer frames to reuse, keep chunk anchors
            types = np.where(types == 2, 3, types)
            self.deferred += 1

        lr_up = np.asarray(upscale_nearest(enc.recon, H, W))
        mvs_hd = np.asarray(_upscale_mvs(enc.mv, (H, W)))

        # submit pipeline ①/② frames
        for i in range(T):
            if types[i] == 1:
                self.queues.submit(InferRequest(stream, t, i, 1,
                                                packet.anchor_hd[i]))
            elif types[i] == 2:
                self.queues.submit(InferRequest(stream, t, i, 2, lr_up[i]))
        done = self.queues.drain()

        # collect per-frame detections; pipeline ③ reuse fills the gaps
        n_cells = (H // self.det_cfg.stride) * (W // self.det_cfg.stride)
        boxes_t = np.zeros((T, n_cells, 4), f32)
        scores_t = np.zeros((T, n_cells), f32)
        for req, (b, s) in done:
            if req.stream == stream and req.chunk_t == t:
                boxes_t[req.frame_idx] = b
                scores_t[req.frame_idx] = s
        boxes, scores = reuse_chunk(jnp.asarray(types), jnp.asarray(mvs_hd),
                                    jnp.asarray(boxes_t),
                                    jnp.asarray(scores_t))
        st = self.streams.setdefault(stream, StreamState(
            last_boxes=np.asarray(boxes[-1]),
            last_scores=np.asarray(scores[-1])))
        st.last_boxes = np.asarray(boxes[-1])
        st.last_scores = np.asarray(scores[-1])
        return np.asarray(boxes), np.asarray(scores), types

    # ------------------------------------------------------------------
    def compute_latency(self, types: np.ndarray, bits: float,
                        bw_kbps: float) -> dict:
        c = self.costs
        n1 = int((types == 1).sum())
        n2 = int((types == 2).sum())
        n3 = int((types == 3).sum())
        t_comp = (n1 * (c.infer + c.decode_hd)
                  + n2 * (c.infer + c.transfer + c.decode_video)
                  + n3 * c.reuse)
        t_queue = float(self.queues.depths.sum()) / self.cfg.gpu_capacity_fps
        t_trans = bits / max(bw_kbps * 1000.0, 1e-6)
        return {"t_trans": t_trans, "t_queue": t_queue, "t_comp": t_comp,
                "total": t_trans + t_queue + t_comp}
