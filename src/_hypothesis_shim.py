"""Minimal deterministic stand-in for the ``hypothesis`` package.

The container this repo runs in does not ship ``hypothesis`` and installing
packages is off-limits.  ``tests/conftest.py`` registers this module as
``hypothesis`` in ``sys.modules`` ONLY when the real package is absent, so
a genuine install always wins (the module is deliberately named
``_hypothesis_shim`` so it can never shadow the real distribution).

Semantics: ``@given`` enumerates a fixed, deterministic set of examples per
strategy — the domain boundaries first (where codec/kernel edge cases live),
then seeded pseudo-random interior points up to ``max_examples``.  No
shrinking, no database; a failing example's kwargs are attached to the
assertion message so it can be replayed by hand.
"""
from __future__ import annotations

import itertools
import types

import numpy as _np

__version__ = "0.0-repro-shim"


class _Strategy:
    """A strategy = boundary examples + a seeded sampler."""

    def __init__(self, boundary, sampler):
        self.boundary = list(boundary)
        self.sampler = sampler


def _integers(min_value, max_value):
    lo, hi = int(min_value), int(max_value)
    mid = (lo + hi) // 2
    bound = [lo, hi, mid, 0 if lo <= 0 <= hi else lo]
    return _Strategy(bound, lambda rng: int(rng.integers(lo, hi + 1)))


def _floats(min_value, max_value, **_kw):
    lo, hi = float(min_value), float(max_value)
    bound = [lo, hi, (lo + hi) / 2.0]
    return _Strategy(bound, lambda rng: float(rng.uniform(lo, hi)))


def _lists(elements, min_size=0, max_size=10):
    def sample(rng):
        n = int(rng.integers(min_size, max_size + 1))
        return [elements.sampler(rng) for _ in range(n)]

    bound = []
    if min_size <= 1 <= max_size:
        # one singleton per distinct boundary (sampled_from may have < 2)
        for b in elements.boundary[:2]:
            bound.append([b])
    bound.append([elements.boundary[0]] * max_size)
    return _Strategy(bound, sample)


def _booleans():
    return _Strategy([False, True], lambda rng: bool(rng.integers(0, 2)))


def _sampled_from(options):
    opts = list(options)
    return _Strategy(opts[:2], lambda rng: opts[int(rng.integers(len(opts)))])


strategies = types.ModuleType("hypothesis.strategies")
strategies.integers = _integers
strategies.floats = _floats
strategies.lists = _lists
strategies.booleans = _booleans
strategies.sampled_from = _sampled_from


def settings(deadline=None, max_examples=10, **_kw):
    def deco(fn):
        fn._shim_max_examples = max_examples
        return fn

    return deco


def given(**strats):
    names = sorted(strats)

    def deco(fn):
        def runner():
            n = getattr(runner, "_shim_max_examples",
                        getattr(fn, "_shim_max_examples", 10))
            rng = _np.random.default_rng(0)
            # boundary cross-product first (capped), then random interior
            combos = list(itertools.islice(
                itertools.product(*(strats[k].boundary for k in names)),
                max(n // 2, 1)))
            while len(combos) < n:
                combos.append(tuple(strats[k].sampler(rng) for k in names))
            for combo in combos[:n]:
                kwargs = dict(zip(names, combo))
                try:
                    fn(**kwargs)
                except AssertionError as e:
                    raise AssertionError(
                        f"falsifying example {kwargs!r}: {e}") from e

        runner.__name__ = fn.__name__
        runner.__doc__ = fn.__doc__
        runner.__module__ = fn.__module__
        runner._shim_max_examples = getattr(fn, "_shim_max_examples", None) \
            or 10
        return runner

    return deco
